//! Drift-scenario integration suite: generator determinism, the
//! windowed-recall reconciliation invariants, and the headline
//! acceptance property — an abrupt-drift scenario driven end to end
//! through the `streamrec experiment` path shows windowed recall
//! dipping at the drift point and recovering, for both the central
//! baseline and a distributed grid.

use std::path::PathBuf;

use streamrec::config::RunConfig;
use streamrec::coordinator::run_pipeline;
use streamrec::data::drift::{DriftConfig, DriftKind, DriftStream};
use streamrec::data::synth::SyntheticConfig;
use streamrec::data::types::Rating;
use streamrec::experiments::{run_scenario, Scenario};
use streamrec::util::json::Json;
use streamrec::util::proptest::forall;

/// Property: every drift shape is a pure function of (seed, config) —
/// two streams built the same way are element-identical, whatever the
/// shape and wherever its schedule lands.
#[test]
fn drift_streams_replay_deterministically() {
    forall("drift_determinism", 24, |rng| {
        let at = rng.next_bounded(90) as f64 / 100.0;
        let kind = match rng.next_bounded(6) {
            0 => DriftKind::Abrupt { at },
            1 => DriftKind::Rotate { start: at, end: (at + 0.3).min(1.0) },
            2 => DriftKind::Recurring {
                period_events: 100 + rng.next_bounded(900),
            },
            3 => DriftKind::Invert { at },
            4 => DriftKind::Churn {
                at,
                fraction: rng.next_bounded(100) as f64 / 100.0,
            },
            _ => DriftKind::Burst {
                at,
                len: 0.2,
                factor: 1.0 + rng.next_bounded(16) as f64,
            },
        };
        let seed = rng.next_bounded(1 << 30);
        let make = || {
            DriftStream::new(
                SyntheticConfig::netflix_like(1500, seed),
                DriftConfig { kind: Some(kind) },
            )
            .collect::<Vec<Rating>>()
        };
        let a = make();
        let b = make();
        assert_eq!(a, b, "seed {seed} / {kind:?} must replay identically");
        assert_eq!(a.len(), 1500);
    });
}

/// The windowed series is an exact re-bucketing of the cumulative
/// outcomes: sums reconcile with the lifetime totals, the weighted mean
/// of window recalls is the average recall, and changing the window
/// size never changes the underlying hit sequence.
#[test]
fn windowed_recall_reconciles_with_cumulative_curve() {
    let events: Vec<Rating> = DriftStream::new(
        SyntheticConfig::netflix_like(4000, 21),
        DriftConfig::from_toml("[drift]\nkind = \"abrupt\"\nat = 0.5").unwrap(),
    )
    .collect();
    let mut reports = Vec::new();
    for window in [250usize, 500] {
        let cfg = RunConfig {
            recall_window: window,
            sample_every: 100,
            ..RunConfig::default()
        };
        let report =
            run_pipeline(&cfg, &events, &format!("t-w{window}")).unwrap();
        let w_events: u64 =
            report.windowed_recall.iter().map(|w| w.events).sum();
        let w_hits: u64 = report.windowed_recall.iter().map(|w| w.hits).sum();
        assert_eq!(w_events, report.events, "window={window}");
        assert_eq!(w_hits, report.hits, "window={window}");
        let weighted: f64 = report
            .windowed_recall
            .iter()
            .map(|w| w.recall() * w.events as f64)
            .sum::<f64>()
            / report.events as f64;
        assert!(
            (weighted - report.avg_recall).abs() < 1e-9,
            "window={window}: weighted mean must equal avg recall"
        );
        // Per-worker windows cover the same totals.
        let worker_events: u64 = report
            .workers
            .iter()
            .flat_map(|w| &w.windows)
            .map(|w| w.events)
            .sum();
        assert_eq!(worker_events, report.events, "window={window}");
        reports.push(report);
    }
    // The window size is a *view* parameter: the evaluated hit sequence
    // (and therefore the lifetime totals) is identical underneath.
    assert_eq!(reports[0].hits, reports[1].hits);
    assert_eq!(reports[0].events, reports[1].events);
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("streamrec_drift_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Acceptance: the declarative driver runs a baseline-vs-distributed
/// abrupt-drift grid end to end, writes `BENCH_drift.json` and the
/// per-window CSVs, and every run's windowed recall dips at the drift
/// point and climbs back.
#[test]
fn abrupt_drift_scenario_dips_and_recovers_end_to_end() {
    let dir = temp_dir("abrupt");
    let toml = format!(
        r#"
        [experiment]
        name = "abrupt-accept"
        events = 20000
        seed = 11
        datasets = "ml-like"
        algorithms = "isgd"
        topologies = "1,2"
        window_events = 1000
        out_dir = "{out}"
        bench_out = "{bench}"

        [drift]
        kind = "abrupt"
        at = 0.5
        "#,
        out = dir.join("windows").display(),
        bench = dir.join("BENCH_drift.json").display(),
    );
    let scenario_path = dir.join("scenario.toml");
    std::fs::write(&scenario_path, toml).unwrap();

    let scenario = Scenario::from_file(&scenario_path).unwrap();
    assert_eq!(scenario.drift_seq(), Some(10_000));
    let outcome = run_scenario(&scenario).unwrap();
    assert_eq!(outcome.runs.len(), 2, "baseline + ni2");

    for run in &outcome.runs {
        assert_eq!(run.report.events, 20_000, "{}", run.label);
        let resp = run
            .response
            .unwrap_or_else(|| panic!("{}: drift response missing", run.label));
        assert_eq!(resp.drift_window, 10, "{}", run.label);
        assert!(
            resp.pre > 0.03,
            "{}: model must have learned something pre-drift (pre={})",
            run.label,
            resp.pre
        );
        assert!(
            resp.dip < 0.6 * resp.pre,
            "{}: windowed recall must dip at the drift point \
             (pre={} dip={})",
            run.label,
            resp.pre,
            resp.dip
        );
        assert!(
            resp.recovered > resp.dip,
            "{}: windowed recall must recover after the dip \
             (dip={} recovered={})",
            run.label,
            resp.dip,
            resp.recovered
        );
        // Per-window CSV exists and has one row per window + header.
        let csv = dir.join("windows").join(format!("{}_windows.csv", run.label));
        let text = std::fs::read_to_string(&csv)
            .unwrap_or_else(|e| panic!("{}: {e}", csv.display()));
        assert_eq!(
            text.lines().count(),
            1 + run.report.windowed_recall.len(),
            "{}",
            run.label
        );
        assert!(text.starts_with("window,start_seq,events,hits,recall"));
    }

    // The JSON summary exists, parses, and carries the drift columns.
    let bench = std::fs::read_to_string(dir.join("BENCH_drift.json")).unwrap();
    let doc = Json::parse(&bench).unwrap();
    assert_eq!(doc.get("scenario").unwrap().as_str(), Some("abrupt-accept"));
    assert_eq!(doc.get("drift").unwrap().as_str(), Some("abrupt"));
    let rows = doc.get("rows").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 2);
    for row in rows {
        assert!(row.get("pre_drift_recall").is_some());
        assert!(row.get("dip_recall").is_some());
        assert!(row.get("recovered_recall").is_some());
        assert!(row.get("avg_recall").unwrap().as_f64().unwrap() > 0.0);
    }

    // Baseline and distributed both present, comparable by label.
    let labels: Vec<&str> =
        outcome.runs.iter().map(|r| r.label.as_str()).collect();
    assert!(labels.iter().any(|l| l.contains("-ni1-")));
    assert!(labels.iter().any(|l| l.contains("-ni2-")));
}

/// The scenario driver composes with the PR 3/4 runtime: a mid-stream
/// rescale and a chaos kill inside one drifted grid run, with the
/// windowed accounting still exact.
#[test]
fn scenario_survives_rescale_and_chaos_kill() {
    let dir = temp_dir("chaos");
    let toml = format!(
        r#"
        [experiment]
        name = "chaos-rescale"
        events = 6000
        seed = 5
        datasets = "nf-like"
        algorithms = "isgd"
        topologies = "2"
        window_events = 500
        out_dir = "{out}"
        bench_out = "{bench}"

        [drift]
        kind = "churn"
        at = 0.5
        fraction = 0.4

        [rescale]
        at = 0.4
        to_n_i = 4

        [fault]
        checkpoint_interval = 64
        chaos_kill_at = 0.75
        "#,
        out = dir.join("windows").display(),
        bench = dir.join("BENCH_drift.json").display(),
    );
    let path = dir.join("scenario.toml");
    std::fs::write(&path, toml).unwrap();
    let scenario = Scenario::from_file(&path).unwrap();
    // The kill fraction resolves against the stream length at run time
    // (0.75 * 6000 = seq 4500).
    assert_eq!(scenario.chaos_kill_at, Some(0.75));
    let outcome = run_scenario(&scenario).unwrap();
    assert_eq!(outcome.runs.len(), 2, "baseline + ni2");

    for run in &outcome.runs {
        assert_eq!(run.report.events, 6000, "{}", run.label);
        assert_eq!(
            run.report.recoveries, 1,
            "{}: the chaos kill must fire and be recovered",
            run.label
        );
        let w_events: u64 =
            run.report.windowed_recall.iter().map(|w| w.events).sum();
        assert_eq!(
            w_events, 6000,
            "{}: windowed accounting exact across crash + cutover",
            run.label
        );
        if run.n_i == 1 {
            assert_eq!(run.report.rescales, 0, "baseline is never rescaled");
        } else {
            assert_eq!(run.report.rescales, 1, "{}", run.label);
            assert_eq!(run.report.n_workers, 16, "ended at n_i=4");
        }
    }
}
