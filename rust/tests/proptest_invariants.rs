//! Property-based invariant tests across modules (the proptest-crate
//! substitute; see util::proptest). Coordinator invariants — routing,
//! batching/channel delivery, state management — per DESIGN.md §5.

use std::collections::{HashMap, HashSet};

use streamrec::config::Topology;
use streamrec::coordinator::Router;
use streamrec::engine::bounded;
use streamrec::eval::MovingRecall;
use streamrec::state::{SweepKind, TrackedMap, VectorSlab};
use streamrec::util::proptest::forall;
use streamrec::util::rng::Pcg32;

#[test]
fn routing_stable_under_replication_growth() {
    // For fixed w=0, a user's column id (u mod n_i) and an item's row id
    // (i mod n_i) fully determine the worker; growing n_i re-partitions
    // but never routes outside [0, n_c).
    forall("routing_growth", 200, |rng| {
        let u = rng.next_u64();
        let i = rng.next_u64();
        for n_i in 1..=8u64 {
            let r = Router::new(Topology::new(n_i, 0).unwrap());
            let k = r.route(u, i);
            assert!(k < r.n_c());
            assert_eq!(k as u64, (i % n_i) * n_i + (u % n_i));
        }
    });
}

#[test]
fn item_replicas_cover_all_user_columns() {
    // Every user column must find a replica of every item somewhere —
    // otherwise some pairs would be unroutable (the paper's "each
    // user-item pair hits only one node" presumes exactly this cover).
    forall("replica_cover", 100, |rng| {
        let n_i = 1 + rng.next_bounded(6);
        let w = rng.next_bounded(3);
        let r = Router::new(Topology::new(n_i, w).unwrap());
        let item = rng.next_u64();
        let replicas = r.item_workers(item);
        let cols: HashSet<usize> =
            replicas.iter().map(|&k| k % r.n_ciw() as usize).collect();
        assert_eq!(cols.len(), r.n_ciw() as usize);
    });
}

#[test]
fn channel_preserves_per_sender_fifo() {
    forall("channel_fifo", 20, |rng| {
        let senders = 1 + rng.next_bounded(4) as usize;
        let per = 200 + rng.next_bounded(300) as usize;
        let cap = 1 + rng.next_bounded(64) as usize;
        let (tx, rx) = bounded::<(usize, usize)>(cap);
        let mut handles = Vec::new();
        for s in 0..senders {
            let tx = tx.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per {
                    tx.send((s, i)).unwrap();
                }
            }));
        }
        drop(tx);
        let mut last: HashMap<usize, isize> = HashMap::new();
        let mut count = 0;
        while let Some((s, i)) = rx.recv() {
            let prev = last.entry(s).or_insert(-1);
            assert!(
                (i as isize) > *prev,
                "sender {s}: {i} arrived after {prev}"
            );
            *prev = i as isize;
            count += 1;
        }
        assert_eq!(count, senders * per);
        for h in handles {
            h.join().unwrap();
        }
    });
}

#[test]
fn slab_mirrors_reference_map_under_random_ops() {
    // The slab (insert/remove/touch/sweep) must agree with a naive
    // HashMap model under arbitrary operation sequences.
    forall("slab_vs_model", 60, |rng| {
        let k = 4;
        let mut slab = VectorSlab::new(k);
        let mut model: HashMap<u64, Vec<f32>> = HashMap::new();
        for step in 0..400u64 {
            let id = rng.next_bounded(64);
            match rng.next_bounded(4) {
                0 => {
                    if !model.contains_key(&id) {
                        let v: Vec<f32> =
                            (0..k).map(|_| rng.next_f32()).collect();
                        slab.insert(id, &v, step);
                        model.insert(id, v);
                    }
                }
                1 => {
                    assert_eq!(
                        slab.remove(id),
                        model.remove(&id).is_some()
                    );
                }
                2 => {
                    if let Some(v) = model.get_mut(&id) {
                        v[0] += 1.0;
                        slab.touch_mut(id, step).unwrap()[0] += 1.0;
                    } else {
                        assert!(slab.touch_mut(id, step).is_none());
                    }
                }
                _ => {
                    // Read check.
                    match model.get(&id) {
                        Some(v) => assert_eq!(slab.get(id).unwrap(), &v[..]),
                        None => assert!(slab.get(id).is_none()),
                    }
                }
            }
            assert_eq!(slab.len(), model.len());
        }
        // Validity mask agrees with membership.
        let live = slab.iter_ids().count();
        assert_eq!(live, model.len());
        let mask_live =
            slab.valid().iter().filter(|&&v| v == 1.0).count();
        assert_eq!(mask_live, model.len());
    });
}

#[test]
fn lru_sweep_equals_filter_on_reference_model() {
    forall("lru_vs_model", 60, |rng| {
        let mut map: TrackedMap<u64, ()> = TrackedMap::new();
        let mut model: HashMap<u64, u64> = HashMap::new(); // id -> last_ts
        for _ in 0..300 {
            let id = rng.next_bounded(100);
            let ts = rng.next_bounded(10_000);
            if model.contains_key(&id) {
                map.touch_mut(&id, ts);
                // Last-write-wins: stream time is monotone in the real
                // pipeline, so touch_mut records the newest event's ts.
                model.insert(id, ts);
            } else {
                map.insert(id, (), ts);
                model.insert(id, ts);
            }
        }
        let cutoff = rng.next_bounded(10_000);
        let mut dead = map.sweep_lru(cutoff);
        dead.sort_unstable();
        let mut want: Vec<u64> = model
            .iter()
            .filter(|(_, &ts)| ts < cutoff)
            .map(|(&id, _)| id)
            .collect();
        want.sort_unstable();
        assert_eq!(dead, want);
    });
}

#[test]
fn moving_recall_equals_naive_window_average() {
    forall("recall_window", 80, |rng| {
        let window = 1 + rng.next_bounded(50) as usize;
        let mut mr = MovingRecall::new(window);
        let mut history: Vec<bool> = Vec::new();
        for _ in 0..rng.next_bounded(300) {
            let hit = rng.next_f32() < 0.3;
            mr.push(hit);
            history.push(hit);
            let tail: Vec<&bool> =
                history.iter().rev().take(window).collect();
            let want = tail.iter().filter(|&&&h| h).count() as f64
                / tail.len() as f64;
            assert!((mr.value() - want).abs() < 1e-12);
        }
    });
}

#[test]
fn touch_timestamps_never_move_backwards_in_sweep_order() {
    // Sweeping with increasing cutoffs is monotone: entries evicted at a
    // lower cutoff cannot survive a higher one.
    forall("sweep_monotone", 40, |rng| {
        let build = |rng: &mut Pcg32| {
            let mut slab = VectorSlab::new(2);
            for id in 0..50u64 {
                slab.insert(id, &[0.0, 0.0], rng.next_bounded(1000));
            }
            slab
        };
        let mut rng2 = rng.clone();
        let mut a = build(rng);
        let mut b = build(&mut rng2);
        let c1 = 300;
        let c2 = 700;
        let dead_low: HashSet<u64> = a.sweep_lru(c1).into_iter().collect();
        let dead_high: HashSet<u64> = b.sweep_lru(c2).into_iter().collect();
        assert!(dead_low.is_subset(&dead_high));
    });
}

#[test]
fn lfu_sweep_respects_min_freq_boundary() {
    forall("lfu_boundary", 60, |rng| {
        let mut map: TrackedMap<u64, ()> = TrackedMap::new();
        let mut touches: HashMap<u64, u64> = HashMap::new();
        for id in 0..40u64 {
            map.insert(id, (), 0);
            let extra = rng.next_bounded(5);
            for _ in 0..extra {
                map.touch_mut(&id, 1);
            }
            touches.insert(id, 1 + extra);
        }
        let min_freq = 1 + rng.next_bounded(5);
        let dead: HashSet<u64> =
            map.sweep_lfu(min_freq).into_iter().collect();
        for (id, freq) in touches {
            assert_eq!(
                dead.contains(&id),
                freq < min_freq,
                "id={id} freq={freq} min={min_freq}"
            );
        }
    });
}

#[test]
fn sweep_kind_roundtrip_on_models() {
    // Smoke: both sweep kinds apply cleanly to both algorithms.
    use streamrec::algorithms::{CosineModel, StreamingRecommender};
    forall("sweep_kinds", 20, |rng| {
        let mut m = CosineModel::new(10);
        for step in 0..200u64 {
            m.update(&streamrec::data::types::Rating::new(
                rng.next_bounded(20),
                rng.next_bounded(30),
                5.0,
                step,
            ));
        }
        let before = m.state_sizes().total();
        let kind = if rng.next_f32() < 0.5 {
            SweepKind::Lru { cutoff_ts: 100 }
        } else {
            SweepKind::Lfu { min_freq: 3 }
        };
        let evicted = m.sweep(kind);
        let after = m.state_sizes().total();
        assert!(after <= before);
        assert!(evicted <= before);
    });
}
