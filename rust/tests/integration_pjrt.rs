//! Integration tests for the AOT/PJRT path: artifact loading, numeric
//! agreement with the native backend, and the full pipeline over PJRT.
//!
//! These tests need `make artifacts` to have run; they fail with an
//! actionable message otherwise (the Makefile `test` target guarantees
//! the ordering).

use streamrec::config::{Backend, RunConfig, Topology};
use streamrec::coordinator::run_pipeline;
use streamrec::data::synth::{SyntheticConfig, SyntheticStream};
use streamrec::runtime::{Manifest, NativeBackend, PjrtEngine, ScoringBackend};
use streamrec::state::VectorSlab;
use streamrec::util::rng::Pcg32;

fn artifacts_dir() -> String {
    // Tests run from the crate root.
    "artifacts".to_string()
}

fn require_artifacts() -> bool {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        true
    } else {
        eprintln!("SKIP: artifacts/manifest.json missing (run `make artifacts`)");
        false
    }
}

#[test]
fn manifest_covers_every_declared_bucket() {
    if !require_artifacts() {
        return;
    }
    let m = Manifest::load(artifacts_dir()).unwrap();
    assert_eq!(m.latent_k, 10);
    assert!(m.topn_overfetch >= 50);
    for &bucket in &m.m_buckets {
        for b in &m.b_sizes {
            assert!(
                m.find("topn", *b, bucket).is_some(),
                "missing topn b={b} m={bucket}"
            );
            assert!(
                m.find("recupd", *b, bucket).is_some(),
                "missing recupd b={b} m={bucket}"
            );
        }
    }
    for b in &m.b_sizes {
        assert!(m.find("isgd", *b, 0).is_some());
    }
    // Every artifact file exists on disk.
    for a in &m.artifacts {
        assert!(a.file.exists(), "{} missing", a.file.display());
    }
}

#[test]
fn pjrt_topn_matches_native_exactly_ordered() {
    if !require_artifacts() {
        return;
    }
    let mut engine = PjrtEngine::new(&artifacts_dir()).unwrap();
    let mut native = NativeBackend::new();
    let mut rng = Pcg32::seeded(99);
    let k = 10;
    let mut slab = VectorSlab::new(k);
    for id in 0..700u64 {
        let v: Vec<f32> = (0..k).map(|_| rng.next_f32() - 0.5).collect();
        slab.insert(id, &v, 0);
    }
    for trial in 0..5 {
        let u: Vec<f32> = (0..k).map(|_| rng.next_f32() - 0.5).collect();
        let got = engine.topn(&u, &slab).unwrap();
        let want = native.topn(&u, &slab, 50);
        assert_eq!(got.len(), want.len(), "trial {trial}");
        for (g, w) in got.iter().zip(want.iter()) {
            assert!(
                (g.score - w.score).abs() < 1e-4,
                "trial {trial}: {g:?} vs {w:?}"
            );
        }
        // Rows must agree except where scores tie.
        for (g, w) in got.iter().zip(want.iter()) {
            if (g.score - w.score).abs() < 1e-7 && g.row != w.row {
                continue; // tie, order unspecified
            }
            assert_eq!(g.row, w.row, "trial {trial}");
        }
    }
}

#[test]
fn pjrt_isgd_step_matches_native_to_f32_noise() {
    if !require_artifacts() {
        return;
    }
    let mut engine = PjrtEngine::new(&artifacts_dir()).unwrap();
    let mut native = NativeBackend::new();
    let mut rng = Pcg32::seeded(5);
    for _ in 0..20 {
        let mut u1: Vec<f32> = (0..10).map(|_| rng.next_f32() - 0.5).collect();
        let mut i1: Vec<f32> = (0..10).map(|_| rng.next_f32() - 0.5).collect();
        let mut u2 = u1.clone();
        let mut i2 = i1.clone();
        let e1 = engine.isgd_step(&mut u1, &mut i1, 0.05, 0.01).unwrap();
        let e2 = native.isgd_step(&mut u2, &mut i2, 0.05, 0.01);
        assert!((e1 - e2).abs() < 1e-5, "err {e1} vs {e2}");
        for d in 0..10 {
            assert!((u1[d] - u2[d]).abs() < 1e-5);
            assert!((i1[d] - i2[d]).abs() < 1e-5);
        }
    }
}

#[test]
fn pjrt_handles_slab_growth_across_buckets() {
    if !require_artifacts() {
        return;
    }
    let mut engine = PjrtEngine::new(&artifacts_dir()).unwrap();
    let mut rng = Pcg32::seeded(6);
    let k = 10;
    let mut slab = VectorSlab::new(k);
    let u: Vec<f32> = (0..k).map(|_| rng.next_f32() - 0.5).collect();
    // Fill through the first bucket boundary: 1024 -> 4096.
    for id in 0..1500u64 {
        let v: Vec<f32> = (0..k).map(|_| rng.next_f32() - 0.5).collect();
        slab.insert(id, &v, 0);
        if id == 500 || id == 1400 {
            let got = engine.topn(&u, &slab).unwrap();
            assert!(!got.is_empty());
            // All returned rows must be live.
            for s in &got {
                assert!(slab.id_at(s.row).is_some());
            }
        }
    }
    assert_eq!(slab.capacity(), 4096);
    assert!(engine.uploads >= 2, "uploads should track slab versions");
}

#[test]
fn device_cache_avoids_reupload_for_repeated_queries() {
    if !require_artifacts() {
        return;
    }
    let mut engine = PjrtEngine::new(&artifacts_dir()).unwrap();
    let mut rng = Pcg32::seeded(7);
    let k = 10;
    let mut slab = VectorSlab::new(k);
    for id in 0..100u64 {
        let v: Vec<f32> = (0..k).map(|_| rng.next_f32() - 0.5).collect();
        slab.insert(id, &v, 0);
    }
    let u: Vec<f32> = (0..k).map(|_| rng.next_f32() - 0.5).collect();
    for _ in 0..5 {
        engine.topn(&u, &slab).unwrap();
    }
    assert_eq!(engine.uploads, 1, "read-only queries must reuse the cache");
    assert_eq!(engine.exec_calls, 5);
}

#[test]
fn full_pipeline_on_pjrt_backend() {
    if !require_artifacts() {
        return;
    }
    let events: Vec<_> =
        SyntheticStream::new(SyntheticConfig::netflix_like(1200, 3)).collect();
    let cfg = RunConfig {
        backend: Backend::Pjrt,
        topology: Topology::central(),
        artifacts_dir: artifacts_dir(),
        sample_every: 200,
        ..RunConfig::default()
    };
    let pjrt = run_pipeline(&cfg, &events, "pjrt-e2e").unwrap();
    let cfg_native =
        RunConfig { backend: Backend::Native, ..cfg };
    let native = run_pipeline(&cfg_native, &events, "native-e2e").unwrap();
    assert_eq!(pjrt.events, 1200);
    // Identical seeds and deterministic routing: recall trajectories agree
    // up to f32 noise in tie-breaks.
    let delta = (pjrt.hits as i64 - native.hits as i64).abs();
    assert!(delta <= 12, "pjrt={} native={}", pjrt.hits, native.hits);
}
