//! Crash-recovery correctness properties for the supervised worker
//! runtime (`engine/actor.rs` + `coordinator/supervisor.rs`).
//!
//! The acceptance property, verified for ISGD and cosine, with and
//! without a concurrent rescale: **kill any worker at any event index
//! and the session is indistinguishable from one that never crashed** —
//! zero event loss, byte-identical top-10 answers at every probe point,
//! identical hit totals and recall curves. Plus torture cases: a kill
//! *during* a checkpoint (the half-taken checkpoint must never be
//! used), the loud-failure contract when fault tolerance is off, and
//! the loud-failure contract when the replay log is too small to
//! recover without loss.

use streamrec::config::{Algorithm, RunConfig, Topology};
use streamrec::coordinator::Cluster;
use streamrec::data::synth::{SyntheticConfig, SyntheticStream};
use streamrec::data::types::Rating;
use streamrec::eval::RunReport;
use streamrec::util::proptest::forall;

fn events(n: u64, seed: u64) -> Vec<Rating> {
    SyntheticStream::new(SyntheticConfig::netflix_like(n, seed)).collect()
}

/// Fault-tolerant config with a 4x4 state-grid ceiling (so the rescale
/// variants can grow from n_i = 2 to 4).
fn fault_cfg(algo: Algorithm, checkpoint_interval: u64) -> RunConfig {
    RunConfig {
        algorithm: algo,
        topology: Topology::new(2, 0).unwrap(),
        rescale_max_n_i: 4,
        sample_every: 200,
        fault_checkpoint_interval: checkpoint_interval,
        ..RunConfig::default()
    }
}

/// First `k` distinct users of a slice, in stream order.
fn panel(evs: &[Rating], k: usize) -> Vec<u64> {
    let mut users = Vec::new();
    for e in evs {
        if !users.contains(&e.user) {
            users.push(e.user);
            if users.len() == k {
                break;
            }
        }
    }
    users
}

/// What one session run produces at the shared probe points.
struct Outcome {
    mid: Vec<Vec<u64>>,
    end: Vec<Vec<u64>>,
    report: RunReport,
}

/// Drive one full session: ingest the first half, probe the panel,
/// optionally rescale to `rescale_to`, ingest the rest, probe again,
/// finish. The chaos and baseline runs execute this identical sequence.
fn run_session(
    cfg: &RunConfig,
    evs: &[Rating],
    users: &[u64],
    rescale_to: Option<u64>,
) -> Outcome {
    let mut cluster = Cluster::spawn_labeled(cfg, "t-fault").unwrap();
    let split = evs.len() / 2;
    cluster.ingest_batch(&evs[..split]).unwrap();
    let mid: Vec<Vec<u64>> = users
        .iter()
        .map(|&u| cluster.recommend(u, 10).unwrap())
        .collect();
    if let Some(n_i) = rescale_to {
        cluster.rescale(Topology::new(n_i, 0).unwrap()).unwrap();
    }
    cluster.ingest_batch(&evs[split..]).unwrap();
    let end: Vec<Vec<u64>> = users
        .iter()
        .map(|&u| cluster.recommend(u, 10).unwrap())
        .collect();
    let report = cluster.finish().unwrap();
    Outcome { mid, end, report }
}

/// Per-worker `processed` summed over live + retired generations.
fn total_processed(report: &RunReport) -> u64 {
    report
        .workers
        .iter()
        .chain(report.retired.iter())
        .map(|w| w.processed)
        .sum()
}

fn assert_indistinguishable(base: &Outcome, chaos: &Outcome, label: &str) {
    assert_eq!(base.mid, chaos.mid, "{label}: mid-stream answers");
    assert_eq!(base.end, chaos.end, "{label}: end-of-stream answers");
    assert_eq!(base.report.events, chaos.report.events, "{label}: events");
    assert_eq!(base.report.hits, chaos.report.hits, "{label}: hit totals");
    assert_eq!(
        base.report.recall_curve, chaos.report.recall_curve,
        "{label}: recall curves"
    );
    assert_eq!(
        total_processed(&chaos.report),
        chaos.report.events,
        "{label}: zero event loss (restored counters + replay cover all)"
    );
    assert_eq!(base.report.recoveries, 0, "{label}: baseline never crashed");
}

#[test]
fn property_kill_any_worker_at_any_event_is_invisible() {
    // For random (algorithm, checkpoint interval, kill position,
    // with/without a concurrent rescale): the crashed-and-recovered
    // session must be indistinguishable from the never-crashed one.
    let evs = events(1600, 21);
    let users = panel(&evs, 5);
    forall("fault_kill_anywhere", 6, |rng| {
        let algo = if rng.next_bounded(2) == 0 {
            Algorithm::Isgd
        } else {
            Algorithm::Cosine
        };
        let ckpt = 1 + rng.next_bounded(64);
        let kill = rng.next_bounded(evs.len() as u64 - 50);
        let rescale_to =
            if rng.next_bounded(2) == 0 { Some(4u64) } else { None };
        let label = format!(
            "algo={algo:?} ckpt={ckpt} kill={kill} rescale={rescale_to:?}"
        );

        let base_cfg = fault_cfg(algo, ckpt);
        let mut chaos_cfg = base_cfg.clone();
        chaos_cfg.fault_chaos_kill_seq = Some(kill);

        let base = run_session(&base_cfg, &evs, &users, rescale_to);
        let chaos = run_session(&chaos_cfg, &evs, &users, rescale_to);

        assert_eq!(
            chaos.report.recoveries, 1,
            "{label}: the kill fires exactly once"
        );
        assert!(
            chaos.report.replayed_events >= 1,
            "{label}: the killed event itself is always replayed"
        );
        assert!(chaos.report.checkpoint_bytes > 0, "{label}");
        assert_indistinguishable(&base, &chaos, &label);
    });
}

#[test]
fn kill_during_checkpoint_is_recovered_exactly() {
    // Torture case: the panic fires *inside* the checkpoint path, after
    // the frame is built but before it reaches the supervisor. The
    // half-taken checkpoint must be invisible — recovery falls back to
    // the previous one plus a longer replay, and the session is still
    // exactly-once.
    let evs = events(1500, 9);
    let users = panel(&evs, 4);
    for algo in [Algorithm::Isgd, Algorithm::Cosine] {
        let base_cfg = fault_cfg(algo, 8);
        let mut chaos_cfg = base_cfg.clone();
        chaos_cfg.fault_chaos_kill_seq = Some(600);
        chaos_cfg.fault_chaos_kill_in_checkpoint = true;
        let base = run_session(&base_cfg, &evs, &users, None);
        let chaos = run_session(&chaos_cfg, &evs, &users, None);
        assert_eq!(chaos.report.recoveries, 1, "{algo:?}");
        assert_indistinguishable(&base, &chaos, &format!("{algo:?} in-ckpt"));
    }
}

#[test]
fn kill_during_checkpoint_with_concurrent_rescale() {
    // The same torture case straddling a rescale cutover: the worker
    // dies in the checkpoint path while a 2 -> 4 scale-out is part of
    // the session. Export-drain recovery plus zeroed-counter rescale
    // checkpoints must keep the accounting exact.
    let evs = events(1400, 33);
    let users = panel(&evs, 4);
    for algo in [Algorithm::Isgd, Algorithm::Cosine] {
        let base_cfg = fault_cfg(algo, 8);
        let mut chaos_cfg = base_cfg.clone();
        // The kill seq sits in the second half, after the cutover.
        chaos_cfg.fault_chaos_kill_seq = Some(1000);
        chaos_cfg.fault_chaos_kill_in_checkpoint = true;
        let base = run_session(&base_cfg, &evs, &users, Some(4));
        let chaos = run_session(&chaos_cfg, &evs, &users, Some(4));
        assert_eq!(chaos.report.recoveries, 1, "{algo:?}");
        assert_eq!(chaos.report.rescales, 1, "{algo:?}");
        assert_indistinguishable(&base, &chaos, &format!("{algo:?} rescale"));
    }
}

#[test]
fn recovery_metrics_are_plumbed_end_to_end() {
    // The observability contract of the tentpole: recoveries,
    // checkpoint_bytes, replayed_events, recovery_pause_ns appear in
    // both the live ClusterMetrics and the final RunReport.
    let evs = events(1200, 5);
    let mut cfg = fault_cfg(Algorithm::Isgd, 16);
    cfg.fault_chaos_kill_seq = Some(500);
    let mut cluster = Cluster::spawn_labeled(&cfg, "t-metrics").unwrap();
    cluster.ingest_batch(&evs[..800]).unwrap();
    // metrics() no longer flushes route buffers; flush explicitly so the
    // processed count is exact across the recovery.
    cluster.flush().unwrap();
    let m = cluster.metrics().unwrap();
    assert_eq!(m.ingested, 800);
    assert_eq!(m.processed, 800, "read-your-writes across the recovery");
    assert_eq!(m.recoveries, 1);
    assert!(m.checkpoint_bytes > 0);
    assert!(m.replayed_events >= 1);
    assert!(m.recovery_pause_ns > 0);
    cluster.ingest_batch(&evs[800..]).unwrap();
    let report = cluster.finish().unwrap();
    assert_eq!(report.recoveries, 1);
    // The final figures can only have grown past the live snapshot.
    assert!(report.checkpoint_bytes >= m.checkpoint_bytes);
    assert!(report.replayed_events >= m.replayed_events);
    assert!(report.recovery_pause_ns >= m.recovery_pause_ns);
    assert_eq!(total_processed(&report), 1200);
}

#[test]
fn recovery_invalidates_cached_answers_for_the_killed_workers_columns() {
    // The serving cache (keyed per user, validated by topology epoch +
    // column generation) must never replay a pre-crash answer into the
    // post-recovery world. An infinite staleness budget makes ingest
    // alone *unable* to invalidate the entry, so the only thing standing
    // between the stale answer and the caller is the column-generation
    // bump in `ServingState::on_recover` — which this test pins down.
    let evs = events(1200, 55);
    let mut cfg = RunConfig {
        algorithm: Algorithm::Isgd,
        topology: Topology::new(1, 0).unwrap(),
        sample_every: 200,
        fault_checkpoint_interval: 8,
        serving_cache_max_staleness: u64::MAX,
        ..RunConfig::default()
    };
    cfg.fault_chaos_kill_seq = Some(900);
    let mut cluster = Cluster::spawn_labeled(&cfg, "t-cache-inv").unwrap();
    cluster.ingest_batch(&evs[..600]).unwrap();
    let user = evs[0].user;
    let before = cluster.recommend(user, 10).unwrap();
    assert_eq!(
        cluster.recommend(user, 10).unwrap(),
        before,
        "repeat query agrees"
    );
    let m = cluster.metrics().unwrap();
    assert_eq!(m.cache_hits, 1, "the repeat query was served from cache");
    assert_eq!(m.recoveries, 0, "the kill seq has not been reached yet");

    // Drive through the kill point: the single worker dies at seq 900
    // and is recovered, which bumps the generation of every column it
    // hosts (all of them, on a 1-worker topology). The metrics probe
    // rides the FIFO *behind* the kill point, so it forces the death to
    // be detected and healed before we query — with an infinite
    // staleness budget, a query racing ahead of detection may still be
    // served from cache, and that is allowed; the property under test
    // is that no query *after* the recovery ever is.
    cluster.ingest_batch(&evs[600..]).unwrap();
    let m = cluster.metrics().unwrap();
    assert_eq!(m.recoveries, 1);
    let after = cluster.recommend(user, 10).unwrap();
    let m = cluster.metrics().unwrap();
    assert_eq!(
        m.cache_hits, 1,
        "a post-recovery query must MISS the cache even under an \
         infinite staleness budget: the entry predates the restored state"
    );

    // The recomputed answer equals a never-crashed session at the same
    // watermark (exactly-once recovery), not the stale cached one.
    let mut clean_cfg = cfg.clone();
    clean_cfg.fault_chaos_kill_seq = None;
    let mut clean = Cluster::spawn_labeled(&clean_cfg, "t-cache-base").unwrap();
    clean.ingest_batch(&evs).unwrap();
    assert_eq!(after, clean.recommend(user, 10).unwrap());
    clean.finish().unwrap();
    cluster.finish().unwrap();
}

#[test]
fn disabled_fault_tolerance_keeps_the_loud_failure_contract() {
    // fault.checkpoint_interval = 0 (the default): a worker death is an
    // explicit session error with the panic cause in the chain — never a
    // silent recovery, never silent loss.
    let evs = events(900, 13);
    let mut cfg = RunConfig {
        topology: Topology::new(2, 0).unwrap(),
        sample_every: 200,
        ..RunConfig::default()
    };
    cfg.fault_chaos_kill_seq = Some(400);
    let mut cluster = Cluster::spawn_labeled(&cfg, "t-loud").unwrap();
    let ingested = cluster.ingest_batch(&evs);
    let outcome = match ingested {
        Err(e) => Err(e),
        Ok(()) => cluster.finish().map(|_| ()),
    };
    let err = outcome.expect_err("a killed worker must surface");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("chaos") || msg.contains("died"),
        "root cause must surface: {msg}"
    );
}

#[test]
fn severed_remote_connection_recovers_byte_identical() {
    // Remote-worker failure without chaos cooperation: every live
    // worker connection is cut mid-stream at the TCP level
    // (`WorkerServer::sever`). The coordinator-side proxies panic on
    // their next write — the same detection surface as a crashed local
    // worker — and the supervisor re-dials the (still listening) host
    // and restores from checkpoints. The recovered session must match
    // the in-proc baseline byte for byte.
    use streamrec::net::WorkerServer;
    let evs = events(1400, 27);
    let users = panel(&evs, 4);
    let server = WorkerServer::bind("127.0.0.1:0").unwrap();

    let base_cfg = fault_cfg(Algorithm::Isgd, 8);
    let base = run_session(&base_cfg, &evs, &users, None);

    let mut cfg = base_cfg.clone();
    cfg.cluster_workers = vec![format!("tcp://{}", server.local_addr())];
    let mut cluster = Cluster::spawn_labeled(&cfg, "t-sever").unwrap();
    let split = evs.len() / 2;
    cluster.ingest_batch(&evs[..split]).unwrap();
    let mid: Vec<Vec<u64>> = users
        .iter()
        .map(|&u| cluster.recommend(u, 10).unwrap())
        .collect();
    let severed = server.sever();
    assert!(severed >= 1, "live connections were cut");
    // Keep streaming: the cut surfaces on the proxies' next writes and
    // recovery must absorb it invisibly.
    cluster.ingest_batch(&evs[split..]).unwrap();
    let end: Vec<Vec<u64>> = users
        .iter()
        .map(|&u| cluster.recommend(u, 10).unwrap())
        .collect();
    let report = cluster.finish().unwrap();
    let remote = Outcome { mid, end, report };

    assert!(
        remote.report.recoveries >= 1,
        "a severed connection is a detected worker loss"
    );
    assert_indistinguishable(&base, &remote, "severed-remote");
    server.wait_idle(std::time::Duration::from_millis(100));
    server.shutdown().unwrap();
}

#[test]
fn hung_remote_worker_is_detected_and_recovered() {
    // A worker that stops making progress WITHOUT dying — no EOF, no
    // error, just silence on an open TCP connection — must be detected
    // by the coordinator's liveness watchdog within the rpc timeout
    // and converted into the ordinary crash-recovery path.
    // `WorkerServer::stall` freezes the hosts' outbound pumps: events
    // still flow in, but no reply, hit batch, or heartbeat `Pong`
    // comes back.
    use std::time::{Duration, Instant};
    use streamrec::net::WorkerServer;
    let evs = events(1200, 97);
    let users = panel(&evs, 4);
    let server = WorkerServer::bind("127.0.0.1:0").unwrap();

    let base_cfg = fault_cfg(Algorithm::Isgd, 8);
    let base = run_session(&base_cfg, &evs, &users, None);

    let mut cfg = base_cfg.clone();
    cfg.cluster_workers = vec![format!("tcp://{}", server.local_addr())];
    cfg.fault_rpc_timeout_ms = 400;
    cfg.fault_heartbeat_interval_ms = 50;
    cfg.fault_dial_backoff_ms = 2;
    let mut cluster = Cluster::spawn_labeled(&cfg, "t-hung").unwrap();
    let split = evs.len() / 2;
    cluster.ingest_batch(&evs[..split]).unwrap();
    let mid: Vec<Vec<u64>> = users
        .iter()
        .map(|&u| cluster.recommend(u, 10).unwrap())
        .collect();

    // Freeze every pump for 1.2 s — long enough that the 400 ms
    // deadline must fire, short enough that the per-slot respawn
    // budget absorbs any repeat detections inside the window.
    server.stall(Duration::from_millis(1200));
    let t0 = Instant::now();
    cluster.ingest_batch(&evs[split..]).unwrap();
    // Let the stall window fully elapse so the probes below land on
    // live pumps; *detection* must already have happened by then,
    // bounded by the rpc timeout — not by the stall length.
    std::thread::sleep(Duration::from_millis(1400));
    let end: Vec<Vec<u64>> = users
        .iter()
        .map(|&u| cluster.recommend(u, 10).unwrap())
        .collect();
    let report = cluster.finish().unwrap();
    assert!(
        t0.elapsed() < Duration::from_secs(20),
        "hung-worker handling must be bounded, not a hang"
    );
    let remote = Outcome { mid, end, report };
    assert!(
        remote.report.recoveries >= 1,
        "the stall was detected as a worker loss"
    );
    assert_indistinguishable(&base, &remote, "hung-remote");
    server.wait_idle(Duration::from_millis(100));
    server.shutdown().unwrap();
}

#[test]
fn respawn_onto_a_briefly_unavailable_listener_succeeds() {
    // A respawn whose re-dial initially fails must retry under the
    // bounded-backoff budget and succeed. The unavailability window is
    // injected deterministically: the fault plan refuses every
    // connection's first two dial attempts (exactly what a
    // not-yet-listening host looks like), and one connection is
    // severed mid-stream so a respawn — and therefore a refused
    // re-dial — actually happens.
    use streamrec::net::WorkerServer;
    let evs = events(1400, 83);
    let users = panel(&evs, 4);
    let server = WorkerServer::bind("127.0.0.1:0").unwrap();

    let base_cfg = fault_cfg(Algorithm::Cosine, 8);
    let base = run_session(&base_cfg, &evs, &users, None);

    let mut cfg = base_cfg.clone();
    cfg.cluster_workers = vec![format!("tcp://{}", server.local_addr())];
    cfg.fault_dial_retries = 4;
    cfg.fault_dial_backoff_ms = 2;
    cfg.fault_net.seed = 19;
    cfg.fault_net.sever_connections = 1;
    cfg.fault_net.sever_after_frames = 3;
    cfg.fault_net.refuse_dials = 2;
    let remote = run_session(&cfg, &evs, &users, None);
    assert!(
        remote.report.recoveries >= 1,
        "the sever forces a respawn through the refused dials"
    );
    assert_indistinguishable(&base, &remote, "refused-then-respawned");
    server.wait_idle(std::time::Duration::from_millis(100));
    server.shutdown().unwrap();
}

#[test]
fn exhausted_replay_log_refuses_to_lose_events() {
    // A replay log smaller than the checkpoint gap cannot recover
    // without losing events — the supervisor must say so explicitly.
    let evs = events(1200, 3);
    let mut cfg = RunConfig {
        topology: Topology::new(1, 0).unwrap(),
        sample_every: 200,
        // Only the eager first-event checkpoints ever run, so by the
        // kill point the log has long since evicted uncovered events.
        fault_checkpoint_interval: 1_000_000,
        fault_replay_log_capacity: 8,
        ..RunConfig::default()
    };
    cfg.fault_chaos_kill_seq = Some(1000);
    let mut cluster = Cluster::spawn_labeled(&cfg, "t-exhaust").unwrap();
    let ingested = cluster.ingest_batch(&evs);
    let outcome = match ingested {
        Err(e) => Err(e),
        Ok(()) => cluster.finish().map(|_| ()),
    };
    let err = outcome.expect_err("recovery must refuse to lose events");
    assert!(
        format!("{err:#}").contains("replay log"),
        "actionable error: {err:#}"
    );
}
