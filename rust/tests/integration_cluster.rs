//! Integration tests for the long-lived `Cluster` session API: the online
//! serving path (replica fan-out + rank-aware merge), live metrics, and
//! the compatibility contract with the one-shot `run_pipeline`.

use std::collections::HashSet;

use streamrec::config::{Algorithm, RunConfig, Topology};
use streamrec::coordinator::{run_pipeline, Cluster, Router};
use streamrec::data::synth::{SyntheticConfig, SyntheticStream};
use streamrec::data::types::Rating;
use streamrec::eval::merge_topn;
use streamrec::util::proptest::forall;

fn events(n: u64, seed: u64) -> Vec<Rating> {
    SyntheticStream::new(SyntheticConfig::movielens_like(n, seed)).collect()
}

fn base_cfg(n_i: u64) -> RunConfig {
    RunConfig {
        topology: Topology::new(n_i, 0).unwrap(),
        sample_every: 500,
        ..RunConfig::default()
    }
}

#[test]
fn end_to_end_session_on_distributed_topology() {
    // The acceptance shape: spawn on n_i=2 (4 workers), interleave
    // ingest / recommend / metrics, then finish.
    let evs = events(6000, 1);
    let mut cluster = Cluster::spawn_labeled(&base_cfg(2), "e2e").unwrap();
    assert_eq!(cluster.n_workers(), 4);
    let hot = evs[0].user;
    assert_eq!(cluster.router().user_workers(hot).len(), 2, "n_i replicas");

    let mut answered = 0usize;
    for chunk in evs.chunks(1000) {
        cluster.ingest_batch(chunk).unwrap();
        let recs = cluster.recommend(hot, 10).unwrap();
        assert!(recs.len() <= 10);
        answered += usize::from(!recs.is_empty());
        let m = cluster.metrics().unwrap();
        // metrics() observes without flushing: accepted events are
        // either processed or still in a route buffer.
        assert_eq!(m.processed + m.buffered, cluster.ingested());
        assert_eq!(m.workers.len(), 4);
        assert_eq!(m.shed_queries, 0);
    }
    assert!(answered > 0, "hot user must get served eventually");

    let report = cluster.finish().unwrap();
    assert_eq!(report.events, 6000);
    assert_eq!(report.n_workers, 4);
    assert_eq!(
        report.workers.iter().map(|w| w.processed).sum::<u64>(),
        6000
    );
    assert!(report.avg_recall >= 0.0 && report.avg_recall <= 1.0);
    assert_eq!(report.recall_curve.last().unwrap().0, 5999);
}

#[test]
fn merged_topn_excludes_items_rated_on_any_replica() {
    // A user's ratings land on different replicas (the item row decides),
    // so no single worker knows the full consumed set — the merge must.
    for algo in [Algorithm::Isgd, Algorithm::Cosine] {
        let mut cfg = base_cfg(2);
        cfg.algorithm = algo;
        let evs = events(5000, 2);
        let mut cluster = Cluster::spawn(&cfg).unwrap();
        cluster.ingest_batch(&evs).unwrap();

        // Collect the globally-rated set per user from the raw stream.
        let mut users_seen: Vec<u64> = evs.iter().map(|e| e.user).collect();
        users_seen.sort_unstable();
        users_seen.dedup();
        for &u in users_seen.iter().take(25) {
            let rated: HashSet<u64> = evs
                .iter()
                .filter(|e| e.user == u)
                .map(|e| e.item)
                .collect();
            let recs = cluster.recommend(u, 20).unwrap();
            for r in &recs {
                assert!(
                    !rated.contains(r),
                    "{}: item {r} rated by user {u} on some replica \
                     but recommended anyway: {recs:?}",
                    cfg.algorithm.name()
                );
            }
        }
        cluster.finish().unwrap();
    }
}

#[test]
fn recommend_is_deterministic_for_fixed_seed() {
    let evs = events(4000, 3);
    let run = || {
        let mut cluster = Cluster::spawn(&base_cfg(2)).unwrap();
        cluster.ingest_batch(&evs).unwrap();
        let mut out = Vec::new();
        for &u in &[evs[0].user, evs[1].user, evs[100].user] {
            out.push(cluster.recommend(u, 10).unwrap());
        }
        cluster.finish().unwrap();
        out
    };
    assert_eq!(run(), run(), "same seed + same stream => same answers");
}

#[test]
fn unknown_user_gets_empty_list() {
    let evs = events(2000, 4);
    let mut cluster = Cluster::spawn(&base_cfg(2)).unwrap();
    cluster.ingest_batch(&evs).unwrap();
    // Synthetic streams draw users from a bounded universe; a huge id is
    // unknown to every replica.
    let unknown = u64::MAX - 7;
    assert!(evs.iter().all(|e| e.user != unknown));
    let recs = cluster.recommend(unknown, 10).unwrap();
    assert!(recs.is_empty(), "cold-start user must get an empty list");
    cluster.finish().unwrap();
}

#[test]
fn query_fans_out_over_user_workers() {
    // One recommend = one answered query on each of the user's n_i
    // replicas (and nowhere else), observable via per-worker counters.
    let evs = events(3000, 5);
    let mut cluster = Cluster::spawn(&base_cfg(2)).unwrap();
    cluster.ingest_batch(&evs).unwrap();
    let user = evs[0].user;
    let replicas: HashSet<usize> =
        cluster.router().user_workers(user).into_iter().collect();
    assert_eq!(replicas.len(), 2);
    let _ = cluster.recommend(user, 10).unwrap();
    let m = cluster.metrics().unwrap();
    for w in &m.workers {
        let expected = u64::from(replicas.contains(&w.worker_id));
        assert_eq!(
            w.queries, expected,
            "worker {} answered {} queries, expected {expected}",
            w.worker_id, w.queries
        );
    }
    assert_eq!(m.queries, 2);
    cluster.finish().unwrap();
}

#[test]
fn session_report_matches_one_shot_wrapper() {
    // run_pipeline is now spawn + ingest_batch + finish; a hand-driven
    // session over the same stream must agree on every deterministic
    // aggregate.
    let evs = events(3000, 6);
    let one_shot = run_pipeline(&base_cfg(2), &evs, "wrap").unwrap();
    let mut cluster = Cluster::spawn_labeled(&base_cfg(2), "hand").unwrap();
    for chunk in evs.chunks(700) {
        cluster.ingest_batch(chunk).unwrap();
    }
    let session = cluster.finish().unwrap();
    assert_eq!(session.events, one_shot.events);
    assert_eq!(session.hits, one_shot.hits);
    assert_eq!(session.recall_curve, one_shot.recall_curve);
    for (a, b) in session.workers.iter().zip(one_shot.workers.iter()) {
        assert_eq!(a.processed, b.processed);
        assert_eq!(a.hits, b.hits);
        assert_eq!(a.state, b.state);
    }
}

#[test]
fn serving_does_not_perturb_learning() {
    // Interleaving queries must not change what the models learn: the
    // final report of a query-heavy session equals a silent one. This
    // holds for *both* algorithms since the serving path became a frozen
    // read (`StreamingRecommender::serve`): cosine's bounded-staleness
    // caches are served as-is instead of being rebuilt on query, so
    // query timing cannot shift the models' state evolution — which is
    // also what lets crash recovery replay events alone (see
    // tests/fault_tolerance.rs).
    let evs = events(3000, 7);
    for algo in [Algorithm::Isgd, Algorithm::Cosine] {
        let mut cfg = base_cfg(2);
        cfg.algorithm = algo;
        let silent = {
            let mut c = Cluster::spawn(&cfg).unwrap();
            c.ingest_batch(&evs).unwrap();
            c.finish().unwrap()
        };
        let noisy = {
            let mut c = Cluster::spawn(&cfg).unwrap();
            for chunk in evs.chunks(250) {
                c.ingest_batch(chunk).unwrap();
                let _ = c.recommend(chunk[0].user, 10).unwrap();
                let _ = c.metrics().unwrap();
            }
            c.finish().unwrap()
        };
        assert_eq!(
            silent.hits, noisy.hits,
            "{algo:?}: queries must be read-only"
        );
        assert_eq!(silent.recall_curve, noisy.recall_curve, "{algo:?}");
        for (a, b) in silent.workers.iter().zip(noisy.workers.iter()) {
            assert_eq!(a.state, b.state, "{algo:?}");
        }
    }
}

#[test]
fn property_merge_of_replica_lists_preserves_rank_order() {
    // The satellite proptest: merged output is non-decreasing in
    // best-rank across replicas, and a single replica's list passes
    // through untouched (minus exclusions, capped at n).
    forall("cluster_merge_rank_order", 200, |rng| {
        let n_lists = 1 + rng.next_bounded(4) as usize;
        let lists: Vec<Vec<u64>> = (0..n_lists)
            .map(|_| {
                let len = rng.next_bounded(15) as usize;
                let mut l = Vec::new();
                for _ in 0..len {
                    let item = rng.next_bounded(40);
                    if !l.contains(&item) {
                        l.push(item);
                    }
                }
                l
            })
            .collect();
        let exclude: HashSet<u64> =
            (0..rng.next_bounded(6)).map(|_| rng.next_bounded(40)).collect();
        let n = 1 + rng.next_bounded(15) as usize;
        let merged = merge_topn(&lists, &exclude, n);

        assert!(merged.len() <= n);
        let best_rank = |item: u64| {
            lists
                .iter()
                .filter_map(|l| l.iter().position(|&x| x == item))
                .min()
                .expect("merged items come from the inputs")
        };
        for pair in merged.windows(2) {
            assert!(
                best_rank(pair[0]) <= best_rank(pair[1]),
                "rank order violated: {merged:?} from {lists:?}"
            );
        }
        for item in &merged {
            assert!(!exclude.contains(item), "excluded item {item} surfaced");
        }
        // Single-replica degenerate case: order preserved exactly.
        if n_lists == 1 {
            let want: Vec<u64> = lists[0]
                .iter()
                .copied()
                .filter(|i| !exclude.contains(i))
                .take(n)
                .collect();
            assert_eq!(merged, want);
        }
    });
}

#[test]
fn router_and_cluster_agree_on_replica_sets() {
    // The serving path promises fan-out over Router::user_workers; the
    // cluster's router accessor must expose the same grid the standalone
    // router computes.
    let cfg = base_cfg(4);
    let standalone = Router::new(cfg.topology);
    let cluster = Cluster::spawn(&cfg).unwrap();
    for u in 0..50u64 {
        assert_eq!(
            cluster.router().user_workers(u),
            standalone.user_workers(u)
        );
    }
    cluster.finish().unwrap();
}
