//! Transport-equivalence properties for the pluggable worker transport
//! (`net/`): a session whose workers live behind loopback TCP must be
//! **byte-identical** to the same seeded session run fully in-process —
//! identical answers at every probe point, identical hit totals and
//! recall curves — including a mid-stream rescale, a mixed
//! local-plus-remote placement cycle, and a chaos-killed remote worker
//! recovered via checkpoint restore + replay over the wire.
//!
//! The last test leaves the in-process harness entirely: it spawns real
//! `streamrec worker` child processes (two of them) and drives the
//! coordinator against them — rescale and remote crash recovery
//! included.

use std::time::Duration;

use streamrec::config::{Algorithm, RunConfig, Topology};
use streamrec::coordinator::Cluster;
use streamrec::data::synth::{SyntheticConfig, SyntheticStream};
use streamrec::data::types::Rating;
use streamrec::eval::RunReport;
use streamrec::net::WorkerServer;
use streamrec::util::proptest::forall;

fn events(n: u64, seed: u64) -> Vec<Rating> {
    SyntheticStream::new(SyntheticConfig::netflix_like(n, seed)).collect()
}

/// First `k` distinct users of a slice, in stream order.
fn panel(evs: &[Rating], k: usize) -> Vec<u64> {
    let mut users = Vec::new();
    for e in evs {
        if !users.contains(&e.user) {
            users.push(e.user);
            if users.len() == k {
                break;
            }
        }
    }
    users
}

/// Base config shared by every pairing: n_i = 2 (4 workers) with
/// headroom to rescale to 4.
fn base_cfg(algo: Algorithm, checkpoint_interval: u64) -> RunConfig {
    RunConfig {
        algorithm: algo,
        topology: Topology::new(2, 0).unwrap(),
        rescale_max_n_i: 4,
        sample_every: 200,
        fault_checkpoint_interval: checkpoint_interval,
        ..RunConfig::default()
    }
}

/// What one session run produces at the shared probe points.
struct Outcome {
    mid: Vec<Vec<u64>>,
    end: Vec<Vec<u64>>,
    report: RunReport,
}

/// Drive one full session: ingest the first half, probe the panel,
/// optionally rescale, ingest the rest, probe again, finish. Identical
/// to the fault-tolerance driver so transport pairings compare the
/// exact same session shape.
fn run_session(
    cfg: &RunConfig,
    evs: &[Rating],
    users: &[u64],
    rescale_to: Option<u64>,
) -> Outcome {
    let mut cluster = Cluster::spawn_labeled(cfg, "t-transport").unwrap();
    let split = evs.len() / 2;
    cluster.ingest_batch(&evs[..split]).unwrap();
    let mid: Vec<Vec<u64>> = users
        .iter()
        .map(|&u| cluster.recommend(u, 10).unwrap())
        .collect();
    if let Some(n_i) = rescale_to {
        cluster.rescale(Topology::new(n_i, 0).unwrap()).unwrap();
    }
    cluster.ingest_batch(&evs[split..]).unwrap();
    let end: Vec<Vec<u64>> = users
        .iter()
        .map(|&u| cluster.recommend(u, 10).unwrap())
        .collect();
    let report = cluster.finish().unwrap();
    Outcome { mid, end, report }
}

fn assert_identical(inproc: &Outcome, tcp: &Outcome, label: &str) {
    assert_eq!(inproc.mid, tcp.mid, "{label}: mid-stream answers");
    assert_eq!(inproc.end, tcp.end, "{label}: end-of-stream answers");
    assert_eq!(inproc.report.events, tcp.report.events, "{label}: events");
    assert_eq!(inproc.report.hits, tcp.report.hits, "{label}: hit totals");
    assert_eq!(
        inproc.report.recall_curve, tcp.report.recall_curve,
        "{label}: recall curves"
    );
}

#[test]
fn property_loopback_tcp_is_byte_identical_to_inproc() {
    // For random (algorithm, checkpointing on/off, with/without a
    // mid-stream rescale): the same seeded stream through all-remote
    // workers answers and scores exactly like the all-local session.
    let evs = events(1400, 17);
    let users = panel(&evs, 5);
    let server = WorkerServer::bind("127.0.0.1:0").unwrap();
    let addr = format!("tcp://{}", server.local_addr());
    forall("transport_equivalence", 4, |rng| {
        let algo = if rng.next_bounded(2) == 0 {
            Algorithm::Isgd
        } else {
            Algorithm::Cosine
        };
        let ckpt = if rng.next_bounded(2) == 0 {
            0
        } else {
            1 + rng.next_bounded(64)
        };
        let rescale_to =
            if rng.next_bounded(2) == 0 { Some(4u64) } else { None };
        let label =
            format!("algo={algo:?} ckpt={ckpt} rescale={rescale_to:?}");

        let cfg = base_cfg(algo, ckpt);
        let mut tcp_cfg = cfg.clone();
        tcp_cfg.cluster_workers = vec![addr.clone()];

        let inproc = run_session(&cfg, &evs, &users, rescale_to);
        let tcp = run_session(&tcp_cfg, &evs, &users, rescale_to);
        assert_identical(&inproc, &tcp, &label);
    });
    server.wait_idle(Duration::from_millis(100));
    assert!(server.connections() >= 4, "every worker slot dialed in");
    assert!(server.events_routed() > 0, "events crossed the wire");
    server.shutdown().unwrap();
}

#[test]
fn mixed_local_and_tcp_placement_is_identical() {
    // Placement cycle ["local", "tcp://..."]: even slots are threads,
    // odd slots are remote — same bytes out, including across a
    // rescale that doubles the worker count.
    let evs = events(1500, 29);
    let users = panel(&evs, 5);
    let server = WorkerServer::bind("127.0.0.1:0").unwrap();
    for algo in [Algorithm::Isgd, Algorithm::Cosine] {
        let cfg = base_cfg(algo, 16);
        let mut mixed_cfg = cfg.clone();
        mixed_cfg.cluster_workers = vec![
            "local".to_string(),
            format!("tcp://{}", server.local_addr()),
        ];
        let inproc = run_session(&cfg, &evs, &users, Some(4));
        let mixed = run_session(&mixed_cfg, &evs, &users, Some(4));
        assert_identical(&inproc, &mixed, &format!("{algo:?} mixed"));
        assert_eq!(mixed.report.rescales, 1);
    }
    server.wait_idle(Duration::from_millis(100));
    server.shutdown().unwrap();
}

#[test]
fn chaos_killed_remote_worker_recovers_byte_identical() {
    // The remote failure path end to end: the chaos kill fires inside
    // the *hosted* actor, the host drops the connection without a final
    // report, the coordinator-side proxy panics (crash parity), and the
    // supervisor re-dials the same host and restores from checkpoints
    // shipped over the wire. The recovered remote session must match
    // the never-crashed in-proc baseline byte for byte.
    let evs = events(1300, 41);
    let users = panel(&evs, 4);
    let server = WorkerServer::bind("127.0.0.1:0").unwrap();
    let addr = format!("tcp://{}", server.local_addr());
    for algo in [Algorithm::Isgd, Algorithm::Cosine] {
        let cfg = base_cfg(algo, 8);
        let mut chaos_cfg = cfg.clone();
        chaos_cfg.cluster_workers = vec![addr.clone()];
        chaos_cfg.fault_chaos_kill_seq = Some(400);

        let inproc = run_session(&cfg, &evs, &users, None);
        let remote = run_session(&chaos_cfg, &evs, &users, None);
        assert_eq!(
            remote.report.recoveries, 1,
            "{algo:?}: the remote kill fires exactly once"
        );
        assert!(
            remote.report.checkpoint_bytes > 0,
            "{algo:?}: checkpoints crossed the wire"
        );
        assert_identical(&inproc, &remote, &format!("{algo:?} remote-kill"));
    }
    server.wait_idle(Duration::from_millis(100));
    server.shutdown().unwrap();
}

#[test]
fn property_net_fault_plans_within_budget_are_invisible() {
    // The chaos-tentpole acceptance property: for random seeded
    // `[fault.net]` plans the retry/timeout budget can absorb —
    // per-connection handshake delays, injected dial refusals,
    // sever-at-frame-N (clean or mid-frame), both algorithms, with and
    // without a concurrent rescale — the remote session must be
    // byte-identical to the fault-free all-in-process run. The sever
    // fuse is kept short (≤ 3 counted frames) so every armed sever is
    // guaranteed to fire before its connection retires naturally.
    let evs = events(1400, 61);
    let users = panel(&evs, 4);
    let server = WorkerServer::bind("127.0.0.1:0").unwrap();
    let addr = format!("tcp://{}", server.local_addr());
    forall("net_fault_invisible", 4, |rng| {
        let algo = if rng.next_bounded(2) == 0 {
            Algorithm::Isgd
        } else {
            Algorithm::Cosine
        };
        let ckpt = 1 + rng.next_bounded(32);
        let rescale_to =
            if rng.next_bounded(2) == 0 { Some(4u64) } else { None };

        let mut tcp_cfg = base_cfg(algo, ckpt);
        tcp_cfg.cluster_workers = vec![addr.clone()];
        tcp_cfg.fault_dial_retries = 5;
        tcp_cfg.fault_dial_backoff_ms = 2;
        tcp_cfg.fault_rpc_timeout_ms = 5_000;
        tcp_cfg.fault_heartbeat_interval_ms = 100;
        tcp_cfg.fault_net.seed = rng.next_u64();
        tcp_cfg.fault_net.delay_ms_max = rng.next_bounded(4);
        tcp_cfg.fault_net.sever_connections = 1 + rng.next_bounded(2);
        tcp_cfg.fault_net.sever_after_frames = 3;
        tcp_cfg.fault_net.mid_frame_cut = rng.next_bounded(2) == 1;
        tcp_cfg.fault_net.refuse_dials = rng.next_bounded(3) as u32;
        let label = format!(
            "algo={algo:?} ckpt={ckpt} rescale={rescale_to:?} net={:?}",
            tcp_cfg.fault_net
        );

        let inproc =
            run_session(&base_cfg(algo, ckpt), &evs, &users, rescale_to);
        let tcp = run_session(&tcp_cfg, &evs, &users, rescale_to);
        assert!(
            tcp.report.recoveries >= 1,
            "{label}: an armed sever must fire and be recovered"
        );
        assert_identical(&inproc, &tcp, &label);
    });
    server.wait_idle(Duration::from_millis(100));
    server.shutdown().unwrap();
}

#[test]
fn exhausted_dial_retries_fail_loudly_with_the_host() {
    // Without fault tolerance, a slot whose host is gone for good must
    // exhaust its dial budget and surface a session error naming the
    // address — never hang, never fail silently. (Bind then drop a
    // listener so the port is almost surely dead.)
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    drop(listener);
    let evs = events(200, 3);
    let mut cfg = base_cfg(Algorithm::Isgd, 0);
    cfg.cluster_workers = vec![format!("tcp://{addr}")];
    cfg.fault_dial_retries = 2;
    cfg.fault_dial_backoff_ms = 1;
    let mut cluster = Cluster::spawn_labeled(&cfg, "t-deadhost").unwrap();
    let outcome = cluster
        .ingest_batch(&evs)
        .and_then(|()| cluster.finish().map(|_| ()));
    let err = outcome.expect_err("a dead host must surface");
    let msg = format!("{err:#}");
    assert!(msg.contains(&addr), "error must name the host: {msg}");
    assert!(msg.contains("3 attempt"), "retry budget visible: {msg}");
}

/// A real `streamrec worker` child process bound to an ephemeral
/// loopback port, address parsed from its first stdout line.
struct WorkerProc {
    child: std::process::Child,
    addr: String,
}

impl WorkerProc {
    fn spawn() -> WorkerProc {
        use std::io::BufRead;
        let mut child = std::process::Command::new(env!(
            "CARGO_BIN_EXE_streamrec"
        ))
        .args(["worker", "--listen", "127.0.0.1:0"])
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn streamrec worker");
        let stdout = child.stdout.take().expect("worker stdout piped");
        let mut line = String::new();
        std::io::BufReader::new(stdout)
            .read_line(&mut line)
            .expect("read the listening line");
        let addr = line
            .trim()
            .rsplit(' ')
            .next()
            .expect("addr on the listening line")
            .to_string();
        assert!(
            line.contains("listening"),
            "unexpected first line: {line:?}"
        );
        WorkerProc { child, addr: format!("tcp://{addr}") }
    }
}

impl Drop for WorkerProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

#[test]
fn two_worker_processes_match_inproc_with_rescale_and_crash() {
    // The acceptance run: a coordinator plus two real worker processes,
    // one mid-stream rescale, and one chaos-killed-and-recovered remote
    // worker — byte-identical to the all-in-process session.
    let evs = events(1200, 53);
    let users = panel(&evs, 4);
    let w1 = WorkerProc::spawn();
    let w2 = WorkerProc::spawn();

    let cfg = base_cfg(Algorithm::Isgd, 8);
    let mut remote_cfg = cfg.clone();
    remote_cfg.cluster_workers = vec![w1.addr.clone(), w2.addr.clone()];
    remote_cfg.fault_chaos_kill_seq = Some(300);

    let inproc = run_session(&cfg, &evs, &users, Some(4));
    let remote = run_session(&remote_cfg, &evs, &users, Some(4));

    assert_eq!(remote.report.rescales, 1);
    assert_eq!(
        remote.report.recoveries, 1,
        "the killed remote worker recovered via re-dial"
    );
    assert_identical(&inproc, &remote, "two-process");
}
