//! Equivalence and accounting properties for the `[memory]` tier.
//!
//! The contract under test, for ISGD and cosine, in-proc and over
//! loopback TCP, with and without a mid-stream rescale or a chaos
//! kill:
//!
//! * **Generous budgets are invisible** — any budget large enough that
//!   pressure never fires produces a session byte-identical to the
//!   unlimited one: same answers, hits, recall curve, and state
//!   fingerprint.
//! * **Spill is lossless** — a budget far *below* the working set with
//!   no eviction policy forces the whole population through the disk
//!   tier, and the session is *still* byte-identical to unlimited:
//!   spilled frames fault back in exactly, on ingest and on query.
//! * **Accounting reconciles** — logical state bytes are a pure
//!   function of the stream (placement-independent across topologies,
//!   rescales, recoveries, and tiering), cluster rollups equal the
//!   per-worker sums, and with spill enabled every worker's reported
//!   resident bytes respect its budget.

use std::time::Duration;

use streamrec::config::{Algorithm, Forgetting, RunConfig, Topology};
use streamrec::coordinator::Cluster;
use streamrec::data::synth::{SyntheticConfig, SyntheticStream};
use streamrec::data::types::Rating;
use streamrec::eval::RunReport;
use streamrec::net::WorkerServer;
use streamrec::util::proptest::forall;

fn events(n: u64, seed: u64) -> Vec<Rating> {
    SyntheticStream::new(SyntheticConfig::netflix_like(n, seed)).collect()
}

/// First `k` distinct users of a slice, in stream order.
fn panel(evs: &[Rating], k: usize) -> Vec<u64> {
    let mut users = Vec::new();
    for e in evs {
        if !users.contains(&e.user) {
            users.push(e.user);
            if users.len() == k {
                break;
            }
        }
    }
    users
}

/// Base config: n_i = 2 (4 workers) over a 4x4 (16-lane) grid ceiling,
/// so rescaling to 4 is reachable and lanes are plentiful enough for
/// tiering to have real cold lanes to choose from.
fn base_cfg(algo: Algorithm, checkpoint_interval: u64) -> RunConfig {
    RunConfig {
        algorithm: algo,
        topology: Topology::new(2, 0).unwrap(),
        rescale_max_n_i: 4,
        sample_every: 200,
        fault_checkpoint_interval: checkpoint_interval,
        memory_check_events: 16,
        ..RunConfig::default()
    }
}

/// What one session produces at the shared probe points.
struct Outcome {
    mid: Vec<Vec<u64>>,
    end: Vec<Vec<u64>>,
    fingerprint: u64,
    report: RunReport,
}

/// Drive one full session: ingest the first half, probe the panel,
/// optionally rescale, ingest the rest, probe again, fingerprint the
/// full model state, finish. The same sequence for every memory
/// configuration so outcomes compare the exact same session shape.
fn run_session(
    cfg: &RunConfig,
    evs: &[Rating],
    users: &[u64],
    rescale_to: Option<u64>,
) -> Outcome {
    let mut cluster = Cluster::spawn_labeled(cfg, "t-memory").unwrap();
    let split = evs.len() / 2;
    cluster.ingest_batch(&evs[..split]).unwrap();
    let mid: Vec<Vec<u64>> = users
        .iter()
        .map(|&u| cluster.recommend(u, 10).unwrap())
        .collect();
    if let Some(n_i) = rescale_to {
        cluster.rescale(Topology::new(n_i, 0).unwrap()).unwrap();
    }
    cluster.ingest_batch(&evs[split..]).unwrap();
    let end: Vec<Vec<u64>> = users
        .iter()
        .map(|&u| cluster.recommend(u, 10).unwrap())
        .collect();
    let fingerprint = cluster.state_fingerprint().unwrap();
    let report = cluster.finish().unwrap();
    Outcome { mid, end, fingerprint, report }
}

fn assert_identical(unlimited: &Outcome, capped: &Outcome, label: &str) {
    assert_eq!(unlimited.mid, capped.mid, "{label}: mid-stream answers");
    assert_eq!(unlimited.end, capped.end, "{label}: end-of-stream answers");
    assert_eq!(
        unlimited.report.hits, capped.report.hits,
        "{label}: hit totals"
    );
    assert_eq!(
        unlimited.report.recall_curve, capped.report.recall_curve,
        "{label}: recall curves"
    );
    assert_eq!(
        unlimited.fingerprint, capped.fingerprint,
        "{label}: state fingerprints"
    );
    assert_eq!(
        unlimited.report.state_bytes, capped.report.state_bytes,
        "{label}: final logical state bytes"
    );
}

#[test]
fn property_budgets_are_result_transparent() {
    // For random (algorithm, transport, budget shape, ± rescale,
    // ± chaos kill): a memory-managed session is byte-identical to the
    // unlimited session with the same shape. "Generous" budgets never
    // feel pressure; "tight" budgets (1 byte, no eviction policy) tier
    // the *entire* population through disk and must still not change a
    // single bit of output.
    let evs = events(1600, 61);
    let users = panel(&evs, 4);
    let server = WorkerServer::bind("127.0.0.1:0").unwrap();
    let addr = format!("tcp://{}", server.local_addr());
    forall("memory_equivalence", 6, |rng| {
        let algo = if rng.next_bounded(2) == 0 {
            Algorithm::Isgd
        } else {
            Algorithm::Cosine
        };
        let tcp = rng.next_bounded(2) == 0;
        let tight = rng.next_bounded(2) == 0;
        let rescale_to =
            if rng.next_bounded(2) == 0 { Some(4u64) } else { None };
        let chaos = rng.next_bounded(2) == 0;
        let label = format!(
            "algo={algo:?} tcp={tcp} tight={tight} rescale={rescale_to:?} \
             chaos={chaos}"
        );

        let mut cfg = base_cfg(algo, if chaos { 32 } else { 0 });
        if chaos {
            cfg.fault_chaos_kill_seq =
                Some(300 + rng.next_bounded(evs.len() as u64 - 600));
        }
        if tcp {
            cfg.cluster_workers = vec![addr.clone()];
        }
        let mut capped = cfg.clone();
        if tight {
            // 1 byte: every lane is over budget at every enforcement
            // point — maximal tiering churn, zero output change.
            capped.memory_budget_bytes = 1;
        } else {
            // Generous: pressure can never fire, and the policy's own
            // clock sweeps must stay exactly as frequent as unlimited.
            capped.memory_budget_bytes = 1 << 40;
            capped.forgetting =
                Forgetting::Lfu { trigger_events: 400, min_freq: 2 };
            cfg.forgetting =
                Forgetting::Lfu { trigger_events: 400, min_freq: 2 };
        }

        let unlimited = run_session(&cfg, &evs, &users, rescale_to);
        let managed = run_session(&capped, &evs, &users, rescale_to);
        assert_identical(&unlimited, &managed, &label);
        if tight {
            assert!(
                managed.report.spills > 0,
                "{label}: a 1-byte budget must have tiered lanes out"
            );
            assert!(
                managed.report.spill_faultins > 0,
                "{label}: touching tiered lanes must have faulted them in"
            );
            assert_eq!(
                unlimited.report.spills, 0,
                "{label}: the unlimited run must not spill"
            );
        }
        if chaos {
            assert!(
                managed.report.recoveries >= 1,
                "{label}: the chaos kill must have fired and recovered"
            );
        }
    });
    server.wait_idle(Duration::from_millis(100));
}

#[test]
fn spilled_lanes_fault_in_for_queries_exactly() {
    // The cluster-level spill/fault-in round trip: spill everything,
    // then serve a panel — answers must equal the unlimited session's,
    // and the fault-ins must show up in the books.
    let evs = events(1800, 7);
    let users = panel(&evs, 6);
    for algo in [Algorithm::Isgd, Algorithm::Cosine] {
        let cfg = base_cfg(algo, 0);
        let mut tight = cfg.clone();
        tight.memory_budget_bytes = 1;

        let mut unlimited = Cluster::spawn_labeled(&cfg, "t-mem-q").unwrap();
        let mut capped = Cluster::spawn_labeled(&tight, "t-mem-q").unwrap();
        unlimited.ingest_batch(&evs).unwrap();
        capped.ingest_batch(&evs).unwrap();
        capped.flush().unwrap();
        let m = capped.metrics().unwrap();
        assert_eq!(m.resident_bytes, 0, "{algo:?}: all lanes tiered out");
        assert!(m.spilled_lanes > 0);
        for &u in &users {
            assert_eq!(
                capped.recommend(u, 10).unwrap(),
                unlimited.recommend(u, 10).unwrap(),
                "{algo:?}: answer served from a faulted-in lane"
            );
        }
        let m2 = capped.metrics().unwrap();
        assert!(
            m2.spill_faultins > m.spill_faultins,
            "{algo:?}: queries faulted spilled lanes back in"
        );
        let rep_c = capped.finish().unwrap();
        let rep_u = unlimited.finish().unwrap();
        assert_eq!(rep_c.hits, rep_u.hits, "{algo:?}: hit totals");
        assert_eq!(
            rep_c.state_bytes, rep_u.state_bytes,
            "{algo:?}: tiering never changes the logical state total"
        );
    }
}

#[test]
fn state_accounting_is_placement_independent() {
    // Logical state bytes (and entry counts) are a pure function of
    // the stream: the same totals whether the lanes live on 1 worker,
    // 4 workers, 16 workers after a rescale, a recovered worker — or
    // on disk.
    let evs = events(2000, 53);
    for algo in [Algorithm::Isgd, Algorithm::Cosine] {
        let run = |n_i: u64,
                   rescale_to: Option<u64>,
                   budget: u64,
                   chaos: bool| {
            let mut cfg = base_cfg(algo, if chaos { 32 } else { 0 });
            cfg.topology = Topology::new(n_i, 0).unwrap();
            cfg.memory_budget_bytes = budget;
            if chaos {
                cfg.fault_chaos_kill_seq = Some(900);
            }
            let mut cluster =
                Cluster::spawn_labeled(&cfg, "t-mem-acct").unwrap();
            cluster.ingest_batch(&evs[..1000]).unwrap();
            if let Some(to) = rescale_to {
                cluster.rescale(Topology::new(to, 0).unwrap()).unwrap();
            }
            cluster.ingest_batch(&evs[1000..]).unwrap();
            cluster.flush().unwrap();
            let m = cluster.metrics().unwrap();
            if budget > 0 {
                for w in &m.workers {
                    assert!(
                        w.state_bytes <= budget,
                        "{algo:?}: worker {} resident {} > budget {budget}",
                        w.worker_id,
                        w.state_bytes,
                    );
                }
            }
            assert_eq!(
                m.state_bytes,
                m.workers
                    .iter()
                    .map(|w| w.state_bytes + w.spilled_bytes)
                    .sum::<u64>(),
                "{algo:?}: cluster rollup equals per-worker sums"
            );
            let report = cluster.finish().unwrap();
            assert_eq!(
                report.state_bytes, m.state_bytes,
                "{algo:?}: final report agrees with the last snapshot"
            );
            let state: (u64, u64, u64) = report.workers.iter().fold(
                (0, 0, 0),
                |acc, w| {
                    (
                        acc.0 + w.state.users,
                        acc.1 + w.state.items,
                        acc.2 + w.state.aux,
                    )
                },
            );
            (report.state_bytes, state)
        };
        let central = run(1, None, 0, false);
        let distributed = run(2, None, 0, false);
        let rescaled = run(2, Some(4), 0, false);
        let tiered = run(2, None, 64 * 1024, false);
        let recovered = run(2, None, 0, true);
        assert_eq!(central, distributed, "{algo:?}: 1 vs 4 workers");
        assert_eq!(central, rescaled, "{algo:?}: across a rescale");
        assert_eq!(central, tiered, "{algo:?}: with lanes tiered to disk");
        assert_eq!(central, recovered, "{algo:?}: across a crash recovery");
        assert!(central.0 > 0, "{algo:?}: the stream built real state");
    }
}
