//! Batching-equivalence tests for the micro-batched data plane: the
//! coordinator's route buffers and bulk channel sends are a *transport*
//! optimization, so every observable — the prequential hit sequence
//! (recall curve), per-worker reports, and online recommendations — must
//! be identical for any `ingest_batch_size` and any ingest chunking.
//! Also covers the flush-before-query rule: a recommend issued
//! mid-buffer flushes the queried user's replica buffers first, so it
//! observes every previously ingested event for that user — while a
//! metrics probe observes without flushing anything at all.

use streamrec::config::{Algorithm, RunConfig, Topology};
use streamrec::coordinator::Cluster;
use streamrec::data::synth::{SyntheticConfig, SyntheticStream};
use streamrec::data::types::Rating;
use streamrec::eval::RunReport;
use streamrec::util::proptest::forall;

fn events(n: u64, seed: u64) -> Vec<Rating> {
    SyntheticStream::new(SyntheticConfig::movielens_like(n, seed)).collect()
}

fn cfg(algo: Algorithm, ingest_batch_size: usize) -> RunConfig {
    RunConfig {
        algorithm: algo,
        topology: Topology::new(2, 0).unwrap(),
        sample_every: 100,
        ingest_batch_size,
        ..RunConfig::default()
    }
}

/// Drive one full session: chunked ingest, end-of-stream top-10 probes
/// for `probes`, then finish.
fn run_session(
    evs: &[Rating],
    cfg: &RunConfig,
    chunk: usize,
    probes: &[u64],
) -> (RunReport, Vec<Vec<u64>>) {
    let mut cluster = Cluster::spawn(cfg).unwrap();
    for ch in evs.chunks(chunk.max(1)) {
        cluster.ingest_batch(ch).unwrap();
    }
    let recs = probes
        .iter()
        .map(|&u| cluster.recommend(u, 10).unwrap())
        .collect();
    (cluster.finish().unwrap(), recs)
}

fn assert_equivalent(
    base: &(RunReport, Vec<Vec<u64>>),
    got: &(RunReport, Vec<Vec<u64>>),
    label: &str,
) {
    let (base_report, base_recs) = base;
    let (report, recs) = got;
    assert_eq!(report.events, base_report.events, "{label}: event count");
    assert_eq!(report.hits, base_report.hits, "{label}: total hits");
    assert_eq!(
        report.recall_curve, base_report.recall_curve,
        "{label}: the per-event hit sequence must be batch-size-invariant"
    );
    for (a, b) in report.workers.iter().zip(base_report.workers.iter()) {
        assert_eq!(a.worker_id, b.worker_id, "{label}: worker order");
        assert_eq!(a.processed, b.processed, "{label}: per-worker load");
        assert_eq!(a.hits, b.hits, "{label}: per-worker hits");
        assert_eq!(a.state, b.state, "{label}: per-worker model state");
    }
    assert_eq!(recs, base_recs, "{label}: recommendations");
}

#[test]
fn property_session_is_ingest_batch_size_invariant() {
    // The satellite proptest: an interleaved stream ingested via buffered
    // micro-batches yields the *identical* RunReport hit sequence and
    // recommend results as event-at-a-time ingest, for random batch
    // sizes and random ingest chunkings.
    let evs = events(2500, 11);
    let probes = [evs[0].user, evs[1].user, evs[50].user];
    let base = run_session(&evs, &cfg(Algorithm::Isgd, 1), usize::MAX, &probes);
    forall("ingest_batch_size_invariance", 8, |rng| {
        let batch = 1 + rng.next_bounded(300) as usize;
        let chunk = 1 + rng.next_bounded(700) as usize;
        let got =
            run_session(&evs, &cfg(Algorithm::Isgd, batch), chunk, &probes);
        assert_equivalent(
            &base,
            &got,
            &format!("isgd batch={batch} chunk={chunk}"),
        );
    });
}

#[test]
fn cosine_session_is_ingest_batch_size_invariant() {
    // Same contract for the DICS path (its bounded-staleness read caches
    // rebuild deterministically from per-worker event order, which
    // batching must not change).
    let evs = events(1500, 13);
    let probes = [evs[0].user, evs[2].user];
    let base =
        run_session(&evs, &cfg(Algorithm::Cosine, 1), usize::MAX, &probes);
    for batch in [7usize, 64, 256] {
        let got =
            run_session(&evs, &cfg(Algorithm::Cosine, batch), 333, &probes);
        assert_equivalent(&base, &got, &format!("cosine batch={batch}"));
    }
}

#[test]
fn query_mid_buffer_sees_all_ingested_events() {
    // ingest_batch_size far larger than the stream: ingest alone never
    // fills a route buffer, so *only* the flush-before-query rule can
    // make these events visible. The probe must see all of them.
    let evs = events(400, 21);
    let mut buffered = Cluster::spawn(&cfg(Algorithm::Isgd, 100_000)).unwrap();
    let mut unbatched = Cluster::spawn(&cfg(Algorithm::Isgd, 1)).unwrap();
    buffered.ingest_batch(&evs).unwrap();
    unbatched.ingest_batch(&evs).unwrap();

    let m = buffered.metrics().unwrap();
    assert_eq!(m.ingested, 400);
    // Regression guard for the serving plane: a metrics probe must NOT
    // force a flush — the events stay buffered and are reported as such.
    assert_eq!(m.processed, 0, "metrics() must not flush route buffers");
    assert_eq!(m.buffered, 400, "buffered events must be accounted for");

    // Read-your-writes: a recommend issued mid-buffer flushes the queried
    // user's replica buffers first, so it answers from models that have
    // seen every prior event for that user — identical to the unbatched
    // cluster, and never recommending something the user already rated.
    let user = evs[0].user;
    let recs = buffered.recommend(user, 10).unwrap();
    assert_eq!(recs, unbatched.recommend(user, 10).unwrap());
    for e in evs.iter().filter(|e| e.user == user) {
        assert!(
            !recs.contains(&e.item),
            "item {} was ingested (still buffered) yet recommended",
            e.item
        );
    }

    let br = buffered.finish().unwrap();
    let ur = unbatched.finish().unwrap();
    assert_eq!(br.hits, ur.hits);
    assert_eq!(br.recall_curve, ur.recall_curve);
}

#[test]
fn finish_drains_the_buffered_tail() {
    // A tail smaller than ingest_batch_size must still reach the workers
    // and the final report (the drain guarantee).
    let evs = events(10, 5);
    let mut cluster = Cluster::spawn(&cfg(Algorithm::Isgd, 64)).unwrap();
    cluster.ingest_batch(&evs).unwrap();
    assert_eq!(cluster.ingested(), 10);
    let report = cluster.finish().unwrap();
    assert_eq!(report.events, 10);
    assert_eq!(
        report.workers.iter().map(|w| w.processed).sum::<u64>(),
        10,
        "buffered tail must be flushed by finish()"
    );
}
