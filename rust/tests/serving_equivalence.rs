//! Serving-plane correctness under concurrency: the query plane must be
//! *semantically invisible* no matter how many threads use it.
//!
//! Two property families:
//!
//! * **Quiesced equivalence** — at any ingest watermark, for any number
//!   of concurrent caller threads, every `recommend` answer equals the
//!   answer a freshly flushed, quiescent reference cluster gives at the
//!   same watermark — for both algorithms, random ingest batch sizes and
//!   chunkings, with and without a mid-stream rescale, in-proc and over
//!   loopback TCP. And after the session, the model state of the
//!   query-hammered cluster is **byte-identical** (`state_fingerprint`)
//!   to a query-free run, with identical hit totals and recall curves.
//! * **Concurrent stress** — N reader threads issue queries *while* the
//!   owner thread ingests and performs a live rescale. No deadlock
//!   (bounded wall time), no shed below the admission threshold, no
//!   degraded answers, and every answer is well-formed.

use std::time::{Duration, Instant};

use streamrec::config::{Algorithm, RunConfig, Topology};
use streamrec::coordinator::Cluster;
use streamrec::data::synth::{SyntheticConfig, SyntheticStream};
use streamrec::data::types::Rating;
use streamrec::eval::RunReport;
use streamrec::net::WorkerServer;
use streamrec::util::proptest::forall;
use streamrec::util::rng::mix64;

fn events(n: u64, seed: u64) -> Vec<Rating> {
    SyntheticStream::new(SyntheticConfig::movielens_like(n, seed)).collect()
}

/// First `k` distinct users of a slice, in stream order.
fn panel(evs: &[Rating], k: usize) -> Vec<u64> {
    let mut users = Vec::new();
    for e in evs {
        if !users.contains(&e.user) {
            users.push(e.user);
            if users.len() == k {
                break;
            }
        }
    }
    users
}

fn cfg(algo: Algorithm, ingest_batch_size: usize) -> RunConfig {
    RunConfig {
        algorithm: algo,
        topology: Topology::new(2, 0).unwrap(),
        rescale_max_n_i: 4,
        sample_every: 200,
        ingest_batch_size,
        ..RunConfig::default()
    }
}

/// What a session produces: the panel answers after each ingest round,
/// the end-of-session state fingerprint, and the final report.
struct Outcome {
    rounds: Vec<Vec<Vec<u64>>>,
    fingerprint: u64,
    report: RunReport,
}

/// The reference: a quiescent cluster, queried single-threaded through
/// `Cluster::recommend` after each chunk (the driver thread is the only
/// thread alive, so each answer is taken at an exact watermark).
fn run_reference(
    cfg: &RunConfig,
    evs: &[Rating],
    chunk: usize,
    users: &[u64],
    rescale_round: Option<usize>,
) -> Outcome {
    let mut cluster = Cluster::spawn_labeled(cfg, "t-serve-ref").unwrap();
    let mut rounds = Vec::new();
    for (r, ch) in evs.chunks(chunk).enumerate() {
        if Some(r) == rescale_round {
            cluster.rescale(Topology::new(4, 0).unwrap()).unwrap();
        }
        cluster.ingest_batch(ch).unwrap();
        rounds.push(
            users
                .iter()
                .map(|&u| cluster.recommend(u, 10).unwrap())
                .collect(),
        );
    }
    let fingerprint = cluster.state_fingerprint().unwrap();
    let report = cluster.finish().unwrap();
    Outcome { rounds, fingerprint, report }
}

/// The noisy run: same ingest schedule, but after every chunk `threads`
/// threads query the whole panel concurrently through cloned
/// [`ServingHandle`]s. All threads must agree with each other — the
/// caller then compares the agreed answers against the reference.
fn run_noisy(
    cfg: &RunConfig,
    evs: &[Rating],
    chunk: usize,
    users: &[u64],
    threads: usize,
    rescale_round: Option<usize>,
) -> Outcome {
    let mut cluster = Cluster::spawn_labeled(cfg, "t-serve-noisy").unwrap();
    let handle = cluster.serving();
    let mut rounds = Vec::new();
    for (r, ch) in evs.chunks(chunk).enumerate() {
        if Some(r) == rescale_round {
            cluster.rescale(Topology::new(4, 0).unwrap()).unwrap();
        }
        cluster.ingest_batch(ch).unwrap();
        // No ingest is in flight now, so every thread's fence covers the
        // full ingested prefix: all answers are at the same watermark.
        let per_thread: Vec<Vec<Vec<u64>>> = std::thread::scope(|s| {
            let joins: Vec<_> = (0..threads)
                .map(|_| {
                    let h = handle.clone();
                    s.spawn(move || {
                        users
                            .iter()
                            .map(|&u| h.recommend(u, 10).unwrap())
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        });
        for t in &per_thread[1..] {
            assert_eq!(
                t, &per_thread[0],
                "round {r}: concurrent threads must agree"
            );
        }
        rounds.push(per_thread.into_iter().next().unwrap());
    }
    let m = cluster.metrics().unwrap();
    assert_eq!(m.shed_queries, 0, "panel load sits below admission");
    assert_eq!(m.degraded_queries, 0, "no worker ever failed");
    let fingerprint = cluster.state_fingerprint().unwrap();
    let report = cluster.finish().unwrap();
    Outcome { rounds, fingerprint, report }
}

/// A query-free run of the same ingest schedule, for the byte-identity
/// baseline.
fn run_silent(
    cfg: &RunConfig,
    evs: &[Rating],
    chunk: usize,
    rescale_round: Option<usize>,
) -> Outcome {
    let mut cluster = Cluster::spawn_labeled(cfg, "t-serve-silent").unwrap();
    for (r, ch) in evs.chunks(chunk).enumerate() {
        if Some(r) == rescale_round {
            cluster.rescale(Topology::new(4, 0).unwrap()).unwrap();
        }
        cluster.ingest_batch(ch).unwrap();
    }
    let fingerprint = cluster.state_fingerprint().unwrap();
    let report = cluster.finish().unwrap();
    Outcome { rounds: Vec::new(), fingerprint, report }
}

fn assert_equivalent(reference: &Outcome, noisy: &Outcome, label: &str) {
    assert_eq!(
        reference.rounds, noisy.rounds,
        "{label}: every concurrent answer must equal the quiesced \
         reference at the same watermark"
    );
    assert_eq!(
        reference.fingerprint, noisy.fingerprint,
        "{label}: queries perturbed model state"
    );
    assert_eq!(reference.report.hits, noisy.report.hits, "{label}: hits");
    assert_eq!(
        reference.report.recall_curve, noisy.report.recall_curve,
        "{label}: recall curves"
    );
}

#[test]
fn property_concurrent_queries_match_quiesced_answers_inproc() {
    // For random (algorithm, ingest batch size, chunking, ± mid-stream
    // rescale): concurrent query answers equal the quiesced reference,
    // and the queried cluster's final state is byte-identical to a
    // query-free run.
    let evs = events(2200, 71);
    let users = panel(&evs, 4);
    forall("serving_equivalence", 6, |rng| {
        let algo = if rng.next_bounded(2) == 0 {
            Algorithm::Isgd
        } else {
            Algorithm::Cosine
        };
        let batch = 1 + rng.next_bounded(200) as usize;
        let chunk = 250 + rng.next_bounded(400) as usize;
        let n_rounds = (evs.len() + chunk - 1) / chunk;
        let rescale_round = if rng.next_bounded(2) == 0 {
            Some(1 + rng.next_bounded(n_rounds.max(2) as u64 - 1) as usize)
        } else {
            None
        };
        let label = format!(
            "algo={algo:?} batch={batch} chunk={chunk} \
             rescale={rescale_round:?}"
        );
        let c = cfg(algo, batch);
        let reference = run_reference(&c, &evs, chunk, &users, rescale_round);
        let noisy = run_noisy(&c, &evs, chunk, &users, 4, rescale_round);
        let silent = run_silent(&c, &evs, chunk, rescale_round);
        assert_equivalent(&reference, &noisy, &label);
        assert_eq!(
            silent.fingerprint, noisy.fingerprint,
            "{label}: query-free state baseline"
        );
        assert_eq!(silent.report.hits, noisy.report.hits, "{label}");
        assert_eq!(
            silent.report.recall_curve, noisy.report.recall_curve,
            "{label}"
        );
    });
}

#[test]
fn concurrent_queries_match_quiesced_answers_over_tcp() {
    // The same equivalence with every worker behind loopback TCP: query
    // frames bypass the event stream on the wire (fence-parked at the
    // host), so this also pins down the remote fence path. The reference
    // is the quiesced *in-proc* cluster — transport must not matter.
    let evs = events(1400, 83);
    let users = panel(&evs, 4);
    let server = WorkerServer::bind("127.0.0.1:0").unwrap();
    let addr = format!("tcp://{}", server.local_addr());
    for algo in [Algorithm::Isgd, Algorithm::Cosine] {
        let c = cfg(algo, 64);
        let mut tcp_cfg = c.clone();
        tcp_cfg.cluster_workers = vec![addr.clone()];
        let reference = run_reference(&c, &evs, 350, &users, Some(2));
        let noisy = run_noisy(&tcp_cfg, &evs, 350, &users, 3, Some(2));
        assert_equivalent(&reference, &noisy, &format!("{algo:?} tcp"));
    }
    server.wait_idle(Duration::from_millis(100));
    server.shutdown().unwrap();
}

/// Shared body of the stress tests: `threads` readers hammer the serving
/// handle with a fixed query budget while the owner thread ingests the
/// whole stream and performs one live rescale in the middle. Returns the
/// total number of successful queries.
fn stress_session(mut cluster: Cluster, evs: &[Rating], threads: usize) -> u64 {
    let users = panel(evs, 16);
    let handle = cluster.serving();
    let t0 = Instant::now();
    let answered = std::thread::scope(|s| {
        let joins: Vec<_> = (0..threads)
            .map(|t| {
                let h = handle.clone();
                let users = &users;
                s.spawn(move || {
                    let mut ok = 0u64;
                    for i in 0..200u64 {
                        let u = users
                            [(mix64(t as u64 ^ i.wrapping_mul(31)) as usize)
                                % users.len()];
                        // n = 0 exercises the empty-ask fast path too.
                        let n = (mix64(i) % 11) as usize;
                        let recs = h.recommend(u, n).unwrap();
                        assert!(recs.len() <= n);
                        ok += 1;
                    }
                    ok
                })
            })
            .collect();

        // Owner thread: live ingest with a rescale in the middle, racing
        // the readers the whole time.
        let half = evs.len() / 2;
        cluster.ingest_batch(&evs[..half]).unwrap();
        cluster.rescale(Topology::new(4, 0).unwrap()).unwrap();
        cluster.ingest_batch(&evs[half..]).unwrap();

        joins.into_iter().map(|j| j.join().unwrap()).sum::<u64>()
    });
    assert!(
        t0.elapsed() < Duration::from_secs(120),
        "stress session must be deadlock-free and bounded"
    );
    let m = cluster.metrics().unwrap();
    assert_eq!(
        m.shed_queries, 0,
        "below the admission threshold nothing is shed"
    );
    assert_eq!(m.degraded_queries, 0, "no worker ever failed");
    assert!(m.queries > 0, "workers actually answered queries");
    assert_eq!(m.rescales, 1);
    let report = cluster.finish().unwrap();
    assert_eq!(report.events, evs.len() as u64, "no ingest lost under load");
    answered
}

#[test]
fn stress_many_readers_during_ingest_and_rescale_inproc() {
    let evs = events(6000, 91);
    let cluster =
        Cluster::spawn_labeled(&cfg(Algorithm::Isgd, 64), "t-stress").unwrap();
    let answered = stress_session(cluster, &evs, 8);
    assert_eq!(answered, 8 * 200);
}

#[test]
fn stress_many_readers_during_ingest_and_rescale_over_tcp() {
    // Same race with a mixed placement — every other worker remote over
    // loopback TCP — so concurrent queries, ingest, and the rescale all
    // cross the wire protocol's serving lane.
    let server = WorkerServer::bind("127.0.0.1:0").unwrap();
    let evs = events(4000, 97);
    let mut c = cfg(Algorithm::Isgd, 64);
    c.cluster_workers =
        vec!["local".to_string(), format!("tcp://{}", server.local_addr())];
    let cluster = Cluster::spawn_labeled(&c, "t-stress-tcp").unwrap();
    let answered = stress_session(cluster, &evs, 4);
    assert_eq!(answered, 4 * 200);
    server.wait_idle(Duration::from_millis(200));
    server.shutdown().unwrap();
}
