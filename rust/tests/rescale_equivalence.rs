//! Migration-correctness properties for `Cluster::rescale`.
//!
//! The two acceptance properties, verified for ISGD and cosine:
//!
//! * **Zero event loss** — for any split point in the stream and any
//!   old→new topology pair, the per-worker `processed` totals (live +
//!   retired generations) always sum to the number of ingested events.
//! * **Exact state migration** — a migrated user's `recommend` result
//!   immediately after a rescale equals the result immediately before,
//!   and a session that rescales mid-stream produces the *same* hit
//!   sequence, recall curve, and answers as one that never rescales
//!   (lanes evolve identically wherever they are hosted) — including
//!   under live forgetting, whose per-lane trigger clocks travel inside
//!   the lane wire frames.

use streamrec::config::{Algorithm, Forgetting, RunConfig, Topology};
use streamrec::coordinator::Cluster;
use streamrec::data::synth::{SyntheticConfig, SyntheticStream};
use streamrec::data::types::Rating;
use streamrec::eval::RunReport;
use streamrec::util::proptest::forall;

fn events(n: u64, seed: u64) -> Vec<Rating> {
    SyntheticStream::new(SyntheticConfig::netflix_like(n, seed)).collect()
}

/// Config with a 4x4 state-grid ceiling so every topology in {1, 2, 4}
/// is reachable from every other.
fn ceiling_cfg(algo: Algorithm, n_i: u64) -> RunConfig {
    RunConfig {
        algorithm: algo,
        topology: Topology::new(n_i, 0).unwrap(),
        rescale_max_n_i: 4,
        sample_every: 200,
        ..RunConfig::default()
    }
}

/// First `k` distinct users of a slice, in stream order.
fn panel(evs: &[Rating], k: usize) -> Vec<u64> {
    let mut users = Vec::new();
    for e in evs {
        if !users.contains(&e.user) {
            users.push(e.user);
            if users.len() == k {
                break;
            }
        }
    }
    users
}

#[test]
fn property_any_split_any_topology_pair_is_exact() {
    // For random (algorithm, split point, old topology, new topology):
    // (a) no events are lost across the cutover, and (b) every probed
    // user's top-10 immediately after the rescale equals the top-10
    // immediately before.
    let evs = events(2500, 77);
    forall("rescale_split_topo_pairs", 8, |rng| {
        let algo = if rng.next_bounded(2) == 0 {
            Algorithm::Isgd
        } else {
            Algorithm::Cosine
        };
        let topos = [1u64, 2, 4];
        let from = topos[rng.next_bounded(3) as usize];
        let to = topos[rng.next_bounded(3) as usize];
        let split = 200 + rng.next_bounded(evs.len() as u64 - 400) as usize;

        let mut cluster =
            Cluster::spawn_labeled(&ceiling_cfg(algo, from), "t-prop")
                .unwrap();
        cluster.ingest_batch(&evs[..split]).unwrap();

        let users = panel(&evs[..split], 6);
        let before: Vec<Vec<u64>> = users
            .iter()
            .map(|&u| cluster.recommend(u, 10).unwrap())
            .collect();

        let stats = cluster.rescale(Topology::new(to, 0).unwrap()).unwrap();
        assert_eq!(stats.from.n_i, from);
        assert_eq!(stats.to.n_i, to);

        // (a) zero loss at the cutover.
        let m = cluster.metrics().unwrap();
        assert_eq!(
            m.processed, split as u64,
            "events lost: algo={algo:?} {from}->{to} split={split}"
        );
        // (b) serving is bit-identical across the cutover.
        for (u, want) in users.iter().zip(before.iter()) {
            let got = cluster.recommend(*u, 10).unwrap();
            assert_eq!(
                &got, want,
                "user {u} answer changed: algo={algo:?} {from}->{to} \
                 split={split}"
            );
        }

        // Rest of the stream + final accounting.
        cluster.ingest_batch(&evs[split..]).unwrap();
        let report = cluster.finish().unwrap();
        assert_eq!(report.events, evs.len() as u64);
        let total: u64 = report
            .workers
            .iter()
            .chain(report.retired.iter())
            .map(|w| w.processed)
            .sum();
        assert_eq!(total, evs.len() as u64, "per-worker sums must cover all");
        assert_eq!(report.rescales, 1);
    });
}

#[test]
fn rescaled_session_equals_never_rescaled_session() {
    // The strongest form of migration correctness: a session that scales
    // out mid-stream is *semantically invisible* — identical hits, recall
    // curve, and answers to a session that never rescaled. (Both sessions
    // issue the same query sequence; cosine's read-side caches are part
    // of the migrated state, so even its bounded-staleness reads agree.)
    let evs = events(3000, 13);
    for algo in [Algorithm::Isgd, Algorithm::Cosine] {
        let users = panel(&evs, 5);
        let run = |rescale_at: Option<usize>| {
            let mut cluster =
                Cluster::spawn_labeled(&ceiling_cfg(algo, 2), "t-equiv")
                    .unwrap();
            let split = rescale_at.unwrap_or(evs.len() / 2);
            cluster.ingest_batch(&evs[..split]).unwrap();
            // Same probe sequence in both runs.
            let mid: Vec<Vec<u64>> = users
                .iter()
                .map(|&u| cluster.recommend(u, 10).unwrap())
                .collect();
            if rescale_at.is_some() {
                cluster.rescale(Topology::new(4, 0).unwrap()).unwrap();
            }
            cluster.ingest_batch(&evs[split..]).unwrap();
            let end: Vec<Vec<u64>> = users
                .iter()
                .map(|&u| cluster.recommend(u, 10).unwrap())
                .collect();
            let report = cluster.finish().unwrap();
            (mid, end, report)
        };
        let (mid_a, end_a, rep_a) = run(None);
        let (mid_b, end_b, rep_b) = run(Some(evs.len() / 2));
        assert_eq!(mid_a, mid_b, "{algo:?}: pre-rescale answers");
        assert_eq!(
            end_a, end_b,
            "{algo:?}: answers after learning on the new topology"
        );
        assert_eq!(rep_a.hits, rep_b.hits, "{algo:?}: hit totals");
        assert_eq!(
            rep_a.recall_curve, rep_b.recall_curve,
            "{algo:?}: recall curves"
        );
        assert_eq!(rep_a.events, rep_b.events);
        assert_eq!(rep_b.rescales, 1);
        assert!(rep_b.migrated_bytes > 0);
    }
}

#[test]
fn round_trip_out_and_back_preserves_answers() {
    // n_i 2 -> 4 -> 2: answers are stable at every boundary and the
    // second rescale lands the state back on a 4-worker layout.
    let evs = events(2000, 5);
    for algo in [Algorithm::Isgd, Algorithm::Cosine] {
        let mut cluster =
            Cluster::spawn_labeled(&ceiling_cfg(algo, 2), "t-round").unwrap();
        cluster.ingest_batch(&evs[..1200]).unwrap();
        let users = panel(&evs[..1200], 6);
        let want: Vec<Vec<u64>> = users
            .iter()
            .map(|&u| cluster.recommend(u, 10).unwrap())
            .collect();

        cluster.rescale(Topology::new(4, 0).unwrap()).unwrap();
        assert_eq!(cluster.n_workers(), 16);
        for (u, w) in users.iter().zip(want.iter()) {
            assert_eq!(&cluster.recommend(*u, 10).unwrap(), w, "{algo:?} out");
        }

        cluster.rescale(Topology::new(2, 0).unwrap()).unwrap();
        assert_eq!(cluster.n_workers(), 4);
        for (u, w) in users.iter().zip(want.iter()) {
            assert_eq!(&cluster.recommend(*u, 10).unwrap(), w, "{algo:?} back");
        }

        let m = cluster.metrics().unwrap();
        assert_eq!(m.processed, 1200);
        assert_eq!(m.rescales, 2);
        assert_eq!(m.router_epoch, 2);

        cluster.ingest_batch(&evs[1200..]).unwrap();
        let report = cluster.finish().unwrap();
        assert_eq!(report.events, 2000);
        let total: u64 = report
            .workers
            .iter()
            .chain(report.retired.iter())
            .map(|w| w.processed)
            .sum();
        assert_eq!(total, 2000);
    }
}

#[test]
fn forgetting_cadence_survives_rescale() {
    // PR 3 documented a caveat: forgetting trigger clocks were
    // worker-scoped and restarted at a cutover, so the equivalence
    // properties were only stated for `forgetting.kind = "none"`. The
    // clocks are per-lane now and travel inside the lane wire frames, so
    // the strongest property holds *with live forgetting*: a session
    // that rescales mid-stream has identical answers, hits, recall
    // curve, and even sweep/eviction totals to one that never rescales.
    let evs = events(3000, 41);
    for algo in [Algorithm::Isgd, Algorithm::Cosine] {
        let mut c = ceiling_cfg(algo, 2);
        // Aggressive LFU so many sweeps fire on both sides of the
        // cutover (~190 events per lane -> several sweeps per lane).
        c.forgetting =
            Forgetting::Lfu { trigger_events: 25, min_freq: 2 };
        let users = panel(&evs, 5);
        let run = |rescale: bool| {
            let mut cluster =
                Cluster::spawn_labeled(&c, "t-forget").unwrap();
            cluster.ingest_batch(&evs[..1500]).unwrap();
            if rescale {
                cluster.rescale(Topology::new(4, 0).unwrap()).unwrap();
            }
            cluster.ingest_batch(&evs[1500..]).unwrap();
            let answers: Vec<Vec<u64>> = users
                .iter()
                .map(|&u| cluster.recommend(u, 10).unwrap())
                .collect();
            let report = cluster.finish().unwrap();
            (answers, report)
        };
        let (ans_a, rep_a) = run(false);
        let (ans_b, rep_b) = run(true);
        assert_eq!(ans_a, ans_b, "{algo:?}: answers with live forgetting");
        assert_eq!(rep_a.hits, rep_b.hits, "{algo:?}: hit totals");
        assert_eq!(
            rep_a.recall_curve, rep_b.recall_curve,
            "{algo:?}: recall curves"
        );
        let totals = |r: &RunReport| {
            let all = || r.workers.iter().chain(r.retired.iter());
            (
                all().map(|w| w.sweeps).sum::<u64>(),
                all().map(|w| w.evicted).sum::<u64>(),
            )
        };
        assert_eq!(
            totals(&rep_a),
            totals(&rep_b),
            "{algo:?}: sweep/eviction totals are placement-independent"
        );
        let (sweeps, evicted) = totals(&rep_b);
        assert!(sweeps > 0, "{algo:?}: forgetting actually fired");
        assert!(evicted > 0, "{algo:?}: sweeps actually evicted state");
    }
}

#[test]
fn pressure_sweeps_survive_rescale() {
    // The `[memory]` analog of forgetting_cadence_survives_rescale:
    // here the clock trigger sits beyond the stream, so *every* sweep
    // is memory-pressure-driven. Pressure is lane-local by design —
    // each lane gets a fixed byte slice of the per-worker budget over
    // the fixed state grid, re-checked on a processed-count cadence
    // that travels inside the lane wire frames — so a mid-stream
    // rescale must change nothing: answers, hits, recall curve, and
    // sweep/eviction totals all match the never-rescaled session, even
    // while cold lanes churn through the disk tier.
    let evs = events(3000, 29);
    for algo in [Algorithm::Isgd, Algorithm::Cosine] {
        let mut c = ceiling_cfg(algo, 2);
        // Per-lane slice = 32 KiB / 16 lanes = 2 KiB: far below a
        // lane's working set, so pressure fires throughout.
        c.memory_budget_bytes = 32 * 1024;
        c.memory_check_events = 16;
        c.forgetting =
            Forgetting::Lfu { trigger_events: 1_000_000, min_freq: 2 };
        let users = panel(&evs, 5);
        let run = |rescale: bool| {
            let mut cluster =
                Cluster::spawn_labeled(&c, "t-pressure").unwrap();
            cluster.ingest_batch(&evs[..1500]).unwrap();
            if rescale {
                cluster.rescale(Topology::new(4, 0).unwrap()).unwrap();
            }
            cluster.ingest_batch(&evs[1500..]).unwrap();
            let answers: Vec<Vec<u64>> = users
                .iter()
                .map(|&u| cluster.recommend(u, 10).unwrap())
                .collect();
            let report = cluster.finish().unwrap();
            (answers, report)
        };
        let (ans_a, rep_a) = run(false);
        let (ans_b, rep_b) = run(true);
        assert_eq!(ans_a, ans_b, "{algo:?}: answers under memory pressure");
        assert_eq!(rep_a.hits, rep_b.hits, "{algo:?}: hit totals");
        assert_eq!(
            rep_a.recall_curve, rep_b.recall_curve,
            "{algo:?}: recall curves"
        );
        let totals = |r: &RunReport| {
            let all = || r.workers.iter().chain(r.retired.iter());
            (
                all().map(|w| w.sweeps).sum::<u64>(),
                all().map(|w| w.evicted).sum::<u64>(),
            )
        };
        assert_eq!(
            totals(&rep_a),
            totals(&rep_b),
            "{algo:?}: pressure sweep/eviction totals are \
             placement-independent"
        );
        let (sweeps, evicted) = totals(&rep_b);
        assert!(sweeps > 0, "{algo:?}: pressure sweeps actually fired");
        assert!(evicted > 0, "{algo:?}: pressure sweeps actually evicted");
        assert!(
            rep_a.spills > 0 && rep_b.spills > 0,
            "{algo:?}: the cap also forced the disk tier to engage"
        );
    }
}

#[test]
fn rescale_of_empty_cluster_is_cheap_and_sound() {
    // No state yet: the cutover moves nothing and the session works
    // normally afterwards.
    let mut cluster =
        Cluster::spawn(&ceiling_cfg(Algorithm::Isgd, 2)).unwrap();
    let stats = cluster.rescale(Topology::new(4, 0).unwrap()).unwrap();
    assert_eq!(stats.lanes_moved, 0, "lazily-built lanes: nothing to move");
    assert_eq!(stats.bytes_moved, 0);
    let evs = events(500, 3);
    cluster.ingest_batch(&evs).unwrap();
    let report = cluster.finish().unwrap();
    assert_eq!(report.events, 500);
    assert_eq!(report.n_workers, 16);
}
