//! Integration tests: full pipeline runs across configurations, checking
//! the system-level invariants the paper's claims rest on.

use streamrec::config::{Algorithm, Forgetting, RunConfig, Topology};
use streamrec::coordinator::{run_pipeline, Router};
use streamrec::data::synth::{SyntheticConfig, SyntheticStream};
use streamrec::data::types::Rating;
use streamrec::util::proptest::forall;

fn events(n: u64, seed: u64) -> Vec<Rating> {
    SyntheticStream::new(SyntheticConfig::movielens_like(n, seed)).collect()
}

fn base_cfg(n_i: u64) -> RunConfig {
    RunConfig {
        topology: Topology::new(n_i, 0).unwrap(),
        sample_every: 500,
        ..RunConfig::default()
    }
}

#[test]
fn every_event_processed_exactly_once_all_topologies() {
    let evs = events(5000, 1);
    for n_i in [1u64, 2, 4, 6] {
        let r = run_pipeline(&base_cfg(n_i), &evs, "once").unwrap();
        assert_eq!(
            r.workers.iter().map(|w| w.processed).sum::<u64>(),
            5000,
            "n_i={n_i}"
        );
        assert_eq!(r.n_workers as u64, n_i * n_i);
    }
}

#[test]
fn worker_load_matches_router_prediction() {
    // The pipeline must send each event to exactly the worker Algorithm 1
    // names — cross-check per-worker processed counts against a
    // host-side replay of the router.
    let evs = events(4000, 2);
    let cfg = base_cfg(4);
    let router = Router::new(cfg.topology);
    let mut expected = vec![0u64; router.n_c()];
    for e in &evs {
        expected[router.route(e.user, e.item)] += 1;
    }
    let r = run_pipeline(&cfg, &evs, "router-match").unwrap();
    let mut got = vec![0u64; router.n_c()];
    for w in &r.workers {
        got[w.worker_id] = w.processed;
    }
    assert_eq!(got, expected);
}

#[test]
fn recall_monotone_data_stays_in_bounds() {
    let evs = events(6000, 3);
    for algo in [Algorithm::Isgd, Algorithm::Cosine] {
        let mut cfg = base_cfg(2);
        cfg.algorithm = algo;
        let r = run_pipeline(&cfg, &evs, "bounds").unwrap();
        assert!(r.avg_recall >= 0.0 && r.avg_recall <= 1.0);
        for (_, v) in &r.recall_curve {
            assert!((0.0..=1.0).contains(v));
        }
        // Curve covers the whole stream.
        assert_eq!(r.recall_curve.last().unwrap().0, 5999);
    }
}

#[test]
fn distributed_runs_are_deterministic() {
    let evs = events(3000, 4);
    let a = run_pipeline(&base_cfg(2), &evs, "det-a").unwrap();
    let b = run_pipeline(&base_cfg(2), &evs, "det-b").unwrap();
    assert_eq!(a.hits, b.hits, "same seed + same routing => same hits");
    assert_eq!(a.recall_curve, b.recall_curve);
    for (wa, wb) in a.workers.iter().zip(b.workers.iter()) {
        assert_eq!(wa.processed, wb.processed);
        assert_eq!(wa.state, wb.state);
    }
}

#[test]
fn state_shrinks_as_replication_grows() {
    // Paper Figs 4/10: per-worker state means fall roughly linearly in
    // worker count.
    let evs = events(8000, 5);
    let mut prev_users = f64::INFINITY;
    for n_i in [1u64, 2, 4] {
        let r = run_pipeline(&base_cfg(n_i), &evs, "shrink").unwrap();
        let users = r.mean_user_state();
        assert!(
            users < prev_users,
            "n_i={n_i}: {users} !< {prev_users}"
        );
        prev_users = users;
    }
}

#[test]
fn forgetting_policies_bound_state_and_report_sweeps() {
    let evs = events(6000, 6);
    for (policy, forgetting) in [
        ("lru", Forgetting::Lru { trigger_secs: 10_000, max_idle_secs: 40_000 }),
        ("lfu", Forgetting::Lfu { trigger_events: 1000, min_freq: 2 }),
    ] {
        let mut cfg = base_cfg(2);
        cfg.forgetting = forgetting;
        let with = run_pipeline(&cfg, &evs, policy).unwrap();
        let without = run_pipeline(&base_cfg(2), &evs, "none").unwrap();
        let sweeps: u64 = with.workers.iter().map(|w| w.sweeps).sum();
        assert!(sweeps > 0, "{policy}: no sweeps triggered");
        assert!(
            with.mean_user_state() <= without.mean_user_state(),
            "{policy}: state must not grow beyond the non-forgetting run"
        );
    }
}

#[test]
fn cosine_distributed_beats_capped_central_throughput() {
    // Fig 14's shape: DICS >> central cosine.
    let evs = events(4000, 7);
    let mut cfg = base_cfg(1);
    cfg.algorithm = Algorithm::Cosine;
    let central = run_pipeline(&cfg, &evs[..1500], "cos-central").unwrap();
    let mut cfg = base_cfg(4);
    cfg.algorithm = Algorithm::Cosine;
    let dist = run_pipeline(&cfg, &evs, "cos-dist").unwrap();
    assert!(
        dist.throughput > central.throughput,
        "distributed {} !> central {}",
        dist.throughput,
        central.throughput
    );
}

#[test]
fn property_pipeline_conserves_events_random_topologies() {
    forall("pipeline_conservation", 8, |rng| {
        let n_i = 1 + rng.next_bounded(3);
        let w = rng.next_bounded(2);
        let n = 500 + rng.next_bounded(1000);
        let evs = events(n, rng.next_u64());
        let cfg = RunConfig {
            topology: Topology::new(n_i, w).unwrap(),
            sample_every: 200,
            ..RunConfig::default()
        };
        let r = run_pipeline(&cfg, &evs, "prop").unwrap();
        assert_eq!(
            r.workers.iter().map(|x| x.processed).sum::<u64>(),
            n
        );
        assert_eq!(r.events, n);
        assert!(r.hits <= n);
    });
}

#[test]
fn toml_config_round_trips_through_pipeline() {
    let toml = r#"
        [run]
        algorithm = "cosine"
        top_n = 5
        [topology]
        n_i = 2
        [forgetting]
        kind = "lfu"
        # Per-worker trigger: 2000 events over 4 workers ~= 500 each.
        trigger_events = 200
        min_freq = 2
    "#;
    let cfg = RunConfig::from_toml(toml).unwrap();
    let evs = events(2000, 8);
    let r = run_pipeline(&cfg, &evs, "toml").unwrap();
    assert_eq!(r.n_workers, 4);
    assert!(r.workers.iter().map(|w| w.sweeps).sum::<u64>() > 0);
}
