//! Drift-transformer overhead benchmark: the scenario engine wraps the
//! synthetic generator on the ingest path of every drift experiment, so
//! its per-event cost must stay a rounding error next to routing and
//! model updates. Measures events/s for the bare generator and for each
//! drift shape layered over it.

use std::time::Duration;

use streamrec::benchutil::{bench_batch, black_box};
use streamrec::data::drift::{DriftConfig, DriftKind, DriftStream};
use streamrec::data::synth::SyntheticConfig;

fn main() {
    const EVENTS: u64 = 100_000;
    println!("== drift stream generation (per-event overhead) ==");
    let shapes: [(&str, Option<DriftKind>); 7] = [
        ("base (no drift)", None),
        ("abrupt", Some(DriftKind::Abrupt { at: 0.5 })),
        ("rotate", Some(DriftKind::Rotate { start: 0.25, end: 0.75 })),
        (
            "recurring",
            Some(DriftKind::Recurring { period_events: 10_000 }),
        ),
        ("invert", Some(DriftKind::Invert { at: 0.5 })),
        ("churn", Some(DriftKind::Churn { at: 0.5, fraction: 0.5 })),
        (
            "burst",
            Some(DriftKind::Burst { at: 0.4, len: 0.2, factor: 8.0 }),
        ),
    ];
    for (name, kind) in shapes {
        bench_batch(
            &format!("drift/{name}"),
            EVENTS,
            1,
            3,
            Duration::from_millis(600),
            || {
                let stream = DriftStream::new(
                    SyntheticConfig::movielens_like(EVENTS, 42),
                    DriftConfig { kind },
                );
                let mut n = 0u64;
                for r in stream {
                    n += black_box(r.item) & 1;
                }
                black_box(n);
            },
        );
    }
}
