//! Open-loop serving-latency load harness (records `BENCH_serving.json`).
//!
//! Queries arrive on a fixed schedule — arrival `i` at `t0 + i/qps` —
//! pulled by a pool of reader threads from a shared atomic counter,
//! while the owner thread ingests the live stream the whole time. The
//! recorded latency is *completion minus scheduled arrival*, so queue
//! wait from falling behind the schedule is charged to the system, not
//! hidden by a coordinated caller (the open-loop/SLO view). Each row
//! runs against a fresh cluster; the `mixed-tcp` rows cycle worker
//! placement between local threads and a loopback-TCP host, so query
//! frames also cross the wire protocol's serving lane.
//!
//! `SERVING_BENCH_SMOKE=1` switches to a single low-QPS short window per
//! transport (the CI smoke: real measured rows, tiny budget).
//!
//! Schema of the emitted rows: docs/EXPERIMENTS.md.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use streamrec::benchutil::black_box;
use streamrec::config::{RunConfig, Topology};
use streamrec::coordinator::Cluster;
use streamrec::data::types::Rating;
use streamrec::data::DatasetSpec;
use streamrec::net::WorkerServer;
use streamrec::util::histogram::Histogram;
use streamrec::util::json::{num, obj, s, to_string, Json};
use streamrec::util::rng::mix64;

/// One load point: a target arrival rate sustained for a window.
struct LoadSpec {
    qps: u64,
    seconds: u64,
    threads: usize,
}

/// First `k` distinct users of a slice, in stream order.
fn panel(evs: &[Rating], k: usize) -> Vec<u64> {
    let mut users = Vec::new();
    for e in evs {
        if !users.contains(&e.user) {
            users.push(e.user);
            if users.len() == k {
                break;
            }
        }
    }
    users
}

/// Drive one row: warm the models, then run the open-loop window with
/// live ingest racing the readers.
fn run_row(
    cfg: &RunConfig,
    transport: &str,
    warm: &[Rating],
    live: &[Rating],
    spec: &LoadSpec,
) -> anyhow::Result<Json> {
    let mut cluster = Cluster::spawn_labeled(
        cfg,
        &format!("serve-{transport}-{}qps", spec.qps),
    )?;
    cluster.ingest_batch(warm)?;
    let users = panel(warm, 64);
    let handle = cluster.serving();
    let total = spec.qps * spec.seconds;
    let window = Duration::from_secs(spec.seconds);
    let next = AtomicU64::new(0);
    let t0 = Instant::now();

    let (hist, ingested) = std::thread::scope(|sc| {
        let joins: Vec<_> = (0..spec.threads)
            .map(|t| {
                let h = handle.clone();
                let next = &next;
                let users = &users;
                sc.spawn(move || {
                    let mut hist = Histogram::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= total {
                            break;
                        }
                        let sched = Duration::from_nanos(
                            i.saturating_mul(1_000_000_000) / spec.qps,
                        );
                        // Wait for the scheduled arrival. The schedule
                        // never slips: a slow answer makes the *next*
                        // arrival late, and that lateness is measured.
                        loop {
                            let now = t0.elapsed();
                            if now >= sched {
                                break;
                            }
                            std::thread::sleep(
                                (sched - now).min(Duration::from_millis(1)),
                            );
                        }
                        let u = users[(mix64(i ^ ((t as u64) << 32))
                            as usize)
                            % users.len()];
                        black_box(h.recommend(u, 10).expect("loadgen query"));
                        hist.record((t0.elapsed() - sched).as_nanos() as u64);
                    }
                    hist
                })
            })
            .collect();

        // The owner thread keeps the cluster under ingest load for the
        // whole window (stopping early only if the stream runs out).
        let mut ingested = 0u64;
        for chunk in live.chunks(512) {
            if t0.elapsed() >= window {
                break;
            }
            cluster.ingest_batch(chunk).expect("live ingest");
            ingested += chunk.len() as u64;
        }

        let mut merged = Histogram::new();
        for j in joins {
            merged.merge(&j.join().expect("reader thread"));
        }
        (merged, ingested)
    });

    let wall = t0.elapsed().as_secs_f64();
    let m = cluster.metrics()?;
    cluster.finish()?;

    let p50 = hist.quantile(0.5) as f64 / 1e3;
    let p99 = hist.quantile(0.99) as f64 / 1e3;
    let p999 = hist.quantile(0.999) as f64 / 1e3;
    println!(
        "{transport:>10} {:>7} {:>9.0} {:>10.1} {:>10.1} {:>10.1} {:>6} {:>8}",
        spec.qps,
        total as f64 / wall,
        p50,
        p99,
        p999,
        m.shed_queries,
        ingested,
    );
    Ok(obj(vec![
        ("transport", s(transport)),
        ("qps_target", num(spec.qps as f64)),
        ("qps_achieved", num(total as f64 / wall)),
        ("queries", num(total as f64)),
        ("threads", num(spec.threads as f64)),
        ("p50_us", num(p50)),
        ("p99_us", num(p99)),
        ("p999_us", num(p999)),
        ("shed", num(m.shed_queries as f64)),
        ("cache_hits", num(m.cache_hits as f64)),
        ("degraded", num(m.degraded_queries as f64)),
        ("ingest_events", num(ingested as f64)),
        ("wall_s", num(wall)),
    ]))
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("SERVING_BENCH_SMOKE")
        .map(|v| v != "0" && !v.is_empty())
        .unwrap_or(false);
    println!(
        "== serving plane: open-loop load under live ingest{} ==",
        if smoke { " (smoke)" } else { "" }
    );

    let dataset = if smoke { "ml-like:20000" } else { "ml-like:120000" };
    let events = DatasetSpec::parse(dataset, 33)?.load()?;
    let (warm, live) = events.split_at(events.len() / 3);

    let cfg = RunConfig {
        topology: Topology::new(2, 0)?,
        sample_every: 10_000,
        ..RunConfig::default()
    };
    let server = WorkerServer::bind("127.0.0.1:0")?;
    let placements: Vec<(&str, Vec<String>)> = vec![
        ("inproc", Vec::new()),
        (
            "mixed-tcp",
            vec![
                "local".to_string(),
                format!("tcp://{}", server.local_addr()),
            ],
        ),
    ];
    let specs: Vec<LoadSpec> = if smoke {
        vec![LoadSpec { qps: 200, seconds: 2, threads: 2 }]
    } else {
        vec![
            LoadSpec { qps: 1_000, seconds: 3, threads: 4 },
            LoadSpec { qps: 4_000, seconds: 3, threads: 4 },
            LoadSpec { qps: 16_000, seconds: 3, threads: 8 },
        ]
    };

    println!(
        "{:>10} {:>7} {:>9} {:>10} {:>10} {:>10} {:>6} {:>8}",
        "transport",
        "qps",
        "achieved",
        "p50 (us)",
        "p99 (us)",
        "p999 (us)",
        "shed",
        "ingest"
    );
    let mut rows: Vec<Json> = Vec::new();
    for (transport, workers) in &placements {
        let mut c = cfg.clone();
        c.cluster_workers = workers.clone();
        for spec in &specs {
            rows.push(run_row(&c, transport, warm, live, spec)?);
        }
    }
    server.wait_idle(Duration::from_millis(200));
    server.shutdown()?;

    let doc = obj(vec![
        (
            "bench",
            s("serving plane: open-loop query latency under live ingest"),
        ),
        ("dataset", s(dataset)),
        ("algorithm", s("isgd")),
        ("n_i", num(2.0)),
        ("smoke", num(if smoke { 1.0 } else { 0.0 })),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_serving.json", to_string(&doc) + "\n")?;
    println!("\n(recorded in BENCH_serving.json)");
    Ok(())
}
