//! Serving-path benchmarks: `Cluster::recommend` latency while the
//! cluster is under concurrent ingest load, plus the rank-aware replica
//! merge in isolation.
//!
//! The recommend number is the one a latency SLO cares about: each query
//! queues behind the in-flight events of the user's replicas (per-worker
//! FIFO), so it includes the queue wait a live system actually pays.

use std::collections::HashSet;
use std::time::{Duration, Instant};

use streamrec::benchutil::{bench, black_box};
use streamrec::config::{RunConfig, Topology};
use streamrec::coordinator::Cluster;
use streamrec::data::DatasetSpec;
use streamrec::eval::merge_topn;
use streamrec::util::histogram::Histogram;

fn main() -> anyhow::Result<()> {
    println!("== serving-path benchmarks ==");

    // 1) Replica merge in isolation: n_i disjoint ranked lists of 10.
    for n_i in [2usize, 4, 6] {
        let lists: Vec<Vec<u64>> = (0..n_i)
            .map(|r| (0..10u64).map(|i| i * n_i as u64 + r as u64).collect())
            .collect();
        let exclude: HashSet<u64> = [3u64, 17, 23].into_iter().collect();
        bench(
            &format!("merge_topn/{n_i}x10"),
            1000,
            20_000,
            Duration::from_millis(200),
            || {
                black_box(merge_topn(
                    black_box(&lists),
                    black_box(&exclude),
                    10,
                ));
            },
        );
    }

    // 2) recommend() latency under concurrent ingest, central vs n_i=2/4.
    let events = DatasetSpec::parse("ml-like:60000", 33)?.load()?;
    // "session ev/s" = events / (first ingest .. finish) wall clock; the
    // window deliberately includes the interleaved query round-trips.
    println!(
        "\n{:>4} {:>10} {:>12} {:>12} {:>12}",
        "n_i", "queries", "p50 (us)", "p99 (us)", "session ev/s"
    );
    for n_i in [1u64, 2, 4] {
        let cfg = RunConfig {
            topology: Topology::new(n_i, 0)?,
            sample_every: 10_000,
            ..RunConfig::default()
        };
        let mut cluster =
            Cluster::spawn_labeled(&cfg, &format!("serve-ni{n_i}"))?;
        // Warm the models with the first half of the stream.
        let (warm, live) = events.split_at(events.len() / 2);
        cluster.ingest_batch(warm)?;
        let hot_user = warm[0].user;

        // Interleave: every chunk of ingest keeps the worker queues busy,
        // then one timed query rides behind that load.
        let mut hist = Histogram::new();
        let mut queries = 0u64;
        for chunk in live.chunks(250) {
            cluster.ingest_batch(chunk)?;
            let t0 = Instant::now();
            let recs = cluster.recommend(hot_user, 10)?;
            hist.record(t0.elapsed().as_nanos() as u64);
            black_box(recs);
            queries += 1;
        }
        let report = cluster.finish()?;
        println!(
            "{n_i:>4} {queries:>10} {:>12.1} {:>12.1} {:>12.0}",
            hist.quantile(0.5) as f64 / 1e3,
            hist.quantile(0.99) as f64 / 1e3,
            report.throughput
        );
    }
    Ok(())
}
