//! Recovery-pause benchmark: when a worker dies, how long does the
//! supervisor take to bring its lanes back, and how does that pause
//! scale with state size and checkpoint cadence?
//!
//! For each algorithm, warm-up size, and `fault.checkpoint_interval`
//! the bench spawns an `n_i = 2` fault-tolerant cluster, ingests the
//! prefix, and injects a deterministic chaos kill on the stream's last
//! event. The next probe detects the crash and the supervisor recovers
//! the worker (respawn + checkpoint restore + watermark-filtered
//! replay); the bench records the recovery pause, the replayed-event
//! count, and the checkpoint volume. A smaller interval means more
//! checkpoint traffic but a shorter replay — this bench is the knob's
//! price list. Results are written to `BENCH_recovery.json` (current
//! working directory), mirroring the `BENCH_rescale.json` convention.
//!
//! `RECOVERY_BENCH_SMOKE=1` (CI, `scripts/record_bench.sh --smoke`)
//! shrinks to one warm size and one interval, same row schema and the
//! same recovery assertions.

use streamrec::config::{Algorithm, RunConfig, Topology};
use streamrec::coordinator::Cluster;
use streamrec::data::DatasetSpec;
use streamrec::util::json::{num, obj, s, to_string, Json};

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("RECOVERY_BENCH_SMOKE")
        .map(|v| v != "0" && !v.is_empty())
        .unwrap_or(false);
    println!("== recovery benchmarks (pause vs state size, smoke={smoke}) ==");
    let dataset = if smoke { "nf-like:5000" } else { "nf-like:120000" };
    let events = DatasetSpec::parse(dataset, 33)?.load()?;
    let warms: &[usize] =
        if smoke { &[4_000] } else { &[5_000, 20_000, 80_000] };
    let intervals: &[u64] = if smoke { &[512] } else { &[512, 8_192] };

    println!(
        "{:8} {:>9} {:>9} | {:>11} {:>9} {:>13}",
        "algo", "events", "ckpt_ivl", "pause", "replayed", "ckpt_bytes"
    );
    let mut rows = Vec::new();
    for algo in [Algorithm::Isgd, Algorithm::Cosine] {
        for &warm in warms {
            for &interval in intervals {
                let cfg = RunConfig {
                    algorithm: algo,
                    topology: Topology::new(2, 0)?,
                    sample_every: 10_000,
                    fault_checkpoint_interval: interval,
                    fault_replay_log_capacity: 1 << 17,
                    // Kill the worker that processes the last event —
                    // maximal state, maximal post-checkpoint suffix.
                    fault_chaos_kill_seq: Some(warm as u64 - 1),
                    ..RunConfig::default()
                };
                let mut cluster = Cluster::spawn_labeled(
                    &cfg,
                    &format!(
                        "bench-recovery-{}-{warm}-{interval}",
                        algo.name()
                    ),
                )?;
                cluster.ingest_batch(&events[..warm])?;
                // Flush the buffered tail (the kill seq is the *last*
                // event, which may still sit in a route buffer); the
                // metrics probe then forces crash detection if the
                // flush has not already tripped over it.
                cluster.flush()?;
                let m = cluster.metrics()?;
                assert_eq!(m.recoveries, 1, "bench kill must have fired");
                assert_eq!(m.processed, warm as u64, "bench lost events");
                let report = cluster.finish()?;
                assert_eq!(report.events, warm as u64);

                println!(
                    "{:8} {:>9} {:>9} | {:>8.2} ms {:>9} {:>13}",
                    algo.name(),
                    warm,
                    interval,
                    m.recovery_pause_ns as f64 / 1e6,
                    m.replayed_events,
                    m.checkpoint_bytes,
                );
                rows.push(obj(vec![
                    ("algorithm", s(algo.name())),
                    ("warm_events", num(warm as f64)),
                    ("checkpoint_interval", num(interval as f64)),
                    (
                        "recovery_pause_ns",
                        num(m.recovery_pause_ns as f64),
                    ),
                    ("replayed_events", num(m.replayed_events as f64)),
                    ("checkpoint_bytes", num(m.checkpoint_bytes as f64)),
                ]));
            }
        }
    }
    let doc = obj(vec![
        ("bench", s("recovery pause vs state size")),
        ("dataset", s(&format!("{dataset} (seed 33)"))),
        ("smoke", num(if smoke { 1.0 } else { 0.0 })),
        (
            "scenario",
            s("n_i 2 (4 workers), kill the worker processing the last \
               event, recover via checkpoint restore + replay"),
        ),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_recovery.json", to_string(&doc) + "\n")?;
    println!("(recorded in BENCH_recovery.json)");
    Ok(())
}
