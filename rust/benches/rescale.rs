//! Rescale-cutover benchmark: how long is the session paused, and how
//! does that pause scale with the amount of model state that has to
//! move?
//!
//! For each algorithm and each warm-up size the bench spawns an
//! `n_i = 2` cluster with a 4x4 state-grid ceiling, ingests the prefix,
//! then measures a scale-out (`n_i 2 -> 4`, 4 -> 16 workers) followed by
//! a scale-in (`4 -> 2`), recording pause wall-time, bytes moved, and
//! lanes moved for both directions. Results are written to
//! `BENCH_rescale.json` (current working directory), mirroring the
//! `BENCH_ingest.json` convention.
//!
//! `RESCALE_BENCH_SMOKE=1` (CI, `scripts/record_bench.sh --smoke`)
//! shrinks the stream to one warm size per algorithm, same row schema.

use streamrec::config::{Algorithm, RunConfig, Topology};
use streamrec::coordinator::Cluster;
use streamrec::data::DatasetSpec;
use streamrec::util::json::{num, obj, s, to_string, Json};

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("RESCALE_BENCH_SMOKE")
        .map(|v| v != "0" && !v.is_empty())
        .unwrap_or(false);
    println!("== rescale benchmarks (pause vs state size, smoke={smoke}) ==");
    let dataset = if smoke { "nf-like:5000" } else { "nf-like:120000" };
    let events = DatasetSpec::parse(dataset, 33)?.load()?;
    let warms: &[usize] =
        if smoke { &[3_000] } else { &[5_000, 20_000, 80_000] };

    println!(
        "{:8} {:>9} {:>12} | {:>11} {:>11} {:>7} | {:>11} {:>11}",
        "algo",
        "events",
        "state_bytes",
        "out_pause",
        "out_MB/s",
        "lanes",
        "in_pause",
        "in_MB/s"
    );
    let mut rows = Vec::new();
    for algo in [Algorithm::Isgd, Algorithm::Cosine] {
        for &warm in warms {
            let cfg = RunConfig {
                algorithm: algo,
                topology: Topology::new(2, 0)?,
                rescale_max_n_i: 4,
                sample_every: 10_000,
                ..RunConfig::default()
            };
            let mut cluster = Cluster::spawn_labeled(
                &cfg,
                &format!("bench-rescale-{}-{warm}", algo.name()),
            )?;
            cluster.ingest_batch(&events[..warm])?;

            let out = cluster.rescale(Topology::new(4, 0)?)?;
            let back = cluster.rescale(Topology::new(2, 0)?)?;
            let report = cluster.finish()?;
            assert_eq!(report.events, warm as u64, "bench lost events");

            let mbps = |bytes: u64, ns: u64| {
                bytes as f64 / 1e6 / (ns as f64 / 1e9).max(1e-9)
            };
            println!(
                "{:8} {:>9} {:>12} | {:>8.2} ms {:>11.1} {:>7} | {:>8.2} ms \
                 {:>11.1}",
                algo.name(),
                warm,
                out.bytes_moved,
                out.pause_ns as f64 / 1e6,
                mbps(out.bytes_moved, out.pause_ns),
                out.lanes_moved,
                back.pause_ns as f64 / 1e6,
                mbps(back.bytes_moved, back.pause_ns),
            );
            rows.push(obj(vec![
                ("algorithm", s(algo.name())),
                ("warm_events", num(warm as f64)),
                ("state_bytes", num(out.bytes_moved as f64)),
                ("lanes", num(out.lanes_moved as f64)),
                ("scale_out_pause_ns", num(out.pause_ns as f64)),
                ("scale_in_pause_ns", num(back.pause_ns as f64)),
                ("scale_in_bytes", num(back.bytes_moved as f64)),
            ]));
        }
    }
    let doc = obj(vec![
        ("bench", s("rescale pause vs state size")),
        ("dataset", s(&format!("{dataset} (seed 33)"))),
        ("topologies", s("n_i 2 -> 4 -> 2, state grid 4x4")),
        ("smoke", num(if smoke { 1.0 } else { 0.0 })),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_rescale.json", to_string(&doc) + "\n")?;
    println!("(recorded in BENCH_rescale.json)");
    Ok(())
}
