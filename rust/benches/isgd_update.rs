//! ISGD update-step benchmarks: native Rust vs the PJRT AOT artifact
//! (per-event and per-call cost). The gap is the PJRT dispatch overhead
//! the batched `recupd` path amortizes — see EXPERIMENTS.md §Perf.

use std::time::Duration;

use streamrec::benchutil::{bench, black_box};
use streamrec::runtime::{NativeBackend, ScoringBackend};
use streamrec::util::rng::Pcg32;

fn main() {
    println!("== isgd update benchmarks ==");
    let budget = Duration::from_millis(400);
    let k = 10;
    let mut rng = Pcg32::seeded(2);
    let mut u: Vec<f32> = (0..k).map(|_| rng.next_f32() - 0.5).collect();
    let mut i: Vec<f32> = (0..k).map(|_| rng.next_f32() - 0.5).collect();

    let mut native = NativeBackend::new();
    bench("isgd_step/native_k10", 1000, 10_000, budget, || {
        black_box(native.isgd_step(&mut u, &mut i, 0.05, 0.01));
    });

    if std::path::Path::new("artifacts/manifest.json").exists() {
        let mut engine =
            streamrec::runtime::pjrt::PjrtEngine::new("artifacts").unwrap();
        // Warm the executable cache outside the timed region.
        let _ = engine.isgd_step(&mut u, &mut i, 0.05, 0.01).unwrap();
        bench(
            "isgd_step/pjrt_k10",
            10,
            200,
            Duration::from_millis(800),
            || {
                black_box(
                    engine.isgd_step(&mut u, &mut i, 0.05, 0.01).unwrap(),
                );
            },
        );
        println!(
            "(pjrt exec_calls={} compiles={})",
            engine.exec_calls, engine.compile_count
        );
    } else {
        println!("artifacts/ missing — run `make artifacts` for PJRT numbers");
    }
}
