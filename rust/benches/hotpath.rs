//! Consolidated hot-path kernel benchmarks → `BENCH_hotpath.json`.
//!
//! One bench records every per-event kernel the runtime leans on — the
//! ISGD step, native top-N scoring, cosine estimate/recommend, router
//! hashing, forgetting sweeps, and the TCP frame-encode path — as
//! ns/op + ops/sec rows. For each kernel this PR optimized, the
//! *pre-optimization shape is frozen here* as a baseline closure and
//! measured side by side, so the committed JSON carries honest
//! baseline-vs-optimized `compare` rows (speedup = baseline/optimized)
//! instead of numbers nobody can reproduce. Every paired variant is
//! also asserted answer-identical before anything is timed.
//!
//! `HOTPATH_BENCH_SMOKE=1` (CI, `scripts/record_bench.sh --smoke`)
//! shrinks shapes and budgets but records the same row schema.

use std::collections::HashSet;
use std::time::Duration;

use streamrec::algorithms::{CosineModel, StreamingRecommender};
use streamrec::benchutil::{bench, bench_batch, black_box, BenchResult};
use streamrec::config::Topology;
use streamrec::coordinator::Router;
use streamrec::data::types::Rating;
use streamrec::runtime::{NativeBackend, Scored, ScoringBackend};
use streamrec::state::{TrackedMap, VectorSlab};
use streamrec::util::json::{num, obj, s, to_string, Json};
use streamrec::util::rng::Pcg32;
use streamrec::util::wire::WireWriter;

fn filled_slab(rows: usize, k: usize, rng: &mut Pcg32) -> VectorSlab {
    let mut slab = VectorSlab::new(k);
    for id in 0..rows as u64 {
        let v: Vec<f32> = (0..k).map(|_| rng.next_f32() - 0.5).collect();
        slab.insert(id, &v, 0);
    }
    slab
}

fn row_json(r: &BenchResult) -> Json {
    obj(vec![
        ("kernel", s(&r.name)),
        ("iters", num(r.iters as f64)),
        ("mean_ns", num(r.mean_ns)),
        ("p50_ns", num(r.p50_ns as f64)),
        ("p99_ns", num(r.p99_ns as f64)),
        ("per_sec", num(r.throughput_per_sec)),
    ])
}

fn compare_json(kernel: &str, base: &BenchResult, opt: &BenchResult) -> Json {
    obj(vec![
        ("kernel", s(kernel)),
        ("baseline_ns", num(base.mean_ns)),
        ("optimized_ns", num(opt.mean_ns)),
        ("speedup", num(base.mean_ns / opt.mean_ns.max(1e-9))),
    ])
}

/// The cosine ranking tail exactly as it was before the select-nth
/// optimization: full sort of the whole candidate set, take n.
fn rank_tail_full_sort(scored: &mut [(f32, f32, u64)], n: usize) -> Vec<u64> {
    scored.sort_unstable_by(|a, b| {
        b.0.total_cmp(&a.0).then(b.1.total_cmp(&a.1)).then(a.2.cmp(&b.2))
    });
    scored.iter().take(n).map(|&(_, _, p)| p).collect()
}

/// The optimized tail: select-nth, truncate, sort only the prefix
/// (the shape now in `CosineModel::rank`).
fn rank_tail_select(scored: &mut Vec<(f32, f32, u64)>, n: usize) -> Vec<u64> {
    let by_rank = |a: &(f32, f32, u64), b: &(f32, f32, u64)| {
        b.0.total_cmp(&a.0).then(b.1.total_cmp(&a.1)).then(a.2.cmp(&b.2))
    };
    if scored.len() > n {
        if n == 0 {
            scored.clear();
        } else {
            scored.select_nth_unstable_by(n - 1, by_rank);
            scored.truncate(n);
        }
    }
    scored.sort_unstable_by(by_rank);
    scored.iter().take(n).map(|&(_, _, p)| p).collect()
}

/// Encode one Events-shaped frame body (tag, count, then
/// 36 bytes/event) into `w` — the wire layout of the hot TCP path.
fn encode_events(w: &mut WireWriter, events: &[(u64, u64, u64, f32, u64)]) {
    w.u8(2);
    w.u32(events.len() as u32);
    for &(seq, user, item, rating, ts) in events {
        w.u64(seq);
        w.u64(user);
        w.u64(item);
        w.f32(rating);
        w.u64(ts);
    }
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("HOTPATH_BENCH_SMOKE")
        .map(|v| v != "0" && !v.is_empty())
        .unwrap_or(false);
    println!("== hot-path kernel benchmarks (smoke={smoke}) ==");
    let budget = Duration::from_millis(if smoke { 120 } else { 400 });
    let min_iters = if smoke { 500 } else { 2_000 };
    let k = 10usize;
    let mut rng = Pcg32::seeded(9);
    let mut rows: Vec<Json> = Vec::new();
    let mut compare: Vec<Json> = Vec::new();

    // ---- isgd step ------------------------------------------------
    {
        let mut u: Vec<f32> = (0..k).map(|_| rng.next_f32() - 0.5).collect();
        let mut i: Vec<f32> = (0..k).map(|_| rng.next_f32() - 0.5).collect();
        let mut be = NativeBackend::new();
        let r = bench("isgd_step/native_k10", 1000, 10_000, budget, || {
            black_box(be.isgd_step(&mut u, &mut i, 0.05, 0.01));
        });
        rows.push(row_json(&r));
    }

    // ---- native top-N: alloc-per-query vs reused scratch ----------
    // The baseline is the pre-optimization per-query cost shape (a
    // fresh exact-sized Vec allocated and dropped every call — the
    // `topn` convenience wrapper preserves it); the optimized variant
    // threads one warm scratch through `topn_into`, the way
    // `IsgdModel::recommend` now does. Small slabs are the serving
    // steady state: per-lane shards after forgetting keep row counts
    // in the tens-to-hundreds, where the allocation is a large slice
    // of the per-query cost.
    let topn_shapes: &[(usize, usize)] = if smoke {
        &[(64, 10), (512, 50)]
    } else {
        &[(64, 10), (512, 50), (4000, 50)]
    };
    for &(m, n) in topn_shapes {
        let slab = filled_slab(m, k, &mut rng);
        let u: Vec<f32> = (0..k).map(|_| rng.next_f32() - 0.5).collect();
        let mut be = NativeBackend::new();
        let mut scratch: Vec<Scored> = Vec::new();
        be.topn_into(&u, &slab, n, &mut scratch);
        assert_eq!(be.topn(&u, &slab, n), scratch, "paired variants agree");
        let base =
            bench(&format!("topn/m{m}_n{n}/alloc"), 200, min_iters, budget, || {
                black_box(be.topn(&u, &slab, n));
            });
        let opt = bench(
            &format!("topn/m{m}_n{n}/scratch"),
            200,
            min_iters,
            budget,
            || {
                be.topn_into(&u, &slab, n, &mut scratch);
                black_box(scratch.len());
            },
        );
        rows.push(row_json(&base));
        rows.push(row_json(&opt));
        compare.push(compare_json(&format!("topn/m{m}_n{n}"), &base, &opt));
    }

    // ---- cosine ranking tail: full sort vs select-nth -------------
    let rank_shapes: &[usize] = if smoke { &[512] } else { &[512, 4096] };
    for &c in rank_shapes {
        let n = 10usize;
        let master: Vec<(f32, f32, u64)> = (0..c as u64)
            .map(|id| (rng.next_f32(), rng.next_f32(), id))
            .collect();
        let mut scratch: Vec<(f32, f32, u64)> = Vec::with_capacity(c);
        scratch.clone_from(&master);
        let want = rank_tail_full_sort(&mut scratch, n);
        scratch.clone_from(&master);
        assert_eq!(rank_tail_select(&mut scratch, n), want, "tails agree");
        let base = bench(
            &format!("cosine_rank/c{c}_n{n}/full_sort"),
            50,
            min_iters,
            budget,
            || {
                scratch.clone_from(&master);
                black_box(rank_tail_full_sort(&mut scratch, n));
            },
        );
        let opt = bench(
            &format!("cosine_rank/c{c}_n{n}/select_nth"),
            50,
            min_iters,
            budget,
            || {
                scratch.clone_from(&master);
                black_box(rank_tail_select(&mut scratch, n));
            },
        );
        rows.push(row_json(&base));
        rows.push(row_json(&opt));
        compare.push(compare_json(&format!("cosine_rank/c{c}_n{n}"), &base, &opt));
    }

    // ---- cosine estimate + recommend (rebuild-inclusive) ----------
    {
        let warm = if smoke { 6_000 } else { 20_000 };
        let mut m = CosineModel::fast(k);
        for step in 0..warm as u64 {
            let user = rng.next_bounded(300);
            let item = rng.next_bounded(600);
            m.update(&Rating::new(user, item, 5.0, step));
        }
        let mut user = 0u64;
        let r = bench("cosine/recommend_fast_n10", 50, 500, budget, || {
            black_box(m.recommend(user % 300, 10).len());
            user += 1;
        });
        rows.push(row_json(&r));
        let rated: HashSet<u64> = m.rated_items(7).into_iter().collect();
        let mut p = 0u64;
        let r = bench("cosine/estimate_cached", 200, min_iters, budget, || {
            black_box(m.estimate(p % 600, &rated));
            p += 1;
        });
        rows.push(row_json(&r));
    }

    // ---- router hash ----------------------------------------------
    {
        let router = Router::new(Topology::new(4, 0)?);
        let pairs: Vec<(u64, u64)> =
            (0..4096).map(|_| (rng.next_u64(), rng.next_u64())).collect();
        let mut i = 0usize;
        let r = bench("route_closed_form/ni4", 1000, 10_000, budget, || {
            let (u, it) = pairs[i & 4095];
            black_box(router.route(u, it));
            i += 1;
        });
        rows.push(row_json(&r));
    }

    // ---- forgetting sweeps ----------------------------------------
    let sweep_sizes: &[usize] =
        if smoke { &[10_000] } else { &[10_000, 100_000] };
    for &n in sweep_sizes {
        let r = bench_batch(
            &format!("sweep_lru/slab_{n}"),
            n as u64,
            2,
            if smoke { 3 } else { 10 },
            budget,
            || {
                let mut slab = VectorSlab::new(10);
                for id in 0..n as u64 {
                    slab.insert(id, &[0.0; 10], rng.next_bounded(1000));
                }
                black_box(slab.sweep_lru(500).len());
            },
        );
        rows.push(row_json(&r));
        let r = bench_batch(
            &format!("sweep_lfu/map_{n}"),
            n as u64,
            2,
            if smoke { 3 } else { 10 },
            budget,
            || {
                let mut map: TrackedMap<u64, [f32; 10]> = TrackedMap::new();
                for id in 0..n as u64 {
                    map.insert(id, [0.0; 10], 0);
                    if id % 2 == 0 {
                        map.touch_mut(&id, 1);
                    }
                }
                black_box(map.sweep_lfu(2).len());
            },
        );
        rows.push(row_json(&r));
    }

    // ---- TCP event-frame encode: fresh writer vs recycled buffer --
    // The baseline freezes the pre-optimization write path (a fresh
    // growable writer per frame, so each frame pays the growth-doubling
    // reallocs); the optimized variant recycles one allocation the way
    // `write_frame_into` now does under `FrameChaos`.
    let batch_shapes: &[usize] = if smoke { &[256] } else { &[16, 256] };
    for &b in batch_shapes {
        let events: Vec<(u64, u64, u64, f32, u64)> = (0..b as u64)
            .map(|i| (i, rng.next_u64(), rng.next_u64(), 5.0, i))
            .collect();
        let mut w = WireWriter::new();
        encode_events(&mut w, &events);
        let want = w.into_bytes();
        let mut buf: Vec<u8> = Vec::new();
        let mut ww = WireWriter::from_vec(std::mem::take(&mut buf));
        ww.reserve(5 + 36 * events.len());
        encode_events(&mut ww, &events);
        buf = ww.into_bytes();
        assert_eq!(buf, want, "paired variants encode identically");
        let base = bench(
            &format!("wire_encode/events{b}/fresh_alloc"),
            200,
            min_iters,
            budget,
            || {
                let mut w = WireWriter::new();
                encode_events(&mut w, &events);
                black_box(w.into_bytes().len());
            },
        );
        let opt = bench(
            &format!("wire_encode/events{b}/recycled"),
            200,
            min_iters,
            budget,
            || {
                let mut w = WireWriter::from_vec(std::mem::take(&mut buf));
                w.reserve(5 + 36 * events.len());
                encode_events(&mut w, &events);
                buf = w.into_bytes();
                black_box(buf.len());
            },
        );
        rows.push(row_json(&base));
        rows.push(row_json(&opt));
        compare.push(compare_json(&format!("wire_encode/events{b}"), &base, &opt));
    }

    println!("\n-- baseline vs optimized --");
    for c in &compare {
        println!("  {}", to_string(c));
    }

    let doc = obj(vec![
        ("bench", s("hot-path kernels: per-kernel cost + baseline-vs-optimized")),
        ("k", num(k as f64)),
        ("smoke", num(if smoke { 1.0 } else { 0.0 })),
        ("rows", Json::Arr(rows)),
        ("compare", Json::Arr(compare)),
    ]);
    std::fs::write("BENCH_hotpath.json", to_string(&doc) + "\n")?;
    println!("(recorded in BENCH_hotpath.json)");
    Ok(())
}
