//! Top-N scoring benchmarks over the capacity-padded item slab: native
//! loop vs the PJRT scoring artifact, across slab buckets. This is the
//! per-event hot spot of DISGD recommendation (Algorithm 2's inner loop).

use std::time::Duration;

use streamrec::benchutil::{bench, black_box};
use streamrec::runtime::{NativeBackend, ScoringBackend};
use streamrec::state::VectorSlab;
use streamrec::util::rng::Pcg32;

fn filled_slab(rows: usize, k: usize, rng: &mut Pcg32) -> VectorSlab {
    let mut slab = VectorSlab::new(k);
    for id in 0..rows as u64 {
        let v: Vec<f32> = (0..k).map(|_| rng.next_f32() - 0.5).collect();
        slab.insert(id, &v, 0);
    }
    slab
}

fn main() {
    println!("== scoring benchmarks ==");
    let k = 10;
    let mut rng = Pcg32::seeded(3);
    let u: Vec<f32> = (0..k).map(|_| rng.next_f32() - 0.5).collect();

    for rows in [512usize, 1000, 4000, 16_000] {
        let slab = filled_slab(rows, k, &mut rng);
        let mut native = NativeBackend::new();
        bench(
            &format!("topn/native_m{rows}"),
            100,
            2_000,
            Duration::from_millis(400),
            || {
                black_box(native.topn(&u, &slab, 50));
            },
        );
    }

    if std::path::Path::new("artifacts/manifest.json").exists() {
        for rows in [1000usize, 4000, 16_000] {
            let slab = filled_slab(rows, k, &mut rng);
            let mut engine =
                streamrec::runtime::pjrt::PjrtEngine::new("artifacts").unwrap();
            let _ = engine.topn(&u, &slab).unwrap(); // warm compile+upload
            bench(
                &format!("topn/pjrt_m{rows}_cached_items"),
                5,
                100,
                Duration::from_millis(800),
                || {
                    black_box(engine.topn(&u, &slab).unwrap());
                },
            );
            println!(
                "  (uploads={} exec_calls={})",
                engine.uploads, engine.exec_calls
            );
        }
    } else {
        println!("artifacts/ missing — run `make artifacts` for PJRT numbers");
    }
}
