//! Transport benchmarks: what does putting workers behind loopback TCP
//! cost, relative to in-process channel senders?
//!
//! Same stream, same seed, same topology — only `[cluster] workers`
//! changes: all-local vs all-TCP (against an in-process
//! [`WorkerServer`](streamrec::net::WorkerServer) on an ephemeral
//! loopback port) vs a mixed half/half cycle. Correctness is asserted,
//! not assumed: every placement must produce the identical hit count
//! (the transport property the equivalence tests prove; here it guards
//! the numbers). A second table measures the recovery pause when a
//! seeded `[fault.net]` sever forces a respawn through refused dials,
//! across three `fault.dial_backoff_ms` settings. Results are recorded
//! in `BENCH_transport.json` (schema: docs/EXPERIMENTS.md).
//!
//! `TRANSPORT_BENCH_SMOKE=1` (CI, `scripts/record_bench.sh --smoke`)
//! shrinks the stream and records one backoff row instead of three,
//! with the same row schema and the same hit-equality assertions.

use std::time::Instant;

use streamrec::config::{NetFaultConfig, RunConfig, Topology};
use streamrec::coordinator::run_pipeline;
use streamrec::data::DatasetSpec;
use streamrec::net::WorkerServer;
use streamrec::util::json::{num, obj, s, to_string, Json};

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("TRANSPORT_BENCH_SMOKE")
        .map(|v| v != "0" && !v.is_empty())
        .unwrap_or(false);
    println!("== transport benchmarks (in-proc vs loopback TCP, smoke={smoke}) ==");
    let dataset = if smoke { "nf-like:8000" } else { "nf-like:30000" };
    let events = DatasetSpec::parse(dataset, 21)?.load()?;
    let warm = if smoke { 1000 } else { 2000 };

    // One host serves every remote slot (each connection is its own
    // actor, exactly like a separate `streamrec worker` process).
    let server = WorkerServer::bind("127.0.0.1:0")?;
    let addr = format!("tcp://{}", server.local_addr());

    let placements: [(&str, Vec<String>); 3] = [
        ("in-proc", vec![]),
        ("loopback-tcp", vec![addr.clone()]),
        ("mixed", vec!["local".to_string(), addr.clone()]),
    ];

    println!(
        "\n{:>14} {:>10} {:>12} {:>10} {:>10}",
        "placement", "events", "ev/s", "hits", "vs in-proc"
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut base_thpt = None;
    let mut base_hits = None;
    for (name, workers) in placements {
        let cfg = RunConfig {
            topology: Topology::new(2, 0)?,
            sample_every: 10_000,
            cluster_workers: workers,
            ..RunConfig::default()
        };
        // Warmup pass (connection setup, allocator, page cache), then
        // the measured pass.
        run_pipeline(&cfg, &events[..warm], &format!("warmup-{name}"))?;
        let t0 = Instant::now();
        let r = run_pipeline(&cfg, &events, &format!("bench-{name}"))?;
        let dt = t0.elapsed().as_secs_f64();
        if base_thpt.is_none() {
            base_thpt = Some(r.throughput);
            base_hits = Some(r.hits);
        }
        // The transports must be indistinguishable above the supervisor.
        assert_eq!(
            Some(r.hits),
            base_hits,
            "placement '{name}' changed the hit count"
        );
        let rel = r.throughput / base_thpt.unwrap().max(1e-9);
        println!(
            "{name:>14} {:>10} {:>12.0} {:>10} {rel:>9.2}x",
            r.events, r.throughput, r.hits
        );
        rows.push(obj(vec![
            ("placement", s(name)),
            ("events", num(r.events as f64)),
            ("throughput_ev_s", num(r.throughput)),
            ("hits", num(r.hits as f64)),
            ("relative_to_inproc", num(rel)),
            ("wall_s", num(dt)),
        ]));
    }

    // Recovery pause under dial backoff: a seeded `[fault.net]` plan
    // severs one remote connection mid-stream and refuses the
    // respawn's first two re-dial attempts, so the recovery pause
    // includes the bounded-backoff ladder. Only `fault.dial_backoff_ms`
    // varies across rows; hits must stay identical to the fault-free
    // baseline (the recovery-equivalence property guarding the
    // numbers).
    println!(
        "\n{:>14} {:>10} {:>12} {:>11} {:>12}",
        "dial backoff", "events", "ev/s", "recoveries", "pause ms"
    );
    let mut recovery_rows: Vec<Json> = Vec::new();
    let backoffs: &[u64] = if smoke { &[5] } else { &[5, 25, 100] };
    for &backoff_ms in backoffs {
        let cfg = RunConfig {
            topology: Topology::new(2, 0)?,
            sample_every: 10_000,
            cluster_workers: vec![addr.clone()],
            fault_checkpoint_interval: 64,
            fault_dial_retries: 4,
            fault_dial_backoff_ms: backoff_ms,
            fault_net: NetFaultConfig {
                seed: 13,
                sever_connections: 1,
                sever_after_frames: 3,
                refuse_dials: 2,
                ..NetFaultConfig::default()
            },
            ..RunConfig::default()
        };
        let label = format!("backoff-{backoff_ms}ms");
        let t0 = Instant::now();
        let r = run_pipeline(&cfg, &events, &format!("bench-{label}"))?;
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(
            Some(r.hits),
            base_hits,
            "chaos run '{label}' changed the hit count"
        );
        assert!(r.recoveries >= 1, "'{label}': the sever must fire");
        let pause_ms = r.recovery_pause_ns as f64 / 1e6;
        println!(
            "{:>12}ms {:>10} {:>12.0} {:>11} {pause_ms:>12.1}",
            backoff_ms, r.events, r.throughput, r.recoveries
        );
        recovery_rows.push(obj(vec![
            ("dial_backoff_ms", num(backoff_ms as f64)),
            ("events", num(r.events as f64)),
            ("throughput_ev_s", num(r.throughput)),
            ("hits", num(r.hits as f64)),
            ("recoveries", num(r.recoveries as f64)),
            ("recovery_pause_ms", num(pause_ms)),
            ("wall_s", num(dt)),
        ]));
    }

    let doc = obj(vec![
        ("bench", s("worker transport: in-proc vs loopback TCP")),
        ("dataset", s(&format!("{dataset} (seed 21)"))),
        ("algorithm", s("isgd")),
        ("n_i", num(2.0)),
        ("smoke", num(if smoke { 1.0 } else { 0.0 })),
        ("rows", Json::Arr(rows)),
        ("recovery_rows", Json::Arr(recovery_rows)),
    ]);
    std::fs::write("BENCH_transport.json", to_string(&doc) + "\n")?;
    println!("\n(recorded in BENCH_transport.json)");

    server.wait_idle(std::time::Duration::from_millis(200));
    server.shutdown()?;
    Ok(())
}
