//! Transport benchmarks: what does putting workers behind loopback TCP
//! cost, relative to in-process channel senders?
//!
//! Same stream, same seed, same topology — only `[cluster] workers`
//! changes: all-local vs all-TCP (against an in-process
//! [`WorkerServer`](streamrec::net::WorkerServer) on an ephemeral
//! loopback port) vs a mixed half/half cycle. Correctness is asserted,
//! not assumed: every placement must produce the identical hit count
//! (the transport property the equivalence tests prove; here it guards
//! the numbers). Results are recorded in `BENCH_transport.json`.

use std::time::Instant;

use streamrec::config::{RunConfig, Topology};
use streamrec::coordinator::run_pipeline;
use streamrec::data::DatasetSpec;
use streamrec::net::WorkerServer;
use streamrec::util::json::{num, obj, s, to_string, Json};

fn main() -> anyhow::Result<()> {
    println!("== transport benchmarks (in-proc vs loopback TCP) ==");
    let events = DatasetSpec::parse("nf-like:30000", 21)?.load()?;

    // One host serves every remote slot (each connection is its own
    // actor, exactly like a separate `streamrec worker` process).
    let server = WorkerServer::bind("127.0.0.1:0")?;
    let addr = format!("tcp://{}", server.local_addr());

    let placements: [(&str, Vec<String>); 3] = [
        ("in-proc", vec![]),
        ("loopback-tcp", vec![addr.clone()]),
        ("mixed", vec!["local".to_string(), addr.clone()]),
    ];

    println!(
        "\n{:>14} {:>10} {:>12} {:>10} {:>10}",
        "placement", "events", "ev/s", "hits", "vs in-proc"
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut base_thpt = None;
    let mut base_hits = None;
    for (name, workers) in placements {
        let cfg = RunConfig {
            topology: Topology::new(2, 0)?,
            sample_every: 10_000,
            cluster_workers: workers,
            ..RunConfig::default()
        };
        // Warmup pass (connection setup, allocator, page cache), then
        // the measured pass.
        run_pipeline(&cfg, &events[..2000], &format!("warmup-{name}"))?;
        let t0 = Instant::now();
        let r = run_pipeline(&cfg, &events, &format!("bench-{name}"))?;
        let dt = t0.elapsed().as_secs_f64();
        if base_thpt.is_none() {
            base_thpt = Some(r.throughput);
            base_hits = Some(r.hits);
        }
        // The transports must be indistinguishable above the supervisor.
        assert_eq!(
            Some(r.hits),
            base_hits,
            "placement '{name}' changed the hit count"
        );
        let rel = r.throughput / base_thpt.unwrap().max(1e-9);
        println!(
            "{name:>14} {:>10} {:>12.0} {:>10} {rel:>9.2}x",
            r.events, r.throughput, r.hits
        );
        rows.push(obj(vec![
            ("placement", s(name)),
            ("events", num(r.events as f64)),
            ("throughput_ev_s", num(r.throughput)),
            ("hits", num(r.hits as f64)),
            ("relative_to_inproc", num(rel)),
            ("wall_s", num(dt)),
        ]));
    }

    let doc = obj(vec![
        ("bench", s("worker transport: in-proc vs loopback TCP")),
        ("dataset", s("nf-like:30000 (seed 21)")),
        ("algorithm", s("isgd")),
        ("n_i", num(2.0)),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_transport.json", to_string(&doc) + "\n")?;
    println!("\n(recorded in BENCH_transport.json)");

    server.wait_idle(std::time::Duration::from_millis(200));
    server.shutdown()?;
    Ok(())
}
