//! Router micro-benchmarks: the closed-form Algorithm 1 vs the literal
//! candidate-list construction, across topologies. L3 §Perf target:
//! >= 10M routes/s (the router must never be the pipeline bottleneck).

use std::time::Duration;

use streamrec::benchutil::{bench, black_box};
use streamrec::config::Topology;
use streamrec::coordinator::Router;
use streamrec::util::rng::Pcg32;

fn main() {
    println!("== routing benchmarks ==");
    let budget = Duration::from_millis(400);
    for n_i in [2u64, 4, 6] {
        let router = Router::new(Topology::new(n_i, 0).unwrap());
        let mut rng = Pcg32::seeded(1);
        let pairs: Vec<(u64, u64)> =
            (0..4096).map(|_| (rng.next_u64(), rng.next_u64())).collect();
        let mut i = 0;
        bench(
            &format!("route_closed_form/ni{n_i}"),
            1000,
            10_000,
            budget,
            || {
                let (u, it) = pairs[i & 4095];
                black_box(router.route(u, it));
                i += 1;
            },
        );
        let mut j = 0;
        bench(
            &format!("route_algorithm1_literal/ni{n_i}"),
            1000,
            10_000,
            budget,
            || {
                let (u, it) = pairs[j & 4095];
                black_box(router.route_candidates(u, it));
                j += 1;
            },
        );
    }
}
