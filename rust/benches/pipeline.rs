//! End-to-end pipeline benchmarks — one per paper table/figure family:
//!
//! * DISGD throughput, central vs n_i ∈ {2,4,6}, ± forgetting (Fig 8)
//! * DICS throughput, central (capped) vs distributed (Fig 14)
//! * channel send/recv cost (engine substrate)
//!
//! These are the criterion-equivalent end-to-end benches (the offline
//! build has no criterion; `benchutil` provides warmup + p50/p99).

use std::time::Instant;

use streamrec::config::{Algorithm, Forgetting, RunConfig, Topology};
use streamrec::coordinator::run_pipeline;
use streamrec::data::DatasetSpec;
use streamrec::engine::bounded;

fn main() -> anyhow::Result<()> {
    println!("== pipeline benchmarks (Fig 8 / Fig 14 shape) ==");
    let events = DatasetSpec::parse("nf-like:30000", 21)?.load()?;

    // Channel substrate cost first (context for the numbers below).
    {
        let (tx, rx) = bounded::<u64>(4096);
        let h = std::thread::spawn(move || {
            let mut n = 0u64;
            while rx.recv().is_some() {
                n += 1;
            }
            n
        });
        let t0 = Instant::now();
        let count = 2_000_000u64;
        for i in 0..count {
            tx.send(i).unwrap();
        }
        drop(tx);
        let received = h.join().unwrap();
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "channel/send_recv: {:.1} M msgs/s (received {received})",
            count as f64 / dt / 1e6
        );
    }

    println!(
        "\n{:8} {:>4} {:>10} {:>12} {:>12} {:>10}",
        "algo", "n_i", "policy", "events", "ev/s", "speedup"
    );
    for algo in [Algorithm::Isgd, Algorithm::Cosine] {
        let mut central_thpt = None;
        for n_i in [1u64, 2, 4, 6] {
            for policy in ["none", "lfu"] {
                let forgetting = match policy {
                    "lfu" => Forgetting::Lfu {
                        trigger_events: 10_000,
                        min_freq: 2,
                    },
                    _ => Forgetting::None,
                };
                let cfg = RunConfig {
                    algorithm: algo,
                    topology: Topology::new(n_i, 0)?,
                    forgetting,
                    sample_every: 10_000,
                    ..RunConfig::default()
                };
                // Cap the central cosine baseline (paper Section 5.3.2).
                let slice = if algo == Algorithm::Cosine && n_i == 1 {
                    &events[..6000]
                } else {
                    &events[..]
                };
                let r = run_pipeline(
                    &cfg,
                    slice,
                    &format!("bench-{}-ni{}-{}", algo.name(), n_i, policy),
                )?;
                if n_i == 1 && policy == "none" {
                    central_thpt = Some(r.throughput);
                }
                let speedup = r.throughput
                    / central_thpt.unwrap_or(r.throughput).max(1e-9);
                println!(
                    "{:8} {n_i:>4} {policy:>10} {:>12} {:>12.0} {speedup:>9.1}x",
                    algo.name(),
                    r.events,
                    r.throughput
                );
            }
        }
        println!();
    }
    Ok(())
}
