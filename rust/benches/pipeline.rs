//! End-to-end pipeline benchmarks — one per paper table/figure family:
//!
//! * DISGD throughput, central vs n_i ∈ {2,4,6}, ± forgetting (Fig 8)
//! * DICS throughput, central (capped) vs distributed (Fig 14)
//! * channel send/recv cost, per-message vs bulk (engine substrate)
//! * `ingest_batch_size` sweep at n_i=2 — the micro-batched data plane's
//!   headline number; results are recorded in `BENCH_ingest.json`
//!   (written to the current working directory).
//!
//! These are the criterion-equivalent end-to-end benches (the offline
//! build has no criterion; `benchutil` provides warmup + p50/p99).
//!
//! `PIPELINE_BENCH_SMOKE=1` (CI, `scripts/record_bench.sh --smoke`)
//! shrinks the stream and skips the Fig 8/14 tables, but still records
//! the full `BENCH_ingest.json` row schema from a real run.

use std::time::Instant;

use streamrec::config::{Algorithm, Forgetting, RunConfig, Topology};
use streamrec::coordinator::run_pipeline;
use streamrec::data::DatasetSpec;
use streamrec::engine::bounded;
use streamrec::util::json::{num, obj, s, to_string, Json};

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("PIPELINE_BENCH_SMOKE")
        .map(|v| v != "0" && !v.is_empty())
        .unwrap_or(false);
    println!("== pipeline benchmarks (Fig 8 / Fig 14 shape, smoke={smoke}) ==");
    let dataset = if smoke { "nf-like:6000" } else { "nf-like:30000" };
    let events = DatasetSpec::parse(dataset, 21)?.load()?;
    let chan_count = if smoke { 200_000u64 } else { 2_000_000u64 };

    // Channel substrate cost first (context for the numbers below):
    // per-message sends vs bulk send_many + draining recv_many.
    {
        let (tx, rx) = bounded::<u64>(4096);
        let h = std::thread::spawn(move || {
            let mut n = 0u64;
            while rx.recv().is_some() {
                n += 1;
            }
            n
        });
        let t0 = Instant::now();
        let count = chan_count;
        for i in 0..count {
            tx.send(i).unwrap();
        }
        drop(tx);
        let received = h.join().unwrap();
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "channel/send_recv:           {:.1} M msgs/s (received {received})",
            count as f64 / dt / 1e6
        );
    }
    {
        let (tx, rx) = bounded::<u64>(4096);
        let h = std::thread::spawn(move || {
            let mut n = 0u64;
            let mut buf = Vec::new();
            while rx.recv_many(&mut buf, usize::MAX) {
                n += buf.len() as u64;
                buf.clear();
            }
            n
        });
        let t0 = Instant::now();
        let count = chan_count;
        let mut batch = Vec::with_capacity(256);
        for i in 0..count {
            batch.push(i);
            if batch.len() == 256 {
                tx.send_many(&mut batch).unwrap();
            }
        }
        tx.send_many(&mut batch).unwrap();
        drop(tx);
        let received = h.join().unwrap();
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "channel/send_many@256+drain: {:.1} M msgs/s (received {received})",
            count as f64 / dt / 1e6
        );
    }

    // ingest_batch_size sweep (ISSUE 2 acceptance): ISGD at n_i=2 on the
    // synthetic stream, one full pipeline per batch size. Recorded in
    // BENCH_ingest.json so wins stay attributable across PRs.
    println!(
        "\n{:>16} {:>12} {:>12} {:>9} {:>14} {:>14}",
        "ingest_batch", "ev/s", "mean batch", "speedup", "send blocked",
        "recv wait"
    );
    let mut sweep_rows: Vec<Json> = Vec::new();
    let mut base_thpt = None;
    let batch_sizes: &[usize] =
        if smoke { &[1, 64, 256] } else { &[1, 8, 64, 256] };
    for &batch_size in batch_sizes {
        let cfg = RunConfig {
            topology: Topology::new(2, 0)?,
            sample_every: 10_000,
            ingest_batch_size: batch_size,
            ..RunConfig::default()
        };
        let r = run_pipeline(&cfg, &events, &format!("bench-bs{batch_size}"))?;
        if base_thpt.is_none() {
            base_thpt = Some(r.throughput);
        }
        let speedup = r.throughput / base_thpt.unwrap().max(1e-9);
        println!(
            "{batch_size:>16} {:>12.0} {:>12.1} {speedup:>8.2}x {:>11.1} ms \
             {:>11.1} ms",
            r.throughput,
            r.mean_send_batch,
            r.backpressure_ns as f64 / 1e6,
            r.recv_blocked_ns as f64 / 1e6,
        );
        sweep_rows.push(obj(vec![
            ("ingest_batch_size", num(batch_size as f64)),
            ("events", num(r.events as f64)),
            ("throughput_ev_s", num(r.throughput)),
            ("speedup_vs_unbatched", num(speedup)),
            ("mean_send_batch", num(r.mean_send_batch)),
            ("backpressure_ns", num(r.backpressure_ns as f64)),
            ("recv_blocked_ns", num(r.recv_blocked_ns as f64)),
        ]));
    }
    let doc = obj(vec![
        ("bench", s("ingest_batch_size sweep")),
        ("dataset", s(&format!("{dataset} (seed 21)"))),
        ("algorithm", s("isgd")),
        ("n_i", num(2.0)),
        ("smoke", num(if smoke { 1.0 } else { 0.0 })),
        ("rows", Json::Arr(sweep_rows)),
    ]);
    std::fs::write("BENCH_ingest.json", to_string(&doc) + "\n")?;
    println!("(sweep recorded in BENCH_ingest.json)");

    if smoke {
        println!("(smoke mode: skipping the Fig 8 / Fig 14 tables)");
        return Ok(());
    }
    println!(
        "\n{:8} {:>4} {:>10} {:>12} {:>12} {:>10}",
        "algo", "n_i", "policy", "events", "ev/s", "speedup"
    );
    for algo in [Algorithm::Isgd, Algorithm::Cosine] {
        let mut central_thpt = None;
        for n_i in [1u64, 2, 4, 6] {
            for policy in ["none", "lfu"] {
                let forgetting = match policy {
                    "lfu" => Forgetting::Lfu {
                        trigger_events: 10_000,
                        min_freq: 2,
                    },
                    _ => Forgetting::None,
                };
                let cfg = RunConfig {
                    algorithm: algo,
                    topology: Topology::new(n_i, 0)?,
                    forgetting,
                    sample_every: 10_000,
                    ..RunConfig::default()
                };
                // Cap the central cosine baseline (paper Section 5.3.2).
                let slice = if algo == Algorithm::Cosine && n_i == 1 {
                    &events[..6000]
                } else {
                    &events[..]
                };
                let r = run_pipeline(
                    &cfg,
                    slice,
                    &format!("bench-{}-ni{}-{}", algo.name(), n_i, policy),
                )?;
                if n_i == 1 && policy == "none" {
                    central_thpt = Some(r.throughput);
                }
                let speedup = r.throughput
                    / central_thpt.unwrap_or(r.throughput).max(1e-9);
                println!(
                    "{:8} {n_i:>4} {policy:>10} {:>12} {:>12.0} {speedup:>9.1}x",
                    algo.name(),
                    r.events,
                    r.throughput
                );
            }
        }
        println!();
    }
    Ok(())
}
