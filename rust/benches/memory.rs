//! Memory-tier benchmark: what does a `[memory]` budget cost?
//!
//! For each algorithm the bench first runs the stream *unlimited* to
//! measure the working set (final resident state bytes), then re-runs
//! it under a budget of **one tenth of that working set** — a
//! population 10x beyond the cap — in two modes:
//!
//! * **spill-only** (no `[forgetting]` policy): pressure sweeps cannot
//!   evict, so the budget is enforced purely by tiering cold lanes to
//!   disk. Resident bytes stay bounded and the results are
//!   *byte-identical* to the unlimited run (asserted on the hit count)
//!   — the cost is fault-in churn, visible in the throughput column.
//! * **evict+spill** (LFU pressure sweeps + spill): sweeps shed
//!   low-frequency entries first, spill covers what remains. The recall
//!   delta vs the unlimited run is the quantified price of forgetting
//!   under pressure.
//!
//! The grid is over-partitioned (`rescale.max_n_i = 4`, so 16 lanes on
//! one worker) to give the tiering real cold lanes to choose from.
//! Results are written to `BENCH_memory.json` (current working
//! directory), mirroring the other `BENCH_*` conventions.
//!
//! `MEMORY_BENCH_SMOKE=1` (CI, `scripts/record_bench.sh --smoke`)
//! shrinks the stream; same row schema, same assertions.

use streamrec::config::{Algorithm, Forgetting, RunConfig, Topology};
use streamrec::coordinator::Cluster;
use streamrec::data::types::Rating;
use streamrec::data::DatasetSpec;
use streamrec::util::json::{num, obj, s, to_string, Json};

struct RunOut {
    resident_bytes: u64,
    state_bytes: u64,
    spilled_bytes: u64,
    spills: u64,
    spill_faultins: u64,
    evicted: u64,
    hits: u64,
    avg_recall: f64,
    throughput: f64,
}

fn run(cfg: &RunConfig, label: &str, events: &[Rating]) -> anyhow::Result<RunOut> {
    let mut cluster = Cluster::spawn_labeled(cfg, label)?;
    cluster.ingest_batch(events)?;
    cluster.flush()?;
    // The snapshot is the bounded-residency witness: every worker
    // re-measures its lanes and re-enforces its budget right before
    // replying, so `resident_bytes` here is exact, not sampled.
    let m = cluster.metrics()?;
    let report = cluster.finish()?;
    assert_eq!(report.events, events.len() as u64, "bench lost events");
    Ok(RunOut {
        resident_bytes: m.resident_bytes,
        state_bytes: m.state_bytes,
        spilled_bytes: m.spilled_bytes,
        spills: report.spills,
        spill_faultins: report.spill_faultins,
        evicted: report
            .workers
            .iter()
            .chain(report.retired.iter())
            .map(|w| w.evicted)
            .sum(),
        hits: report.hits,
        avg_recall: report.avg_recall,
        throughput: report.throughput,
    })
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("MEMORY_BENCH_SMOKE")
        .map(|v| v != "0" && !v.is_empty())
        .unwrap_or(false);
    println!("== memory-tier benchmarks (10x beyond the cap, smoke={smoke}) ==");
    let dataset = if smoke { "nf-like:6000" } else { "nf-like:120000" };
    let events = DatasetSpec::parse(dataset, 41)?.load()?;
    let n = events.len() as u64;

    println!(
        "{:8} {:12} {:>12} {:>12} {:>8} {:>8} | {:>8} {:>11}",
        "algo", "mode", "resident", "cap", "spills", "faultin", "recall", "thpt"
    );
    let mut rows = Vec::new();
    for algo in [Algorithm::Isgd, Algorithm::Cosine] {
        let base = RunConfig {
            algorithm: algo,
            topology: Topology::new(1, 0)?,
            // 16 lanes on the single worker: cold lanes exist, and the
            // lane partitioning is identical across all three modes.
            rescale_max_n_i: 4,
            sample_every: 10_000,
            memory_check_events: 32,
            ..RunConfig::default()
        };

        let unlimited =
            run(&base, &format!("bench-mem-{}-unlimited", algo.name()), &events)?;
        // The headline shape: a budget of a tenth of the working set.
        let cap = (unlimited.resident_bytes / 10).max(1);

        let spill_cfg = RunConfig {
            memory_budget_bytes: cap,
            ..base.clone()
        };
        let spill_only = run(
            &spill_cfg,
            &format!("bench-mem-{}-spill", algo.name()),
            &events,
        )?;
        assert!(
            spill_only.resident_bytes <= cap,
            "{}: resident {} exceeds cap {}",
            algo.name(),
            spill_only.resident_bytes,
            cap
        );
        assert!(spill_only.spills >= 1, "a 10x cap must force spills");
        assert_eq!(
            spill_only.hits, unlimited.hits,
            "spill is lossless: capped hits must equal unlimited hits"
        );

        let evict_cfg = RunConfig {
            memory_budget_bytes: cap,
            // Clock never fires on its own (huge trigger): every sweep
            // in this run is memory-pressure-driven.
            forgetting: Forgetting::Lfu {
                trigger_events: u64::MAX,
                min_freq: 2,
            },
            ..base.clone()
        };
        let evict = run(
            &evict_cfg,
            &format!("bench-mem-{}-evict", algo.name()),
            &events,
        )?;
        assert!(evict.resident_bytes <= cap);

        for (mode, out, budget) in [
            ("unlimited", &unlimited, 0u64),
            ("spill-only", &spill_only, cap),
            ("evict+spill", &evict, cap),
        ] {
            println!(
                "{:8} {:12} {:>12} {:>12} {:>8} {:>8} | {:>8.4} {:>8.0}/s",
                algo.name(),
                mode,
                out.resident_bytes,
                budget,
                out.spills,
                out.spill_faultins,
                out.avg_recall,
                out.throughput,
            );
            rows.push(obj(vec![
                ("algorithm", s(algo.name())),
                ("mode", s(mode)),
                ("events", num(n as f64)),
                ("memory_budget_bytes", num(budget as f64)),
                ("resident_bytes", num(out.resident_bytes as f64)),
                ("state_bytes", num(out.state_bytes as f64)),
                ("spilled_bytes", num(out.spilled_bytes as f64)),
                ("spills", num(out.spills as f64)),
                ("spill_faultins", num(out.spill_faultins as f64)),
                ("evicted", num(out.evicted as f64)),
                ("avg_recall", num(out.avg_recall)),
                (
                    "recall_cost_vs_unlimited",
                    num(unlimited.avg_recall - out.avg_recall),
                ),
                ("throughput_ev_s", num(out.throughput)),
            ]));
        }
    }
    let doc = obj(vec![
        ("bench", s("memory budget: resident bound + recall cost")),
        ("dataset", s(&format!("{dataset} (seed 41)"))),
        ("smoke", num(if smoke { 1.0 } else { 0.0 })),
        (
            "scenario",
            s("1 worker x 16 lanes; cap = working set / 10; spill-only \
               is byte-identical to unlimited, evict+spill quantifies \
               the recall cost of pressure eviction"),
        ),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_memory.json", to_string(&doc) + "\n")?;
    println!("(recorded in BENCH_memory.json)");
    Ok(())
}
