//! Forgetting-sweep benchmarks: LRU/LFU scan cost over slab and tracked
//! map state at realistic sizes — the cost the paper blames for DICS
//! throughput loss under aggressive LFU (Section 5.3.2).

use std::time::Duration;

use streamrec::benchutil::{bench_batch, black_box};
use streamrec::state::{TrackedMap, VectorSlab};
use streamrec::util::rng::Pcg32;

fn main() {
    println!("== forgetting sweep benchmarks ==");
    let mut rng = Pcg32::seeded(4);
    for n in [10_000usize, 100_000] {
        // VectorSlab sweep (DISGD item state).
        bench_batch(
            &format!("sweep_lru/slab_{n}"),
            n as u64,
            2,
            10,
            Duration::from_millis(600),
            || {
                let mut slab = VectorSlab::new(10);
                for id in 0..n as u64 {
                    slab.insert(id, &[0.0; 10], rng.next_bounded(1000));
                }
                // Sweep evicts ~half.
                black_box(slab.sweep_lru(500).len());
            },
        );
        // TrackedMap sweep (user state).
        bench_batch(
            &format!("sweep_lfu/map_{n}"),
            n as u64,
            2,
            10,
            Duration::from_millis(600),
            || {
                let mut map: TrackedMap<u64, [f32; 10]> = TrackedMap::new();
                for id in 0..n as u64 {
                    map.insert(id, [0.0; 10], 0);
                    if id % 2 == 0 {
                        map.touch_mut(&id, 1);
                    }
                }
                black_box(map.sweep_lfu(2).len());
            },
        );
    }
}
