//! Offline shim for the `xla` PJRT bindings.
//!
//! The streamrec build environment has no XLA runtime; this crate keeps
//! `runtime::pjrt` *compiling* with the exact API surface it consumes,
//! while every fallible entry point returns [`Error::Unavailable`] at
//! runtime. That is safe because the PJRT path is always gated:
//! `PjrtEngine::new` loads the artifact manifest first (absent without
//! `make artifacts`), the PJRT integration tests skip without it, and
//! `PjrtBackend` degrades to the native backend on any engine error.
//!
//! Replace the `xla` path dependency in `rust/Cargo.toml` with the real
//! bindings to light up the AOT/PJRT layer; no source change needed.

/// Error surfaced by every shimmed operation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Error {
    /// The XLA runtime is not available in this build.
    Unavailable,
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "xla shim: PJRT runtime unavailable in this build")
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

/// One PJRT device (CPU in the real bindings).
#[derive(Debug, Clone, Copy)]
pub struct Device;

/// Parsed HLO module.
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(Error::Unavailable)
    }
}

/// An XLA computation ready for compilation.
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Host-side literal (tensor) value.
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Self {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::Unavailable)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::Unavailable)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable)
    }
}

/// Device-resident buffer.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable)
    }
}

/// Compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable)
    }

    pub fn execute_b(
        &self,
        _args: &[&PjRtBuffer],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable)
    }
}

/// The PJRT client.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::Unavailable)
    }

    pub fn platform_name(&self) -> String {
        "shim".to_string()
    }

    pub fn devices(&self) -> Vec<Device> {
        Vec::new()
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&Device>,
    ) -> Result<PjRtBuffer> {
        Err(Error::Unavailable)
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert_eq!(PjRtClient::cpu().unwrap_err(), Error::Unavailable);
        assert_eq!(
            HloModuleProto::from_text_file("x").unwrap_err(),
            Error::Unavailable
        );
        assert!(Literal::vec1(&[1.0]).reshape(&[1, 1]).is_err());
        let c = XlaComputation::from_proto(&HloModuleProto);
        let _ = c; // constructible without a runtime
    }
}
