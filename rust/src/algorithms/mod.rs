//! Streaming recommender algorithms.
//!
//! [`StreamingRecommender`] is the contract the prequential evaluator and
//! the distributed pipeline drive. Central and distributed variants run
//! the *same* model code: "distributed" just means `n_c` independent
//! instances behind the splitting-and-replication router (Section 4) —
//! that is the whole point of the shared-nothing design.

pub mod cosine;
pub mod isgd;

use crate::data::types::{ItemId, Rating, StateSizes, UserId};
use crate::state::SweepKind;

pub use cosine::CosineModel;
pub use isgd::IsgdModel;

/// An online recommender that alternates recommending and learning.
pub trait StreamingRecommender {
    /// Algorithm name for reports ("isgd" | "cosine").
    fn name(&self) -> &'static str;

    /// Top-`n` recommendations for `user`, excluding items the user has
    /// already rated (Algorithm 2/3's "if p not in user's rated items").
    /// An unknown user yields an empty list (cold start: recall 0, the
    /// prequential protocol's behaviour).
    fn recommend(&mut self, user: UserId, n: usize) -> Vec<ItemId>;

    /// Learn from one feedback element (the training half of the
    /// prequential loop).
    fn update(&mut self, event: &Rating);

    /// Items `user` has rated *on this replica*. The online query path
    /// unions these across a user's replicas so the merged top-N can
    /// exclude items consumed anywhere — a rating lands on exactly one
    /// worker, so local filtering inside [`Self::recommend`] is not
    /// enough. Unknown user: empty.
    fn rated_items(&self, user: UserId) -> Vec<ItemId> {
        let _ = user;
        Vec::new()
    }

    /// Current state-entry counts (the paper's memory metric).
    fn state_sizes(&self) -> StateSizes;

    /// Apply a forgetting sweep; returns the number of evicted entries.
    fn sweep(&mut self, kind: SweepKind) -> u64;
}

/// Construct the configured algorithm (invoked inside a worker thread so
/// `!Send` backends are legal).
pub fn build_model(
    cfg: &crate::config::RunConfig,
    worker_id: usize,
) -> anyhow::Result<Box<dyn StreamingRecommender>> {
    match cfg.algorithm {
        crate::config::Algorithm::Isgd => {
            let backend =
                crate::runtime::make_backend(cfg.backend, &cfg.artifacts_dir)?;
            Ok(Box::new(IsgdModel::new(
                cfg.latent_k,
                cfg.eta,
                cfg.lambda,
                // Decorrelate worker init streams deterministically.
                cfg.seed ^ crate::util::rng::mix64(worker_id as u64),
                backend,
            )))
        }
        crate::config::Algorithm::Cosine => {
            // Pipelines default to the bounded-staleness fast mode; the
            // strict (exact) mode stays available for cross-checks via
            // cfg.cosine_strict.
            Ok(Box::new(CosineModel::with_mode(
                cfg.neighbors_k,
                cfg.cosine_strict,
            )))
        }
    }
}
