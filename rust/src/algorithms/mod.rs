//! Streaming recommender algorithms.
//!
//! [`StreamingRecommender`] is the contract the prequential evaluator and
//! the distributed pipeline drive. Central and distributed variants run
//! the *same* model code: "distributed" just means `n_c` independent
//! instances behind the splitting-and-replication router (Section 4) —
//! that is the whole point of the shared-nothing design.

pub mod cosine;
pub mod isgd;

use crate::data::types::{ItemId, Rating, StateSizes, UserId};
use crate::state::SweepKind;

pub use cosine::CosineModel;
pub use isgd::IsgdModel;

/// An online recommender that alternates recommending and learning.
pub trait StreamingRecommender {
    /// Algorithm name for reports ("isgd" | "cosine").
    fn name(&self) -> &'static str;

    /// Top-`n` recommendations for `user`, excluding items the user has
    /// already rated (Algorithm 2/3's "if p not in user's rated items").
    /// An unknown user yields an empty list (cold start: recall 0, the
    /// prequential protocol's behaviour).
    fn recommend(&mut self, user: UserId, n: usize) -> Vec<ItemId>;

    /// The *serving-path* read: like [`Self::recommend`], but it must
    /// not mutate any **visible** (serialized) model state. The online
    /// query path calls this, and two guarantees depend on the
    /// distinction: queries never perturb what the models learn, and
    /// crash recovery can rebuild a worker by replaying *events* alone —
    /// if a query could move state that `export_partition` ships (e.g.
    /// read-triggered cache maintenance), a replayed timeline without
    /// the query would diverge from the original.
    ///
    /// The default delegates to [`Self::recommend`], which is correct
    /// for models whose recommend only touches unserialized scratch
    /// (ISGD). Models with read-triggered maintenance of visible state
    /// (cosine's bounded-staleness neighborhood caches) override this
    /// with a frozen read.
    fn serve(&mut self, user: UserId, n: usize) -> Vec<ItemId> {
        self.recommend(user, n)
    }

    /// Learn from one feedback element (the training half of the
    /// prequential loop).
    fn update(&mut self, event: &Rating);

    /// Items `user` has rated *on this replica*. The online query path
    /// unions these across a user's replicas so the merged top-N can
    /// exclude items consumed anywhere — a rating lands on exactly one
    /// worker, so local filtering inside [`Self::recommend`] is not
    /// enough. Unknown user: empty.
    fn rated_items(&self, user: UserId) -> Vec<ItemId> {
        let _ = user;
        Vec::new()
    }

    /// Current state-entry counts (the paper's memory metric).
    fn state_sizes(&self) -> StateSizes;

    /// Estimated resident bytes of this model's **visible** (serialized)
    /// state. This is a deterministic accounting computed from entry
    /// counts and dimensions — not an allocator measurement — so a model
    /// and its migrated copy report the same figure and per-lane rollups
    /// are placement-independent. The `[memory]` budget (pressure sweeps
    /// and cold-lane spill) keys off this number.
    ///
    /// The default derives a coarse figure from [`Self::state_sizes`];
    /// real models override it with per-structure accounting.
    fn state_bytes(&self) -> u64 {
        let s = self.state_sizes();
        (s.users + s.items + s.aux) * 32
    }

    /// Apply a forgetting sweep; returns the number of evicted entries.
    fn sweep(&mut self, kind: SweepKind) -> u64;

    /// Serialize this model's state into the compact binary framing of
    /// [`crate::util::wire`], keeping only users selected by `keep_user`
    /// (item-side state — factor rows, counts, co-occurrence rows — is
    /// always exported in full: items are not owned by a single user).
    ///
    /// This is the export half of live rescaling: the cluster moves whole
    /// model lanes between workers with `keep_user = |_| true`, and the
    /// snapshot is *exact* — recency/frequency metadata and the model's
    /// RNG stream travel with the values, so a migrated model is
    /// bit-identical to the original for every future recommend, update,
    /// and sweep.
    fn export_partition(&self, keep_user: &dyn Fn(UserId) -> bool) -> Vec<u8>;

    /// Merge a snapshot produced by [`Self::export_partition`] into this
    /// model. Entries present in both sides are overwritten by the
    /// import, and the imported RNG stream replaces the local one — the
    /// intended use is loading a snapshot into a freshly-built model of
    /// the same configuration (the migration path), where this makes the
    /// result exact. Fails on algorithm/shape mismatch or a corrupt
    /// snapshot, leaving partially-applied state behind; the cluster
    /// treats that as fatal for the rescale.
    fn import_partition(&mut self, bytes: &[u8]) -> anyhow::Result<()>;
}

/// Construct the configured algorithm (invoked inside a worker thread so
/// `!Send` backends are legal).
///
/// `instance_id` decorrelates the model's init-RNG stream from its
/// siblings. The cluster passes the *lane* id (the virtual grid cell),
/// not the physical worker id, so a lane's RNG stream — and therefore
/// its entire model evolution — is identical wherever the lane is
/// hosted (the rescale-equivalence requirement). With the default state
/// grid the lane id and worker id coincide.
pub fn build_model(
    cfg: &crate::config::RunConfig,
    instance_id: usize,
) -> anyhow::Result<Box<dyn StreamingRecommender>> {
    match cfg.algorithm {
        crate::config::Algorithm::Isgd => {
            let backend =
                crate::runtime::make_backend(cfg.backend, &cfg.artifacts_dir)?;
            Ok(Box::new(IsgdModel::new(
                cfg.latent_k,
                cfg.eta,
                cfg.lambda,
                // Decorrelate per-instance init streams deterministically.
                cfg.seed ^ crate::util::rng::mix64(instance_id as u64),
                backend,
            )))
        }
        crate::config::Algorithm::Cosine => {
            // Pipelines default to the bounded-staleness fast mode; the
            // strict (exact) mode stays available for cross-checks via
            // cfg.cosine_strict.
            Ok(Box::new(CosineModel::with_mode(
                cfg.neighbors_k,
                cfg.cosine_strict,
            )))
        }
    }
}
