//! Incremental item-based cosine similarity — the TencentRec-style model
//! (Huang et al. 2015, Equation 6/7) behind the central baseline and DICS
//! (Algorithm 3).
//!
//! With binary positive-only feedback (the paper filters to 5-star
//! events, `r = 1`), Equation 6 reduces to
//!
//! ```text
//! sim(p, q) = pairCount(p, q) / (sqrt(count(p)) * sqrt(count(q)))
//! ```
//!
//! maintained incrementally: each event `<u, i>` bumps `count(i)` and
//! `pairCount(i, j)` for every `j` already in u's history. Equation 7's
//! estimate for candidate `p` given user `u` becomes
//!
//! ```text
//! r̂(u, p) = Σ_{q ∈ N^k(p), q ∈ rated(u)} sim(p, q)
//!           ─────────────────────────────────────────
//!           Σ_{q ∈ N^k(p)}                sim(p, q)
//! ```
//!
//! i.e. the fraction of p's top-k neighborhood mass the user has consumed
//! (rated neighbors contribute `r = 1` to the numerator, unrated ones 0).
//! Ties break toward more rated-neighborhood mass.
//!
//! # State and cost profile (faithful to the paper)
//!
//! The state mirrors what the paper describes — per-item co-occurrence
//! adjacency ("with each item, a list of similar items"), per-user
//! history — and like TencentRec the model maintains per-item **top-k
//! neighbor lists**. Maintenance is lazy-with-dirty-marking: an event on
//! item `i` invalidates `i` and every partner of `i` (their sims share
//! `count(i)`), and a stale neighborhood is rebuilt in O(deg) on next
//! use. This keeps Equation 7 reads at O(k) while paying the paper's
//! O(deg)-per-update maintenance price — the "inherent slowness" that
//! kills the central ML-25M run in Section 5.3.2 (the harness caps that
//! baseline instead of dying).

use std::collections::{HashMap, HashSet};

use anyhow::{bail, Result};

use crate::algorithms::StreamingRecommender;
use crate::data::types::{ItemId, Rating, StateSizes, UserId};
use crate::state::{SweepKind, TrackedMap};
use crate::util::wire::{WireReader, WireWriter};

/// Wire tag identifying a cosine state snapshot (see
/// [`StreamingRecommender::export_partition`]).
pub const COSINE_WIRE_TAG: u8 = 2;

/// Cached Equation-7 neighborhood of one item.
#[derive(Debug, Clone)]
struct Neighborhood {
    /// Top-k partners by similarity, descending.
    neighbors: Vec<(ItemId, f32)>,
    /// Σ sim over the top-k (Equation 7 denominator).
    mass: f32,
}

/// The incremental cosine model for one worker.
pub struct CosineModel {
    /// Per-item rating count (denominator of Equation 6).
    item_count: TrackedMap<ItemId, u64>,
    /// Co-occurrence adjacency: pairs[p][q] = #users who rated both.
    /// Stored symmetrically for O(deg) scans.
    pairs: HashMap<ItemId, HashMap<ItemId, u64>>,
    /// Lazily-maintained top-k neighbor lists (TencentRec's "list of
    /// similar items" state).
    topk: HashMap<ItemId, Neighborhood>,
    /// Items whose cached neighborhood is stale.
    dirty: HashSet<ItemId>,
    /// Per-user rated history (insertion-ordered).
    users: TrackedMap<UserId, Vec<ItemId>>,
    /// Neighborhood size k of Equation 7.
    neighbors_k: usize,
    /// Exactness mode. `strict` marks every partner of a touched item
    /// dirty (cached sims are always exact — used by tests and the
    /// correctness cross-checks). Fast mode (default in pipelines) lets
    /// partner sims drift within a bounded staleness window and rebuilds
    /// a neighborhood only after `dirt(p) >= max(4, deg(p)/8)` bumps —
    /// the same eager-but-approximate maintenance TencentRec describes.
    /// The recall impact is measured in the ablation bench (§Perf).
    strict: bool,
    /// Pair bumps since last rebuild, per item (fast-mode throttle).
    dirt: HashMap<ItemId, u32>,
    /// Scratch buffers (no allocation on the steady-state hot path).
    cand_scratch: Vec<ItemId>,
    rated_scratch: HashSet<ItemId>,
    sims_scratch: Vec<(f32, ItemId)>,
    scored_scratch: Vec<(f32, f32, ItemId)>,
    /// Events processed (diagnostics).
    pub updates: u64,
    /// Neighborhood rebuilds performed (perf counter).
    pub rebuilds: u64,
}

impl CosineModel {
    /// Strict (exact) model — every read sees fully fresh similarities.
    pub fn new(neighbors_k: usize) -> Self {
        Self::with_mode(neighbors_k, true)
    }

    /// Fast model with bounded staleness (pipeline default).
    pub fn fast(neighbors_k: usize) -> Self {
        Self::with_mode(neighbors_k, false)
    }

    /// Model with explicit exactness mode (see the `strict` field docs).
    pub fn with_mode(neighbors_k: usize, strict: bool) -> Self {
        Self {
            strict,
            dirt: HashMap::new(),
            item_count: TrackedMap::new(),
            pairs: HashMap::new(),
            topk: HashMap::new(),
            dirty: HashSet::new(),
            users: TrackedMap::new(),
            neighbors_k,
            cand_scratch: Vec::new(),
            rated_scratch: HashSet::new(),
            sims_scratch: Vec::new(),
            scored_scratch: Vec::new(),
            updates: 0,
            rebuilds: 0,
        }
    }

    /// Equation 6 for one pair given its co-occurrence count.
    #[inline]
    fn sim(&self, p: ItemId, q: ItemId, co: u64) -> f32 {
        let cp = self.item_count.peek(&p).copied().unwrap_or(0);
        let cq = self.item_count.peek(&q).copied().unwrap_or(0);
        if cp == 0 || cq == 0 {
            return 0.0;
        }
        co as f32 / ((cp as f32).sqrt() * (cq as f32).sqrt())
    }

    /// Fill `sims` with `p`'s top-k `(sim, partner)` pairs from the
    /// adjacency, in (sim desc, then item id) order — the one similarity
    /// scan behind cache rebuilds *and* strict frozen reads. The total
    /// order matters: equal-similarity partners would otherwise be
    /// ordered by HashMap iteration, which differs between a model and
    /// its migrated copy — the rescale/recovery equivalence guarantees
    /// need this scan to be deterministic, and the two callers must
    /// never diverge.
    fn collect_topk(&self, p: ItemId, sims: &mut Vec<(f32, ItemId)>) {
        sims.clear();
        let Some(adj) = self.pairs.get(&p) else {
            return;
        };
        let cp = self.item_count.peek(&p).copied().unwrap_or(0);
        if cp == 0 {
            return;
        }
        let cp_sqrt = (cp as f32).sqrt();
        for (&q, &co) in adj {
            let cq = self.item_count.peek(&q).copied().unwrap_or(0);
            if cq == 0 {
                continue;
            }
            sims.push((co as f32 / (cp_sqrt * (cq as f32).sqrt()), q));
        }
        let by_sim_then_id = |a: &(f32, ItemId), b: &(f32, ItemId)| {
            b.0.total_cmp(&a.0).then(a.1.cmp(&b.1))
        };
        if sims.len() > self.neighbors_k {
            sims.select_nth_unstable_by(self.neighbors_k - 1, by_sim_then_id);
            sims.truncate(self.neighbors_k);
        }
        sims.sort_unstable_by(by_sim_then_id);
    }

    /// Rebuild the top-k neighborhood of `p` from its adjacency.
    fn rebuild(&mut self, p: ItemId) {
        if self.pairs.get(&p).is_none()
            || self.item_count.peek(&p).copied().unwrap_or(0) == 0
        {
            self.topk.remove(&p);
            return;
        }
        let mut sims = std::mem::take(&mut self.sims_scratch);
        self.collect_topk(p, &mut sims);
        let mass: f32 = sims.iter().map(|(s, _)| s).sum();
        self.topk.insert(
            p,
            Neighborhood {
                neighbors: sims.iter().map(|&(s, q)| (q, s)).collect(),
                mass,
            },
        );
        self.sims_scratch = sims;
        self.rebuilds += 1;
    }

    /// Fresh-enough neighborhood for `p`.
    ///
    /// Strict mode: rebuild whenever any input of p's sims changed.
    /// Fast mode: rebuild when p has no cache or has absorbed enough
    /// pair bumps relative to its degree (amortized O(1) per bump).
    fn fresh_neighborhood(&mut self, p: ItemId) -> Option<&Neighborhood> {
        let needs = if !self.topk.contains_key(&p) {
            self.pairs.contains_key(&p)
        } else if self.strict {
            self.dirty.contains(&p)
        } else {
            let deg = self.pairs.get(&p).map(|a| a.len()).unwrap_or(0);
            let dirt = self.dirt.get(&p).copied().unwrap_or(0);
            dirt as usize >= (deg / 8).max(4).min(64)
        };
        if needs {
            self.rebuild(p);
            self.dirty.remove(&p);
            self.dirt.remove(&p);
        }
        self.topk.get(&p)
    }

    /// Equation 7 estimate for candidate `p` against a rated set.
    /// Returns `(estimate, rated_mass)`; exposed for targeted tests.
    pub fn estimate(
        &mut self,
        p: ItemId,
        rated: &HashSet<ItemId>,
    ) -> (f32, f32) {
        let Some(nb) = self.fresh_neighborhood(p) else {
            return (0.0, 0.0);
        };
        if nb.mass <= 0.0 {
            return (0.0, 0.0);
        }
        let num: f32 = nb
            .neighbors
            .iter()
            .filter(|(q, _)| rated.contains(q))
            .map(|(_, s)| s)
            .sum();
        (num / nb.mass, num)
    }

    /// Equation 7 for `p` without touching caches — the serving-path
    /// sibling of [`CosineModel::estimate`]. Strict mode recomputes the
    /// top-k from the adjacency on the fly (same values its always-fresh
    /// cache would hold); fast mode serves the cached neighborhood
    /// exactly as-is. Neither rebuilds nor clears dirt, so serving never
    /// moves the serialized state that checkpoints and migrations ship.
    fn estimate_frozen(
        &mut self,
        p: ItemId,
        rated: &HashSet<ItemId>,
    ) -> (f32, f32) {
        if self.strict {
            // The same deterministic scan `rebuild` uses, into the same
            // scratch buffer — just never cached (no visible state
            // moves; scratch is not serialized).
            let mut sims = std::mem::take(&mut self.sims_scratch);
            self.collect_topk(p, &mut sims);
            let mass: f32 = sims.iter().map(|(s, _)| s).sum();
            let num: f32 = sims
                .iter()
                .filter(|(_, q)| rated.contains(q))
                .map(|(s, _)| s)
                .sum();
            self.sims_scratch = sims;
            if mass <= 0.0 {
                return (0.0, 0.0);
            }
            (num / mass, num)
        } else {
            let Some(nb) = self.topk.get(&p) else {
                return (0.0, 0.0);
            };
            if nb.mass <= 0.0 {
                return (0.0, 0.0);
            }
            let num: f32 = nb
                .neighbors
                .iter()
                .filter(|(q, _)| rated.contains(q))
                .map(|(_, s)| s)
                .sum();
            (num / nb.mass, num)
        }
    }

    /// Total pair-adjacency entries (the paper's "complex structures in
    /// the state" — the dominant memory term of DICS).
    fn pair_entries(&self) -> u64 {
        self.pairs.values().map(|m| m.len() as u64).sum()
    }

    /// Remove an item from every structure, invalidating partners.
    fn evict_item(&mut self, id: ItemId) {
        self.item_count.remove(&id);
        self.topk.remove(&id);
        self.dirty.remove(&id);
        self.dirt.remove(&id);
        if let Some(adj) = self.pairs.remove(&id) {
            for q in adj.keys() {
                if let Some(back) = self.pairs.get_mut(q) {
                    back.remove(&id);
                }
                self.dirty.insert(*q);
            }
        }
    }
}

impl CosineModel {
    /// The one candidate-generation + Equation-7 scoring pipeline behind
    /// both read paths. `frozen = false` is the training read
    /// ([`StreamingRecommender::recommend`]): neighborhoods due for
    /// maintenance are rebuilt on the way. `frozen = true` is the
    /// serving read ([`StreamingRecommender::serve`]): strict mode
    /// recomputes freshness on the fly without caching, fast mode serves
    /// the caches exactly as-is — no *visible* state moves (the scratch
    /// buffers are reused by both paths; they are not serialized state).
    fn rank(&mut self, user: UserId, n: usize, frozen: bool) -> Vec<ItemId> {
        let Some(history) = self.users.peek(&user) else {
            return Vec::new();
        };
        // Detach the rated set and candidate list from &self. Once
        // `rated` is a detached local, iterating it while calling
        // `fresh_neighborhood` (&mut self) is fine — no cloned Vec copy
        // of it is needed.
        let mut rated = std::mem::take(&mut self.rated_scratch);
        rated.clear();
        rated.extend(history.iter().copied());
        let mut candidates = std::mem::take(&mut self.cand_scratch);
        candidates.clear();
        if self.strict {
            // Exact: every co-occurrence partner of a rated item (pure
            // read in both modes).
            for j in rated.iter() {
                if let Some(adj) = self.pairs.get(j) {
                    for &q in adj.keys() {
                        if !rated.contains(&q) {
                            candidates.push(q);
                        }
                    }
                }
            }
        } else {
            // TencentRec-style: candidates come from the *similar-item
            // lists* of the rated items (bounded at |rated| * k).
            for &j in rated.iter() {
                let nb = if frozen {
                    self.topk.get(&j)
                } else {
                    self.fresh_neighborhood(j)
                };
                if let Some(nb) = nb {
                    for &(q, _) in &nb.neighbors {
                        if !rated.contains(&q) {
                            candidates.push(q);
                        }
                    }
                }
            }
        }
        candidates.sort_unstable();
        candidates.dedup();

        let mut scored = std::mem::take(&mut self.scored_scratch);
        scored.clear();
        for &p in &candidates {
            let (est, rated_mass) = if frozen {
                self.estimate_frozen(p, &rated)
            } else {
                self.estimate(p, &rated)
            };
            if est > 0.0 {
                scored.push((est, rated_mass, p));
            }
        }
        // (est desc, rated_mass desc, id asc) — a strict total order
        // (ids are unique), so select-nth + sort-the-prefix returns the
        // byte-identical list a full sort would, at O(C + n log n)
        // instead of O(C log C) over the whole candidate set (the same
        // shape `collect_topk` uses; BENCH_hotpath.json `cosine_rank/*`).
        let by_rank = |a: &(f32, f32, ItemId), b: &(f32, f32, ItemId)| {
            b.0.total_cmp(&a.0).then(b.1.total_cmp(&a.1)).then(a.2.cmp(&b.2))
        };
        if scored.len() > n {
            if n == 0 {
                scored.clear();
            } else {
                scored.select_nth_unstable_by(n - 1, by_rank);
                scored.truncate(n);
            }
        }
        scored.sort_unstable_by(by_rank);
        let out: Vec<ItemId> =
            scored.iter().take(n).map(|&(_, _, p)| p).collect();
        // Return the scratch buffers.
        self.cand_scratch = candidates;
        self.rated_scratch = rated;
        self.scored_scratch = scored;
        out
    }
}

impl StreamingRecommender for CosineModel {
    fn name(&self) -> &'static str {
        "cosine"
    }

    fn recommend(&mut self, user: UserId, n: usize) -> Vec<ItemId> {
        self.rank(user, n, false)
    }

    /// Frozen serving read (see the trait docs): identical scoring
    /// pipeline to [`StreamingRecommender::recommend`], but stale
    /// neighborhoods are served as-is instead of rebuilt, and strict
    /// mode recomputes freshness on the fly without caching — no visible
    /// state moves. Fast-mode cache freshness is driven entirely by the
    /// (event-deterministic) prequential training path, which keeps
    /// serving answers replayable after a crash; the price is that a
    /// rarely-trained item's cached neighborhood is served at whatever
    /// staleness the training traffic left it.
    fn serve(&mut self, user: UserId, n: usize) -> Vec<ItemId> {
        self.rank(user, n, true)
    }

    fn rated_items(&self, user: UserId) -> Vec<ItemId> {
        self.users.peek(&user).cloned().unwrap_or_default()
    }

    fn update(&mut self, event: &Rating) {
        let now = event.ts;
        let item = event.item;
        // Bump item count (creates the entry on first sight). count(i)
        // enters sim(i, *): i and every partner of i go stale.
        match self.item_count.touch_mut(&item, now) {
            Some(c) => *c += 1,
            None => self.item_count.insert(item, 1, now),
        }
        if self.strict {
            self.dirty.insert(item);
            if let Some(adj) = self.pairs.get(&item) {
                for q in adj.keys() {
                    self.dirty.insert(*q);
                }
            }
        } else {
            *self.dirt.entry(item).or_insert(0) += 1;
        }
        // Co-occurrence with the user's history, both directions. The
        // history borrow (`self.users`) and the graph mutations
        // (`self.pairs` / `self.dirty` / `self.dirt`) touch disjoint
        // fields, so no clone of the history is needed.
        if let Some(history) = self.users.peek(&event.user) {
            for &j in history {
                if j == item {
                    continue;
                }
                *self
                    .pairs
                    .entry(item)
                    .or_default()
                    .entry(j)
                    .or_insert(0) += 1;
                *self
                    .pairs
                    .entry(j)
                    .or_default()
                    .entry(item)
                    .or_insert(0) += 1;
                if self.strict {
                    self.dirty.insert(j);
                } else {
                    *self.dirt.entry(j).or_insert(0) += 1;
                }
            }
        }
        // Append to history (first occurrence only).
        match self.users.touch_mut(&event.user, now) {
            Some(h) => {
                if !h.contains(&item) {
                    h.push(item);
                }
            }
            None => self.users.insert(event.user, vec![item], now),
        }
        self.updates += 1;
    }

    fn state_sizes(&self) -> StateSizes {
        StateSizes {
            users: self.users.len() as u64,
            items: self.item_count.len() as u64,
            aux: self.pair_entries(),
        }
    }

    fn state_bytes(&self) -> u64 {
        // Deterministic per-structure accounting: counts (id + count +
        // recency/frequency metadata), co-occurrence adjacency (16 bytes
        // per directed entry + a per-row header), user histories (id +
        // metadata + 8 bytes per rated item), and the visible read-side
        // caches — topk neighborhoods (12 bytes per cached neighbor),
        // the dirty set, and the fast-mode dirt counters. All are
        // functions of logical state only, so a migrated copy reports
        // the same figure.
        let items = self.item_count.len() as u64;
        let pair_rows = self.pairs.len() as u64;
        let pair_entries = self.pair_entries();
        let history: u64 =
            self.users.iter().map(|(_, h)| h.len() as u64).sum();
        let users = self.users.len() as u64;
        let cached: u64 = self
            .topk
            .values()
            .map(|n| n.neighbors.len() as u64)
            .sum();
        64 + items * 32
            + pair_rows * 8
            + pair_entries * 16
            + users * 32
            + history * 8
            + self.topk.len() as u64 * 12
            + cached * 12
            + self.dirty.len() as u64 * 8
            + self.dirt.len() as u64 * 12
    }

    fn export_partition(&self, keep_user: &dyn Fn(UserId) -> bool) -> Vec<u8> {
        let mut w = WireWriter::new();
        w.u8(COSINE_WIRE_TAG);
        w.u32(self.neighbors_k as u32);
        w.u8(self.strict as u8);
        w.u64(self.updates);
        // Item counts, sorted by id for deterministic snapshot bytes.
        let mut items: Vec<(ItemId, u64, u64, u64)> = self
            .item_count
            .iter_meta()
            .map(|(id, c, ts, freq)| (*id, *c, ts, freq))
            .collect();
        items.sort_unstable_by_key(|(id, ..)| *id);
        w.u32(items.len() as u32);
        for (id, count, last_ts, freq) in items {
            w.u64(id);
            w.u64(count);
            w.u64(last_ts);
            w.u64(freq);
        }
        // Co-occurrence rows (the symmetric adjacency travels in full;
        // it is item-side state).
        let mut rows: Vec<ItemId> = self.pairs.keys().copied().collect();
        rows.sort_unstable();
        w.u32(rows.len() as u32);
        for p in rows {
            let adj = &self.pairs[&p];
            let mut partners: Vec<(ItemId, u64)> =
                adj.iter().map(|(&q, &co)| (q, co)).collect();
            partners.sort_unstable_by_key(|(q, _)| *q);
            w.u64(p);
            w.u32(partners.len() as u32);
            for (q, co) in partners {
                w.u64(q);
                w.u64(co);
            }
        }
        // User histories (insertion order preserved — it is model state:
        // the co-occurrence loop walks it).
        let mut users: Vec<(UserId, &Vec<ItemId>, u64, u64)> = self
            .users
            .iter_meta()
            .filter(|(id, ..)| keep_user(**id))
            .map(|(id, h, ts, freq)| (*id, h, ts, freq))
            .collect();
        users.sort_unstable_by_key(|(id, ..)| *id);
        w.u32(users.len() as u32);
        for (id, history, last_ts, freq) in users {
            w.u64(id);
            w.u64(last_ts);
            w.u64(freq);
            w.u64_slice(history);
        }
        // Cache state travels too. In fast mode the bounded-staleness
        // caches are *semantically visible*: a cached neighborhood may
        // lag the adjacency by up to its dirt budget, and Equation 7
        // reads serve from the cache — dropping it would make a migrated
        // model answer *fresher* than the original, breaking the
        // rescale equivalence guarantee. (Strict mode would get away
        // with rebuilding, but exporting is cheap and exact for both.)
        let mut cached: Vec<ItemId> = self.topk.keys().copied().collect();
        cached.sort_unstable();
        w.u32(cached.len() as u32);
        for p in cached {
            let nb = &self.topk[&p];
            w.u64(p);
            w.f32(nb.mass);
            w.u32(nb.neighbors.len() as u32);
            for &(q, sim) in &nb.neighbors {
                w.u64(q);
                w.f32(sim);
            }
        }
        let mut dirty: Vec<ItemId> = self.dirty.iter().copied().collect();
        dirty.sort_unstable();
        w.u64_slice(&dirty);
        let mut dirt: Vec<(ItemId, u32)> =
            self.dirt.iter().map(|(&p, &d)| (p, d)).collect();
        dirt.sort_unstable_by_key(|(p, _)| *p);
        w.u32(dirt.len() as u32);
        for (p, d) in dirt {
            w.u64(p);
            w.u32(d);
        }
        w.into_bytes()
    }

    fn import_partition(&mut self, bytes: &[u8]) -> Result<()> {
        let mut r = WireReader::new(bytes);
        let tag = r.u8()?;
        if tag != COSINE_WIRE_TAG {
            bail!("cosine import: wire tag {tag} is not a cosine snapshot");
        }
        let k = r.u32()? as usize;
        if k != self.neighbors_k {
            bail!(
                "cosine import: neighborhood k {k} != configured {}",
                self.neighbors_k
            );
        }
        let strict = r.u8()? != 0;
        if strict != self.strict {
            bail!(
                "cosine import: snapshot strict={strict} != configured {}",
                self.strict
            );
        }
        self.updates += r.u64()?;
        let n_items = r.u32()?;
        for _ in 0..n_items {
            let id = r.u64()?;
            let count = r.u64()?;
            let last_ts = r.u64()?;
            let freq = r.u64()?;
            self.item_count.insert_with_meta(id, count, last_ts, freq);
        }
        let n_rows = r.u32()?;
        for _ in 0..n_rows {
            let p = r.u64()?;
            let deg = r.u32()?;
            let row = self.pairs.entry(p).or_default();
            for _ in 0..deg {
                let q = r.u64()?;
                let co = r.u64()?;
                row.insert(q, co);
            }
        }
        let n_users = r.u32()?;
        for _ in 0..n_users {
            let id = r.u64()?;
            let last_ts = r.u64()?;
            let freq = r.u64()?;
            let history = r.u64_slice()?;
            self.users.insert_with_meta(id, history, last_ts, freq);
        }
        // Cache state: restore exactly what the exporter had (see the
        // export comment — bounded-staleness caches are visible state).
        let n_cached = r.u32()?;
        for _ in 0..n_cached {
            let p = r.u64()?;
            let mass = r.f32()?;
            let len = r.u32()?;
            // Cap the pre-allocation by what the buffer could possibly
            // hold, so a corrupt length prefix can't balloon memory.
            let mut neighbors =
                Vec::with_capacity((len as usize).min(r.remaining() / 12 + 1));
            for _ in 0..len {
                let q = r.u64()?;
                let sim = r.f32()?;
                neighbors.push((q, sim));
            }
            self.topk.insert(p, Neighborhood { neighbors, mass });
        }
        for p in r.u64_slice()? {
            self.dirty.insert(p);
        }
        let n_dirt = r.u32()?;
        for _ in 0..n_dirt {
            let p = r.u64()?;
            let d = r.u32()?;
            self.dirt.insert(p, d);
        }
        if !r.is_done() {
            bail!("cosine import: {} trailing bytes", r.remaining());
        }
        Ok(())
    }

    fn sweep(&mut self, kind: SweepKind) -> u64 {
        let (dead_users, dead_items) = match kind {
            SweepKind::Lru { cutoff_ts } => (
                self.users.sweep_lru(cutoff_ts),
                self.item_count.sweep_lru(cutoff_ts),
            ),
            SweepKind::Lfu { min_freq } => (
                self.users.sweep_lfu(min_freq),
                self.item_count.sweep_lfu(min_freq),
            ),
            SweepKind::Decay { factor } => {
                // Gradual forgetting (extension): decay co-occurrence
                // evidence; counts reaching zero are evicted, so this
                // DOES bound DICS memory (unlike the ISGD variant).
                self.item_count.for_each_value_mut(|_, c| {
                    *c = (*c as f32 * factor) as u64;
                });
                let dead_items =
                    self.item_count.retain_or_collect(|_, c| *c > 0);
                let mut evicted = dead_items.len() as u64;
                for p in self.pairs.values_mut() {
                    p.retain(|_, co| {
                        *co = (*co as f32 * factor) as u64;
                        *co > 0
                    });
                }
                self.pairs.retain(|_, p| !p.is_empty());
                // All cached sims are stale after a global decay.
                self.topk.clear();
                self.dirty.clear();
                self.dirt.clear();
                for id in &dead_items {
                    if let Some(adj) = self.pairs.remove(id) {
                        for q in adj.keys() {
                            if let Some(back) = self.pairs.get_mut(q) {
                                back.remove(id);
                            }
                        }
                    }
                }
                evicted += self
                    .users
                    .retain_or_collect(|_, h| !h.is_empty())
                    .len() as u64;
                return evicted;
            }
        };
        // Cascade: drop evicted items from the pair adjacency and the
        // neighbor caches (the paper names exactly this iteration as the
        // DICS forgetting cost).
        for id in &dead_items {
            // item_count entry is already gone; clean the graph + caches.
            self.topk.remove(id);
            self.dirty.remove(id);
            self.dirt.remove(id);
            if let Some(adj) = self.pairs.remove(id) {
                for q in adj.keys() {
                    if let Some(back) = self.pairs.get_mut(q) {
                        back.remove(id);
                    }
                    self.dirty.insert(*q);
                }
            }
        }
        (dead_users.len() + dead_items.len()) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(user: u64, item: u64, ts: u64) -> Rating {
        Rating::new(user, item, 5.0, ts)
    }

    fn rated(items: &[u64]) -> HashSet<u64> {
        items.iter().copied().collect()
    }

    #[test]
    fn cold_start_empty() {
        let mut m = CosineModel::new(10);
        assert!(m.recommend(1, 10).is_empty());
        m.update(&ev(1, 5, 0));
        // Only rated item exists -> no candidates.
        assert!(m.recommend(1, 10).is_empty());
    }

    #[test]
    fn co_occurrence_drives_recommendation() {
        let mut m = CosineModel::new(10);
        // Items 1,2 heavily co-consumed; item 3 independent.
        for u in 0..20 {
            m.update(&ev(u, 1, u));
            m.update(&ev(u, 2, u + 1000));
        }
        for u in 100..105 {
            m.update(&ev(u, 3, u));
        }
        m.update(&ev(999, 1, 5000));
        let recs = m.recommend(999, 5);
        assert_eq!(recs.first(), Some(&2), "co-consumed partner first: {recs:?}");
        assert!(!recs.contains(&1), "rated item must be excluded");
    }

    #[test]
    fn similarity_matches_equation6() {
        let mut m = CosineModel::new(10);
        // count(1)=3, count(2)=2, pair(1,2)=2.
        m.update(&ev(10, 1, 0));
        m.update(&ev(10, 2, 1)); // pair += 1
        m.update(&ev(11, 1, 2));
        m.update(&ev(11, 2, 3)); // pair += 1
        m.update(&ev(12, 1, 4));
        let co = m.pairs[&1][&2];
        assert_eq!(co, 2);
        let s = m.sim(1, 2, co);
        let want = 2.0 / (3.0f32.sqrt() * 2.0f32.sqrt());
        assert!((s - want).abs() < 1e-6, "sim={s} want={want}");
    }

    #[test]
    fn cached_neighborhood_tracks_updates() {
        let mut m = CosineModel::new(10);
        m.update(&ev(1, 10, 0));
        m.update(&ev(1, 20, 1));
        let (est, _) = m.estimate(20, &rated(&[10]));
        assert!(est > 0.0);
        let rebuilds_before = m.rebuilds;
        // Re-estimating without intervening updates must hit the cache.
        let (est2, _) = m.estimate(20, &rated(&[10]));
        assert_eq!(est, est2);
        assert_eq!(m.rebuilds, rebuilds_before);
        // An update touching item 20's partner invalidates the cache.
        m.update(&ev(2, 10, 2));
        let _ = m.estimate(20, &rated(&[10]));
        assert!(m.rebuilds > rebuilds_before, "dirty mark must force rebuild");
    }

    #[test]
    fn estimate_matches_bruteforce_equation7() {
        // Randomized cross-check of the cached path against a direct
        // Equation 7 evaluation.
        use crate::util::proptest::forall;
        forall("cosine_cache_vs_bruteforce", 30, |rng| {
            let k = 1 + rng.next_bounded(5) as usize;
            let mut m = CosineModel::new(k);
            for step in 0..150u64 {
                m.update(&ev(
                    rng.next_bounded(12),
                    rng.next_bounded(15),
                    step,
                ));
            }
            let user = rng.next_bounded(12);
            let Some(history) = m.users.peek(&user).cloned() else {
                return;
            };
            let rset: HashSet<u64> = history.iter().copied().collect();
            for p in 0..15u64 {
                if rset.contains(&p) {
                    continue;
                }
                let (est, _) = m.estimate(p, &rset);
                // Brute force: all sims of p, top-k, Eq 7.
                let mut sims: Vec<(f32, u64)> = m
                    .pairs
                    .get(&p)
                    .map(|adj| {
                        adj.iter()
                            .map(|(&q, &co)| (m.sim(p, q, co), q))
                            .filter(|(s, _)| *s > 0.0)
                            .collect()
                    })
                    .unwrap_or_default();
                // Same (sim desc, id asc) order as the cached rebuild so
                // boundary ties agree.
                sims.sort_unstable_by(|a, b| {
                    b.0.total_cmp(&a.0).then(a.1.cmp(&b.1))
                });
                sims.truncate(k);
                let den: f32 = sims.iter().map(|(s, _)| s).sum();
                let num: f32 = sims
                    .iter()
                    .filter(|(_, q)| rset.contains(q))
                    .map(|(s, _)| s)
                    .sum();
                let want = if den > 0.0 { num / den } else { 0.0 };
                assert!(
                    (est - want).abs() < 1e-5,
                    "p={p} est={est} want={want}"
                );
            }
        });
    }

    #[test]
    fn pair_counts_symmetric() {
        let mut m = CosineModel::new(10);
        m.update(&ev(1, 10, 0));
        m.update(&ev(1, 20, 1));
        m.update(&ev(1, 30, 2));
        assert_eq!(m.pairs[&10][&20], m.pairs[&20][&10]);
        assert_eq!(m.pairs[&10][&30], m.pairs[&30][&10]);
        // 3 items pairwise: 3 unordered pairs -> 6 directed entries.
        assert_eq!(m.pair_entries(), 6);
        assert_eq!(m.state_sizes().aux, 6);
    }

    #[test]
    fn duplicate_ratings_do_not_duplicate_history() {
        let mut m = CosineModel::new(10);
        m.update(&ev(1, 10, 0));
        m.update(&ev(1, 10, 1));
        assert_eq!(m.users.peek(&1).unwrap().len(), 1);
        assert_eq!(*m.item_count.peek(&10).unwrap(), 2);
        assert_eq!(m.rated_items(1), vec![10]);
        assert!(m.rated_items(2).is_empty());
    }

    #[test]
    fn lru_sweep_cascades_into_pairs() {
        let mut m = CosineModel::new(10);
        m.update(&ev(1, 10, 0));
        m.update(&ev(1, 20, 1));
        m.update(&ev(2, 30, 1000));
        let evicted = m.sweep(SweepKind::Lru { cutoff_ts: 500 });
        // user 1, items 10+20 evicted (item 30 and user 2 survive).
        assert_eq!(evicted, 3);
        assert_eq!(m.pair_entries(), 0, "pair adjacency must be cascaded");
        assert!(m.item_count.contains(&30));
        // Recommending against evicted items yields nothing.
        assert!(m.recommend(1, 5).is_empty());
    }

    #[test]
    fn evict_item_cleans_everything() {
        let mut m = CosineModel::new(10);
        m.update(&ev(1, 10, 0));
        m.update(&ev(1, 20, 1));
        m.evict_item(10);
        assert!(!m.item_count.contains(&10));
        assert!(m.pairs.get(&20).map(|a| a.is_empty()).unwrap_or(true));
        assert!(!m.topk.contains_key(&10));
    }

    #[test]
    fn neighborhood_cap_limits_equation7() {
        // With k=1 only the single most-similar neighbor matters.
        let mut m = CosineModel::new(1);
        for u in 0..10 {
            m.update(&ev(u, 1, u)); // strong partner of 99
            m.update(&ev(u, 99, u + 100));
        }
        m.update(&ev(50, 2, 0)); // weak partner of 99
        m.update(&ev(50, 99, 1));
        m.update(&ev(777, 1, 2000));
        let (est, _) = m.estimate(99, &rated(&[1]));
        assert!((est - 1.0).abs() < 1e-6, "top-1 neighborhood fully rated");
        let (est2, _) = m.estimate(99, &rated(&[2]));
        assert_eq!(est2, 0.0, "weak neighbor outside top-1 neighborhood");
    }

    #[test]
    fn decay_sweep_fades_and_eventually_evicts() {
        let mut m = CosineModel::new(10);
        for u in 0..4 {
            m.update(&ev(u, 1, u));
            m.update(&ev(u, 2, u + 100));
        }
        let co_before = m.pairs[&1][&2];
        assert!(co_before >= 4);
        m.sweep(SweepKind::Decay { factor: 0.5 });
        assert_eq!(m.pairs[&1][&2], co_before / 2);
        // Repeated decay drives evidence to zero and evicts everything.
        let mut total = 0;
        for _ in 0..8 {
            total += m.sweep(SweepKind::Decay { factor: 0.5 });
        }
        assert!(total > 0, "zeroed entries must be evicted");
        assert_eq!(m.state_sizes().items, 0);
        assert_eq!(m.state_sizes().aux, 0);
    }

    #[test]
    fn export_import_is_exact_for_both_modes() {
        for strict in [true, false] {
            let mut m = CosineModel::with_mode(5, strict);
            let mut ts = 0u64;
            for u in 0..25u64 {
                for i in 0..6u64 {
                    m.update(&ev(u % 9, (u * 3 + i) % 14, ts));
                    ts += 1;
                }
            }
            // Warm some neighborhood caches so import must not depend on
            // them being cold on the source side.
            let _ = m.recommend(3, 10);
            let snap = m.export_partition(&|_| true);
            let mut n = CosineModel::with_mode(5, strict);
            n.import_partition(&snap).unwrap();
            assert_eq!(n.state_sizes(), m.state_sizes());
            for u in 0..9u64 {
                assert_eq!(
                    n.recommend(u, 10),
                    m.recommend(u, 10),
                    "strict={strict} user={u}"
                );
                let mut a = n.rated_items(u);
                let mut b = m.rated_items(u);
                a.sort_unstable();
                b.sort_unstable();
                assert_eq!(a, b);
            }
            // Future learning agrees too (counts and histories migrated
            // exactly; caches rebuild deterministically).
            for step in 0..40u64 {
                let e = ev(step % 11, (step * 7) % 16, ts + step);
                m.update(&e);
                n.update(&e);
            }
            for u in 0..11u64 {
                assert_eq!(n.recommend(u, 10), m.recommend(u, 10));
            }
            assert_eq!(
                m.export_partition(&|_| true),
                n.export_partition(&|_| true),
                "re-exported snapshots must be byte-identical"
            );
        }
    }

    #[test]
    fn serve_is_a_pure_read_in_both_modes() {
        // The serving path must not move anything export_partition ships
        // (the crash-replay exactness requirement): byte-identical
        // snapshots and zero rebuilds across any number of serves.
        for strict in [true, false] {
            let mut m = CosineModel::with_mode(5, strict);
            let mut ts = 0;
            for u in 0..20u64 {
                for i in 0..5u64 {
                    m.update(&ev(u % 7, (u * 3 + i) % 11, ts));
                    ts += 1;
                }
            }
            let before = m.export_partition(&|_| true);
            let rebuilds_before = m.rebuilds;
            for u in 0..7u64 {
                let _ = m.serve(u, 10);
            }
            assert_eq!(m.rebuilds, rebuilds_before, "strict={strict}");
            assert_eq!(
                m.export_partition(&|_| true),
                before,
                "strict={strict}: serving moved visible state"
            );
        }
    }

    #[test]
    fn serve_matches_recommend_on_fresh_caches() {
        for strict in [true, false] {
            let mut m = CosineModel::with_mode(5, strict);
            let mut ts = 0;
            for u in 0..20u64 {
                for i in 0..5u64 {
                    m.update(&ev(u % 7, (u * 3 + i) % 11, ts));
                    ts += 1;
                }
            }
            for u in 0..7u64 {
                // recommend refreshes whatever is due, then the frozen
                // read over the now-fresh caches agrees exactly.
                let via_recommend = m.recommend(u, 10);
                let via_serve = m.serve(u, 10);
                assert_eq!(
                    via_serve, via_recommend,
                    "strict={strict} user={u}"
                );
            }
        }
    }

    #[test]
    fn cosine_import_rejects_mismatch() {
        let m = CosineModel::new(10);
        let snap = m.export_partition(&|_| true);
        assert!(CosineModel::new(4).import_partition(&snap).is_err());
        assert!(CosineModel::fast(10).import_partition(&snap).is_err());
        let mut ok = CosineModel::new(10);
        assert!(ok.import_partition(&snap).is_ok());
        assert!(ok.import_partition(&[0xFF]).is_err());
        assert!(ok.import_partition(&snap[..snap.len() - 1]).is_err());
    }

    #[test]
    fn export_user_filter_keeps_item_side_state() {
        let mut m = CosineModel::new(10);
        for u in 0..4u64 {
            m.update(&ev(u, 1, u));
            m.update(&ev(u, 2, u + 50));
        }
        let snap = m.export_partition(&|u| u == 0);
        let mut n = CosineModel::new(10);
        n.import_partition(&snap).unwrap();
        let s = n.state_sizes();
        assert_eq!(s.users, 1);
        assert_eq!(s.items, 2);
        assert_eq!(s.aux, m.state_sizes().aux);
        assert_eq!(n.rated_items(0), vec![1, 2]);
        assert!(n.rated_items(1).is_empty());
    }

    #[test]
    fn state_sizes_counts() {
        let mut m = CosineModel::new(10);
        for u in 0..5 {
            for i in 0..4 {
                m.update(&ev(u, i, u * 4 + i));
            }
        }
        let s = m.state_sizes();
        assert_eq!(s.users, 5);
        assert_eq!(s.items, 4);
        assert_eq!(s.aux, 12); // 6 unordered pairs x 2 directions
    }

    #[test]
    fn state_bytes_is_deterministic_and_migration_invariant() {
        let mut m = CosineModel::fast(10);
        assert_eq!(m.state_bytes(), 64, "empty model: base overhead only");
        for u in 0..12u64 {
            for i in 0..6u64 {
                m.update(&ev(u % 4, (u + i) % 9, u * 6 + i));
            }
        }
        // Read path populates the visible topk caches too.
        let _ = m.recommend(1, 5);
        let b = m.state_bytes();
        assert!(b > 64);
        // A migrated copy (counts, pairs, histories, caches, dirt all
        // travel) reports the identical figure.
        let mut n = CosineModel::fast(10);
        n.import_partition(&m.export_partition(&|_| true)).unwrap();
        assert_eq!(n.state_bytes(), b);
        // Evicting everything returns to the base overhead.
        m.sweep(SweepKind::Lru { cutoff_ts: u64::MAX });
        assert_eq!(m.state_sizes().users, 0);
        assert!(m.state_bytes() < b);
    }

    #[test]
    fn rank_matches_full_sort_reference() {
        // The select-nth ranking tail must return the byte-identical
        // prefix of a full sort, ties included. `rank` with n >= |scored|
        // never enters the select-nth branch — it IS the naive full-sort
        // reference — so every top-n must equal its prefix. Ratings are
        // uniform 5.0, so similarity and estimate ties are everywhere;
        // the (est desc, mass desc, id asc) tie-break carries the proof.
        use crate::util::proptest::forall;
        for strict in [true, false] {
            forall("cosine_rank_vs_full_sort", 25, |rng| {
                let k = 1 + rng.next_bounded(5) as usize;
                let mut m = CosineModel::with_mode(k, strict);
                for step in 0..200u64 {
                    m.update(&ev(
                        rng.next_bounded(10),
                        rng.next_bounded(18),
                        step,
                    ));
                }
                for user in 0..10u64 {
                    // recommend() first settles any due cache rebuilds;
                    // the full list and every shorter read after it see
                    // identical estimates.
                    let full = m.recommend(user, 10_000);
                    for n in [0usize, 1, 2, 3, 7, 15] {
                        let top = m.recommend(user, n);
                        assert_eq!(
                            top,
                            full[..n.min(full.len())],
                            "strict={strict} user={user} n={n}"
                        );
                        let served = m.serve(user, n);
                        assert_eq!(
                            served,
                            full[..n.min(full.len())],
                            "serve: strict={strict} user={user} n={n}"
                        );
                    }
                }
            });
        }
    }
}
