//! ISGD — incremental matrix factorization (Vinagre et al. 2014), the
//! model behind both the central baseline and DISGD (Algorithm 2).
//!
//! Positive-only boolean feedback: every observed `<user, item>` has
//! target rating 1, error `err = 1 - U_u . I_i^T`, vectors initialized
//! ~N(0, 0.1), one SGD step per event, single pass over the stream.
//!
//! The numeric work (scoring against the item matrix, the fused update)
//! is delegated to a [`ScoringBackend`] — either hand-written Rust or the
//! AOT-compiled JAX/Pallas artifacts via PJRT. Both see the identical
//! `VectorSlab` memory.

use std::collections::HashSet;

use anyhow::{bail, Result};

use crate::algorithms::StreamingRecommender;
use crate::data::types::{ItemId, Rating, StateSizes, UserId};
use crate::runtime::{Scored, ScoringBackend};
use crate::state::{SweepKind, TrackedMap, VectorSlab};
use crate::util::rng::Pcg32;
use crate::util::wire::{WireReader, WireWriter};

/// Wire tag identifying an ISGD state snapshot (see
/// [`StreamingRecommender::export_partition`]).
pub const ISGD_WIRE_TAG: u8 = 1;

/// Per-user state: the latent vector + rated-item history.
struct UserState {
    vec: Box<[f32]>,
    rated: HashSet<ItemId>,
}

/// The ISGD model for one worker (or the whole system when central).
pub struct IsgdModel {
    users: TrackedMap<UserId, UserState>,
    items: VectorSlab,
    backend: Box<dyn ScoringBackend>,
    rng: Pcg32,
    k: usize,
    eta: f32,
    lambda: f32,
    /// Scratch for recommend() (no per-event allocation).
    rec_buf: Vec<ItemId>,
    /// Caller-owned scoring scratch threaded through
    /// [`ScoringBackend::topn_into`] — the candidate heap lives here, so
    /// steady-state serving allocates nothing per query.
    topn_scratch: Vec<Scored>,
    /// Events processed (diagnostics).
    pub updates: u64,
}

impl IsgdModel {
    /// Model with latent dimension `k`, learning rate `eta`, L2 weight
    /// `lambda`, init-RNG `seed`, and the given scoring backend.
    pub fn new(
        k: usize,
        eta: f32,
        lambda: f32,
        seed: u64,
        backend: Box<dyn ScoringBackend>,
    ) -> Self {
        Self {
            users: TrackedMap::new(),
            items: VectorSlab::new(k),
            backend,
            rng: Pcg32::seeded(seed),
            k,
            eta,
            lambda,
            rec_buf: Vec::new(),
            topn_scratch: Vec::new(),
            updates: 0,
        }
    }

    fn random_vector(&mut self) -> Vec<f32> {
        (0..self.k)
            .map(|_| (self.rng.next_gaussian() * 0.1) as f32)
            .collect()
    }

    /// Expose the item slab (tests / state inspection).
    pub fn items(&self) -> &VectorSlab {
        &self.items
    }

    /// Name of the scoring backend in use ("native" | "pjrt").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }
}

impl StreamingRecommender for IsgdModel {
    fn name(&self) -> &'static str {
        "isgd"
    }

    fn recommend(&mut self, user: UserId, n: usize) -> Vec<ItemId> {
        let Some(state) = self.users.peek(&user) else {
            return Vec::new(); // cold start: nothing to score with
        };
        if self.items.is_empty() {
            return Vec::new();
        }
        // Over-fetch so rated items can be filtered out locally. 50 is the
        // artifact overfetch bound; the native backend honours any size,
        // PJRT caps at the compiled length (n + |rated| rarely exceeds it).
        let want = (n + state.rated.len()).min(n + 40);
        self.backend.topn_into(
            &state.vec,
            &self.items,
            want,
            &mut self.topn_scratch,
        );
        self.rec_buf.clear();
        for s in &self.topn_scratch {
            if let Some(id) = self.items.id_at(s.row) {
                if !state.rated.contains(&id) {
                    self.rec_buf.push(id);
                    if self.rec_buf.len() == n {
                        break;
                    }
                }
            }
        }
        self.rec_buf.clone()
    }

    fn rated_items(&self, user: UserId) -> Vec<ItemId> {
        self.users
            .peek(&user)
            .map(|s| s.rated.iter().copied().collect())
            .unwrap_or_default()
    }

    fn update(&mut self, event: &Rating) {
        let now = event.ts;
        if !self.users.contains(&event.user) {
            let vec = self.random_vector().into_boxed_slice();
            self.users.insert(
                event.user,
                UserState { vec, rated: HashSet::new() },
                now,
            );
        }
        if !self.items.contains(event.item) {
            let vec = self.random_vector();
            self.items.insert(event.item, &vec, now);
        }
        // Shared-nothing: both vectors are worker-local; the fused step
        // mutates them in place (Equations 2-4).
        let user = self.users.touch_mut(&event.user, now).unwrap();
        let item = self.items.touch_mut(event.item, now).unwrap();
        self.backend.isgd_step(&mut user.vec, item, self.eta, self.lambda);
        user.rated.insert(event.item);
        self.updates += 1;
    }

    fn state_sizes(&self) -> StateSizes {
        StateSizes {
            users: self.users.len() as u64,
            items: self.items.len() as u64,
            aux: 0,
        }
    }

    fn state_bytes(&self) -> u64 {
        // Deterministic per-structure accounting (entry counts x entry
        // widths), identical for a model and its migrated copy. Per
        // user: id + recency/frequency metadata + k f32s + the rated
        // set (8 bytes per item id). Per live item row: id + metadata +
        // k f32s + validity slot. The slab's capacity padding is
        // deliberately excluded — it is allocator layout, not state,
        // and it would differ across bucket boundaries after a
        // migration re-pack.
        let k4 = 4 * self.k as u64;
        let rated: u64 = self
            .users
            .iter()
            .map(|(_, s)| s.rated.len() as u64)
            .sum();
        let users = self.users.len() as u64;
        let items = self.items.len() as u64;
        64 + users * (32 + k4) + rated * 8 + items * (36 + k4)
    }

    fn export_partition(&self, keep_user: &dyn Fn(UserId) -> bool) -> Vec<u8> {
        let mut w = WireWriter::new();
        w.u8(ISGD_WIRE_TAG);
        w.u32(self.k as u32);
        let (rng_state, rng_inc) = self.rng.snapshot();
        w.u64(rng_state);
        w.u64(rng_inc);
        w.u64(self.updates);
        // Items in slab-row order: importing in this order re-packs rows
        // with their relative order preserved, which keeps the top-N
        // scan's score-tie behavior identical after a migration.
        let items: Vec<(ItemId, usize)> = self.items.iter_ids().collect();
        w.u32(items.len() as u32);
        for (id, _row) in items {
            let (last_ts, freq) = self.items.meta(id).unwrap_or((0, 1));
            w.u64(id);
            w.u64(last_ts);
            w.u64(freq);
            for &v in self.items.get(id).expect("live id has a vector") {
                w.f32(v);
            }
        }
        // Users sorted by id so the snapshot bytes are deterministic
        // (HashMap iteration order is not).
        let mut users: Vec<(UserId, &UserState, u64, u64)> = self
            .users
            .iter_meta()
            .filter(|(id, ..)| keep_user(**id))
            .map(|(id, v, ts, freq)| (*id, v, ts, freq))
            .collect();
        users.sort_unstable_by_key(|(id, ..)| *id);
        w.u32(users.len() as u32);
        for (id, state, last_ts, freq) in users {
            w.u64(id);
            w.u64(last_ts);
            w.u64(freq);
            for &v in state.vec.iter() {
                w.f32(v);
            }
            let mut rated: Vec<ItemId> = state.rated.iter().copied().collect();
            rated.sort_unstable();
            w.u64_slice(&rated);
        }
        w.into_bytes()
    }

    fn import_partition(&mut self, bytes: &[u8]) -> Result<()> {
        let mut r = WireReader::new(bytes);
        let tag = r.u8()?;
        if tag != ISGD_WIRE_TAG {
            bail!("isgd import: wire tag {tag} is not an ISGD snapshot");
        }
        let k = r.u32()? as usize;
        if k != self.k {
            bail!("isgd import: latent dim {k} != configured {}", self.k);
        }
        let rng_state = r.u64()?;
        let rng_inc = r.u64()?;
        self.rng = Pcg32::restore(rng_state, rng_inc);
        self.updates += r.u64()?;
        let n_items = r.u32()?;
        let mut vec_buf = vec![0.0f32; k];
        for _ in 0..n_items {
            let id = r.u64()?;
            let last_ts = r.u64()?;
            let freq = r.u64()?;
            for v in vec_buf.iter_mut() {
                *v = r.f32()?;
            }
            if self.items.contains(id) {
                self.items.remove(id);
            }
            self.items.insert_with_meta(id, &vec_buf, last_ts, freq);
        }
        let n_users = r.u32()?;
        for _ in 0..n_users {
            let id = r.u64()?;
            let last_ts = r.u64()?;
            let freq = r.u64()?;
            let mut vec = vec![0.0f32; k].into_boxed_slice();
            for v in vec.iter_mut() {
                *v = r.f32()?;
            }
            let rated: HashSet<ItemId> =
                r.u64_slice()?.into_iter().collect();
            self.users.insert_with_meta(
                id,
                UserState { vec, rated },
                last_ts,
                freq,
            );
        }
        if !r.is_done() {
            bail!("isgd import: {} trailing bytes", r.remaining());
        }
        Ok(())
    }

    fn sweep(&mut self, kind: SweepKind) -> u64 {
        let (dead_users, dead_items) = match kind {
            SweepKind::Lru { cutoff_ts } => (
                self.users.sweep_lru(cutoff_ts),
                self.items.sweep_lru(cutoff_ts),
            ),
            SweepKind::Lfu { min_freq } => (
                self.users.sweep_lfu(min_freq),
                self.items.sweep_lfu(min_freq),
            ),
            SweepKind::Decay { factor } => {
                // Gradual forgetting (extension): old taste fades toward
                // the origin instead of being evicted; state size is
                // unchanged but stale vectors drop out of the top-N.
                self.users.for_each_value_mut(|_, s| {
                    for v in s.vec.iter_mut() {
                        *v *= factor;
                    }
                });
                self.items.decay_all(factor);
                return 0;
            }
        };
        (dead_users.len() + dead_items.len()) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;

    fn model(seed: u64) -> IsgdModel {
        IsgdModel::new(10, 0.05, 0.01, seed, Box::new(NativeBackend::new()))
    }

    fn ev(user: u64, item: u64, ts: u64) -> Rating {
        Rating::new(user, item, 5.0, ts)
    }

    #[test]
    fn cold_start_returns_empty() {
        let mut m = model(1);
        assert!(m.recommend(99, 10).is_empty());
        m.update(&ev(1, 2, 0));
        // User 1 known, but item 2 is the only (rated) item -> empty.
        assert!(m.recommend(1, 10).is_empty());
        // Unknown user still empty even though items exist.
        assert!(m.recommend(42, 10).is_empty());
    }

    #[test]
    fn rated_items_never_recommended() {
        let mut m = model(2);
        for item in 0..20 {
            m.update(&ev(1, item, item));
        }
        for item in 0..5 {
            m.update(&ev(2, item, 100 + item));
        }
        let recs = m.recommend(2, 10);
        assert!(!recs.is_empty());
        for r in &recs {
            assert!(!(0..5).contains(r), "rated item {r} recommended");
        }
        let mut rated = m.rated_items(2);
        rated.sort_unstable();
        assert_eq!(rated, vec![0, 1, 2, 3, 4]);
        assert!(m.rated_items(999).is_empty());
    }

    #[test]
    fn repeated_co_consumption_ranks_item_up() {
        let mut m = model(3);
        // Users 1..40 all rate items 100 and 200 together; user 50 rates
        // only 100. Item 200 should be highly ranked for user 50.
        let mut ts = 0;
        for round in 0..6 {
            for u in 1..40 {
                m.update(&ev(u, 100, ts));
                m.update(&ev(u, 200, ts + 1));
                // noise so the catalog has alternatives
                m.update(&ev(u, 300 + u + round * 50, ts + 2));
                ts += 3;
            }
        }
        for _ in 0..5 {
            m.update(&ev(50, 100, ts));
            ts += 1;
        }
        let recs = m.recommend(50, 5);
        assert!(
            recs.contains(&200),
            "co-consumed item should rank in top-5, got {recs:?}"
        );
    }

    #[test]
    fn state_sizes_track_population() {
        let mut m = model(4);
        for u in 0..7 {
            for i in 0..3 {
                m.update(&ev(u, i, u * 3 + i));
            }
        }
        let s = m.state_sizes();
        assert_eq!(s.users, 7);
        assert_eq!(s.items, 3);
        assert_eq!(s.aux, 0);
    }

    #[test]
    fn lru_sweep_evicts_idle_users_and_items() {
        let mut m = model(5);
        m.update(&ev(1, 10, 0));
        m.update(&ev(2, 20, 1000));
        let evicted = m.sweep(SweepKind::Lru { cutoff_ts: 500 });
        assert_eq!(evicted, 2); // user 1 + item 10
        let s = m.state_sizes();
        assert_eq!(s.users, 1);
        assert_eq!(s.items, 1);
    }

    #[test]
    fn lfu_sweep_evicts_cold_entries() {
        let mut m = model(6);
        for _ in 0..10 {
            m.update(&ev(1, 10, 0));
        }
        m.update(&ev(2, 20, 0));
        let evicted = m.sweep(SweepKind::Lfu { min_freq: 3 });
        assert_eq!(evicted, 2); // user 2 + item 20
        assert!(m.users.contains(&1));
        assert!(m.items.contains(10));
    }

    #[test]
    fn decay_sweep_shrinks_vectors_not_state() {
        let mut m = model(9);
        m.update(&ev(1, 10, 0));
        let before = m.items().get(10).unwrap().to_vec();
        let evicted = m.sweep(SweepKind::Decay { factor: 0.5 });
        assert_eq!(evicted, 0, "decay never evicts ISGD state");
        assert_eq!(m.state_sizes().users, 1);
        let after = m.items().get(10).unwrap();
        for (b, a) in before.iter().zip(after.iter()) {
            assert!((a - b * 0.5).abs() < 1e-6);
        }
    }

    #[test]
    fn state_bytes_is_deterministic_and_migration_invariant() {
        let mut m = model(8);
        assert_eq!(m.state_bytes(), 64, "empty model: base overhead only");
        for u in 0..30u64 {
            for i in 0..8u64 {
                m.update(&ev(u % 6, (u * 3 + i) % 20, u * 8 + i));
            }
        }
        let b = m.state_bytes();
        assert!(b > 64, "populated model accounts its entries");
        // Closed form: users*(32+4k) + rated*8 + items*(36+4k) + 64.
        let s = m.state_sizes();
        let rated: u64 = (0..6u64).map(|u| m.rated_items(u).len() as u64).sum();
        assert_eq!(b, 64 + s.users * (32 + 40) + rated * 8 + s.items * (36 + 40));
        // A migrated copy reports the identical figure.
        let mut n = model(777);
        n.import_partition(&m.export_partition(&|_| true)).unwrap();
        assert_eq!(n.state_bytes(), b);
        // Eviction shrinks it.
        m.sweep(SweepKind::Lru { cutoff_ts: u64::MAX });
        assert_eq!(m.state_bytes(), 64);
    }

    #[test]
    fn export_import_is_bit_exact() {
        let mut m = model(21);
        for u in 0..40u64 {
            for i in 0..12u64 {
                m.update(&ev(u % 7, (u * 5 + i) % 25, u * 12 + i));
            }
        }
        let snap = m.export_partition(&|_| true);
        let mut n = model(999); // different seed: import must replace it
        n.import_partition(&snap).unwrap();
        assert_eq!(n.state_sizes(), m.state_sizes());
        // Bit-identical serving...
        for u in 0..7u64 {
            assert_eq!(n.recommend(u, 10), m.recommend(u, 10));
            assert_eq!(n.rated_items(u), m.rated_items(u));
        }
        // ...and bit-identical future learning (RNG stream migrated, so
        // new-entity initialization draws the same vectors).
        for step in 0..50u64 {
            let e = ev(100 + step % 3, 200 + step % 9, 10_000 + step);
            m.update(&e);
            n.update(&e);
        }
        for u in [0u64, 100, 101, 102] {
            assert_eq!(n.recommend(u, 10), m.recommend(u, 10));
        }
        // Snapshot bytes are deterministic: re-export equals export.
        assert_eq!(m.export_partition(&|_| true), n.export_partition(&|_| true));
    }

    #[test]
    fn export_user_filter_slices_users_only() {
        let mut m = model(3);
        for u in 0..6u64 {
            for i in 0..4u64 {
                m.update(&ev(u, i + u, u * 4 + i));
            }
        }
        let snap = m.export_partition(&|u| u % 2 == 0);
        let mut n = model(3);
        n.import_partition(&snap).unwrap();
        let s = n.state_sizes();
        assert_eq!(s.users, 3, "only the filtered user slice travels");
        assert_eq!(s.items, m.state_sizes().items, "items travel in full");
        assert!(n.rated_items(1).is_empty());
        let mut got = n.rated_items(2);
        got.sort_unstable();
        let mut want = m.rated_items(2);
        want.sort_unstable();
        assert_eq!(got, want);
    }

    #[test]
    fn import_rejects_garbage_and_mismatch() {
        let mut m = model(1);
        assert!(m.import_partition(&[]).is_err());
        assert!(m.import_partition(&[9, 0, 0]).is_err());
        let snap = m.export_partition(&|_| true);
        let mut wrong_k = IsgdModel::new(
            5,
            0.05,
            0.01,
            1,
            Box::new(NativeBackend::new()),
        );
        assert!(wrong_k.import_partition(&snap).is_err());
        // Truncated snapshot errors instead of panicking.
        let mut big = model(2);
        big.update(&ev(1, 2, 0));
        let snap = big.export_partition(&|_| true);
        assert!(m.import_partition(&snap[..snap.len() - 3]).is_err());
    }

    #[test]
    fn deterministic_across_runs() {
        let run = |seed| {
            let mut m = model(seed);
            for u in 0..50u64 {
                for i in 0..10u64 {
                    m.update(&ev(u % 9, (u * 7 + i) % 30, u * 10 + i));
                }
            }
            m.recommend(3, 10)
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }
}
