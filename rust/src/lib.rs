//! # streamrec
//!
//! A distributed real-time recommender system for big data streams —
//! a Rust + JAX/Pallas reproduction of Hazem, Awad & Hassan (2022).
//!
//! The paper's *splitting & replication* mechanism distributes streaming
//! recommender algorithms (incremental matrix factorization and
//! incremental item-based cosine similarity) over a shared-nothing
//! cluster without any state synchronization, and bounds unbounded stream
//! state with LRU/LFU forgetting.
//!
//! ## Architecture (three layers)
//!
//! * **Layer 3 (this crate)** — the coordinator: a from-scratch
//!   shared-nothing stream engine ([`engine`]), the Algorithm-1 router and
//!   leader/worker pipeline ([`coordinator`]), the streaming algorithms
//!   ([`algorithms`]), worker-local state with forgetting ([`state`]),
//!   prequential evaluation ([`eval`]), datasets ([`data`]), and the
//!   experiment harness ([`experiments`]).
//! * **Layer 2 (JAX, build-time)** — `python/compile/model.py`: the ISGD
//!   compute graph, AOT-lowered to HLO-text artifacts.
//! * **Layer 1 (Pallas, build-time)** — `python/compile/kernels/`: the
//!   tiled scoring kernel and the fused ISGD update kernel.
//!
//! The [`runtime`] module loads the AOT artifacts via the PJRT CPU client;
//! Python never runs on the request path.
//!
//! ## Quickstart
//!
//! ```no_run
//! use streamrec::config::{RunConfig, Topology};
//! use streamrec::coordinator::run_pipeline;
//! use streamrec::data::DatasetSpec;
//!
//! let events = DatasetSpec::parse("ml-like:50000", 42).unwrap()
//!     .load().unwrap();
//! let mut cfg = RunConfig::default();
//! cfg.topology = Topology::new(2, 0).unwrap(); // n_i=2 -> 4 workers
//! let report = run_pipeline(&cfg, &events, "quickstart").unwrap();
//! println!("{}", report.summary());
//! ```

pub mod algorithms;
pub mod benchutil;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod eval;
pub mod experiments;
pub mod runtime;
pub mod state;
pub mod util;
