//! # streamrec
//!
//! A distributed real-time recommender system for big data streams —
//! a Rust + JAX/Pallas reproduction of Hazem, Awad & Hassan (2022).
//!
//! The paper's *splitting & replication* mechanism distributes streaming
//! recommender algorithms (incremental matrix factorization and
//! incremental item-based cosine similarity) over a shared-nothing
//! cluster without any state synchronization, and bounds unbounded stream
//! state with LRU/LFU forgetting.
//!
//! ## Architecture (three layers)
//!
//! * **Layer 3 (this crate)** — the coordinator: a from-scratch
//!   shared-nothing stream engine ([`engine`]), the Algorithm-1 router and
//!   leader/worker pipeline ([`coordinator`]), the streaming algorithms
//!   ([`algorithms`]), worker-local state with forgetting ([`state`]),
//!   prequential evaluation ([`eval`]), datasets ([`data`]), and the
//!   experiment harness ([`experiments`]).
//! * **Layer 2 (JAX, build-time)** — `python/compile/model.py`: the ISGD
//!   compute graph, AOT-lowered to HLO-text artifacts.
//! * **Layer 1 (Pallas, build-time)** — `python/compile/kernels/`: the
//!   tiled scoring kernel and the fused ISGD update kernel.
//!
//! The [`runtime`] module loads the AOT artifacts via the PJRT CPU client;
//! Python never runs on the request path.
//!
//! ## Quickstart: the `Cluster` session API
//!
//! The system is built for *unbounded* streams: spawn the shared-nothing
//! workers once, then interleave ingest (the learning loop), online
//! recommendation queries (the serving loop), and live metrics for as
//! long as the stream lasts. `recommend` fans each query out to all
//! `n_i` replicas of the user and merges their local top-N lists into a
//! global top-N (the paper's replicated-user read path).
//!
//! ```no_run
//! use streamrec::config::{RunConfig, Topology};
//! use streamrec::coordinator::Cluster;
//! use streamrec::data::DatasetSpec;
//!
//! let events = DatasetSpec::parse("ml-like:50000", 42).unwrap()
//!     .load().unwrap();
//! let mut cfg = RunConfig::default();
//! cfg.topology = Topology::new(2, 0).unwrap(); // n_i=2 -> 4 workers
//!
//! let mut cluster = Cluster::spawn(&cfg).unwrap();
//! let user = events[0].user;
//! for chunk in events.chunks(10_000) {
//!     cluster.ingest_batch(chunk).unwrap();          // learning loop
//!     let recs = cluster.recommend(user, 10).unwrap(); // serving loop
//!     let live = cluster.metrics().unwrap();           // no shutdown
//!     println!("recall so far {:.4}, top-10 {recs:?}", live.recall);
//! }
//! let report = cluster.finish().unwrap(); // drain + join + final report
//! println!("{}", report.summary());
//! ```
//!
//! ## Throughput tuning
//!
//! The ingest data plane is micro-batched: `ingest` routes the event and
//! appends it to a per-worker buffer; the buffer moves to its worker with
//! one bulk channel send (one lock, one wakeup) once it holds
//! `RunConfig::ingest_batch_size` events, and workers drain everything
//! queued per wakeup. Three rules of thumb:
//!
//! * **`ingest_batch_size`** (TOML: `engine.ingest_batch_size`) trades
//!   per-event transport cost against buffering delay. `1` is the old
//!   send-per-event plane; larger values amortize the channel crossing
//!   over the batch. Sweep it for your workload with
//!   `cargo run --release --bench pipeline` (writes `BENCH_ingest.json`).
//! * **Flush-on-query** — you never trade consistency for throughput:
//!   every route buffer is flushed before a `recommend`/`metrics` probe
//!   is sent and in `finish()`, so reads always observe every prior
//!   ingest and results are identical for any batch size
//!   (property-tested in `tests/batching_equivalence.rs`).
//! * **Prefer `ingest_batch` over per-event `ingest`** when events arrive
//!   in slices: same semantics, but the routing loop stays hot and
//!   buffers fill without re-entering the session between events.
//!
//! `RunReport::{backpressure_ns, recv_blocked_ns, mean_send_batch}` tell
//! you which side of the transport (sender stalls vs receiver idling) a
//! configuration is paying for.
//!
//! ## Migrating from `run_pipeline`
//!
//! The historical one-shot entry point survives with identical signature
//! and semantics as a thin wrapper — `run_pipeline(&cfg, &events, label)`
//! is exactly `Cluster::spawn_labeled(&cfg, label)?` +
//! `ingest_batch(&events)?` + `finish()`. Keep it for batch experiments;
//! switch to [`coordinator::Cluster`] when you need to query or observe
//! the system while the stream is live.

pub mod algorithms;
pub mod benchutil;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod eval;
pub mod experiments;
pub mod runtime;
pub mod state;
pub mod util;
