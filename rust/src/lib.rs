//! # streamrec
//!
//! A distributed real-time recommender system for big data streams —
//! a Rust + JAX/Pallas reproduction of Hazem, Awad & Hassan (2022).
//!
//! The paper's *splitting & replication* mechanism distributes streaming
//! recommender algorithms (incremental matrix factorization and
//! incremental item-based cosine similarity) over a shared-nothing
//! cluster without any state synchronization, and bounds unbounded stream
//! state with LRU/LFU forgetting.
//!
//! Two companion documents go deeper than this page: `ARCHITECTURE.md`
//! (the worker grid, the control/data planes, the ordering guarantees,
//! and the rescale protocol, with diagrams) and `docs/CONFIG.md` (every
//! TOML knob with defaults, ranges, and the paper section it maps to).
//!
//! ## Architecture (three layers)
//!
//! * **Layer 3 (this crate)** — the coordinator: a from-scratch
//!   shared-nothing stream engine ([`engine`]), the Algorithm-1 router and
//!   leader/worker pipeline ([`coordinator`]), the streaming algorithms
//!   ([`algorithms`]), worker-local state with forgetting ([`state`]),
//!   prequential evaluation ([`eval`]), datasets ([`data`]), and the
//!   experiment harness ([`experiments`]).
//! * **Layer 2 (JAX, build-time)** — `python/compile/model.py`: the ISGD
//!   compute graph, AOT-lowered to HLO-text artifacts.
//! * **Layer 1 (Pallas, build-time)** — `python/compile/kernels/`: the
//!   tiled scoring kernel and the fused ISGD update kernel.
//!
//! The [`runtime`] module loads the AOT artifacts via the PJRT CPU client;
//! Python never runs on the request path.
//!
//! ## Quickstart: the `Cluster` session API
//!
//! The system is built for *unbounded* streams: spawn the shared-nothing
//! workers once, then interleave ingest (the learning loop), online
//! recommendation queries (the serving loop), live metrics, and — when
//! load changes — live rescaling, for as long as the stream lasts.
//! `recommend` fans each query out to all replicas of the user and merges
//! their local top-N lists into a global top-N (the paper's
//! replicated-user read path). `rescale` migrates the running system to a
//! new worker topology with zero event loss and exact model state.
//!
//! This example compiles and runs as a doc-test (`cargo test --doc`):
//!
//! ```
//! # fn main() -> anyhow::Result<()> {
//! use streamrec::config::{RunConfig, Topology};
//! use streamrec::coordinator::Cluster;
//! use streamrec::data::DatasetSpec;
//!
//! let events = DatasetSpec::parse("ml-like:4000", 42)?.load()?;
//! let mut cfg = RunConfig::default();
//! cfg.topology = Topology::new(2, 0)?; // spawn at n_i=2 -> 4 workers
//! cfg.rescale_max_n_i = 4;             // reserve headroom to grow to n_i=4
//!
//! let mut cluster = Cluster::spawn(&cfg)?;
//! let user = events[0].user;
//! let (first_half, rest) = events.split_at(events.len() / 2);
//!
//! cluster.ingest_batch(first_half)?;               // learning loop
//! let recs = cluster.recommend(user, 10)?;         // serving loop
//! let live = cluster.metrics()?;                   // live counters
//! assert_eq!(live.processed + live.buffered, cluster.ingested());
//!
//! // Live elastic rescale: 4 -> 16 workers. Zero events lost, model
//! // state moves exactly — the same query answers the same way.
//! let stats = cluster.rescale(Topology::new(4, 0)?)?;
//! assert_eq!(cluster.n_workers(), 16);
//! assert_eq!(cluster.recommend(user, 10)?, recs);
//!
//! cluster.ingest_batch(rest)?;
//! let report = cluster.finish()?;                  // drain + join + report
//! assert_eq!(report.events, events.len() as u64);
//! assert_eq!(report.rescales, 1);
//! println!("{} (paused {:.2} ms for the rescale)",
//!          report.summary(), stats.pause_ns as f64 / 1e6);
//! # Ok(())
//! # }
//! ```
//!
//! ## Throughput tuning
//!
//! The ingest data plane is micro-batched: `ingest` routes the event and
//! appends it to a per-worker buffer; the buffer moves to its worker with
//! one bulk channel send (one lock, one wakeup) once it holds
//! `RunConfig::ingest_batch_size` events, and workers drain everything
//! queued per wakeup. Three rules of thumb:
//!
//! * **`ingest_batch_size`** (TOML: `engine.ingest_batch_size`) trades
//!   per-event transport cost against buffering delay. `1` is the old
//!   send-per-event plane; larger values amortize the channel crossing
//!   over the batch. Sweep it for your workload with
//!   `cargo run --release --bench pipeline` (writes `BENCH_ingest.json`).
//! * **Flush-on-query** — you never trade consistency for throughput:
//!   a `recommend` flushes the *queried user's replica* route buffers
//!   and carries a read-your-writes fence, so it always observes every
//!   prior ingest for that user; `finish()` drains everything. Other
//!   workers' buffers are left alone, and a `metrics` probe flushes
//!   nothing at all (it reports `processed + buffered == ingested`).
//!   Results are identical for any batch size (property-tested in
//!   `tests/batching_equivalence.rs`).
//! * **Prefer `ingest_batch` over per-event `ingest`** when events arrive
//!   in slices: same semantics, but the routing loop stays hot and
//!   buffers fill without re-entering the session between events.
//!
//! `RunReport::{backpressure_ns, recv_blocked_ns, mean_send_batch}` tell
//! you which side of the transport (sender stalls vs receiver idling) a
//! configuration is paying for.
//!
//! ## The serving plane (concurrent queries under live ingest)
//!
//! Queries run on a plane of their own: every worker has a dedicated
//! bounded *query lane* that bypasses the ingest FIFO, so a `recommend`
//! never queues behind ingest backpressure — in process and over TCP
//! alike (query frames may overtake event frames on the wire). A
//! read-your-writes **fence** (the newest sequence routed to the
//! worker, captured at fan-out) keeps answers exact anyway: the worker
//! parks the query until its applied watermark reaches the fence.
//! [`coordinator::Cluster::serving`] returns a cloneable
//! [`coordinator::ServingHandle`] whose `recommend` takes `&self`, so
//! any number of threads query concurrently while ingest — and even a
//! live rescale — proceed (property-tested in
//! `tests/serving_equivalence.rs`). Repeated queries hit a sharded
//! serving cache validated by `(topology epoch, column generation,
//! column event count)`: a rescale, a crash recovery, or any write
//! past `serving.cache_max_staleness` invalidates, so a stale answer is
//! never served across those boundaries. Overload is *shed*, never
//! queued unboundedly — at most `serving.max_in_flight` queries run at
//! once and a full worker lane refuses instead of blocking
//! (`ClusterMetrics::shed_queries`). The open-loop load harness
//! `benches/serving.rs` drives a target QPS against a live ingesting
//! cluster (one worker remote over loopback TCP) and records
//! p50/p99/p99.9 serving latency into `BENCH_serving.json`.
//!
//! ## Elastic rescaling
//!
//! Model state is partitioned on a fixed virtual *state grid* into
//! *lanes* (one independent model per virtual cell); physical workers
//! host groups of lanes. [`coordinator::Cluster::rescale`] moves whole
//! lanes between workers — never splitting or merging model state — so a
//! topology change is exact: zero event loss, identical recommendations,
//! identical recall curves (property-tested in
//! `tests/rescale_equivalence.rs`; pause cost measured by
//! `benches/rescale.rs`, recorded in `BENCH_rescale.json`).
//!
//! By default the state grid equals the spawn topology (no behavior
//! change vs the paper; rescale can shrink to any divisor topology and
//! grow back). To grow *beyond* the spawn size, reserve headroom at
//! spawn with `rescale.max_n_i` — the Flink "max parallelism" analog.
//! See `ARCHITECTURE.md` for the full protocol and the trade-off.
//!
//! ## Fault tolerance
//!
//! Set `fault.checkpoint_interval` and a worker crash becomes invisible:
//! workers checkpoint each model lane every N events (same wire framing
//! as rescaling, stamped with the lane's high-watermark sequence
//! number), the coordinator keeps a bounded replay log of recent
//! envelopes, and the supervisor respawns a crashed worker, restores its
//! lanes from the latest checkpoints, and replays the watermark-filtered
//! suffix. Recovery is **exactly-once**: hits, recall curves, and
//! recommendations of a crashed-and-recovered session are identical to
//! a never-crashed one, for both algorithms, even mid-rescale
//! (property-tested in `tests/fault_tolerance.rs`; recovery pause vs
//! state size is measured by `benches/recovery.rs`, recorded in
//! `BENCH_recovery.json`). The per-lane forgetting clocks travel inside
//! the same lane frames, so sweep cadence also survives both rescale
//! and recovery. With the default `fault.checkpoint_interval = 0`
//! nothing is checkpointed and a worker death is a loud session error —
//! the paper's original contract.
//!
//! ## Concept drift & windowed evaluation
//!
//! The synthetic generator can be wrapped in a drift scenario
//! ([`data::drift`]): six deterministic, seedable shapes — abrupt
//! preference flip, gradual rotation, recurring/seasonal drift,
//! popularity inversion, user churn + cold-start waves, arrival-rate
//! bursts — each a pure function of popularity ranks, scheduled as
//! stream fractions. Alongside the paper's cumulative moving-average
//! recall, every run now reports *windowed* (tumbling, time-local)
//! recall ([`eval::windowed`]): `RunReport::windowed_recall` globally,
//! `WorkerReport::windows` per worker — the view where a drift shows up
//! as a dip and recovery as the climb back. The `streamrec experiment`
//! subcommand ([`experiments::scenario`]) runs declarative
//! baseline-vs-distributed grids over drifted streams and records each
//! run's drift response (`BENCH_drift.json`; schema in
//! docs/EXPERIMENTS.md, knobs in docs/CONFIG.md).
//!
//! ## Networked workers
//!
//! Workers can run in other processes or on other machines with no
//! behavior change: start a host with `streamrec worker --listen
//! host:port` (a [`net::WorkerServer`]), list it under
//! `[cluster] workers` in the TOML, and the coordinator dials it
//! instead of spawning a local thread — mixing `"local"` and
//! `"tcp://host:port"` entries freely. Every `WorkerMsg` crosses the
//! socket as a length-prefixed frame ([`net`]), replies multiplex by
//! request id, and a dropped connection is handled exactly like a
//! crashed local worker (checkpoint-restore recovery included).
//! Loopback TCP and in-proc sessions are byte-identical
//! (property-tested in `tests/transport_equivalence.rs`; throughput
//! cost measured by `benches/transport.rs`, recorded in
//! `BENCH_transport.json`).
//!
//! ## Migrating from `run_pipeline`
//!
//! The historical one-shot entry point survives with identical signature
//! and semantics as a thin wrapper — `run_pipeline(&cfg, &events, label)`
//! is exactly `Cluster::spawn_labeled(&cfg, label)?` +
//! `ingest_batch(&events)?` + `finish()`. Keep it for batch experiments;
//! switch to [`coordinator::Cluster`] when you need to query, observe, or
//! rescale the system while the stream is live.

#![warn(missing_docs)]

pub mod algorithms;
pub mod benchutil;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod eval;
pub mod experiments;
pub mod net;
pub mod runtime;
pub mod state;
pub mod util;
