//! Configuration system: typed run configuration + a TOML-subset parser
//! (offline build has no `toml`/`serde`; DESIGN.md §3).
//!
//! The accepted TOML subset: `[section]` headers, `key = value` pairs with
//! string / integer / float / boolean values, `#` comments. That covers
//! every shipped config (see `configs/*.toml`), and the parser rejects
//! anything outside the subset loudly rather than mis-reading it.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

/// Which streaming recommender to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// Incremental SGD matrix factorization (ISGD / DISGD).
    Isgd,
    /// Incremental item-based cosine similarity (TencentRec / DICS).
    Cosine,
}

impl Algorithm {
    /// Parse a config string (`isgd`/`disgd`, `cosine`/`dics`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "isgd" | "disgd" => Ok(Self::Isgd),
            "cosine" | "dics" => Ok(Self::Cosine),
            other => bail!("unknown algorithm '{other}' (isgd|cosine)"),
        }
    }

    /// Canonical name used in reports and labels.
    pub fn name(&self) -> &'static str {
        match self {
            Self::Isgd => "isgd",
            Self::Cosine => "cosine",
        }
    }
}

/// Numeric backend for the ISGD hot path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust scoring/update (cross-checked against PJRT; used for the
    /// large figure sweeps).
    Native,
    /// AOT-compiled JAX/Pallas artifacts executed via the PJRT CPU client
    /// (one client per worker thread; the xla crate types are !Send).
    Pjrt,
}

impl Backend {
    /// Parse a config string (`native` | `pjrt`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "native" => Ok(Self::Native),
            "pjrt" => Ok(Self::Pjrt),
            other => bail!("unknown backend '{other}' (native|pjrt)"),
        }
    }

    /// Canonical name used in reports and logs.
    pub fn name(&self) -> &'static str {
        match self {
            Self::Native => "native",
            Self::Pjrt => "pjrt",
        }
    }
}

/// Forgetting technique (Section 5.2): bounds unbounded state growth.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Forgetting {
    /// Keep everything (the paper's base configuration).
    None,
    /// Least-recently-used: every `trigger_secs` of event time, evict
    /// entries idle for more than `max_idle_secs`.
    Lru {
        /// Event-time seconds between sweep scans.
        trigger_secs: u64,
        /// Entries idle longer than this are evicted.
        max_idle_secs: u64,
    },
    /// Least-frequently-used: every `trigger_events` processed records,
    /// evict entries with frequency below `min_freq` (tuned aggressively
    /// for memory, per the paper).
    Lfu {
        /// Processed-record count between sweep scans.
        trigger_events: u64,
        /// Entries touched fewer times than this are evicted.
        min_freq: u64,
    },
    /// Gradual forgetting (the paper's future-work extension, Section 6):
    /// every `trigger_events` records, multiplicatively decay the model —
    /// ISGD shrinks latent vectors toward 0, DICS decays co-occurrence
    /// counts (entries reaching 0 are evicted). Old evidence fades
    /// instead of being cut off, trading eviction cliffs for smoothness.
    Decay {
        /// Processed-record count between decay applications.
        trigger_events: u64,
        /// Multiplicative factor applied to model evidence (`0 < f < 1`).
        factor: f32,
    },
}

impl Forgetting {
    /// Canonical policy name used in reports and labels.
    pub fn name(&self) -> &'static str {
        match self {
            Self::None => "none",
            Self::Lru { .. } => "lru",
            Self::Lfu { .. } => "lfu",
            Self::Decay { .. } => "decay",
        }
    }
}

/// Replication topology (Section 4): `n_c = n_i^2 + w * n_i` workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Topology {
    /// Replication factor `n_i` (number of item splits).
    pub n_i: u64,
    /// Spare-worker knob `w` (usually 0 in the paper's evaluation).
    pub w: u64,
}

impl Topology {
    /// Build a topology from the replication factor and spare-worker
    /// knob; `n_i` must be at least 1.
    pub fn new(n_i: u64, w: u64) -> Result<Self> {
        if n_i == 0 {
            bail!("n_i must be >= 1");
        }
        Ok(Self { n_i, w })
    }

    /// Single-worker central baseline.
    pub fn central() -> Self {
        Self { n_i: 1, w: 0 }
    }

    /// Total worker count `n_c = n_i^2 + w * n_i`.
    pub fn n_c(&self) -> u64 {
        self.n_i * self.n_i + self.w * self.n_i
    }

    /// Workers per item split (`n_ciw` in Algorithm 1): `n_c / n_i`
    /// `= n_i + w`. Note: the paper prints `n_c/n_i + w`, which double
    /// counts `w` — with it, the worker grid would have `n_i * (n_i + 2w)`
    /// cells and exceed `n_c` whenever `w > 0`, so the candidate lists of
    /// Algorithm 1 could not intersect in a valid worker id. We implement
    /// the evidently-intended grid (`n_i` item rows x `n_i + w` user
    /// columns = exactly `n_c` workers), which coincides with the printed
    /// formula for the paper's evaluated configurations (all `w = 0`).
    /// See coordinator::router for the full derivation.
    pub fn n_ciw(&self) -> u64 {
        self.n_c() / self.n_i
    }

    /// True for the single-worker (central baseline) topology.
    pub fn is_central(&self) -> bool {
        self.n_c() == 1
    }
}

/// Deterministic network fault-injection plan knobs (TOML:
/// `[fault.net]`). All-zero (the default) means no plan: the transport
/// layer is transparent. With any knob set, both sides of every remote
/// worker connection derive the *same* per-connection fault schedule
/// from `seed` and the worker slot ordinal (the plan rides to the host
/// inside the `Hello` frame), so an injected failure replays exactly —
/// same seed, same faults, same recovery. Entries mapped to local
/// (in-process) transports have no connection and take no fault. See
/// `net/chaos.rs` and docs/CONFIG.md.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct NetFaultConfig {
    /// Seed the per-connection fault schedules are derived from
    /// (mixed with the worker slot ordinal).
    pub seed: u64,
    /// Upper bound (milliseconds) of the seeded per-connection
    /// handshake delay injected after a dial succeeds. `0` = no delays.
    pub delay_ms_max: u64,
    /// Sever the connections of the first this-many worker slot
    /// ordinals (each at a seeded frame index; respawned slots get
    /// fresh ordinals and run clean, so the fault budget is bounded).
    /// `0` = no severs. Severs need `fault.checkpoint_interval > 0` to
    /// be absorbed by recovery; without it they are loud session errors.
    pub sever_connections: u64,
    /// Upper bound on the seeded frame index a severed connection is
    /// cut at (the actual index is drawn per connection in
    /// `1..=sever_after_frames`). Ignored while `sever_connections = 0`;
    /// `0` falls back to 1.
    pub sever_after_frames: u64,
    /// Cut *mid-frame* — write a frame's length prefix and a truncated
    /// body before severing — instead of cutting cleanly on a frame
    /// boundary. Exercises the decoder's truncation handling.
    pub mid_frame_cut: bool,
    /// Refuse the first this-many dial attempts of every connection
    /// (simulated connection-refused before the socket is touched).
    /// Must stay within `fault.dial_retries` or every dial would fail;
    /// validated at parse time.
    pub refuse_dials: u32,
}

impl NetFaultConfig {
    /// True when every knob is at its default — no fault plan is built
    /// and the transport layer stays transparent.
    pub fn is_noop(&self) -> bool {
        *self == Self::default()
    }
}

/// Complete run configuration for one pipeline execution.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Which streaming recommender to run.
    pub algorithm: Algorithm,
    /// Numeric backend for the ISGD hot path.
    pub backend: Backend,
    /// Worker-grid topology (Section 4).
    pub topology: Topology,
    /// Forgetting technique bounding state growth (Section 5.2).
    pub forgetting: Forgetting,
    /// Recommendation-list size N (paper: 10).
    pub top_n: usize,
    /// Moving-average window for online recall (paper: 5000).
    pub recall_window: usize,
    /// ISGD latent dimension k (paper: 10).
    pub latent_k: usize,
    /// ISGD learning rate (paper: 0.05).
    pub eta: f32,
    /// ISGD L2 regularization (paper: 0.01).
    pub lambda: f32,
    /// DICS neighborhood size for Equation 7.
    pub neighbors_k: usize,
    /// DICS maintenance mode: true = exact similarity freshness (slow,
    /// the faithful-but-blows-up-centrally profile); false = TencentRec-
    /// style bounded staleness (pipeline default; see algorithms::cosine).
    pub cosine_strict: bool,
    /// Bounded channel capacity between router and each worker.
    pub channel_capacity: usize,
    /// Coordinator-side micro-batch size: `Cluster::ingest` buffers
    /// routed envelopes per worker and flushes a worker's buffer with one
    /// bulk channel send once it holds this many events (1 = unbatched,
    /// event-at-a-time). Read-your-writes ordering is preserved at any
    /// value: every buffer is flushed before a query or metrics probe is
    /// sent and in `finish()`. Bench-tuned default; sweep it with
    /// `cargo run --release --bench pipeline` (BENCH_ingest.json).
    pub ingest_batch_size: usize,
    /// Emit a recall sample every this many events per worker.
    pub sample_every: usize,
    /// RNG seed for model init.
    pub seed: u64,
    /// Directory holding the AOT artifacts (for Backend::Pjrt).
    pub artifacts_dir: String,
    /// Rescale ceiling (TOML: `rescale.max_n_i`) — the `n_i` of the
    /// virtual *state grid* that model state is partitioned on (the
    /// Flink max-parallelism analog). `0` (default) pins the state grid
    /// to the spawn topology: behavior is identical to a cluster without
    /// rescaling, and `Cluster::rescale` can move to any topology whose
    /// grid divides the spawn grid (scale-in and back). A non-zero value
    /// fixes a finer grid so the cluster can later grow beyond its spawn
    /// size, at the cost of model granularity being that of the ceiling
    /// grid from the first event. See docs/CONFIG.md.
    pub rescale_max_n_i: u64,
    /// Spare-worker ceiling companion to `rescale_max_n_i` (TOML:
    /// `rescale.max_w`): the state grid gets `max_n_i + max_w` user
    /// columns. Ignored while `rescale_max_n_i = 0`.
    pub rescale_max_w: u64,
    /// Per-lane checkpoint cadence for crash recovery (TOML:
    /// `fault.checkpoint_interval`): a worker checkpoints a lane after
    /// this many events applied to it (plus one eager checkpoint on the
    /// lane's first event). `0` (default) disables fault tolerance
    /// entirely — no checkpoints, no replay log, and a worker death is a
    /// loud session error, exactly the pre-fault-tolerance behavior.
    pub fault_checkpoint_interval: u64,
    /// Capacity of the coordinator-side replay log in envelopes (TOML:
    /// `fault.replay_log_capacity`). The log keeps the most recent
    /// accepted events so a recovery can replay the suffix past a lane's
    /// latest checkpoint; if an event needed for recovery was already
    /// evicted, recovery fails loudly instead of losing it. Unused while
    /// `fault_checkpoint_interval = 0`.
    pub fault_replay_log_capacity: usize,
    /// Deterministic chaos injection (TOML: `fault.chaos_kill_seq`, `-1`
    /// = off): the worker that processes this global stream sequence
    /// number panics right before applying it. Exactly one worker
    /// processes any seq, so this kills one worker, reproducibly, at an
    /// exact stream position — the fault-tolerance test harness.
    pub fault_chaos_kill_seq: Option<u64>,
    /// Chaos refinement (TOML: `fault.chaos_kill_in_checkpoint`): defer
    /// the injected panic from the event itself to the worker's next
    /// checkpoint attempt at/after it — the "kill during checkpoint"
    /// torture case (the half-taken checkpoint must never be used).
    /// With fault tolerance off there are no checkpoints, so this
    /// degenerates to the plain event kill.
    pub fault_chaos_kill_in_checkpoint: bool,
    /// Worker transport plan (TOML: `cluster.workers`): one endpoint
    /// string per entry, cycled over the worker slots in order. `"local"`
    /// (or `"inproc"`) spawns the slot as an in-process thread;
    /// `"tcp://host:port"` dials a remote `streamrec worker --listen`
    /// host and runs the slot there. Empty (the default) means every
    /// worker is a local thread — the pre-networking behavior,
    /// bit-for-bit. See docs/CONFIG.md and `net/`.
    pub cluster_workers: Vec<String>,
    /// Dial retry budget for remote worker connections (TOML:
    /// `fault.dial_retries`): after a failed or refused dial the
    /// transport retries up to this many times with bounded exponential
    /// backoff + seeded jitter before declaring the slot's host
    /// unreachable (a loud session error naming the address). `0`
    /// restores the pre-backoff dial-once behavior.
    pub fault_dial_retries: u32,
    /// Base backoff between dial retries in milliseconds (TOML:
    /// `fault.dial_backoff_ms`). Attempt `n` sleeps roughly
    /// `dial_backoff_ms * 2^n` (exponent capped) plus seeded jitter.
    pub fault_dial_backoff_ms: u64,
    /// RPC deadline in milliseconds (TOML: `fault.rpc_timeout_ms`): an
    /// in-flight remote RPC (query / snapshot / export) older than this
    /// converts the connection into the join-panic crash path — the
    /// same path a dead socket takes — so a *hung* worker can never
    /// block `recommend`/`metrics`/`rescale` forever. `0` disables the
    /// deadline (pre-PR-7 blocking behavior).
    pub fault_rpc_timeout_ms: u64,
    /// Coordinator-side liveness ping interval in milliseconds (TOML:
    /// `fault.heartbeat_interval_ms`): the proxy pings an idle
    /// connection this often and treats `fault.rpc_timeout_ms` of
    /// silence after a ping as a hung worker. `0` disables heartbeats
    /// (only RPC deadlines and dead sockets detect failures).
    pub fault_heartbeat_interval_ms: u64,
    /// Deterministic network fault-injection plan (TOML: `[fault.net]`).
    /// Defaults to a no-op; see [`NetFaultConfig`].
    pub fault_net: NetFaultConfig,
    /// Per-worker query channel capacity (TOML:
    /// `serving.queue_capacity`): how many in-flight queries a worker's
    /// dedicated serving lane buffers before `recommend` sheds the
    /// query instead of blocking. The serving plane never waits on a
    /// full queue — that is the load-shedding contract.
    pub serving_queue_capacity: usize,
    /// Admission-control ceiling (TOML: `serving.max_in_flight`): the
    /// maximum number of concurrently admitted `recommend` calls across
    /// all caller threads. Arrivals beyond it are shed immediately
    /// (counted in `ClusterMetrics::shed_queries`) rather than queued,
    /// keeping tail latency bounded under overload.
    pub serving_max_in_flight: usize,
    /// Number of shards in the serving cache (TOML:
    /// `serving.cache_shards`), rounded up to a power of two. More
    /// shards means less lock contention between caller threads; each
    /// shard is an independent `user -> answer` map.
    pub serving_cache_shards: usize,
    /// Serving-cache staleness budget in *events* (TOML:
    /// `serving.cache_max_staleness`): a cached answer for a user is
    /// reused only while fewer than this many ingested events have
    /// touched the user's state column since the answer was computed.
    /// `0` (the default) is strict read-your-writes: any newer event in
    /// the column invalidates the entry. Rescales and worker recoveries
    /// always invalidate regardless of this budget.
    pub serving_cache_max_staleness: u64,
    /// Per-worker resident state budget in bytes (TOML:
    /// `memory.budget_bytes`). `0` (default) = unlimited, exactly the
    /// pre-budget behavior. With a budget set, each lane gets an equal
    /// slice of it (`budget / state-grid lanes` — the state grid is fixed
    /// for a session, so the slice is placement-independent): a lane over
    /// its slice triggers a pressure sweep through the configured
    /// `[forgetting]` policy, and a worker whose resident lanes together
    /// exceed the budget spills its coldest lanes to disk (see
    /// `memory.spill`). Accounting uses the models' deterministic
    /// [`state_bytes`](crate::algorithms::StreamingRecommender::state_bytes)
    /// figure, not allocator numbers, so budget-driven behavior replays
    /// exactly. See docs/CONFIG.md and ARCHITECTURE.md §11.
    pub memory_budget_bytes: u64,
    /// Cold-lane spill switch (TOML: `memory.spill`, default `true`).
    /// While the budget is exceeded after pressure sweeps, the worker
    /// serializes its coldest lanes (smallest watermark) through the
    /// lane-frame format into a disk store and faults them back in on
    /// the lane's next event, query, or export — result-transparent
    /// tiering. `false` keeps everything resident (the budget then only
    /// drives pressure sweeps). Ignored while `memory.budget_bytes = 0`.
    pub memory_spill: bool,
    /// Directory for spilled lane frames (TOML: `memory.spill_dir`).
    /// Empty (default) uses the platform temp directory. Each worker
    /// actor creates a unique subdirectory and removes it on shutdown;
    /// spilled frames never need to outlive the actor (crash recovery
    /// uses supervisor checkpoints + replay, not spill files).
    pub memory_spill_dir: String,
    /// Per-lane pressure-check cadence in events (TOML:
    /// `memory.check_events`, default 64): a lane re-measures its
    /// `state_bytes` and checks its budget slice every this many events
    /// *applied to that lane*. The counter travels in lane frames, so
    /// the cadence is preserved across migration and recovery. Must be
    /// >= 1.
    pub memory_check_events: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            algorithm: Algorithm::Isgd,
            backend: Backend::Native,
            topology: Topology::central(),
            forgetting: Forgetting::None,
            top_n: 10,
            recall_window: 5000,
            latent_k: 10,
            eta: 0.05,
            lambda: 0.01,
            neighbors_k: 10,
            cosine_strict: false,
            channel_capacity: 4096,
            ingest_batch_size: 64,
            sample_every: 100,
            seed: 42,
            artifacts_dir: "artifacts".to_string(),
            rescale_max_n_i: 0,
            rescale_max_w: 0,
            fault_checkpoint_interval: 0,
            fault_replay_log_capacity: 65_536,
            fault_chaos_kill_seq: None,
            fault_chaos_kill_in_checkpoint: false,
            cluster_workers: Vec::new(),
            fault_dial_retries: 4,
            fault_dial_backoff_ms: 50,
            fault_rpc_timeout_ms: 30_000,
            fault_heartbeat_interval_ms: 1_000,
            fault_net: NetFaultConfig::default(),
            serving_queue_capacity: 1024,
            serving_max_in_flight: 256,
            serving_cache_shards: 16,
            serving_cache_max_staleness: 0,
            memory_budget_bytes: 0,
            memory_spill: true,
            memory_spill_dir: String::new(),
            memory_check_events: 64,
        }
    }
}

impl RunConfig {
    /// Load from a TOML-subset file; missing keys keep defaults.
    pub fn from_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref()).with_context(|| {
            format!("reading config {}", path.as_ref().display())
        })?;
        Self::from_toml(&text)
    }

    /// The `[memory]` footgun: a byte budget with no eviction policy.
    /// Pressure sweeps derive their eviction from `[forgetting]`, so
    /// with `Forgetting::None` a pressure check can evict nothing and
    /// every over-budget lane goes straight to the disk tier (or, with
    /// `memory.spill = false` too, the budget is simply unenforceable).
    /// That is a legal configuration — the spill tier keeps results
    /// byte-identical — but it is almost never what a capped deployment
    /// wants, so `Cluster::metrics` warns once per session and the
    /// scenario driver refuses to run it. Returns the warning text when
    /// the combination is configured.
    pub fn memory_footgun(&self) -> Option<String> {
        if self.memory_budget_bytes > 0 && self.forgetting == Forgetting::None {
            Some(format!(
                "[memory] budget_bytes = {} is set but [forgetting] is \
                 'none': pressure sweeps cannot evict anything, so the \
                 budget is enforced by disk spill alone{}. Configure a \
                 [forgetting] policy (lru/lfu/decay) to shed state.",
                self.memory_budget_bytes,
                if self.memory_spill {
                    ""
                } else {
                    " — and memory.spill = false disables that too, \
                     leaving the budget unenforced"
                }
            ))
        } else {
            None
        }
    }

    /// Parse from TOML-subset text.
    pub fn from_toml(text: &str) -> Result<Self> {
        let kv = parse_toml_subset(text)?;
        let mut cfg = Self::default();
        let get = |k: &str| kv.get(k);

        if let Some(v) = get("run.algorithm") {
            cfg.algorithm = Algorithm::parse(v.str()?)?;
        }
        if let Some(v) = get("run.backend") {
            cfg.backend = Backend::parse(v.str()?)?;
        }
        let n_i = get("topology.n_i").map(|v| v.int()).transpose()?.unwrap_or(1);
        let w = get("topology.w").map(|v| v.int()).transpose()?.unwrap_or(0);
        cfg.topology = Topology::new(n_i.max(1) as u64, w as u64)?;

        match get("forgetting.kind").map(|v| v.str()).transpose()? {
            None | Some("none") => cfg.forgetting = Forgetting::None,
            Some("lru") => {
                cfg.forgetting = Forgetting::Lru {
                    trigger_secs: get("forgetting.trigger_secs")
                        .map(|v| v.int())
                        .transpose()?
                        .unwrap_or(86_400) as u64,
                    max_idle_secs: get("forgetting.max_idle_secs")
                        .map(|v| v.int())
                        .transpose()?
                        .unwrap_or(30 * 86_400) as u64,
                }
            }
            Some("lfu") => {
                cfg.forgetting = Forgetting::Lfu {
                    trigger_events: get("forgetting.trigger_events")
                        .map(|v| v.int())
                        .transpose()?
                        .unwrap_or(50_000) as u64,
                    min_freq: get("forgetting.min_freq")
                        .map(|v| v.int())
                        .transpose()?
                        .unwrap_or(2) as u64,
                }
            }
            Some("decay") => {
                cfg.forgetting = Forgetting::Decay {
                    trigger_events: get("forgetting.trigger_events")
                        .map(|v| v.int())
                        .transpose()?
                        .unwrap_or(50_000) as u64,
                    factor: get("forgetting.factor")
                        .map(|v| v.num())
                        .transpose()?
                        .unwrap_or(0.95) as f32,
                }
            }
            Some(other) => bail!("unknown forgetting '{other}'"),
        }

        macro_rules! num {
            ($key:expr, $field:expr, $ty:ty) => {
                if let Some(v) = get($key) {
                    $field = v.num()? as $ty;
                }
            };
        }
        num!("run.top_n", cfg.top_n, usize);
        num!("run.recall_window", cfg.recall_window, usize);
        num!("run.sample_every", cfg.sample_every, usize);
        num!("run.seed", cfg.seed, u64);
        num!("model.latent_k", cfg.latent_k, usize);
        num!("model.eta", cfg.eta, f32);
        num!("model.lambda", cfg.lambda, f32);
        num!("model.neighbors_k", cfg.neighbors_k, usize);
        if let Some(v) = get("model.cosine_strict") {
            cfg.cosine_strict = v.bool()?;
        }
        num!("engine.channel_capacity", cfg.channel_capacity, usize);
        num!("engine.ingest_batch_size", cfg.ingest_batch_size, usize);
        num!("rescale.max_n_i", cfg.rescale_max_n_i, u64);
        num!("rescale.max_w", cfg.rescale_max_w, u64);
        num!(
            "fault.checkpoint_interval",
            cfg.fault_checkpoint_interval,
            u64
        );
        num!(
            "fault.replay_log_capacity",
            cfg.fault_replay_log_capacity,
            usize
        );
        if let Some(v) = get("fault.chaos_kill_seq") {
            let seq = v.int()?;
            cfg.fault_chaos_kill_seq =
                if seq < 0 { None } else { Some(seq as u64) };
        }
        if let Some(v) = get("fault.chaos_kill_in_checkpoint") {
            cfg.fault_chaos_kill_in_checkpoint = v.bool()?;
        }
        if let Some(v) = get("run.artifacts_dir") {
            cfg.artifacts_dir = v.str()?.to_string();
        }
        if let Some(v) = get("cluster.workers") {
            cfg.cluster_workers = v
                .str_list()
                .context("cluster.workers must be a list of strings")?;
        }
        num!("fault.dial_retries", cfg.fault_dial_retries, u32);
        num!("fault.dial_backoff_ms", cfg.fault_dial_backoff_ms, u64);
        num!("fault.rpc_timeout_ms", cfg.fault_rpc_timeout_ms, u64);
        num!(
            "fault.heartbeat_interval_ms",
            cfg.fault_heartbeat_interval_ms,
            u64
        );
        num!("fault.net.seed", cfg.fault_net.seed, u64);
        num!("fault.net.delay_ms_max", cfg.fault_net.delay_ms_max, u64);
        num!(
            "fault.net.sever_connections",
            cfg.fault_net.sever_connections,
            u64
        );
        num!(
            "fault.net.sever_after_frames",
            cfg.fault_net.sever_after_frames,
            u64
        );
        if let Some(v) = get("fault.net.mid_frame_cut") {
            cfg.fault_net.mid_frame_cut = v.bool()?;
        }
        num!("fault.net.refuse_dials", cfg.fault_net.refuse_dials, u32);
        num!("serving.queue_capacity", cfg.serving_queue_capacity, usize);
        num!("serving.max_in_flight", cfg.serving_max_in_flight, usize);
        num!("serving.cache_shards", cfg.serving_cache_shards, usize);
        num!(
            "serving.cache_max_staleness",
            cfg.serving_cache_max_staleness,
            u64
        );
        num!("memory.budget_bytes", cfg.memory_budget_bytes, u64);
        if let Some(v) = get("memory.spill") {
            cfg.memory_spill = v.bool()?;
        }
        if let Some(v) = get("memory.spill_dir") {
            cfg.memory_spill_dir = v.str()?.to_string();
        }
        num!("memory.check_events", cfg.memory_check_events, u64);
        if cfg.memory_check_events == 0 {
            bail!("memory.check_events must be >= 1");
        }
        if cfg.serving_queue_capacity == 0 {
            bail!("serving.queue_capacity must be >= 1");
        }
        if cfg.serving_max_in_flight == 0 {
            bail!("serving.max_in_flight must be >= 1");
        }
        if cfg.serving_cache_shards == 0 {
            bail!("serving.cache_shards must be >= 1");
        }
        if cfg.fault_net.refuse_dials > cfg.fault_dial_retries {
            bail!(
                "fault.net.refuse_dials = {} exceeds fault.dial_retries = \
                 {}: every dial would fail before the retry budget runs \
                 out — raise dial_retries or lower refuse_dials",
                cfg.fault_net.refuse_dials,
                cfg.fault_dial_retries
            );
        }
        Ok(cfg)
    }
}

/// A parsed TOML-subset scalar.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    /// A double-quoted string.
    Str(String),
    /// An integer literal.
    Int(i64),
    /// A float literal.
    Float(f64),
    /// `true` | `false`.
    Bool(bool),
    /// A single-line array of scalars, e.g. `["local", "tcp://h:p"]`.
    List(Vec<TomlValue>),
}

impl TomlValue {
    /// The string value, or an error for any other type.
    pub fn str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            other => Err(anyhow!("expected string, got {other:?}")),
        }
    }

    /// The integer value, or an error for any other type.
    pub fn int(&self) -> Result<i64> {
        match self {
            TomlValue::Int(i) => Ok(*i),
            other => Err(anyhow!("expected integer, got {other:?}")),
        }
    }

    /// The boolean value, or an error for any other type.
    pub fn bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            other => Err(anyhow!("expected boolean, got {other:?}")),
        }
    }

    /// The numeric value (int or float widened to f64), or an error.
    pub fn num(&self) -> Result<f64> {
        match self {
            TomlValue::Int(i) => Ok(*i as f64),
            TomlValue::Float(f) => Ok(*f),
            other => Err(anyhow!("expected number, got {other:?}")),
        }
    }

    /// The value as a list of strings (an empty `[]` is fine), or an
    /// error for any other shape — including a list with a non-string
    /// element.
    pub fn str_list(&self) -> Result<Vec<String>> {
        match self {
            TomlValue::List(items) => items
                .iter()
                .map(|v| v.str().map(str::to_string))
                .collect(),
            other => Err(anyhow!("expected list of strings, got {other:?}")),
        }
    }

    /// The numeric value validated as a stream fraction in `[0, 1]` —
    /// the schedule unit of the drift/scenario knobs (`drift.at`,
    /// `rescale.at`, `fault.chaos_kill_at`, ...), or an error.
    pub fn frac(&self) -> Result<f64> {
        let v = self.num()?;
        if !(0.0..=1.0).contains(&v) {
            bail!("expected a stream fraction in [0, 1], got {v}");
        }
        Ok(v)
    }
}

/// Parse the TOML subset into flat `section.key -> value` pairs.
pub fn parse_toml_subset(text: &str) -> Result<BTreeMap<String, TomlValue>> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| anyhow!("line {}: bad section", lineno + 1))?;
            section = name.trim().to_string();
            continue;
        }
        let (key, value) = line.split_once('=').ok_or_else(|| {
            anyhow!("line {}: expected key = value", lineno + 1)
        })?;
        let key = key.trim();
        let full_key = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        out.insert(full_key, parse_value(value.trim(), lineno + 1)?);
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str, lineno: usize) -> Result<TomlValue> {
    if let Some(stripped) = v.strip_prefix('[') {
        let inner = stripped
            .strip_suffix(']')
            .ok_or_else(|| {
                anyhow!("line {lineno}: unterminated array (arrays must be \
                         single-line)")
            })?
            .trim();
        if inner.is_empty() {
            return Ok(TomlValue::List(Vec::new()));
        }
        return split_array_items(inner, lineno)?
            .into_iter()
            .map(|item| parse_value(item.trim(), lineno))
            .collect::<Result<Vec<_>>>()
            .map(TomlValue::List);
    }
    if let Some(stripped) = v.strip_prefix('"') {
        let inner = stripped
            .strip_suffix('"')
            .ok_or_else(|| anyhow!("line {lineno}: unterminated string"))?;
        return Ok(TomlValue::Str(inner.to_string()));
    }
    match v {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = v.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = v.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("line {lineno}: cannot parse value '{v}'")
}

/// Split the inside of a single-line array on top-level commas (commas
/// inside quoted strings or nested brackets don't count).
fn split_array_items(inner: &str, lineno: usize) -> Result<Vec<&str>> {
    let mut items = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    let mut depth = 0usize;
    for (i, c) in inner.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => {
                depth = depth.checked_sub(1).ok_or_else(|| {
                    anyhow!("line {lineno}: unbalanced ']' in array")
                })?;
            }
            ',' if !in_str && depth == 0 => {
                items.push(&inner[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if in_str || depth != 0 {
        bail!("line {lineno}: unbalanced array literal");
    }
    items.push(&inner[start..]);
    Ok(items)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topology_math_matches_paper() {
        // Section 5.2: n_i in {2,4,6} with n_c = n_i^2 -> {4,16,36}.
        for (n_i, n_c) in [(2u64, 4u64), (4, 16), (6, 36)] {
            let t = Topology::new(n_i, 0).unwrap();
            assert_eq!(t.n_c(), n_c);
            assert_eq!(t.n_ciw(), n_i); // n_c/n_i + 0 = n_i
        }
        // w > 0: n_c = n_i^2 + w*n_i; grid is n_i rows x (n_i + w) cols.
        let t = Topology::new(2, 3).unwrap();
        assert_eq!(t.n_c(), 4 + 6);
        assert_eq!(t.n_ciw(), 5);
        assert_eq!(t.n_i * t.n_ciw(), t.n_c());
        assert!(Topology::central().is_central());
    }

    #[test]
    fn parses_full_config() {
        let text = r#"
            # paper defaults
            [run]
            algorithm = "disgd"
            backend = "native"
            top_n = 10
            recall_window = 5000
            seed = 7

            [topology]
            n_i = 4
            w = 0

            [model]
            eta = 0.05
            lambda = 0.01
            latent_k = 10

            [forgetting]
            kind = "lru"
            trigger_secs = 3600
            max_idle_secs = 86400
        "#;
        let cfg = RunConfig::from_toml(text).unwrap();
        assert_eq!(cfg.algorithm, Algorithm::Isgd);
        assert_eq!(cfg.topology.n_c(), 16);
        assert_eq!(cfg.seed, 7);
        assert!(matches!(
            cfg.forgetting,
            Forgetting::Lru { trigger_secs: 3600, max_idle_secs: 86400 }
        ));
        assert!((cfg.eta - 0.05).abs() < 1e-9);
    }

    #[test]
    fn defaults_match_paper_hyperparameters() {
        let cfg = RunConfig::default();
        assert_eq!(cfg.top_n, 10);
        assert_eq!(cfg.recall_window, 5000);
        assert_eq!(cfg.latent_k, 10);
        assert!((cfg.eta - 0.05).abs() < 1e-9);
        assert!((cfg.lambda - 0.01).abs() < 1e-9);
        assert!(cfg.ingest_batch_size >= 1);
    }

    #[test]
    fn parses_engine_section() {
        let cfg = RunConfig::from_toml(
            "[engine]\nchannel_capacity = 128\ningest_batch_size = 256",
        )
        .unwrap();
        assert_eq!(cfg.channel_capacity, 128);
        assert_eq!(cfg.ingest_batch_size, 256);
    }

    #[test]
    fn parses_rescale_section() {
        let cfg = RunConfig::default();
        assert_eq!(cfg.rescale_max_n_i, 0, "default: grid = spawn topology");
        assert_eq!(cfg.rescale_max_w, 0);
        let cfg = RunConfig::from_toml("[rescale]\nmax_n_i = 4\nmax_w = 1")
            .unwrap();
        assert_eq!(cfg.rescale_max_n_i, 4);
        assert_eq!(cfg.rescale_max_w, 1);
    }

    #[test]
    fn parses_fault_section() {
        let cfg = RunConfig::default();
        assert_eq!(cfg.fault_checkpoint_interval, 0, "default: FT off");
        assert_eq!(cfg.fault_replay_log_capacity, 65_536);
        assert_eq!(cfg.fault_chaos_kill_seq, None);
        assert!(!cfg.fault_chaos_kill_in_checkpoint);
        let cfg = RunConfig::from_toml(
            "[fault]\ncheckpoint_interval = 512\nreplay_log_capacity = 4096\n\
             chaos_kill_seq = 99\nchaos_kill_in_checkpoint = true",
        )
        .unwrap();
        assert_eq!(cfg.fault_checkpoint_interval, 512);
        assert_eq!(cfg.fault_replay_log_capacity, 4096);
        assert_eq!(cfg.fault_chaos_kill_seq, Some(99));
        assert!(cfg.fault_chaos_kill_in_checkpoint);
        // -1 is the explicit "off" spelling for the chaos kill.
        let cfg =
            RunConfig::from_toml("[fault]\nchaos_kill_seq = -1").unwrap();
        assert_eq!(cfg.fault_chaos_kill_seq, None);
    }

    #[test]
    fn parses_supervision_knobs() {
        let cfg = RunConfig::default();
        assert_eq!(cfg.fault_dial_retries, 4);
        assert_eq!(cfg.fault_dial_backoff_ms, 50);
        assert_eq!(cfg.fault_rpc_timeout_ms, 30_000);
        assert_eq!(cfg.fault_heartbeat_interval_ms, 1_000);
        let cfg = RunConfig::from_toml(
            "[fault]\ndial_retries = 7\ndial_backoff_ms = 5\n\
             rpc_timeout_ms = 250\nheartbeat_interval_ms = 0",
        )
        .unwrap();
        assert_eq!(cfg.fault_dial_retries, 7);
        assert_eq!(cfg.fault_dial_backoff_ms, 5);
        assert_eq!(cfg.fault_rpc_timeout_ms, 250);
        assert_eq!(cfg.fault_heartbeat_interval_ms, 0);
    }

    #[test]
    fn parses_serving_section() {
        let cfg = RunConfig::default();
        assert_eq!(cfg.serving_queue_capacity, 1024);
        assert_eq!(cfg.serving_max_in_flight, 256);
        assert_eq!(cfg.serving_cache_shards, 16);
        assert_eq!(cfg.serving_cache_max_staleness, 0);
        let cfg = RunConfig::from_toml(
            "[serving]\nqueue_capacity = 64\nmax_in_flight = 8\n\
             cache_shards = 4\ncache_max_staleness = 500",
        )
        .unwrap();
        assert_eq!(cfg.serving_queue_capacity, 64);
        assert_eq!(cfg.serving_max_in_flight, 8);
        assert_eq!(cfg.serving_cache_shards, 4);
        assert_eq!(cfg.serving_cache_max_staleness, 500);
        // Zeroes would deadlock or divide by zero downstream; rejected
        // loudly at parse time.
        for bad in [
            "[serving]\nqueue_capacity = 0",
            "[serving]\nmax_in_flight = 0",
            "[serving]\ncache_shards = 0",
        ] {
            assert!(RunConfig::from_toml(bad).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn parses_fault_net_section() {
        let cfg = RunConfig::default();
        assert!(cfg.fault_net.is_noop(), "default: no fault plan");
        let cfg = RunConfig::from_toml(
            "[fault.net]\nseed = 9\ndelay_ms_max = 3\n\
             sever_connections = 2\nsever_after_frames = 40\n\
             mid_frame_cut = true\nrefuse_dials = 2",
        )
        .unwrap();
        assert!(!cfg.fault_net.is_noop());
        assert_eq!(cfg.fault_net.seed, 9);
        assert_eq!(cfg.fault_net.delay_ms_max, 3);
        assert_eq!(cfg.fault_net.sever_connections, 2);
        assert_eq!(cfg.fault_net.sever_after_frames, 40);
        assert!(cfg.fault_net.mid_frame_cut);
        assert_eq!(cfg.fault_net.refuse_dials, 2);
        // A seed alone is enough to make the plan non-noop (explicit
        // opt-in spelling for "delays only drawn elsewhere").
        let cfg = RunConfig::from_toml("[fault.net]\nseed = 1").unwrap();
        assert!(!cfg.fault_net.is_noop());
    }

    #[test]
    fn refusal_budget_must_fit_the_retry_budget() {
        // refuse_dials > dial_retries would make every dial fail; the
        // parser rejects it loudly instead of producing a doomed run.
        let err = RunConfig::from_toml(
            "[fault]\ndial_retries = 1\n[fault.net]\nrefuse_dials = 3",
        )
        .unwrap_err();
        assert!(
            format!("{err:#}").contains("refuse_dials"),
            "unexpected error: {err:#}"
        );
        // Equal budgets are fine: the last attempt succeeds.
        let cfg = RunConfig::from_toml(
            "[fault]\ndial_retries = 3\n[fault.net]\nrefuse_dials = 3",
        )
        .unwrap();
        assert_eq!(cfg.fault_net.refuse_dials, 3);
    }

    #[test]
    fn parses_memory_section() {
        let cfg = RunConfig::default();
        assert_eq!(cfg.memory_budget_bytes, 0, "default: unlimited");
        assert!(cfg.memory_spill);
        assert!(cfg.memory_spill_dir.is_empty());
        assert_eq!(cfg.memory_check_events, 64);
        let cfg = RunConfig::from_toml(
            "[memory]\nbudget_bytes = 1048576\nspill = false\n\
             spill_dir = \"/tmp/spill\"\ncheck_events = 16",
        )
        .unwrap();
        assert_eq!(cfg.memory_budget_bytes, 1_048_576);
        assert!(!cfg.memory_spill);
        assert_eq!(cfg.memory_spill_dir, "/tmp/spill");
        assert_eq!(cfg.memory_check_events, 16);
        // A zero check cadence would never re-measure; rejected loudly.
        assert!(RunConfig::from_toml("[memory]\ncheck_events = 0").is_err());
        // A cap with no [forgetting] policy parses fine here (spill alone
        // honors the resident cap); the *scenario driver* rejects it and
        // Cluster::metrics warns — both through memory_footgun().
        let cfg =
            RunConfig::from_toml("[memory]\nbudget_bytes = 4096").unwrap();
        assert_eq!(cfg.memory_budget_bytes, 4096);
        assert!(matches!(cfg.forgetting, Forgetting::None));
        let warning = cfg.memory_footgun().expect("cap without policy warns");
        assert!(warning.contains("4096"));
        assert!(warning.contains("disk spill alone"));
        let mut no_spill = cfg.clone();
        no_spill.memory_spill = false;
        let warning = no_spill.memory_footgun().unwrap();
        assert!(warning.contains("unenforced"), "spill-off variant is louder");
        // Any eviction policy (or no cap) silences it.
        let ok = RunConfig::from_toml(
            "[memory]\nbudget_bytes = 4096\n[forgetting]\nkind = \"lfu\"",
        )
        .unwrap();
        assert!(ok.memory_footgun().is_none());
        assert!(RunConfig::default().memory_footgun().is_none());
    }

    #[test]
    fn parses_cosine_strict_bool() {
        let cfg =
            RunConfig::from_toml("[model]\ncosine_strict = true").unwrap();
        assert!(cfg.cosine_strict);
        assert!(RunConfig::from_toml("[model]\ncosine_strict = 1").is_err());
    }

    #[test]
    fn frac_values_validate_range() {
        assert!((TomlValue::Float(0.5).frac().unwrap() - 0.5).abs() < 1e-12);
        assert!((TomlValue::Int(1).frac().unwrap() - 1.0).abs() < 1e-12);
        assert!(TomlValue::Float(1.5).frac().is_err());
        assert!(TomlValue::Float(-0.1).frac().is_err());
        assert!(TomlValue::Str("x".into()).frac().is_err());
    }

    #[test]
    fn parses_cluster_section() {
        let cfg = RunConfig::default();
        assert!(cfg.cluster_workers.is_empty(), "default: all-local");
        let cfg = RunConfig::from_toml(
            "[cluster]\nworkers = [\"local\", \"tcp://127.0.0.1:7461\"] \
             # mixed plan",
        )
        .unwrap();
        assert_eq!(
            cfg.cluster_workers,
            vec!["local".to_string(), "tcp://127.0.0.1:7461".to_string()]
        );
        let cfg = RunConfig::from_toml("[cluster]\nworkers = []").unwrap();
        assert!(cfg.cluster_workers.is_empty());
    }

    #[test]
    fn array_parsing_rejects_bad_shapes() {
        // Non-string elements in cluster.workers are a loud error.
        assert!(RunConfig::from_toml("[cluster]\nworkers = [1, 2]").is_err());
        // A scalar where a list is expected is a loud error.
        assert!(
            RunConfig::from_toml("[cluster]\nworkers = \"local\"").is_err()
        );
        // Unterminated / unbalanced arrays are loud errors.
        assert!(parse_toml_subset("a = [\"x\"").is_err());
        assert!(parse_toml_subset("a = [\"x\"]]").is_err());
        // Commas inside quoted strings don't split items.
        let kv = parse_toml_subset("a = [\"x,y\", \"z\"]").unwrap();
        assert_eq!(
            kv["a"],
            TomlValue::List(vec![
                TomlValue::Str("x,y".into()),
                TomlValue::Str("z".into())
            ])
        );
    }

    #[test]
    fn rejects_bad_input() {
        assert!(RunConfig::from_toml("[run]\nalgorithm = \"bogus\"").is_err());
        assert!(parse_toml_subset("keyvalue").is_err());
        assert!(parse_toml_subset("[unclosed").is_err());
        assert!(parse_toml_subset("a = @").is_err());
    }

    #[test]
    fn comments_and_strings() {
        let kv =
            parse_toml_subset("a = \"x # not comment\" # real comment").unwrap();
        assert_eq!(kv["a"], TomlValue::Str("x # not comment".into()));
    }
}
