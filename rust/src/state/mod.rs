//! Worker-local (shared-nothing) state stores: tracked keyed maps, the
//! capacity-padded vector slab the AOT artifacts consume, the
//! forgetting trigger clocks, and the cold-lane spill store.

pub mod forgetting;
pub mod spill;
pub mod tracked;
pub mod vector_slab;

pub use forgetting::{ForgetClock, SweepKind};
pub use spill::SpillStore;
pub use tracked::TrackedMap;
pub use vector_slab::VectorSlab;
