//! Worker-local (shared-nothing) state stores: tracked keyed maps, the
//! capacity-padded vector slab the AOT artifacts consume, and the
//! forgetting trigger clocks.

pub mod forgetting;
pub mod tracked;
pub mod vector_slab;

pub use forgetting::{ForgetClock, SweepKind};
pub use tracked::TrackedMap;
pub use vector_slab::VectorSlab;
