//! Cold-lane spill store: the disk tier of the `[memory]` budget.
//!
//! When a worker's resident lane bytes exceed its budget even after
//! pressure sweeps, the engine serializes whole lanes — through the same
//! lane-frame format that checkpoints and rescale migration use — and
//! parks the frames here. A spilled lane is *not* a different kind of
//! state: the frame is byte-identical to the checkpoint the lane would
//! have produced, so faulting it back in (frame → `import_partition`)
//! reconstructs the lane exactly and every downstream guarantee
//! (rescale equivalence, crash recovery, TCP workers) holds unchanged.
//!
//! The store is strictly actor-local and ephemeral: each store owns a
//! unique directory (under the configured spill dir, or the platform
//! temp dir) and removes it on drop. Spilled frames never need to
//! outlive the actor — crash recovery rebuilds workers from supervisor
//! checkpoints plus replay, not from spill files.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{Context, Result};

use crate::data::types::StateSizes;

/// Distinguishes concurrently-created stores within one process.
static STORE_SEQ: AtomicU64 = AtomicU64::new(0);

/// Metadata the engine keeps about a spilled lane so it can account for
/// it — entry counts *and* the lane's baseline-relative counters, which
/// must keep contributing to worker rollups while the lane is on disk —
/// without touching the disk frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpillMeta {
    /// The lane's logical `state_bytes` at spill time (deterministic
    /// model accounting — identical after fault-in).
    pub bytes: u64,
    /// The lane's applied watermark at spill time (spill order is
    /// coldest-first by this).
    pub watermark: u64,
    /// The lane's state-entry counts at spill time.
    pub sizes: StateSizes,
    /// Events applied to the lane since its counter baseline.
    pub processed: u64,
    /// Prequential hits since the baseline.
    pub hits: u64,
    /// Entries evicted by sweeps since the baseline.
    pub evicted: u64,
    /// Sweeps run since the baseline.
    pub sweeps: u64,
}

struct SpilledLane {
    path: PathBuf,
    frame_len: u64,
    meta: SpillMeta,
}

/// Disk store holding spilled lane frames for one worker actor.
///
/// Keys are lane ids (state-grid cells). Frames are opaque bytes — the
/// engine's lane-frame encoding — written one file per lane. All
/// accounting methods are O(1) or O(spilled lanes); no disk I/O happens
/// outside [`SpillStore::put`] / [`SpillStore::take`] /
/// [`SpillStore::remove`].
pub struct SpillStore {
    dir: PathBuf,
    entries: BTreeMap<usize, SpilledLane>,
    /// Cumulative spill count (monotone; survives take/remove).
    spills: u64,
    /// Cumulative fault-in count (monotone).
    faultins: u64,
}

impl SpillStore {
    /// Create a store rooted in a fresh unique directory under `base`
    /// (empty `base` = the platform temp directory). The directory
    /// itself is created lazily on the first [`SpillStore::put`].
    pub fn new(base: &str, worker_id: usize) -> Self {
        let root = if base.is_empty() {
            std::env::temp_dir()
        } else {
            PathBuf::from(base)
        };
        let dir = root.join(format!(
            "streamrec-spill-{}-{}-w{}",
            std::process::id(),
            STORE_SEQ.fetch_add(1, Ordering::Relaxed),
            worker_id
        ));
        Self { dir, entries: BTreeMap::new(), spills: 0, faultins: 0 }
    }

    /// Spill a lane: write `frame` to disk and record `meta`. Replaces
    /// any previous frame for the lane.
    pub fn put(
        &mut self,
        lane: usize,
        frame: &[u8],
        meta: SpillMeta,
    ) -> Result<()> {
        std::fs::create_dir_all(&self.dir).with_context(|| {
            format!("creating spill dir {}", self.dir.display())
        })?;
        let path = self.dir.join(format!("lane-{lane}.frame"));
        std::fs::write(&path, frame).with_context(|| {
            format!("writing spill frame {}", path.display())
        })?;
        self.entries.insert(
            lane,
            SpilledLane { path, frame_len: frame.len() as u64, meta },
        );
        self.spills += 1;
        Ok(())
    }

    /// Fault a lane back in: read and delete its frame, returning the
    /// bytes exactly as written. `None` if the lane is not spilled.
    pub fn take(&mut self, lane: usize) -> Result<Option<Vec<u8>>> {
        let Some(entry) = self.entries.remove(&lane) else {
            return Ok(None);
        };
        let frame = std::fs::read(&entry.path).with_context(|| {
            format!("reading spill frame {}", entry.path.display())
        })?;
        let _ = std::fs::remove_file(&entry.path);
        self.faultins += 1;
        Ok(Some(frame))
    }

    /// Discard a spilled frame without reading it (the lane is being
    /// overwritten wholesale, e.g. by a rescale `Import`). Returns true
    /// if a frame was dropped.
    pub fn remove(&mut self, lane: usize) -> bool {
        match self.entries.remove(&lane) {
            Some(entry) => {
                let _ = std::fs::remove_file(&entry.path);
                true
            }
            None => false,
        }
    }

    /// True if `lane` currently has a spilled frame.
    pub fn contains(&self, lane: usize) -> bool {
        self.entries.contains_key(&lane)
    }

    /// Recorded metadata for a spilled lane.
    pub fn meta(&self, lane: usize) -> Option<SpillMeta> {
        self.entries.get(&lane).map(|e| e.meta)
    }

    /// Number of lanes currently spilled.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no lanes are spilled.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Sum of the spilled lanes' logical `state_bytes` (the model
    /// accounting figure, not the on-disk frame size).
    pub fn spilled_bytes(&self) -> u64 {
        self.entries.values().map(|e| e.meta.bytes).sum()
    }

    /// Sum of the spilled lanes' on-disk frame sizes.
    pub fn spilled_frame_bytes(&self) -> u64 {
        self.entries.values().map(|e| e.frame_len).sum()
    }

    /// Cumulative number of lane spills performed (monotone).
    pub fn spills(&self) -> u64 {
        self.spills
    }

    /// Cumulative number of lane fault-ins performed (monotone).
    pub fn faultins(&self) -> u64 {
        self.faultins
    }

    /// Lane ids of the spilled lanes, ascending.
    pub fn lanes(&self) -> Vec<usize> {
        self.entries.keys().copied().collect()
    }
}

impl Drop for SpillStore {
    fn drop(&mut self) {
        // Best-effort cleanup: the dir only exists if something spilled.
        if self.dir.exists() {
            let _ = std::fs::remove_dir_all(&self.dir);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(bytes: u64, watermark: u64) -> SpillMeta {
        SpillMeta {
            bytes,
            watermark,
            sizes: StateSizes { users: 1, items: 2, aux: 3 },
            processed: 10,
            hits: 4,
            evicted: 0,
            sweeps: 1,
        }
    }

    #[test]
    fn round_trips_frames_byte_identically() {
        let mut store = SpillStore::new("", 0);
        assert!(store.is_empty());
        let frame: Vec<u8> = (0..=255u8).cycle().take(1000).collect();
        store.put(3, &frame, meta(4096, 17)).unwrap();
        assert!(store.contains(3));
        assert_eq!(store.len(), 1);
        assert_eq!(store.spilled_bytes(), 4096);
        assert_eq!(store.spilled_frame_bytes(), 1000);
        assert_eq!(store.meta(3).unwrap().watermark, 17);
        assert_eq!(store.spills(), 1);
        let back = store.take(3).unwrap().unwrap();
        assert_eq!(back, frame, "fault-in must be byte-identical");
        assert!(!store.contains(3));
        assert_eq!(store.spilled_bytes(), 0);
        assert_eq!(store.faultins(), 1);
        assert_eq!(store.take(3).unwrap(), None, "double take is None");
    }

    #[test]
    fn replaces_and_removes_entries() {
        let mut store = SpillStore::new("", 7);
        store.put(0, b"old", meta(10, 1)).unwrap();
        store.put(0, b"new", meta(20, 2)).unwrap();
        assert_eq!(store.len(), 1);
        assert_eq!(store.spilled_bytes(), 20, "replace overwrites meta");
        assert_eq!(store.spills(), 2, "spill count is cumulative");
        assert_eq!(store.take(0).unwrap().unwrap(), b"new");
        store.put(1, b"x", meta(5, 3)).unwrap();
        assert!(store.remove(1));
        assert!(!store.remove(1), "second remove is a no-op");
        assert!(store.is_empty());
    }

    #[test]
    fn lanes_are_sorted_and_dir_is_cleaned_up() {
        let mut store = SpillStore::new("", 1);
        for lane in [5usize, 1, 9] {
            store.put(lane, b"frame", meta(1, lane as u64)).unwrap();
        }
        assert_eq!(store.lanes(), vec![1, 5, 9]);
        let dir = store.dir.clone();
        assert!(dir.exists());
        drop(store);
        assert!(!dir.exists(), "drop removes the spill dir");
    }

    #[test]
    fn distinct_stores_never_collide() {
        let mut a = SpillStore::new("", 0);
        let mut b = SpillStore::new("", 0);
        a.put(0, b"aaa", meta(1, 1)).unwrap();
        b.put(0, b"bbb", meta(1, 1)).unwrap();
        assert_eq!(a.take(0).unwrap().unwrap(), b"aaa");
        assert_eq!(b.take(0).unwrap().unwrap(), b"bbb");
    }
}
