//! Forgetting triggers (Section 5.2): decide *when* a sweep runs.
//!
//! * LFU triggers every `trigger_events` processed records (paper: "after
//!   processing every c records the scan starts").
//! * LRU triggers every `trigger_secs` of *event time* (paper: "after t
//!   time the scan starts") — event time, not wall clock, so runs are
//!   reproducible and independent of host speed.
//!
//! The sweep itself lives with the state stores (`TrackedMap`,
//! `VectorSlab`); algorithms cascade evictions across their stores.

use crate::config::Forgetting;

/// Which sweep fired.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SweepKind {
    /// Evict entries with `last_ts < cutoff_ts`.
    Lru {
        /// Entries last touched before this event time are evicted.
        cutoff_ts: u64,
    },
    /// Evict entries with `freq < min_freq`.
    Lfu {
        /// Entries touched fewer times than this are evicted.
        min_freq: u64,
    },
    /// Gradual forgetting: multiplicatively decay model evidence
    /// (extension; Section 6 future work).
    Decay {
        /// Multiplicative decay factor.
        factor: f32,
    },
}

/// Per-worker trigger clock.
#[derive(Debug, Clone)]
pub struct ForgetClock {
    policy: Forgetting,
    events_since_sweep: u64,
    last_sweep_ts: u64,
    sweeps: u64,
}

impl ForgetClock {
    /// Fresh clock for `policy` (no sweeps yet).
    pub fn new(policy: Forgetting) -> Self {
        Self { policy, events_since_sweep: 0, last_sweep_ts: 0, sweeps: 0 }
    }

    /// The policy this clock drives.
    pub fn policy(&self) -> Forgetting {
        self.policy
    }

    /// Sweeps triggered so far.
    pub fn sweeps(&self) -> u64 {
        self.sweeps
    }

    /// Internal trigger state as `(events_since_sweep, last_sweep_ts,
    /// sweeps)` — what has to travel in a lane snapshot for the
    /// forgetting *cadence* to survive a migration or a crash recovery
    /// (the policy itself is configuration and does not travel).
    pub fn state(&self) -> (u64, u64, u64) {
        (self.events_since_sweep, self.last_sweep_ts, self.sweeps)
    }

    /// Restore trigger state captured by [`ForgetClock::state`]. After a
    /// restore the clock fires on exactly the event it would have fired
    /// on had the lane never moved.
    pub fn restore(
        &mut self,
        events_since_sweep: u64,
        last_sweep_ts: u64,
        sweeps: u64,
    ) {
        self.events_since_sweep = events_since_sweep;
        self.last_sweep_ts = last_sweep_ts;
        self.sweeps = sweeps;
    }

    /// Advance by one processed event at event-time `now_ts`; returns the
    /// sweep to perform, if due.
    pub fn on_event(&mut self, now_ts: u64) -> Option<SweepKind> {
        match self.policy {
            Forgetting::None => None,
            Forgetting::Lru { trigger_secs, max_idle_secs } => {
                if now_ts.saturating_sub(self.last_sweep_ts) >= trigger_secs {
                    self.last_sweep_ts = now_ts;
                    self.sweeps += 1;
                    Some(SweepKind::Lru {
                        cutoff_ts: now_ts.saturating_sub(max_idle_secs),
                    })
                } else {
                    None
                }
            }
            Forgetting::Lfu { trigger_events, min_freq } => {
                self.events_since_sweep += 1;
                if self.events_since_sweep >= trigger_events {
                    self.events_since_sweep = 0;
                    self.sweeps += 1;
                    Some(SweepKind::Lfu { min_freq })
                } else {
                    None
                }
            }
            Forgetting::Decay { trigger_events, factor } => {
                self.events_since_sweep += 1;
                if self.events_since_sweep >= trigger_events {
                    self.events_since_sweep = 0;
                    self.sweeps += 1;
                    Some(SweepKind::Decay { factor })
                } else {
                    None
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_never_sweeps() {
        let mut c = ForgetClock::new(Forgetting::None);
        for ts in 0..10_000 {
            assert_eq!(c.on_event(ts), None);
        }
        assert_eq!(c.sweeps(), 0);
    }

    #[test]
    fn lfu_triggers_on_count() {
        let mut c = ForgetClock::new(Forgetting::Lfu {
            trigger_events: 3,
            min_freq: 2,
        });
        assert_eq!(c.on_event(0), None);
        assert_eq!(c.on_event(0), None);
        assert_eq!(c.on_event(0), Some(SweepKind::Lfu { min_freq: 2 }));
        assert_eq!(c.on_event(0), None); // counter reset
        assert_eq!(c.sweeps(), 1);
    }

    #[test]
    fn lru_triggers_on_event_time() {
        let mut c = ForgetClock::new(Forgetting::Lru {
            trigger_secs: 100,
            max_idle_secs: 50,
        });
        assert_eq!(c.on_event(10), None);
        assert_eq!(
            c.on_event(120),
            Some(SweepKind::Lru { cutoff_ts: 70 })
        );
        assert_eq!(c.on_event(150), None); // 30s since last sweep
        assert_eq!(
            c.on_event(220),
            Some(SweepKind::Lru { cutoff_ts: 170 })
        );
    }

    #[test]
    fn decay_triggers_on_count() {
        let mut c = ForgetClock::new(Forgetting::Decay {
            trigger_events: 2,
            factor: 0.9,
        });
        assert_eq!(c.on_event(0), None);
        assert_eq!(c.on_event(1), Some(SweepKind::Decay { factor: 0.9 }));
        assert_eq!(c.sweeps(), 1);
    }

    #[test]
    fn state_round_trip_preserves_cadence() {
        // Two clocks, same policy: advance one to mid-cycle, copy its
        // state into the other — both must fire on the same future event.
        let policy = Forgetting::Lfu { trigger_events: 5, min_freq: 1 };
        let mut a = ForgetClock::new(policy);
        for ts in 0..3 {
            assert_eq!(a.on_event(ts), None);
        }
        let (ev, ts, sw) = a.state();
        assert_eq!((ev, ts, sw), (3, 0, 0));
        let mut b = ForgetClock::new(policy);
        b.restore(ev, ts, sw);
        assert_eq!(b.on_event(3), None);
        assert_eq!(b.on_event(4), Some(SweepKind::Lfu { min_freq: 1 }));
        assert_eq!(a.on_event(3), None);
        assert_eq!(a.on_event(4), Some(SweepKind::Lfu { min_freq: 1 }));
        assert_eq!(a.state(), b.state());
    }

    #[test]
    fn lru_cutoff_saturates_at_zero() {
        let mut c = ForgetClock::new(Forgetting::Lru {
            trigger_secs: 1,
            max_idle_secs: 1000,
        });
        assert_eq!(c.on_event(5), Some(SweepKind::Lru { cutoff_ts: 0 }));
    }
}
