//! `TrackedMap`: a keyed state store that records recency and frequency
//! metadata per entry, so the LRU/LFU forgetting techniques (Section 5.2)
//! can sweep it. This is the Rust stand-in for Flink keyed state — each
//! worker owns its own instances; nothing is shared (shared-nothing).

use std::collections::HashMap;

/// Entry metadata + value.
#[derive(Debug, Clone)]
struct Entry<V> {
    value: V,
    /// Event-time seconds of the last touch (LRU controller input).
    last_ts: u64,
    /// Touch count (LFU controller input).
    freq: u64,
}

/// Keyed store with recency/frequency tracking.
#[derive(Debug, Clone, Default)]
pub struct TrackedMap<K, V> {
    map: HashMap<K, Entry<V>>,
}

impl<K: std::hash::Hash + Eq + Clone, V> TrackedMap<K, V> {
    /// Empty store.
    pub fn new() -> Self {
        Self { map: HashMap::new() }
    }

    /// Live entry count.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when no entries are live.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// True if `k` is live.
    pub fn contains(&self, k: &K) -> bool {
        self.map.contains_key(k)
    }

    /// Read without touching metadata (recommendation path reads should
    /// not count as "use" — only learning updates do, mirroring the
    /// paper's "count of users' requests towards items").
    pub fn peek(&self, k: &K) -> Option<&V> {
        self.map.get(k).map(|e| &e.value)
    }

    /// Mutable access that records a touch at `now_ts`.
    pub fn touch_mut(&mut self, k: &K, now_ts: u64) -> Option<&mut V> {
        self.map.get_mut(k).map(|e| {
            e.last_ts = now_ts;
            e.freq += 1;
            &mut e.value
        })
    }

    /// Insert (or overwrite) with a first touch at `now_ts`.
    pub fn insert(&mut self, k: K, v: V, now_ts: u64) {
        self.map.insert(k, Entry { value: v, last_ts: now_ts, freq: 1 });
    }

    /// Remove an entry, returning its value.
    pub fn remove(&mut self, k: &K) -> Option<V> {
        self.map.remove(k).map(|e| e.value)
    }

    /// Iterate values without touching.
    pub fn iter(&self) -> impl Iterator<Item = (&K, &V)> {
        self.map.iter().map(|(k, e)| (k, &e.value))
    }

    /// Iterate `(key, value, last_ts, freq)` without touching — the
    /// export half of state migration (metadata must travel with the
    /// value or the first post-migration LRU/LFU sweep would treat every
    /// migrated entry as brand new).
    pub fn iter_meta(&self) -> impl Iterator<Item = (&K, &V, u64, u64)> {
        self.map.iter().map(|(k, e)| (k, &e.value, e.last_ts, e.freq))
    }

    /// Insert (or overwrite) with explicit recency/frequency metadata —
    /// the import half of state migration.
    pub fn insert_with_meta(&mut self, k: K, v: V, last_ts: u64, freq: u64) {
        self.map.insert(k, Entry { value: v, last_ts, freq });
    }

    /// Touch count of an entry (LFU input).
    pub fn freq(&self, k: &K) -> Option<u64> {
        self.map.get(k).map(|e| e.freq)
    }

    /// Last-touch event time of an entry (LRU input).
    pub fn last_ts(&self, k: &K) -> Option<u64> {
        self.map.get(k).map(|e| e.last_ts)
    }

    /// Mutate every value in place without touching metadata (used by
    /// the gradual-forgetting extension to decay model evidence).
    pub fn for_each_value_mut(&mut self, mut f: impl FnMut(&K, &mut V)) {
        for (k, e) in self.map.iter_mut() {
            f(k, &mut e.value);
        }
    }

    /// Remove entries for which `pred` returns true; returns removed keys.
    pub fn retain_or_collect(
        &mut self,
        mut keep: impl FnMut(&K, &V) -> bool,
    ) -> Vec<K> {
        let dead: Vec<K> = self
            .map
            .iter()
            .filter(|(k, e)| !keep(k, &e.value))
            .map(|(k, _)| k.clone())
            .collect();
        for k in &dead {
            self.map.remove(k);
        }
        dead
    }

    /// LRU sweep: evict entries idle since before `cutoff_ts`.
    /// Returns the evicted keys (the caller may need to cascade, e.g.
    /// DICS removes pair entries for evicted items).
    pub fn sweep_lru(&mut self, cutoff_ts: u64) -> Vec<K> {
        let dead: Vec<K> = self
            .map
            .iter()
            .filter(|(_, e)| e.last_ts < cutoff_ts)
            .map(|(k, _)| k.clone())
            .collect();
        for k in &dead {
            self.map.remove(k);
        }
        dead
    }

    /// LFU sweep: evict entries with `freq < min_freq`, then reset the
    /// surviving counters (periodic aging, so frequency reflects the
    /// current window rather than all history).
    pub fn sweep_lfu(&mut self, min_freq: u64) -> Vec<K> {
        let dead: Vec<K> = self
            .map
            .iter()
            .filter(|(_, e)| e.freq < min_freq)
            .map(|(k, _)| k.clone())
            .collect();
        for k in &dead {
            self.map.remove(k);
        }
        for e in self.map.values_mut() {
            e.freq = 0;
        }
        dead
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn touch_updates_metadata() {
        let mut m: TrackedMap<u64, i32> = TrackedMap::new();
        m.insert(1, 10, 100);
        assert_eq!(m.freq(&1), Some(1));
        *m.touch_mut(&1, 200).unwrap() += 5;
        assert_eq!(m.peek(&1), Some(&15));
        assert_eq!(m.freq(&1), Some(2));
        assert_eq!(m.last_ts(&1), Some(200));
    }

    #[test]
    fn peek_does_not_touch() {
        let mut m: TrackedMap<u64, i32> = TrackedMap::new();
        m.insert(1, 10, 100);
        let _ = m.peek(&1);
        assert_eq!(m.freq(&1), Some(1));
        assert_eq!(m.last_ts(&1), Some(100));
    }

    #[test]
    fn meta_roundtrip_for_migration() {
        let mut m: TrackedMap<u64, i32> = TrackedMap::new();
        m.insert(1, 10, 100);
        m.touch_mut(&1, 250);
        let mut n: TrackedMap<u64, i32> = TrackedMap::new();
        for (k, v, ts, freq) in m.iter_meta() {
            n.insert_with_meta(*k, *v, ts, freq);
        }
        assert_eq!(n.peek(&1), Some(&10));
        assert_eq!(n.last_ts(&1), Some(250));
        assert_eq!(n.freq(&1), Some(2));
    }

    #[test]
    fn lru_sweep_respects_cutoff() {
        let mut m: TrackedMap<u64, ()> = TrackedMap::new();
        m.insert(1, (), 100);
        m.insert(2, (), 200);
        m.insert(3, (), 300);
        m.touch_mut(&1, 400); // rescued by a later touch
        let dead = m.sweep_lru(250);
        assert_eq!(dead, vec![2]);
        assert_eq!(m.len(), 2);
        assert!(m.contains(&1) && m.contains(&3));
    }

    #[test]
    fn lfu_sweep_evicts_cold_and_ages_survivors() {
        let mut m: TrackedMap<u64, ()> = TrackedMap::new();
        m.insert(1, (), 0);
        m.insert(2, (), 0);
        for _ in 0..5 {
            m.touch_mut(&1, 1);
        }
        let dead = m.sweep_lfu(3);
        assert_eq!(dead, vec![2]);
        assert_eq!(m.freq(&1), Some(0)); // aged
    }
}
