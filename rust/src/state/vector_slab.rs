//! `VectorSlab`: the worker-local item matrix as one contiguous, capacity-
//! padded f32 slab with a validity mask — the exact memory layout the AOT
//! scoring artifacts consume (`items: (M, K)`, `valid: (M,)`), shared with
//! the native scoring backend so the two backends are bit-compatible.
//!
//! Capacity grows in the artifact bucket sizes (1024/4096/16384, then x4),
//! so a slab can always be handed to a PJRT executable without reshaping.
//! Rows are recycled through a free list when forgetting evicts items.

use std::collections::HashMap;

use crate::data::types::ItemId;

/// Artifact capacity buckets (must match `python/compile/aot.py`).
pub const BUCKETS: [usize; 3] = [1024, 4096, 16384];

/// Round a row count up to the next artifact bucket (or x4 beyond).
pub fn bucket_for(rows: usize) -> usize {
    for b in BUCKETS {
        if rows <= b {
            return b;
        }
    }
    let mut cap = *BUCKETS.last().unwrap();
    while cap < rows {
        cap *= 4;
    }
    cap
}

/// Contiguous (capacity x k) f32 store with id<->row maps, validity mask
/// and per-row recency/frequency metadata for the forgetting sweeps.
#[derive(Debug, Clone)]
pub struct VectorSlab {
    k: usize,
    data: Vec<f32>,
    valid: Vec<f32>,
    row_of: HashMap<ItemId, usize>,
    id_of: Vec<Option<ItemId>>,
    free: Vec<usize>,
    last_ts: Vec<u64>,
    freq: Vec<u64>,
    live: usize,
    /// Rows `[0, high_water)` have been used at least once; fresh inserts
    /// take `high_water` in O(1) instead of scanning for a free row.
    high_water: usize,
    /// Monotone mutation counter: lets backends cache device-resident
    /// copies of the slab and re-upload only when it actually changed.
    version: u64,
}

impl VectorSlab {
    /// Empty slab of `k`-dimensional rows at the smallest bucket size.
    pub fn new(k: usize) -> Self {
        let cap = BUCKETS[0];
        Self {
            k,
            data: vec![0.0; cap * k],
            valid: vec![0.0; cap],
            row_of: HashMap::new(),
            id_of: vec![None; cap],
            free: Vec::new(),
            last_ts: vec![0; cap],
            freq: vec![0; cap],
            live: 0,
            high_water: 0,
            version: 0,
        }
    }

    /// Mutation counter (bumped by insert/remove/touch_mut).
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Latent dimension of every row.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Live row count (the paper's items-state "memory" metric).
    pub fn len(&self) -> usize {
        self.live
    }

    /// True when no rows are live.
    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Padded capacity (the artifact bucket currently in use).
    pub fn capacity(&self) -> usize {
        self.valid.len()
    }

    /// True if `id` has a live row.
    pub fn contains(&self, id: ItemId) -> bool {
        self.row_of.contains_key(&id)
    }

    /// Slab row of a live id.
    pub fn row(&self, id: ItemId) -> Option<usize> {
        self.row_of.get(&id).copied()
    }

    /// Id living at `row` (None for free or out-of-range rows).
    pub fn id_at(&self, row: usize) -> Option<ItemId> {
        self.id_of.get(row).copied().flatten()
    }

    /// Immutable vector access (no metadata touch).
    pub fn get(&self, id: ItemId) -> Option<&[f32]> {
        self.row_of
            .get(&id)
            .map(|&r| &self.data[r * self.k..(r + 1) * self.k])
    }

    /// Mutable vector access recording a learning touch at `now_ts`.
    pub fn touch_mut(&mut self, id: ItemId, now_ts: u64) -> Option<&mut [f32]> {
        let r = *self.row_of.get(&id)?;
        self.last_ts[r] = now_ts;
        self.freq[r] += 1;
        self.version += 1;
        Some(&mut self.data[r * self.k..(r + 1) * self.k])
    }

    /// Insert a new vector; returns its row. Panics if the id exists.
    pub fn insert(&mut self, id: ItemId, vec: &[f32], now_ts: u64) -> usize {
        assert_eq!(vec.len(), self.k);
        assert!(
            !self.row_of.contains_key(&id),
            "insert of existing id {id}"
        );
        let row = match self.free.pop() {
            Some(r) => r,
            None => {
                if self.high_water == self.capacity() {
                    self.grow();
                }
                let r = self.high_water;
                self.high_water += 1;
                r
            }
        };
        self.data[row * self.k..(row + 1) * self.k].copy_from_slice(vec);
        self.valid[row] = 1.0;
        self.id_of[row] = Some(id);
        self.row_of.insert(id, row);
        self.last_ts[row] = now_ts;
        self.freq[row] = 1;
        self.live += 1;
        self.version += 1;
        row
    }

    /// Recency/frequency metadata of a live id, for state export (the
    /// forgetting sweeps key off these, so migration must carry them).
    pub fn meta(&self, id: ItemId) -> Option<(u64, u64)> {
        self.row_of.get(&id).map(|&r| (self.last_ts[r], self.freq[r]))
    }

    /// Insert with explicit metadata — the import half of state
    /// migration. Same row-assignment policy as [`VectorSlab::insert`],
    /// so importing rows in export (row) order preserves their relative
    /// order, which keeps score-tie behavior in the top-N scan
    /// deterministic across a migration.
    pub fn insert_with_meta(
        &mut self,
        id: ItemId,
        vec: &[f32],
        last_ts: u64,
        freq: u64,
    ) -> usize {
        let row = self.insert(id, vec, last_ts);
        self.freq[row] = freq;
        row
    }

    /// Remove an id; its row returns to the free list (mask zeroed so the
    /// scoring artifacts ignore it).
    pub fn remove(&mut self, id: ItemId) -> bool {
        let Some(row) = self.row_of.remove(&id) else {
            return false;
        };
        self.valid[row] = 0.0;
        self.id_of[row] = None;
        self.data[row * self.k..(row + 1) * self.k].fill(0.0);
        self.free.push(row);
        self.live -= 1;
        self.version += 1;
        true
    }

    fn grow(&mut self) {
        let old = self.capacity();
        let new = bucket_for(old + 1);
        self.data.resize(new * self.k, 0.0);
        self.valid.resize(new, 0.0);
        self.id_of.resize(new, None);
        self.last_ts.resize(new, 0);
        self.freq.resize(new, 0);
        log::debug!("vector slab grew {old} -> {new} rows");
    }

    /// The raw (capacity x k) matrix — PJRT artifact input 2.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// The validity mask (capacity,) — PJRT artifact input 3.
    pub fn valid(&self) -> &[f32] {
        &self.valid
    }

    /// Highest ever-used row + 1; scans can stop here instead of at
    /// `capacity()` (the padding above has never held data).
    pub fn high_water(&self) -> usize {
        self.high_water
    }

    /// Iterate live (id, row) pairs.
    pub fn iter_ids(&self) -> impl Iterator<Item = (ItemId, usize)> + '_ {
        self.id_of[..self.high_water]
            .iter()
            .enumerate()
            .filter_map(|(r, id)| id.map(|i| (i, r)))
    }

    /// LRU sweep: evict rows idle since before `cutoff_ts`; returns ids.
    pub fn sweep_lru(&mut self, cutoff_ts: u64) -> Vec<ItemId> {
        let dead: Vec<ItemId> = self
            .iter_ids()
            .filter(|&(_, r)| self.last_ts[r] < cutoff_ts)
            .map(|(id, _)| id)
            .collect();
        for id in &dead {
            self.remove(*id);
        }
        dead
    }

    /// Gradual forgetting: scale every live vector by `factor`
    /// (extension; old evidence fades instead of being evicted).
    pub fn decay_all(&mut self, factor: f32) {
        for r in 0..self.high_water {
            if self.valid[r] == 1.0 {
                for v in &mut self.data[r * self.k..(r + 1) * self.k] {
                    *v *= factor;
                }
            }
        }
        self.version += 1;
    }

    /// LFU sweep: evict rows with freq < min_freq, age survivors to 0.
    pub fn sweep_lfu(&mut self, min_freq: u64) -> Vec<ItemId> {
        let dead: Vec<ItemId> = self
            .iter_ids()
            .filter(|&(_, r)| self.freq[r] < min_freq)
            .map(|(id, _)| id)
            .collect();
        for id in &dead {
            self.remove(*id);
        }
        for (_, r) in self.iter_ids().collect::<Vec<_>>() {
            self.freq[r] = 0;
        }
        dead
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(k: usize, x: f32) -> Vec<f32> {
        vec![x; k]
    }

    #[test]
    fn bucket_rounding() {
        assert_eq!(bucket_for(1), 1024);
        assert_eq!(bucket_for(1024), 1024);
        assert_eq!(bucket_for(1025), 4096);
        assert_eq!(bucket_for(16384), 16384);
        assert_eq!(bucket_for(16385), 65536);
    }

    #[test]
    fn insert_get_remove() {
        let mut s = VectorSlab::new(4);
        let r = s.insert(7, &v(4, 1.5), 10);
        assert_eq!(s.len(), 1);
        assert_eq!(s.get(7).unwrap(), &[1.5; 4]);
        assert_eq!(s.valid()[r], 1.0);
        assert_eq!(s.id_at(r), Some(7));
        assert!(s.remove(7));
        assert!(!s.remove(7));
        assert_eq!(s.len(), 0);
        assert_eq!(s.valid()[r], 0.0);
        assert_eq!(s.get(7), None);
    }

    #[test]
    fn rows_recycled_after_removal() {
        let mut s = VectorSlab::new(2);
        let r1 = s.insert(1, &v(2, 1.0), 0);
        s.remove(1);
        let r2 = s.insert(2, &v(2, 2.0), 0);
        assert_eq!(r1, r2);
        assert_eq!(s.id_at(r2), Some(2));
    }

    #[test]
    fn grows_through_buckets() {
        let mut s = VectorSlab::new(2);
        for id in 0..1025u64 {
            s.insert(id, &v(2, id as f32), 0);
        }
        assert_eq!(s.capacity(), 4096);
        assert_eq!(s.len(), 1025);
        // All originals intact after the grow.
        assert_eq!(s.get(0).unwrap(), &[0.0, 0.0]);
        assert_eq!(s.get(1024).unwrap(), &[1024.0, 1024.0]);
        assert_eq!(s.data().len(), 4096 * 2);
    }

    #[test]
    fn touch_updates_freq_and_ts() {
        let mut s = VectorSlab::new(2);
        s.insert(5, &v(2, 0.0), 100);
        s.touch_mut(5, 200).unwrap()[0] = 9.0;
        assert_eq!(s.get(5).unwrap()[0], 9.0);
        let dead = s.sweep_lru(150);
        assert!(dead.is_empty(), "touched row must survive lru sweep");
        let dead = s.sweep_lru(250);
        assert_eq!(dead, vec![5]);
    }

    #[test]
    fn insert_with_meta_preserves_sweep_inputs() {
        let mut s = VectorSlab::new(2);
        s.insert_with_meta(5, &[1.0, 2.0], 300, 7);
        assert_eq!(s.meta(5), Some((300, 7)));
        assert_eq!(s.meta(6), None);
        // A migrated row must survive exactly the sweeps the original
        // would have survived.
        assert!(s.sweep_lru(300).is_empty());
        assert_eq!(s.sweep_lru(301), vec![5]);
    }

    #[test]
    fn lfu_sweep() {
        let mut s = VectorSlab::new(2);
        s.insert(1, &v(2, 0.0), 0);
        s.insert(2, &v(2, 0.0), 0);
        for _ in 0..4 {
            s.touch_mut(1, 1);
        }
        let dead = s.sweep_lfu(3);
        assert_eq!(dead, vec![2]);
        assert!(s.contains(1));
    }

    #[test]
    fn decay_scales_live_rows_only() {
        let mut s = VectorSlab::new(2);
        s.insert(1, &[2.0, 4.0], 0);
        s.insert(2, &[1.0, 1.0], 0);
        s.remove(2);
        let v0 = s.version();
        s.decay_all(0.5);
        assert_eq!(s.get(1).unwrap(), &[1.0, 2.0]);
        assert!(s.version() > v0, "decay must invalidate device caches");
    }

    #[test]
    fn mask_zeroed_rows_have_zero_data() {
        let mut s = VectorSlab::new(3);
        s.insert(1, &[1.0, 2.0, 3.0], 0);
        let r = s.row(1).unwrap();
        s.remove(1);
        assert_eq!(&s.data()[r * 3..r * 3 + 3], &[0.0, 0.0, 0.0]);
    }
}
