//! Frame protocol of the networked worker transport.
//!
//! Every [`WorkerMsg`] variant (and every worker→coordinator message)
//! has a frame: a `u32` little-endian length prefix on the socket,
//! then a one-byte tag, then the body encoded with
//! [`wire`](crate::util::wire). Reply-`Sender`-carrying variants
//! (`Query`, `MetricsSnapshot`, `Export`) become RPC: the
//! coordinator-side proxy assigns a `req_id`, parks the reply sender in
//! a multiplexer, and the worker host echoes the id on the answer frame
//! (see `net/remote.rs` and `net/server.rs`).
//!
//! Layout rules, enforced by the round-trip tests:
//!
//! * every variable-length section carries its own length prefix — no
//!   trailing-`rest` payloads — so any strict-prefix truncation decodes
//!   to a loud [`WireError`], never a panic and never a silent partial
//!   read;
//! * [`Frame::decode`] requires full consumption: trailing bytes after
//!   a well-formed body are an error (a frame is exactly one message);
//! * decoding allocates proportionally to the *received* bytes, so a
//!   hostile length prefix cannot balloon memory.

use std::io::{Read, Write};

use crate::config::{
    Algorithm, Backend, Forgetting, NetFaultConfig, RunConfig, Topology,
};
use crate::data::types::{Rating, StateSizes};
use crate::engine::actor::{
    Envelope, LaneSnapshot, ReplicaAnswer, WorkerExport,
};
use crate::engine::WorkerSnapshot;
use crate::eval::{HitSample, WindowStat, WorkerReport};
use crate::util::histogram::Histogram;
use crate::util::wire::{WireError, WireReader, WireWriter};

/// Bumped on any incompatible layout change; carried in the hello
/// frame and checked by the host before anything else is decoded.
/// v2: liveness `Ping`/`Pong` frames + supervision and `[fault.net]`
/// knobs appended to the config codec.
/// v3: the query-plane split — `Query` frames carry the read-your-writes
/// fence (and may arrive out of FIFO order; the host's serving lane
/// parks them on the fence) + the `[serving]` knobs appended to the
/// config codec.
/// v4: the memory subsystem — `[memory]` knobs appended to the config
/// codec, resident/spill accounting appended to `SnapshotReply`, and
/// `state_bytes`/`spills`/`spill_faultins` appended to `Report`.
pub(crate) const PROTO_VERSION: u8 = 4;

/// Upper bound on a single frame body (sanity cap so a corrupt length
/// prefix fails fast instead of attempting a giant read).
pub(crate) const MAX_FRAME: usize = 1 << 30;

// Coordinator → worker host.
const TAG_HELLO: u8 = 1;
const TAG_EVENTS: u8 = 2;
const TAG_QUERY: u8 = 3;
const TAG_SNAPSHOT: u8 = 4;
const TAG_EXPORT: u8 = 5;
const TAG_IMPORT: u8 = 6;
const TAG_CLOSE: u8 = 7;
const TAG_PING: u8 = 8;
// Worker host → coordinator.
const TAG_ANSWER: u8 = 16;
const TAG_SNAPSHOT_REPLY: u8 = 17;
const TAG_EXPORT_REPLY: u8 = 18;
const TAG_HITS: u8 = 19;
const TAG_DONE: u8 = 20;
const TAG_CHECKPOINT: u8 = 21;
const TAG_REPORT: u8 = 22;
const TAG_PONG: u8 = 23;

/// First frame on every connection: everything the host needs to build
/// the actor for one worker slot — its ordinal, the state-grid shape,
/// the armed chaos policy, and the full run configuration.
#[derive(Debug, Clone)]
pub(crate) struct Hello {
    /// Session-unique worker ordinal of the slot this connection hosts.
    pub(crate) ord: u64,
    /// State-grid item rows (`StateGrid::v_i`).
    pub(crate) v_i: u64,
    /// State-grid user columns (`StateGrid::v_u`).
    pub(crate) v_u: u64,
    /// Armed chaos kill position (respawned slots carry `None`).
    pub(crate) kill_at_seq: Option<u64>,
    /// Whether the kill defers to the next checkpoint attempt.
    pub(crate) kill_in_checkpoint: bool,
    /// The run configuration the actor is built from.
    pub(crate) cfg: RunConfig,
}

/// One message on the transport socket, either direction. The tag
/// ranges keep the directions disjoint so a misrouted frame is an
/// immediate decode error rather than a confusing state.
pub(crate) enum Frame {
    /// Connection opener, coordinator → host (boxed: `RunConfig` makes
    /// this variant much larger than the hot `Events` one).
    Hello(Box<Hello>),
    /// A batch of stream events in FIFO order.
    Events(Vec<Envelope>),
    /// [`QueryMsg`](crate::engine::actor::QueryMsg) as RPC. Unlike every
    /// other coordinator frame this one is *not* FIFO-ordered relative
    /// to `Events`: the proxy writes it immediately (the serving-lane
    /// bypass), and the host parks it until the actor's applied
    /// watermark reaches `fence`.
    Query {
        /// Multiplexer key echoed on the matching `Answer`.
        req_id: u64,
        /// User to recommend for.
        user: u64,
        /// Per-lane list length.
        n: u64,
        /// Read-your-writes fence (`seq + 1` of the last event routed
        /// to this worker; `0` = none).
        fence: u64,
    },
    /// `WorkerMsg::MetricsSnapshot` as RPC.
    Snapshot {
        /// Multiplexer key echoed on the matching `SnapshotReply`.
        req_id: u64,
    },
    /// `WorkerMsg::Export` as RPC (terminal for the actor).
    Export {
        /// Multiplexer key echoed on the matching `ExportReply`.
        req_id: u64,
    },
    /// `WorkerMsg::Import` (no reply; FIFO position is the contract).
    Import {
        /// Virtual grid cell to install.
        lane: u64,
        /// Recovery (`true`) vs rescale (`false`) counter semantics.
        restore_counters: bool,
        /// Encoded lane frame.
        bytes: Vec<u8>,
    },
    /// End of the coordinator's stream: drain, report, hang up.
    Close,
    /// Coordinator-side liveness probe. The host answers with a `Pong`
    /// echoing the nonce through its ordinary write path, so a pong
    /// proves the whole host loop — not just the socket — is alive.
    Ping {
        /// Echoed verbatim on the matching `Pong`.
        nonce: u64,
    },
    /// Reply to `Ping` (host → coordinator).
    Pong {
        /// Nonce of the `Ping` being answered.
        nonce: u64,
    },
    /// Reply to `Query`.
    Answer {
        /// Multiplexer key of the originating `Query`.
        req_id: u64,
        /// The replica's ranked lists + rated set.
        answer: ReplicaAnswer,
    },
    /// Reply to `Snapshot`.
    SnapshotReply {
        /// Multiplexer key of the originating `Snapshot`.
        req_id: u64,
        /// Live counters.
        snap: WorkerSnapshot,
    },
    /// Reply to `Export`.
    ExportReply {
        /// Multiplexer key of the originating `Export`.
        req_id: u64,
        /// Every hosted lane, serialized.
        export: WorkerExport,
    },
    /// `CollectorMsg::Hits` forwarded home.
    Hits(Vec<HitSample>),
    /// `CollectorMsg::Done` forwarded home.
    Done {
        /// Ordinal of the drained worker.
        worker_id: u64,
    },
    /// A periodic lane checkpoint forwarded home.
    Checkpoint {
        /// Ordinal of the checkpointing worker.
        ord: u64,
        /// Virtual grid cell the frame snapshots.
        lane: u64,
        /// Encoded lane frame.
        bytes: Vec<u8>,
    },
    /// The actor's final [`WorkerReport`] (boxed for the same size
    /// reason as `Hello`). A connection that ends *without* this frame
    /// is a crashed worker.
    Report(Box<WorkerReport>),
}

impl Frame {
    /// Encode into a frame body (tag + payload, no length prefix — the
    /// socket layer adds that).
    pub(crate) fn encode(&self) -> Vec<u8> {
        let mut w = WireWriter::with_capacity(self.size_hint());
        self.encode_into(&mut w);
        w.into_bytes()
    }

    /// Exact encoded body size for the fixed-layout and hot variants,
    /// a ballpark for the cold config/report ones. Only a capacity
    /// hint — encoding never depends on it — but an exact hint on the
    /// per-event path means the write buffer never growth-reallocs
    /// mid-frame (BENCH_hotpath.json `wire_encode/*` rows).
    fn size_hint(&self) -> usize {
        match self {
            Frame::Hello(_) | Frame::Report(_) => 512,
            Frame::Events(envs) => 5 + 36 * envs.len(),
            Frame::Query { .. } => 33,
            Frame::Snapshot { .. } | Frame::Export { .. } => 9,
            Frame::Import { bytes, .. } => 14 + bytes.len(),
            Frame::Close => 1,
            Frame::Ping { .. } | Frame::Pong { .. } => 9,
            Frame::Answer { answer, .. } => {
                13 + answer.lists.iter().map(|l| 4 + 8 * l.len()).sum::<usize>()
                    + 4
                    + 8 * answer.rated.len()
            }
            Frame::SnapshotReply { .. } => 113,
            Frame::ExportReply { export, .. } => {
                21 + export
                    .lanes
                    .iter()
                    .map(|l| 12 + l.bytes.len())
                    .sum::<usize>()
            }
            Frame::Hits(samples) => 5 + 9 * samples.len(),
            Frame::Done { .. } => 9,
            Frame::Checkpoint { bytes, .. } => 21 + bytes.len(),
        }
    }

    /// Append the encoded body to `w` (the workhorse behind
    /// [`Frame::encode`] and [`write_frame_into`]'s reused buffer).
    fn encode_into(&self, w: &mut WireWriter) {
        match self {
            Frame::Hello(h) => {
                w.u8(TAG_HELLO);
                w.u8(PROTO_VERSION);
                w.u64(h.ord);
                w.u64(h.v_i);
                w.u64(h.v_u);
                opt_u64(w, h.kill_at_seq);
                w.u8(u8::from(h.kill_in_checkpoint));
                encode_config(w, &h.cfg);
            }
            Frame::Events(envs) => {
                w.u8(TAG_EVENTS);
                w.u32(envs.len() as u32);
                for env in envs {
                    w.u64(env.seq);
                    w.u64(env.rating.user);
                    w.u64(env.rating.item);
                    w.f32(env.rating.rating);
                    w.u64(env.rating.ts);
                }
            }
            Frame::Query { req_id, user, n, fence } => {
                w.u8(TAG_QUERY);
                w.u64(*req_id);
                w.u64(*user);
                w.u64(*n);
                w.u64(*fence);
            }
            Frame::Snapshot { req_id } => {
                w.u8(TAG_SNAPSHOT);
                w.u64(*req_id);
            }
            Frame::Export { req_id } => {
                w.u8(TAG_EXPORT);
                w.u64(*req_id);
            }
            Frame::Import { lane, restore_counters, bytes } => {
                w.u8(TAG_IMPORT);
                w.u64(*lane);
                w.u8(u8::from(*restore_counters));
                w.byte_slice(bytes);
            }
            Frame::Close => w.u8(TAG_CLOSE),
            Frame::Ping { nonce } => {
                w.u8(TAG_PING);
                w.u64(*nonce);
            }
            Frame::Pong { nonce } => {
                w.u8(TAG_PONG);
                w.u64(*nonce);
            }
            Frame::Answer { req_id, answer } => {
                w.u8(TAG_ANSWER);
                w.u64(*req_id);
                w.u32(answer.lists.len() as u32);
                for list in &answer.lists {
                    w.u64_slice(list);
                }
                w.u64_slice(&answer.rated);
            }
            Frame::SnapshotReply { req_id, snap } => {
                w.u8(TAG_SNAPSHOT_REPLY);
                w.u64(*req_id);
                w.u64(snap.worker_id as u64);
                w.u64(snap.processed);
                w.u64(snap.hits);
                w.u64(snap.queries);
                w.u64(snap.lanes);
                encode_state(w, &snap.state);
                w.u64(snap.state_bytes);
                w.u64(snap.spilled_lanes);
                w.u64(snap.spilled_bytes);
                w.u64(snap.spills);
                w.u64(snap.spill_faultins);
            }
            Frame::ExportReply { req_id, export } => {
                w.u8(TAG_EXPORT_REPLY);
                w.u64(*req_id);
                w.u64(export.ord as u64);
                w.u32(export.lanes.len() as u32);
                for lane in &export.lanes {
                    w.u64(lane.lane);
                    w.byte_slice(&lane.bytes);
                }
            }
            Frame::Hits(samples) => {
                w.u8(TAG_HITS);
                w.u32(samples.len() as u32);
                for s in samples {
                    w.u64(s.seq);
                    w.u8(u8::from(s.hit));
                }
            }
            Frame::Done { worker_id } => {
                w.u8(TAG_DONE);
                w.u64(*worker_id);
            }
            Frame::Checkpoint { ord, lane, bytes } => {
                w.u8(TAG_CHECKPOINT);
                w.u64(*ord);
                w.u64(*lane);
                w.byte_slice(bytes);
            }
            Frame::Report(report) => {
                w.u8(TAG_REPORT);
                encode_report(w, report);
            }
        }
    }

    /// Decode a frame body. Unknown tags, truncation at any byte,
    /// version skew, and trailing garbage are all loud [`WireError`]s.
    pub(crate) fn decode(bytes: &[u8]) -> Result<Frame, WireError> {
        let mut r = WireReader::new(bytes);
        let tag = r.u8()?;
        let frame = match tag {
            TAG_HELLO => {
                let proto = r.u8()?;
                if proto != PROTO_VERSION {
                    return Err(WireError {
                        pos: 1,
                        msg: format!(
                            "peer speaks protocol v{proto}, this build \
                             speaks v{PROTO_VERSION}"
                        ),
                    });
                }
                let ord = r.u64()?;
                let v_i = r.u64()?;
                let v_u = r.u64()?;
                let kill_at_seq = read_opt_u64(&mut r)?;
                let kill_in_checkpoint = r.u8()? != 0;
                let cfg = decode_config(&mut r)?;
                Frame::Hello(Box::new(Hello {
                    ord,
                    v_i,
                    v_u,
                    kill_at_seq,
                    kill_in_checkpoint,
                    cfg,
                }))
            }
            TAG_EVENTS => {
                let n = r.u32()? as usize;
                let mut envs =
                    Vec::with_capacity(n.min(r.remaining() / 36 + 1));
                for _ in 0..n {
                    let seq = r.u64()?;
                    let user = r.u64()?;
                    let item = r.u64()?;
                    let rating = r.f32()?;
                    let ts = r.u64()?;
                    envs.push(Envelope {
                        seq,
                        rating: Rating::new(user, item, rating, ts),
                    });
                }
                Frame::Events(envs)
            }
            TAG_QUERY => Frame::Query {
                req_id: r.u64()?,
                user: r.u64()?,
                n: r.u64()?,
                fence: r.u64()?,
            },
            TAG_SNAPSHOT => Frame::Snapshot { req_id: r.u64()? },
            TAG_EXPORT => Frame::Export { req_id: r.u64()? },
            TAG_IMPORT => Frame::Import {
                lane: r.u64()?,
                restore_counters: r.u8()? != 0,
                bytes: r.byte_slice()?,
            },
            TAG_CLOSE => Frame::Close,
            TAG_PING => Frame::Ping { nonce: r.u64()? },
            TAG_PONG => Frame::Pong { nonce: r.u64()? },
            TAG_ANSWER => {
                let req_id = r.u64()?;
                let n = r.u32()? as usize;
                let mut lists = Vec::with_capacity(n.min(r.remaining()));
                for _ in 0..n {
                    lists.push(r.u64_slice()?);
                }
                let rated = r.u64_slice()?;
                Frame::Answer {
                    req_id,
                    answer: ReplicaAnswer { lists, rated },
                }
            }
            TAG_SNAPSHOT_REPLY => Frame::SnapshotReply {
                req_id: r.u64()?,
                snap: WorkerSnapshot {
                    worker_id: r.u64()? as usize,
                    processed: r.u64()?,
                    hits: r.u64()?,
                    queries: r.u64()?,
                    lanes: r.u64()?,
                    state: decode_state(&mut r)?,
                    state_bytes: r.u64()?,
                    spilled_lanes: r.u64()?,
                    spilled_bytes: r.u64()?,
                    spills: r.u64()?,
                    spill_faultins: r.u64()?,
                },
            },
            TAG_EXPORT_REPLY => {
                let req_id = r.u64()?;
                let ord = r.u64()? as usize;
                let n = r.u32()? as usize;
                let mut lanes = Vec::with_capacity(n.min(r.remaining()));
                for _ in 0..n {
                    lanes.push(LaneSnapshot {
                        lane: r.u64()?,
                        bytes: r.byte_slice()?,
                    });
                }
                Frame::ExportReply {
                    req_id,
                    export: WorkerExport { ord, lanes },
                }
            }
            TAG_HITS => {
                let n = r.u32()? as usize;
                let mut samples =
                    Vec::with_capacity(n.min(r.remaining() / 9 + 1));
                for _ in 0..n {
                    samples.push(HitSample {
                        seq: r.u64()?,
                        hit: r.u8()? != 0,
                    });
                }
                Frame::Hits(samples)
            }
            TAG_DONE => Frame::Done { worker_id: r.u64()? },
            TAG_CHECKPOINT => Frame::Checkpoint {
                ord: r.u64()?,
                lane: r.u64()?,
                bytes: r.byte_slice()?,
            },
            TAG_REPORT => Frame::Report(Box::new(decode_report(&mut r)?)),
            other => {
                return Err(WireError {
                    pos: 0,
                    msg: format!("unknown frame tag {other}"),
                })
            }
        };
        if !r.is_done() {
            return Err(WireError {
                pos: bytes.len() - r.remaining(),
                msg: format!(
                    "{} trailing bytes after frame tag {tag}",
                    r.remaining()
                ),
            });
        }
        Ok(frame)
    }
}

/// Write one length-prefixed frame, building it in the caller-owned
/// `buf` (cleared, allocation recycled) — a connection's steady-state
/// event path allocates nothing per frame. The prefix and body go out
/// in a single `write_all` so a frame is never interleaved with
/// another writer's bytes (each connection has exactly one writer
/// thread), and the wire bytes are exactly
/// `(body.len() as u32).to_le_bytes() ++ frame.encode()` — the prefix
/// is written as a placeholder and patched once the body length is
/// known.
pub(crate) fn write_frame_into(
    w: &mut impl Write,
    frame: &Frame,
    buf: &mut Vec<u8>,
) -> std::io::Result<()> {
    let mut ww = WireWriter::from_vec(std::mem::take(buf));
    ww.reserve(4 + frame.size_hint());
    ww.u32(0); // length placeholder
    frame.encode_into(&mut ww);
    let mut out = ww.into_bytes();
    let body_len = (out.len() - 4) as u32;
    out[..4].copy_from_slice(&body_len.to_le_bytes());
    let res = w.write_all(&out);
    *buf = out;
    res
}

/// Read one length-prefixed frame. `Ok(None)` is a clean end-of-stream
/// (EOF exactly at a frame boundary); EOF anywhere inside a frame, a
/// length prefix over [`MAX_FRAME`], and any decode failure are errors.
pub(crate) fn read_frame(
    r: &mut impl Read,
) -> std::io::Result<Option<Frame>> {
    // Probe one byte so a clean hangup between frames is Ok(None)
    // rather than an UnexpectedEof error.
    let mut first = [0u8; 1];
    loop {
        match r.read(&mut first) {
            Ok(0) => return Ok(None),
            Ok(_) => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    let mut rest = [0u8; 3];
    r.read_exact(&mut rest)?;
    let len =
        u32::from_le_bytes([first[0], rest[0], rest[1], rest[2]]) as usize;
    if len > MAX_FRAME {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame of {len} bytes exceeds cap {MAX_FRAME}"),
        ));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Frame::decode(&body).map(Some).map_err(|e| {
        std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
    })
}

fn opt_u64(w: &mut WireWriter, v: Option<u64>) {
    w.u8(u8::from(v.is_some()));
    w.u64(v.unwrap_or(0));
}

fn read_opt_u64(r: &mut WireReader<'_>) -> Result<Option<u64>, WireError> {
    let has = r.u8()? != 0;
    let raw = r.u64()?;
    Ok(has.then_some(raw))
}

fn encode_state(w: &mut WireWriter, s: &StateSizes) {
    w.u64(s.users);
    w.u64(s.items);
    w.u64(s.aux);
}

fn decode_state(r: &mut WireReader<'_>) -> Result<StateSizes, WireError> {
    Ok(StateSizes { users: r.u64()?, items: r.u64()?, aux: r.u64()? })
}

/// Serialize the complete [`RunConfig`] — the remote host must build
/// models, clocks, and channels from *exactly* the coordinator's
/// configuration or the byte-identical-across-transports property
/// breaks.
fn encode_config(w: &mut WireWriter, cfg: &RunConfig) {
    w.u8(match cfg.algorithm {
        Algorithm::Isgd => 0,
        Algorithm::Cosine => 1,
    });
    w.u8(match cfg.backend {
        Backend::Native => 0,
        Backend::Pjrt => 1,
    });
    w.u64(cfg.topology.n_i);
    w.u64(cfg.topology.w);
    match cfg.forgetting {
        Forgetting::None => {
            w.u8(0);
            w.u64(0);
            w.u64(0);
        }
        Forgetting::Lru { trigger_secs, max_idle_secs } => {
            w.u8(1);
            w.u64(trigger_secs);
            w.u64(max_idle_secs);
        }
        Forgetting::Lfu { trigger_events, min_freq } => {
            w.u8(2);
            w.u64(trigger_events);
            w.u64(min_freq);
        }
        Forgetting::Decay { trigger_events, factor } => {
            w.u8(3);
            w.u64(trigger_events);
            w.u64(factor.to_bits() as u64);
        }
    }
    w.u64(cfg.top_n as u64);
    w.u64(cfg.recall_window as u64);
    w.u64(cfg.latent_k as u64);
    w.f32(cfg.eta);
    w.f32(cfg.lambda);
    w.u64(cfg.neighbors_k as u64);
    w.u8(u8::from(cfg.cosine_strict));
    w.u64(cfg.channel_capacity as u64);
    w.u64(cfg.ingest_batch_size as u64);
    w.u64(cfg.sample_every as u64);
    w.u64(cfg.seed);
    w.string(&cfg.artifacts_dir);
    w.u64(cfg.rescale_max_n_i);
    w.u64(cfg.rescale_max_w);
    w.u64(cfg.fault_checkpoint_interval);
    w.u64(cfg.fault_replay_log_capacity as u64);
    opt_u64(w, cfg.fault_chaos_kill_seq);
    w.u8(u8::from(cfg.fault_chaos_kill_in_checkpoint));
    w.u32(cfg.cluster_workers.len() as u32);
    for entry in &cfg.cluster_workers {
        w.string(entry);
    }
    w.u32(cfg.fault_dial_retries);
    w.u64(cfg.fault_dial_backoff_ms);
    w.u64(cfg.fault_rpc_timeout_ms);
    w.u64(cfg.fault_heartbeat_interval_ms);
    w.u64(cfg.fault_net.seed);
    w.u64(cfg.fault_net.delay_ms_max);
    w.u64(cfg.fault_net.sever_connections);
    w.u64(cfg.fault_net.sever_after_frames);
    w.u8(u8::from(cfg.fault_net.mid_frame_cut));
    w.u32(cfg.fault_net.refuse_dials);
    w.u64(cfg.serving_queue_capacity as u64);
    w.u64(cfg.serving_max_in_flight as u64);
    w.u64(cfg.serving_cache_shards as u64);
    w.u64(cfg.serving_cache_max_staleness);
    w.u64(cfg.memory_budget_bytes);
    w.u8(u8::from(cfg.memory_spill));
    w.string(&cfg.memory_spill_dir);
    w.u64(cfg.memory_check_events);
}

fn decode_config(r: &mut WireReader<'_>) -> Result<RunConfig, WireError> {
    let bad = |pos: usize, msg: String| WireError { pos, msg };
    let algorithm = match r.u8()? {
        0 => Algorithm::Isgd,
        1 => Algorithm::Cosine,
        t => return Err(bad(0, format!("unknown algorithm tag {t}"))),
    };
    let backend = match r.u8()? {
        0 => Backend::Native,
        1 => Backend::Pjrt,
        t => return Err(bad(0, format!("unknown backend tag {t}"))),
    };
    let n_i = r.u64()?;
    let w_spares = r.u64()?;
    let topology = Topology::new(n_i, w_spares)
        .map_err(|e| bad(0, format!("bad topology: {e}")))?;
    let forget_tag = r.u8()?;
    let a = r.u64()?;
    let b = r.u64()?;
    let forgetting = match forget_tag {
        0 => Forgetting::None,
        1 => Forgetting::Lru { trigger_secs: a, max_idle_secs: b },
        2 => Forgetting::Lfu { trigger_events: a, min_freq: b },
        3 => Forgetting::Decay {
            trigger_events: a,
            factor: f32::from_bits(b as u32),
        },
        t => return Err(bad(0, format!("unknown forgetting tag {t}"))),
    };
    let top_n = r.u64()? as usize;
    let recall_window = r.u64()? as usize;
    let latent_k = r.u64()? as usize;
    let eta = r.f32()?;
    let lambda = r.f32()?;
    let neighbors_k = r.u64()? as usize;
    let cosine_strict = r.u8()? != 0;
    let channel_capacity = r.u64()? as usize;
    let ingest_batch_size = r.u64()? as usize;
    let sample_every = r.u64()? as usize;
    let seed = r.u64()?;
    let artifacts_dir = r.string()?;
    let rescale_max_n_i = r.u64()?;
    let rescale_max_w = r.u64()?;
    let fault_checkpoint_interval = r.u64()?;
    let fault_replay_log_capacity = r.u64()? as usize;
    let fault_chaos_kill_seq = read_opt_u64(r)?;
    let fault_chaos_kill_in_checkpoint = r.u8()? != 0;
    let n_workers = r.u32()? as usize;
    let mut cluster_workers =
        Vec::with_capacity(n_workers.min(r.remaining()));
    for _ in 0..n_workers {
        cluster_workers.push(r.string()?);
    }
    let fault_dial_retries = r.u32()?;
    let fault_dial_backoff_ms = r.u64()?;
    let fault_rpc_timeout_ms = r.u64()?;
    let fault_heartbeat_interval_ms = r.u64()?;
    let fault_net = NetFaultConfig {
        seed: r.u64()?,
        delay_ms_max: r.u64()?,
        sever_connections: r.u64()?,
        sever_after_frames: r.u64()?,
        mid_frame_cut: r.u8()? != 0,
        refuse_dials: r.u32()?,
    };
    let serving_queue_capacity = r.u64()? as usize;
    let serving_max_in_flight = r.u64()? as usize;
    let serving_cache_shards = r.u64()? as usize;
    let serving_cache_max_staleness = r.u64()?;
    let memory_budget_bytes = r.u64()?;
    let memory_spill = r.u8()? != 0;
    let memory_spill_dir = r.string()?;
    let memory_check_events = r.u64()?;
    Ok(RunConfig {
        algorithm,
        backend,
        topology,
        forgetting,
        top_n,
        recall_window,
        latent_k,
        eta,
        lambda,
        neighbors_k,
        cosine_strict,
        channel_capacity,
        ingest_batch_size,
        sample_every,
        seed,
        artifacts_dir,
        rescale_max_n_i,
        rescale_max_w,
        fault_checkpoint_interval,
        fault_replay_log_capacity,
        fault_chaos_kill_seq,
        fault_chaos_kill_in_checkpoint,
        cluster_workers,
        fault_dial_retries,
        fault_dial_backoff_ms,
        fault_rpc_timeout_ms,
        fault_heartbeat_interval_ms,
        fault_net,
        serving_queue_capacity,
        serving_max_in_flight,
        serving_cache_shards,
        serving_cache_max_staleness,
        memory_budget_bytes,
        memory_spill,
        memory_spill_dir,
        memory_check_events,
    })
}

fn encode_report(w: &mut WireWriter, rep: &WorkerReport) {
    w.u64(rep.worker_id as u64);
    w.u64(rep.processed);
    w.u64(rep.hits);
    w.u64(rep.queries);
    encode_state(w, &rep.state);
    rep.latency.wire_encode(w);
    w.u64(rep.sweeps);
    w.u64(rep.evicted);
    w.u64(rep.recommend_ns);
    w.u64(rep.update_ns);
    w.u32(rep.windows.len() as u32);
    for win in &rep.windows {
        w.u64(win.index);
        w.u64(win.start_seq);
        w.u64(win.events);
        w.u64(win.hits);
    }
    w.u64(rep.state_bytes);
    w.u64(rep.spills);
    w.u64(rep.spill_faultins);
}

fn decode_report(
    r: &mut WireReader<'_>,
) -> Result<WorkerReport, WireError> {
    let worker_id = r.u64()? as usize;
    let processed = r.u64()?;
    let hits = r.u64()?;
    let queries = r.u64()?;
    let state = decode_state(r)?;
    let latency = Histogram::wire_decode(r)?;
    let sweeps = r.u64()?;
    let evicted = r.u64()?;
    let recommend_ns = r.u64()?;
    let update_ns = r.u64()?;
    let n = r.u32()? as usize;
    let mut windows = Vec::with_capacity(n.min(r.remaining() / 32 + 1));
    for _ in 0..n {
        windows.push(WindowStat {
            index: r.u64()?,
            start_seq: r.u64()?,
            events: r.u64()?,
            hits: r.u64()?,
        });
    }
    let state_bytes = r.u64()?;
    let spills = r.u64()?;
    let spill_faultins = r.u64()?;
    Ok(WorkerReport {
        worker_id,
        processed,
        hits,
        queries,
        state,
        state_bytes,
        latency,
        sweeps,
        evicted,
        spills,
        spill_faultins,
        recommend_ns,
        update_ns,
        windows,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    /// Round-trip oracle that sidesteps `PartialEq` (WorkerReport holds
    /// a Histogram): decode then re-encode must reproduce the bytes.
    fn assert_round_trips(frame: &Frame) {
        let bytes = frame.encode();
        let back = Frame::decode(&bytes).unwrap_or_else(|e| {
            panic!("decode failed: {e} (frame of {} bytes)", bytes.len())
        });
        assert_eq!(back.encode(), bytes, "decode→encode is identity");
    }

    /// Every strict prefix of an encoded frame must decode to an error.
    fn assert_prefixes_error(frame: &Frame) {
        let bytes = frame.encode();
        for cut in 0..bytes.len() {
            assert!(
                Frame::decode(&bytes[..cut]).is_err(),
                "prefix of {cut}/{} bytes must error",
                bytes.len()
            );
        }
    }

    fn sample_report() -> WorkerReport {
        let mut latency = Histogram::new();
        for v in [13u64, 999, 100_000] {
            latency.record(v);
        }
        WorkerReport {
            worker_id: 6,
            processed: 4096,
            hits: 17,
            queries: 3,
            state: StateSizes { users: 5, items: 9, aux: 2 },
            state_bytes: 7_777,
            latency,
            sweeps: 1,
            evicted: 40,
            spills: 3,
            spill_faultins: 2,
            recommend_ns: 123_456,
            update_ns: 654_321,
            windows: vec![
                WindowStat {
                    index: 0,
                    start_seq: 0,
                    events: 5000,
                    hits: 12,
                },
                WindowStat {
                    index: 1,
                    start_seq: 5000,
                    events: 96,
                    hits: 5,
                },
            ],
        }
    }

    fn every_variant() -> Vec<Frame> {
        let cfg = RunConfig {
            forgetting: Forgetting::Decay {
                trigger_events: 100,
                factor: 0.875,
            },
            fault_chaos_kill_seq: Some(777),
            cluster_workers: vec![
                "local".to_string(),
                "tcp://127.0.0.1:7461".to_string(),
            ],
            fault_dial_retries: 6,
            fault_rpc_timeout_ms: 1234,
            fault_net: NetFaultConfig {
                seed: 5,
                delay_ms_max: 2,
                sever_connections: 1,
                sever_after_frames: 30,
                mid_frame_cut: true,
                refuse_dials: 2,
            },
            serving_queue_capacity: 77,
            serving_max_in_flight: 33,
            serving_cache_shards: 8,
            serving_cache_max_staleness: 12,
            memory_budget_bytes: 1 << 20,
            memory_spill: false,
            memory_spill_dir: "/tmp/spill".to_string(),
            memory_check_events: 32,
            ..RunConfig::default()
        };
        vec![
            Frame::Hello(Box::new(Hello {
                ord: 3,
                v_i: 4,
                v_u: 4,
                kill_at_seq: Some(99),
                kill_in_checkpoint: true,
                cfg,
            })),
            Frame::Events(vec![
                Envelope { seq: 0, rating: Rating::new(1, 2, 5.0, 10) },
                Envelope {
                    seq: u64::MAX,
                    rating: Rating::new(7, 8, -0.0, 0),
                },
            ]),
            Frame::Events(Vec::new()),
            Frame::Query { req_id: 42, user: 17, n: 10, fence: 5000 },
            Frame::Snapshot { req_id: 43 },
            Frame::Export { req_id: 44 },
            Frame::Import {
                lane: 5,
                restore_counters: true,
                bytes: vec![1, 2, 3],
            },
            Frame::Close,
            Frame::Answer {
                req_id: 42,
                answer: ReplicaAnswer {
                    lists: vec![vec![9, 8, 7], vec![], vec![1]],
                    rated: vec![2, 4],
                },
            },
            Frame::SnapshotReply {
                req_id: 43,
                snap: WorkerSnapshot {
                    worker_id: 3,
                    processed: 100,
                    hits: 4,
                    queries: 2,
                    lanes: 1,
                    state: StateSizes { users: 10, items: 20, aux: 0 },
                    state_bytes: 2048,
                    spilled_lanes: 1,
                    spilled_bytes: 512,
                    spills: 2,
                    spill_faultins: 1,
                },
            },
            Frame::ExportReply {
                req_id: 44,
                export: WorkerExport {
                    ord: 3,
                    lanes: vec![
                        LaneSnapshot { lane: 0, bytes: vec![1; 50] },
                        LaneSnapshot { lane: 9, bytes: Vec::new() },
                    ],
                },
            },
            Frame::Hits(vec![
                HitSample { seq: 1, hit: true },
                HitSample { seq: 2, hit: false },
            ]),
            Frame::Done { worker_id: 3 },
            Frame::Checkpoint { ord: 3, lane: 7, bytes: vec![4; 60] },
            Frame::Report(Box::new(sample_report())),
            Frame::Ping { nonce: 77 },
            Frame::Pong { nonce: 77 },
        ]
    }

    #[test]
    fn every_frame_round_trips() {
        for frame in every_variant() {
            assert_round_trips(&frame);
        }
    }

    #[test]
    fn every_frame_rejects_every_strict_prefix() {
        for frame in every_variant() {
            assert_prefixes_error(&frame);
        }
    }

    #[test]
    fn trailing_garbage_and_unknown_tags_error() {
        let mut bytes = Frame::Close.encode();
        bytes.push(0);
        let err = Frame::decode(&bytes).unwrap_err();
        assert!(err.to_string().contains("trailing"), "{err}");
        assert!(Frame::decode(&[0]).is_err(), "tag 0 is unassigned");
        assert!(Frame::decode(&[200]).is_err(), "tag 200 is unassigned");
        assert!(Frame::decode(&[]).is_err(), "empty body");
    }

    #[test]
    fn hello_version_skew_is_loud() {
        let frame = &every_variant()[0];
        let mut bytes = frame.encode();
        bytes[1] = PROTO_VERSION + 1;
        let err = Frame::decode(&bytes).unwrap_err();
        assert!(err.to_string().contains("protocol"), "{err}");
    }

    #[test]
    fn config_round_trip_covers_every_forgetting_kind() {
        for forgetting in [
            Forgetting::None,
            Forgetting::Lru { trigger_secs: 60, max_idle_secs: 3600 },
            Forgetting::Lfu { trigger_events: 10, min_freq: 2 },
            Forgetting::Decay { trigger_events: 7, factor: 0.5 },
        ] {
            let cfg = RunConfig {
                forgetting,
                memory_budget_bytes: 9999,
                memory_spill: false,
                memory_spill_dir: "spill".to_string(),
                memory_check_events: 7,
                ..RunConfig::default()
            };
            let mut w = WireWriter::new();
            encode_config(&mut w, &cfg);
            let bytes = w.into_bytes();
            let mut r = WireReader::new(&bytes);
            let back = decode_config(&mut r).unwrap();
            assert!(r.is_done());
            assert_eq!(back.forgetting, cfg.forgetting);
            assert_eq!(back.algorithm, cfg.algorithm);
            assert_eq!(back.seed, cfg.seed);
            assert_eq!(back.artifacts_dir, cfg.artifacts_dir);
            assert_eq!(back.fault_dial_retries, cfg.fault_dial_retries);
            assert_eq!(back.fault_net, cfg.fault_net);
            assert_eq!(
                back.serving_queue_capacity,
                cfg.serving_queue_capacity
            );
            assert_eq!(back.serving_max_in_flight, cfg.serving_max_in_flight);
            assert_eq!(back.serving_cache_shards, cfg.serving_cache_shards);
            assert_eq!(
                back.serving_cache_max_staleness,
                cfg.serving_cache_max_staleness
            );
            assert_eq!(back.memory_budget_bytes, cfg.memory_budget_bytes);
            assert_eq!(back.memory_spill, cfg.memory_spill);
            assert_eq!(back.memory_spill_dir, cfg.memory_spill_dir);
            assert_eq!(back.memory_check_events, cfg.memory_check_events);
        }
    }

    #[test]
    fn property_decode_is_total_on_hostile_bytes() {
        // The decoder must be total: arbitrary byte soup, bit-flipped
        // real frames, and truncations may only ever yield Ok or a
        // WireError — never a panic, never an attempt to allocate more
        // than the received bytes warrant.
        forall("net_decode_total", 24, |rng| {
            let soup: Vec<u8> = (0..rng.next_bounded(512))
                .map(|_| rng.next_u32() as u8)
                .collect();
            let _ = Frame::decode(&soup);
            let variants = every_variant();
            let pick =
                rng.next_bounded(variants.len() as u64) as usize;
            let mut bytes = variants[pick].encode();
            if !bytes.is_empty() {
                let flips = 1 + rng.next_bounded(8) as usize;
                for _ in 0..flips {
                    let at = rng.next_bounded(bytes.len() as u64) as usize;
                    bytes[at] ^= 1 << rng.next_bounded(8);
                }
                let _ = Frame::decode(&bytes);
                let cut = rng.next_bounded(bytes.len() as u64) as usize;
                let _ = Frame::decode(&bytes[..cut]);
            }
        });
    }

    #[test]
    fn oversized_frame_is_rejected_before_allocation() {
        // Both ends read frames through the same `read_frame`, so this
        // covers the coordinator proxy and the worker host alike: a
        // length prefix over the 1 GiB cap errors out immediately —
        // the body is never allocated (the cursor holds only 4 bytes).
        for len in [(MAX_FRAME + 1) as u32, u32::MAX] {
            let prefix = len.to_le_bytes();
            let mut cursor = std::io::Cursor::new(&prefix[..]);
            let err = read_frame(&mut cursor).unwrap_err();
            assert!(
                err.to_string().contains("exceeds cap"),
                "want loud cap rejection, got: {err}"
            );
        }
    }

    #[test]
    fn property_random_frames_round_trip_and_reject_prefixes() {
        forall("net_frame_roundtrip", 12, |rng| {
            let n = rng.next_bounded(32) as usize;
            let envs: Vec<Envelope> = (0..n)
                .map(|_| Envelope {
                    seq: rng.next_u64(),
                    rating: Rating::new(
                        rng.next_u64(),
                        rng.next_u64(),
                        rng.next_f32(),
                        rng.next_u64(),
                    ),
                })
                .collect();
            let samples: Vec<HitSample> = (0..rng.next_bounded(64))
                .map(|_| HitSample {
                    seq: rng.next_u64(),
                    hit: rng.next_bounded(2) == 1,
                })
                .collect();
            let ckpt = Frame::Checkpoint {
                ord: rng.next_u64(),
                lane: rng.next_u64(),
                bytes: (0..rng.next_bounded(48))
                    .map(|_| rng.next_u32() as u8)
                    .collect(),
            };
            for frame in
                [Frame::Events(envs), Frame::Hits(samples), ckpt]
            {
                assert_round_trips(&frame);
                assert_prefixes_error(&frame);
            }
        });
    }

    #[test]
    fn reused_write_buffer_is_byte_identical_to_fresh_writes() {
        // One recycled buffer across every variant (small frames after
        // big ones included) must put the exact same bytes on the wire
        // as a fresh buffer per frame, and both must equal the documented
        // layout: le length prefix ++ Frame::encode().
        let mut reused = Vec::new();
        let mut buf = Vec::new();
        for frame in every_variant() {
            write_frame_into(&mut reused, &frame, &mut buf).unwrap();
        }
        let mut fresh = Vec::new();
        for frame in every_variant() {
            write_frame_into(&mut fresh, &frame, &mut Vec::new()).unwrap();
        }
        assert_eq!(reused, fresh);
        let mut manual = Vec::new();
        for frame in every_variant() {
            let body = frame.encode();
            manual.extend_from_slice(&(body.len() as u32).to_le_bytes());
            manual.extend_from_slice(&body);
        }
        assert_eq!(reused, manual);
    }

    #[test]
    fn stream_read_write_round_trips_and_ends_cleanly() {
        let mut buf = Vec::new();
        let mut scratch = Vec::new();
        for frame in every_variant() {
            write_frame_into(&mut buf, &frame, &mut scratch).unwrap();
        }
        let mut cursor = std::io::Cursor::new(&buf[..]);
        let mut n = 0;
        while let Some(frame) = read_frame(&mut cursor).unwrap() {
            assert_round_trips(&frame);
            n += 1;
        }
        assert_eq!(n, every_variant().len());
        // EOF inside a frame is an error, not a silent None.
        let mut cursor = std::io::Cursor::new(&buf[..buf.len() - 1]);
        loop {
            match read_frame(&mut cursor) {
                Ok(Some(_)) => continue,
                Ok(None) => panic!("truncated tail frame must error"),
                Err(_) => break,
            }
        }
        // A hostile length prefix over the cap fails fast.
        let huge = ((MAX_FRAME + 1) as u32).to_le_bytes();
        let mut cursor = std::io::Cursor::new(&huge[..]);
        assert!(read_frame(&mut cursor).is_err());
    }
}
