//! Coordinator-side proxy for a remote worker: the thread that stands
//! where an in-process [`WorkerActor`](crate::engine::actor::WorkerActor)
//! would, speaking [`WorkerMsg`] on one side and the frame protocol
//! ([`net::proto`](crate::net::proto)) on the other.
//!
//! # Shape
//!
//! The proxy thread dials the host, sends the hello frame, then becomes
//! the connection's single *writer*: it drains its `WorkerMsg` FIFO,
//! batches consecutive events into one `Events` frame, and forwards
//! control messages — flushing buffered events first, so the socket
//! carries exactly the FIFO order the in-proc actor would have seen. A
//! companion *reader* thread dispatches inbound frames: RPC replies
//! resolve through a request-id multiplexer back to the parked reply
//! `Sender`s, hit batches and `Done` markers go to the collector, and
//! checkpoints are forwarded with the same non-blocking `try_send`
//! contract the in-proc actor has (a full channel drops the frame; a
//! fresher one always follows — blocking here would deadlock against a
//! coordinator that is itself blocked sending events to this proxy).
//!
//! # Failure model
//!
//! Any connection loss — dial failure, write error, EOF before the
//! final `Report` frame — makes the proxy **panic**, exactly like a
//! crashed in-proc worker. That is deliberate: the supervisor's two
//! crash-detection paths (failed channel send and join-time panic) then
//! work unchanged, and its recovery (respawn the slot → this transport
//! re-dials → restore checkpoints → replay) is transport-agnostic.
//! Before panicking the proxy clears the reply multiplexer (dropping
//! the parked senders, so a coordinator blocked on a reply wakes with
//! "sender gone" — the same degradation as a dead local worker) and
//! shuts the socket down so the reader thread cannot stay blocked.

use std::collections::HashMap;
use std::net::{Shutdown, TcpStream};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::engine::actor::{
    ChaosPolicy, CollectorMsg, Envelope, ReplicaAnswer, WorkerExport,
    WorkerMsg,
};
use crate::engine::{Sender, WorkerSnapshot};
use crate::eval::WorkerReport;
use crate::net::proto::{read_frame, write_frame, Frame, Hello};
use crate::net::WorkerBoot;

/// A parked reply sender, keyed by request id in the multiplexer.
enum Pending {
    Query(Sender<ReplicaAnswer>),
    Snapshot(Sender<WorkerSnapshot>),
    Export(Sender<WorkerExport>),
}

type Mux = Arc<Mutex<HashMap<u64, Pending>>>;

/// Run the proxy for one worker slot until the coordinator hangs up
/// (normal end of session / retire) or the actor exports. Panics on
/// connection loss — see the module docs for why that is the contract.
pub(crate) fn run_proxy(addr: &str, boot: WorkerBoot) -> Result<WorkerReport> {
    let WorkerBoot { ord, cfg, grid, rx, col_tx, ckpt_tx, chaos } = boot;
    let mut stream = match TcpStream::connect(addr) {
        Ok(s) => s,
        Err(e) => lost(ord, addr, &format!("dial failed: {e}")),
    };
    // Event batches are already coalesced; don't let Nagle delay the
    // small RPC frames behind them.
    let _ = stream.set_nodelay(true);

    let hello = Frame::Hello(Box::new(Hello {
        ord: ord as u64,
        v_i: grid.v_i(),
        v_u: grid.v_u(),
        kill_at_seq: chaos.kill_at_seq(),
        kill_in_checkpoint: chaos.kill_in_checkpoint(),
        cfg,
    }));
    if let Err(e) = write_frame(&mut stream, &hello) {
        lost(ord, addr, &format!("hello failed: {e}"));
    }

    let mux: Mux = Arc::new(Mutex::new(HashMap::new()));
    let report: Arc<Mutex<Option<WorkerReport>>> = Arc::new(Mutex::new(None));
    let reader = {
        let stream = match stream.try_clone() {
            Ok(s) => s,
            Err(e) => lost(ord, addr, &format!("clone failed: {e}")),
        };
        let mux = Arc::clone(&mux);
        let report = Arc::clone(&report);
        let col_tx = col_tx.clone();
        std::thread::Builder::new()
            .name(format!("net-reader-{ord}"))
            .spawn(move || {
                read_loop(stream, &mux, &report, &col_tx, &ckpt_tx)
            })
            .expect("spawn net reader")
    };

    // Writer loop: drain the FIFO, batch events, forward control frames
    // in FIFO position. `send` returns the frame to flush *after* the
    // buffered events, preserving order on the socket.
    let mut next_req: u64 = 0;
    let mut inbox: Vec<WorkerMsg> = Vec::new();
    let mut events: Vec<Envelope> = Vec::new();
    let mut exported = false;
    'drain: while rx.recv_many(&mut inbox, usize::MAX) {
        for msg in inbox.drain(..) {
            let frame = match msg {
                WorkerMsg::Event(env) => {
                    events.push(env);
                    continue;
                }
                WorkerMsg::Query { user, n, reply } => {
                    let req_id = next_req;
                    next_req += 1;
                    park(&mux, req_id, Pending::Query(reply));
                    Frame::Query { req_id, user, n: n as u64 }
                }
                WorkerMsg::MetricsSnapshot { reply } => {
                    let req_id = next_req;
                    next_req += 1;
                    park(&mux, req_id, Pending::Snapshot(reply));
                    Frame::Snapshot { req_id }
                }
                WorkerMsg::Import { lane, bytes, restore_counters } => {
                    Frame::Import { lane, restore_counters, bytes }
                }
                WorkerMsg::Export { reply } => {
                    let req_id = next_req;
                    next_req += 1;
                    park(&mux, req_id, Pending::Export(reply));
                    if let Err(e) = flush_events(&mut stream, &mut events)
                        .and_then(|()| {
                            write_frame(&mut stream, &Frame::Export { req_id })
                        })
                    {
                        fail(&mux, &stream);
                        lost(ord, addr, &e);
                    }
                    // Export is terminal for the actor (in-proc parity:
                    // it breaks its drain loop, so later sends fail).
                    // Stop consuming the FIFO *now* — blocking in
                    // recv_many here would deadlock the coordinator's
                    // retire, which joins this thread before dropping
                    // the next generation's senders.
                    exported = true;
                    break 'drain;
                }
            };
            if let Err(e) = flush_events(&mut stream, &mut events)
                .and_then(|()| write_frame(&mut stream, &frame))
            {
                fail(&mux, &stream);
                lost(ord, addr, &e);
            }
        }
        if let Err(e) = flush_events(&mut stream, &mut events) {
            fail(&mux, &stream);
            lost(ord, addr, &e);
        }
    }
    drop(rx);
    if !exported {
        // Clean hangup: all coordinator senders gone. Tell the host to
        // drain and report.
        if let Err(e) = flush_events(&mut stream, &mut events)
            .and_then(|()| write_frame(&mut stream, &Frame::Close))
        {
            fail(&mux, &stream);
            lost(ord, addr, &e);
        }
    }

    // Wait for the reader: it exits after the host's final Report frame
    // (clean) or on EOF/error (crash). Keep `stream` alive until then —
    // dropping it would close the connection under the reader.
    let cause = reader
        .join()
        .unwrap_or_else(|_| Some("reader panicked".to_string()));
    let final_report = report.lock().expect("mux poisoned").take();
    drop(stream);
    match final_report {
        Some(rep) => Ok(rep),
        None => {
            let why = cause.unwrap_or_else(|| {
                "connection closed without a final report".to_string()
            });
            lost(ord, addr, &why)
        }
    }
}

/// Panic with the connection-loss cause — the supervisor treats this
/// exactly like a crashed in-proc worker (see the module docs).
fn lost(ord: usize, addr: &str, cause: &dyn std::fmt::Display) -> ! {
    panic!("worker {ord} lost connection to {addr}: {cause}")
}

fn park(mux: &Mux, req_id: u64, pending: Pending) {
    mux.lock().expect("mux poisoned").insert(req_id, pending);
}

/// Pre-panic cleanup on a write error: drop every parked reply sender
/// (a coordinator blocked on one wakes with "sender gone") and shut the
/// socket down so the reader thread cannot stay blocked mid-read.
fn fail(mux: &Mux, stream: &TcpStream) {
    mux.lock().expect("mux poisoned").clear();
    let _ = stream.shutdown(Shutdown::Both);
}

fn flush_events(
    stream: &mut TcpStream,
    events: &mut Vec<Envelope>,
) -> std::io::Result<()> {
    if events.is_empty() {
        return Ok(());
    }
    let frame = Frame::Events(std::mem::take(events));
    write_frame(stream, &frame)
}

/// Reader-thread body: dispatch inbound frames until the host hangs up.
/// Returns the abnormal-exit cause (`None` = clean EOF). Always clears
/// the multiplexer on the way out so no reply sender outlives the
/// connection.
fn read_loop(
    stream: TcpStream,
    mux: &Mux,
    report: &Arc<Mutex<Option<WorkerReport>>>,
    col_tx: &Sender<CollectorMsg>,
    ckpt_tx: &Option<Sender<crate::engine::actor::CheckpointMsg>>,
) -> Option<String> {
    let mut reader = std::io::BufReader::new(stream);
    let cause = loop {
        match read_frame(&mut reader) {
            Ok(None) => break None,
            Err(e) => break Some(e.to_string()),
            Ok(Some(frame)) => match frame {
                Frame::Answer { req_id, answer } => {
                    match take(mux, req_id) {
                        Some(Pending::Query(tx)) => {
                            let _ = tx.send(answer);
                        }
                        _ => log::warn!("unmatched answer (req {req_id})"),
                    }
                }
                Frame::SnapshotReply { req_id, snap } => {
                    match take(mux, req_id) {
                        Some(Pending::Snapshot(tx)) => {
                            let _ = tx.send(snap);
                        }
                        _ => log::warn!("unmatched snapshot (req {req_id})"),
                    }
                }
                Frame::ExportReply { req_id, export } => {
                    match take(mux, req_id) {
                        Some(Pending::Export(tx)) => {
                            let _ = tx.send(export);
                        }
                        _ => log::warn!("unmatched export (req {req_id})"),
                    }
                }
                Frame::Hits(samples) => {
                    // Blocking is safe: the collector drains its channel
                    // unconditionally for the whole session.
                    let _ = col_tx.send(CollectorMsg::Hits(samples));
                }
                Frame::Done { worker_id } => {
                    let _ = col_tx.send(CollectorMsg::Done {
                        worker_id: worker_id as usize,
                    });
                }
                Frame::Checkpoint { ord, lane, bytes } => {
                    // Same contract as the in-proc actor: never block on
                    // a full checkpoint channel (the coordinator may be
                    // blocked sending events to this very proxy; waiting
                    // for it to drain checkpoints would deadlock the
                    // cycle). A dropped frame is always superseded by a
                    // fresher one.
                    if let Some(tx) = ckpt_tx {
                        let msg = crate::engine::actor::CheckpointMsg {
                            ord: ord as usize,
                            lane,
                            bytes,
                        };
                        let _ = tx.try_send(msg);
                    }
                }
                Frame::Report(rep) => {
                    *report.lock().expect("report poisoned") = Some(*rep);
                }
                _ => break Some("host sent a coordinator frame".into()),
            },
        }
    };
    mux.lock().expect("mux poisoned").clear();
    cause
}

fn take(mux: &Mux, req_id: u64) -> Option<Pending> {
    mux.lock().expect("mux poisoned").remove(&req_id)
}
