//! Coordinator-side proxy for a remote worker: the thread that stands
//! where an in-process [`WorkerActor`](crate::engine::actor::WorkerActor)
//! would, speaking [`WorkerMsg`] on one side and the frame protocol
//! ([`net::proto`](crate::net::proto)) on the other.
//!
//! # Shape
//!
//! The proxy thread dials the host (with bounded exponential backoff —
//! see [`chaos::dial_with_backoff`]), sends the hello frame, then
//! becomes the connection's single *writer* over the slot's two inputs:
//! each wakeup on the shared [`WakeSignal`] it first drains the
//! dedicated serving lane (`query_rx`) and writes each query as a
//! `Query` frame *immediately* — ahead of any buffered events, which is
//! the whole point of the lane; the frame carries the read-your-writes
//! fence, so the host parks it until the covered events (later on the
//! same socket, or already there) are applied — then drains the
//! `WorkerMsg` FIFO, batches consecutive events into one `Events`
//! frame, and forwards control messages, flushing buffered events
//! first, so event-FIFO traffic keeps exactly the order the in-proc
//! actor would have seen. A companion *reader* thread dispatches
//! inbound frames: RPC replies resolve through a request-id multiplexer
//! back to the parked reply `Sender`s, hit batches and `Done` markers
//! go to the collector, and checkpoints are forwarded with the same
//! non-blocking `try_send` contract the in-proc actor has (a full
//! channel drops the frame; a fresher one always follows — blocking
//! here would deadlock against a coordinator that is itself blocked
//! sending events to this proxy).
//!
//! # Failure model
//!
//! Any connection loss — exhausted dial retries, write error, EOF
//! before the final `Report` frame — makes the proxy **panic**, exactly
//! like a crashed in-proc worker. That is deliberate: the supervisor's
//! two crash-detection paths (failed channel send and join-time panic)
//! then work unchanged, and its recovery (respawn the slot → this
//! transport re-dials → restore checkpoints → replay) is
//! transport-agnostic. Before panicking the proxy clears the reply
//! multiplexer (dropping the parked senders, so a coordinator blocked
//! on a reply wakes with "sender gone" — the same degradation as a dead
//! local worker) and shuts the socket down so the reader thread cannot
//! stay blocked.
//!
//! A *hung* peer — socket open, nothing moving — is converted into the
//! same path by the writer-side watchdog: while `fault.rpc_timeout_ms`
//! is non-zero the writer wakes on a deadline even when the FIFO is
//! idle, fails the connection if the oldest parked RPC reply is overdue,
//! and (with `fault.heartbeat_interval_ms` armed) sends liveness
//! `Ping`s; a ping that stays unanswered past the RPC deadline with no
//! other inbound traffic declares the worker hung. The reader thread
//! never needs its own timeout: the watchdog's shutdown wakes it from
//! any blocking read. Both knobs at zero restore the pre-watchdog
//! blocking behavior exactly.

use std::collections::HashMap;
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::engine::actor::{
    CollectorMsg, Envelope, QueryMsg, ReplicaAnswer, WorkerExport, WorkerMsg,
};
use crate::engine::{Sender, WorkerSnapshot};
use crate::eval::WorkerReport;
use crate::net::chaos::{self, FrameChaos, NetFaultPlan, Side};
use crate::net::proto::{read_frame, Frame, Hello};
use crate::net::WorkerBoot;

/// A parked reply sender, keyed by request id in the multiplexer.
enum Pending {
    Query(Sender<ReplicaAnswer>),
    Snapshot(Sender<WorkerSnapshot>),
    Export(Sender<WorkerExport>),
}

/// A multiplexer entry: the parked sender plus when it was parked, so
/// the watchdog can age the oldest outstanding RPC.
struct Parked {
    since: Instant,
    pending: Pending,
}

type Mux = Arc<Mutex<HashMap<u64, Parked>>>;

/// Inbound-traffic clock shared between the reader thread (which stamps
/// it on every frame) and the writer-side watchdog (which ages it).
/// Milliseconds since proxy start, monotone, relaxed — the watchdog
/// only needs "roughly how stale", never ordering against other memory.
struct Health {
    start: Instant,
    last_rx_ms: AtomicU64,
}

impl Health {
    fn new() -> Health {
        Health { start: Instant::now(), last_rx_ms: AtomicU64::new(0) }
    }

    fn now_ms(&self) -> u64 {
        self.start.elapsed().as_millis() as u64
    }

    fn touch(&self) {
        self.last_rx_ms.store(self.now_ms(), Ordering::Relaxed);
    }

    fn last_rx_ms(&self) -> u64 {
        self.last_rx_ms.load(Ordering::Relaxed)
    }
}

/// Writer-side liveness state: RPC deadlines, ping cadence, and the
/// hung-worker verdict. One tick per writer wakeup.
struct Watchdog {
    rpc_timeout: Option<Duration>,
    heartbeat: Option<Duration>,
    health: Arc<Health>,
    next_ping_at: Instant,
    /// Send-time (health-clock ms) of the oldest unanswered ping.
    ping_outstanding_ms: Option<u64>,
    nonce: u64,
}

impl Watchdog {
    fn new(
        rpc_timeout_ms: u64,
        heartbeat_ms: u64,
        health: Arc<Health>,
    ) -> Watchdog {
        Watchdog {
            rpc_timeout: (rpc_timeout_ms > 0)
                .then(|| Duration::from_millis(rpc_timeout_ms)),
            heartbeat: (heartbeat_ms > 0)
                .then(|| Duration::from_millis(heartbeat_ms)),
            health,
            next_ping_at: Instant::now(),
            ping_outstanding_ms: None,
            nonce: 0,
        }
    }

    /// One watchdog pass. `Err` is the connection-loss cause — the
    /// caller fails the connection and panics with it. `allow_ping` is
    /// false once `Close`/`Export` went out: the host is draining and
    /// may hang up at any moment, so no new traffic is injected (an
    /// already-outstanding ping or parked RPC still ages normally).
    fn tick(
        &mut self,
        mux: &Mux,
        link: &mut FrameChaos,
        stream: &TcpStream,
        allow_ping: bool,
    ) -> std::result::Result<(), String> {
        let now = Instant::now();
        if let Some(limit) = self.rpc_timeout {
            let oldest = mux
                .lock()
                .expect("mux poisoned")
                .values()
                .map(|p| now.saturating_duration_since(p.since))
                .max();
            if let Some(age) = oldest {
                if age > limit {
                    return Err(format!(
                        "rpc deadline exceeded: a reply is {}ms \
                         overdue (fault.rpc_timeout_ms = {})",
                        age.as_millis(),
                        limit.as_millis()
                    ));
                }
            }
        }
        let last_rx = self.health.last_rx_ms();
        if let Some(sent) = self.ping_outstanding_ms {
            if last_rx >= sent {
                self.ping_outstanding_ms = None;
            } else if let Some(limit) = self.rpc_timeout {
                let silent = self.health.now_ms().saturating_sub(sent);
                if silent > limit.as_millis() as u64 {
                    return Err(format!(
                        "worker hung: liveness ping unanswered and no \
                         inbound traffic for {silent}ms \
                         (fault.rpc_timeout_ms = {})",
                        limit.as_millis()
                    ));
                }
            }
        }
        if allow_ping {
            if let Some(every) = self.heartbeat {
                if now >= self.next_ping_at {
                    let sent_ms = self.health.now_ms();
                    let frame = Frame::Ping { nonce: self.nonce };
                    self.nonce += 1;
                    link.write(stream, &frame, false).map_err(|e| {
                        format!("liveness ping failed: {e}")
                    })?;
                    if self.ping_outstanding_ms.is_none() {
                        self.ping_outstanding_ms = Some(sent_ms);
                    }
                    self.next_ping_at = now + every;
                }
            }
        }
        Ok(())
    }
}

/// Run the proxy for one worker slot until the coordinator hangs up
/// (normal end of session / retire) or the actor exports. Panics on
/// connection loss — see the module docs for why that is the contract.
pub(crate) fn run_proxy(addr: &str, boot: WorkerBoot) -> Result<WorkerReport> {
    let WorkerBoot {
        ord,
        cfg,
        grid,
        rx,
        query_rx,
        signal,
        col_tx,
        ckpt_tx,
        chaos,
    } = boot;
    let rpc_timeout_ms = cfg.fault_rpc_timeout_ms;
    let heartbeat_ms = cfg.fault_heartbeat_interval_ms;
    let fault = NetFaultPlan::from_config(&cfg)
        .map(|plan| plan.connection(ord as u64));
    let mut link = fault
        .as_ref()
        .map_or_else(FrameChaos::none, |f| {
            FrameChaos::armed(f, Side::Coordinator)
        });
    let stream = match chaos::dial_with_backoff(addr, ord as u64, &cfg) {
        Ok(s) => s,
        Err(e) => lost(ord, addr, &e),
    };
    // Event batches are already coalesced; don't let Nagle delay the
    // small RPC frames behind them.
    let _ = stream.set_nodelay(true);

    let hello = Frame::Hello(Box::new(Hello {
        ord: ord as u64,
        v_i: grid.v_i(),
        v_u: grid.v_u(),
        kill_at_seq: chaos.kill_at_seq(),
        kill_in_checkpoint: chaos.kill_in_checkpoint(),
        cfg,
    }));
    if let Err(e) = link.write(&stream, &hello, true) {
        lost(ord, addr, &format!("hello failed: {e}"));
    }

    let mux: Mux = Arc::new(Mutex::new(HashMap::new()));
    let report: Arc<Mutex<Option<WorkerReport>>> = Arc::new(Mutex::new(None));
    let health = Arc::new(Health::new());
    let reader = {
        let stream = match stream.try_clone() {
            Ok(s) => s,
            Err(e) => lost(ord, addr, &format!("clone failed: {e}")),
        };
        let mux = Arc::clone(&mux);
        let report = Arc::clone(&report);
        let health = Arc::clone(&health);
        let col_tx = col_tx.clone();
        std::thread::Builder::new()
            .name(format!("net-reader-{ord}"))
            .spawn(move || {
                read_loop(stream, &mux, &report, &health, &col_tx, &ckpt_tx)
            })
            .expect("spawn net reader")
    };

    // Watchdog cadence: the heartbeat interval when armed, otherwise a
    // quarter of the RPC deadline — frequent enough that a deadline is
    // never overshot by more than a tick. Both knobs zero = no ticking,
    // the writer blocks exactly as it did before the watchdog existed.
    let tick = if rpc_timeout_ms == 0 && heartbeat_ms == 0 {
        None
    } else {
        let ms = if heartbeat_ms > 0 {
            heartbeat_ms
        } else {
            (rpc_timeout_ms / 4).max(1)
        };
        Some(Duration::from_millis(ms))
    };
    let mut watchdog =
        Watchdog::new(rpc_timeout_ms, heartbeat_ms, Arc::clone(&health));

    // Writer loop: each WakeSignal wakeup drains the serving lane first
    // (queries go out immediately — the fence makes overtaking buffered
    // events safe), then the FIFO: batch events, forward control frames
    // in FIFO position (`flush_events` before each control frame
    // preserves event-FIFO order on the socket).
    const IDLE_WAIT: Duration = Duration::from_millis(10);
    let idle = tick.unwrap_or(IDLE_WAIT);
    let mut next_req: u64 = 0;
    let mut inbox: Vec<WorkerMsg> = Vec::new();
    let mut events: Vec<Envelope> = Vec::new();
    let mut qbuf: Vec<QueryMsg> = Vec::new();
    let mut exported = false;
    'drain: loop {
        // Epoch read BEFORE draining: anything arriving after it bumps
        // the epoch, so the idle wait below can never sleep through a
        // message (see `WakeSignal`).
        let seen = signal.epoch();
        let mut progress = query_rx.try_drain(&mut qbuf) > 0;
        for q in qbuf.drain(..) {
            let req_id = next_req;
            next_req += 1;
            park(&mux, req_id, Pending::Query(q.reply));
            let frame = Frame::Query {
                req_id,
                user: q.user,
                n: q.n as u64,
                fence: q.fence,
            };
            if let Err(e) = link.write(&stream, &frame, true) {
                fail(&mux, &stream);
                lost(ord, addr, &e);
            }
        }
        if rx.try_drain(&mut inbox) > 0 {
            progress = true;
        }
        for msg in inbox.drain(..) {
            let frame = match msg {
                WorkerMsg::Event(env) => {
                    events.push(env);
                    continue;
                }
                WorkerMsg::MetricsSnapshot { reply } => {
                    let req_id = next_req;
                    next_req += 1;
                    park(&mux, req_id, Pending::Snapshot(reply));
                    Frame::Snapshot { req_id }
                }
                WorkerMsg::Import { lane, bytes, restore_counters } => {
                    Frame::Import { lane, restore_counters, bytes }
                }
                WorkerMsg::Export { reply } => {
                    let req_id = next_req;
                    next_req += 1;
                    park(&mux, req_id, Pending::Export(reply));
                    if let Err(e) = flush_events(
                        &mut link,
                        &stream,
                        &mut events,
                    )
                    .and_then(|()| {
                        link.write(&stream, &Frame::Export { req_id }, true)
                    }) {
                        fail(&mux, &stream);
                        lost(ord, addr, &e);
                    }
                    // Export is terminal for the actor (in-proc parity:
                    // it breaks its drain loop, so later sends fail).
                    // Stop consuming the inputs *now* — waiting here
                    // would deadlock the coordinator's retire, which
                    // joins this thread before dropping the next
                    // generation's senders.
                    exported = true;
                    break 'drain;
                }
            };
            if let Err(e) = flush_events(&mut link, &stream, &mut events)
                .and_then(|()| link.write(&stream, &frame, true))
            {
                fail(&mux, &stream);
                lost(ord, addr, &e);
            }
        }
        if let Err(e) = flush_events(&mut link, &stream, &mut events) {
            fail(&mux, &stream);
            lost(ord, addr, &e);
        }
        if tick.is_some() {
            if let Err(cause) =
                watchdog.tick(&mux, &mut link, &stream, true)
            {
                fail(&mux, &stream);
                lost(ord, addr, &cause);
            }
        }
        if rx.is_ended() {
            // End of stream: every coordinator-side event sender is
            // gone (the serving plan drops its clone last, so no query
            // can still be en route behind this point).
            break 'drain;
        }
        if !progress {
            let t0 = Instant::now();
            signal.wait_past(seen, idle);
            rx.record_wait(t0.elapsed().as_nanos() as u64);
        }
    }
    // Closing the serving lane drops any still-queued QueryMsg (reply
    // senders with them): a fan-out blocked on this slot wakes with
    // "sender gone" and retries — same degradation as a dead in-proc
    // worker's parked queries.
    drop(query_rx);
    drop(rx);
    if !exported {
        // Clean hangup: all coordinator senders gone. Tell the host to
        // drain and report.
        if let Err(e) = flush_events(&mut link, &stream, &mut events)
            .and_then(|()| link.write(&stream, &Frame::Close, true))
        {
            fail(&mux, &stream);
            lost(ord, addr, &e);
        }
    }

    // Wait for the reader: it exits after the host's final Report frame
    // (clean) or on EOF/error (crash). Keep `stream` alive until then —
    // dropping it would close the connection under the reader. While a
    // watchdog is armed, keep ticking it (without new pings — the host
    // may hang up mid-drain) so an outstanding Export RPC or an already
    // unanswered ping still converts a hang into the crash path.
    if let Some(t) = tick {
        while !reader.is_finished() {
            if let Err(cause) =
                watchdog.tick(&mux, &mut link, &stream, false)
            {
                fail(&mux, &stream);
                lost(ord, addr, &cause);
            }
            std::thread::sleep(t);
        }
    }
    let cause = reader
        .join()
        .unwrap_or_else(|_| Some("reader panicked".to_string()));
    let final_report = report.lock().expect("mux poisoned").take();
    drop(stream);
    match final_report {
        Some(rep) => Ok(rep),
        None => {
            let why = cause.unwrap_or_else(|| {
                "connection closed without a final report".to_string()
            });
            lost(ord, addr, &why)
        }
    }
}

/// Panic with the connection-loss cause — the supervisor treats this
/// exactly like a crashed in-proc worker (see the module docs).
fn lost(ord: usize, addr: &str, cause: &dyn std::fmt::Display) -> ! {
    panic!("worker {ord} lost connection to {addr}: {cause}")
}

fn park(mux: &Mux, req_id: u64, pending: Pending) {
    mux.lock()
        .expect("mux poisoned")
        .insert(req_id, Parked { since: Instant::now(), pending });
}

/// Pre-panic cleanup on a write error: drop every parked reply sender
/// (a coordinator blocked on one wakes with "sender gone") and shut the
/// socket down so the reader thread cannot stay blocked mid-read.
fn fail(mux: &Mux, stream: &TcpStream) {
    mux.lock().expect("mux poisoned").clear();
    let _ = stream.shutdown(Shutdown::Both);
}

fn flush_events(
    link: &mut FrameChaos,
    stream: &TcpStream,
    events: &mut Vec<Envelope>,
) -> std::io::Result<()> {
    if events.is_empty() {
        return Ok(());
    }
    let frame = Frame::Events(std::mem::take(events));
    let res = link.write(stream, &frame, true);
    // Take the batch buffer back out of the frame so its capacity is
    // reused across drained windows — the steady-state ingest path
    // re-grows nothing per flush.
    if let Frame::Events(mut batch) = frame {
        batch.clear();
        *events = batch;
    }
    res
}

/// Reader-thread body: dispatch inbound frames until the host hangs up.
/// Returns the abnormal-exit cause (`None` = clean EOF). Always clears
/// the multiplexer on the way out so no reply sender outlives the
/// connection. Every inbound frame — `Pong`s included — stamps the
/// shared [`Health`] clock the writer-side watchdog ages.
fn read_loop(
    stream: TcpStream,
    mux: &Mux,
    report: &Arc<Mutex<Option<WorkerReport>>>,
    health: &Arc<Health>,
    col_tx: &Sender<CollectorMsg>,
    ckpt_tx: &Option<Sender<crate::engine::actor::CheckpointMsg>>,
) -> Option<String> {
    let mut reader = std::io::BufReader::new(stream);
    let cause = loop {
        match read_frame(&mut reader) {
            Ok(None) => break None,
            Err(e) => break Some(e.to_string()),
            Ok(Some(frame)) => {
                health.touch();
                match frame {
                    Frame::Answer { req_id, answer } => {
                        match take(mux, req_id) {
                            Some(Pending::Query(tx)) => {
                                let _ = tx.send(answer);
                            }
                            _ => {
                                log::warn!("unmatched answer (req {req_id})")
                            }
                        }
                    }
                    Frame::SnapshotReply { req_id, snap } => {
                        match take(mux, req_id) {
                            Some(Pending::Snapshot(tx)) => {
                                let _ = tx.send(snap);
                            }
                            _ => log::warn!(
                                "unmatched snapshot (req {req_id})"
                            ),
                        }
                    }
                    Frame::ExportReply { req_id, export } => {
                        match take(mux, req_id) {
                            Some(Pending::Export(tx)) => {
                                let _ = tx.send(export);
                            }
                            _ => log::warn!(
                                "unmatched export (req {req_id})"
                            ),
                        }
                    }
                    Frame::Hits(samples) => {
                        // Blocking is safe: the collector drains its
                        // channel unconditionally for the whole session.
                        let _ = col_tx.send(CollectorMsg::Hits(samples));
                    }
                    Frame::Done { worker_id } => {
                        let _ = col_tx.send(CollectorMsg::Done {
                            worker_id: worker_id as usize,
                        });
                    }
                    Frame::Checkpoint { ord, lane, bytes } => {
                        // Same contract as the in-proc actor: never
                        // block on a full checkpoint channel (the
                        // coordinator may be blocked sending events to
                        // this very proxy; waiting for it to drain
                        // checkpoints would deadlock the cycle). A
                        // dropped frame is always superseded by a
                        // fresher one.
                        if let Some(tx) = ckpt_tx {
                            let msg = crate::engine::actor::CheckpointMsg {
                                ord: ord as usize,
                                lane,
                                bytes,
                            };
                            let _ = tx.try_send(msg);
                        }
                    }
                    Frame::Pong { .. } => {
                        // The `health.touch()` above is the whole point;
                        // the nonce needs no matching — any inbound
                        // frame proves liveness.
                    }
                    Frame::Report(rep) => {
                        *report.lock().expect("report poisoned") =
                            Some(*rep);
                    }
                    _ => break Some("host sent a coordinator frame".into()),
                }
            }
        }
    };
    mux.lock().expect("mux poisoned").clear();
    cause
}

fn take(mux: &Mux, req_id: u64) -> Option<Pending> {
    mux.lock()
        .expect("mux poisoned")
        .remove(&req_id)
        .map(|p| p.pending)
}
