//! The worker host: a TCP server that runs `WorkerActor`s for remote
//! coordinators (`streamrec worker --listen <addr>`).
//!
//! Each accepted connection hosts exactly one worker slot: the first
//! frame must be the hello (ordinal, state-grid shape, chaos policy,
//! full run configuration), after which the host builds the same
//! channel plumbing an in-process spawn would have — a bounded
//! `WorkerMsg` FIFO, a collector channel, and (with fault tolerance on)
//! a checkpoint channel, and the dedicated serving lane `Query` frames
//! ride (fence and all) — and runs the actor on a local thread. A
//! *reader* thread translates inbound frames into `WorkerMsg`s and
//! `QueryMsg`s; reply senders for the RPC variants are parked in a FIFO
//! of pending replies, and the connection's handler thread *pumps*
//! outbound traffic: hit batches, checkpoints, RPC replies, and finally
//! the actor's report. Event-FIFO RPCs (snapshot, export) complete in
//! request order because the actor is sequential; query replies do
//! *not* — a fence can park a query past a later snapshot — so the pump
//! resolves them out of order wherever they sit in the queue.
//!
//! # Ordering invariant
//!
//! The in-proc actor hands buffered hit samples to the collector
//! *before* a checkpoint frame can reach the supervisor (crash safety:
//! the frame's watermark covers those samples). The pump preserves this
//! over the single ordered socket by draining the checkpoint channel
//! *first* and the collector channel *second* each iteration, then
//! writing collector frames *before* checkpoint frames: a checkpoint
//! captured at drain time provably entered its channel after the hits
//! that precede it entered theirs, so those hits are in the later drain
//! and ship ahead of it.
//!
//! # Failure model
//!
//! If the actor dies (an injected chaos kill, or a real bug), the
//! connection is dropped *without* a final `Report` frame — the
//! coordinator-side proxy translates that into a worker panic and the
//! supervisor's checkpoint-restore recovery takes over, re-dialing this
//! same host for the replacement slot. The server itself stays up: one
//! crashed slot never takes down its neighbors.

use std::collections::{HashMap, VecDeque};
use std::io::BufReader;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::router::StateGrid;
use crate::engine::actor::{
    ChaosPolicy, CollectorMsg, QueryMsg, ReplicaAnswer, WorkerActor,
    WorkerExport, WorkerMsg,
};
use crate::engine::{
    bounded, bounded_with_signal, spawn, Receiver, Sender, WakeSignal,
    WorkerSnapshot,
};
use crate::net::chaos::{FrameChaos, NetFaultPlan, Side};
use crate::net::proto::{read_frame, Frame, Hello};

/// How often the accept loop polls for shutdown between connections.
const ACCEPT_POLL: Duration = Duration::from_millis(25);

/// Pump idle sleep while waiting for outbound traffic.
const PUMP_POLL: Duration = Duration::from_millis(1);

/// State shared between the server handle, the accept loop, and the
/// per-connection handlers.
struct Shared {
    stop: AtomicBool,
    connections: AtomicU64,
    events_routed: AtomicU64,
    active: AtomicUsize,
    /// Live connection sockets by connection id — the [`WorkerServer::sever`]
    /// chaos hook shuts these down abruptly.
    streams: Mutex<HashMap<u64, TcpStream>>,
    /// Until when every connection's outbound pump is frozen — the
    /// [`WorkerServer::stall`] hung-worker test hook.
    stall_until: Mutex<Option<Instant>>,
}

impl Shared {
    /// True while a [`WorkerServer::stall`] window is open.
    fn stalled(&self) -> bool {
        match *self.stall_until.lock().expect("stall poisoned") {
            Some(until) => Instant::now() < until,
            None => false,
        }
    }
}

/// A TCP server hosting one `WorkerActor` per inbound connection —
/// the remote end of the `tcp://` transport. Bind one with
/// [`WorkerServer::bind`] (also the engine behind `streamrec worker
/// --listen`), point a coordinator's `[cluster] workers` entry at it,
/// and stop it with [`WorkerServer::shutdown`].
pub struct WorkerServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    handlers: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl WorkerServer {
    /// Bind `addr` (e.g. `"127.0.0.1:7461"`, or port `0` for an
    /// ephemeral port — see [`WorkerServer::local_addr`]) and start
    /// accepting coordinator connections in a background thread.
    pub fn bind(addr: &str) -> Result<Self> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding worker server on {addr}"))?;
        let local = listener.local_addr().context("resolving bound addr")?;
        listener
            .set_nonblocking(true)
            .context("making the accept loop pollable")?;
        let shared = Arc::new(Shared {
            stop: AtomicBool::new(false),
            connections: AtomicU64::new(0),
            events_routed: AtomicU64::new(0),
            active: AtomicUsize::new(0),
            streams: Mutex::new(HashMap::new()),
            stall_until: Mutex::new(None),
        });
        let handlers: Arc<Mutex<Vec<JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let shared = Arc::clone(&shared);
            let handlers = Arc::clone(&handlers);
            std::thread::Builder::new()
                .name("net-accept".to_string())
                .spawn(move || accept_loop(listener, &shared, &handlers))
                .context("spawning the accept loop")?
        };
        log::info!("worker server listening on {local}");
        Ok(Self { addr: local, shared, accept: Some(accept), handlers })
    }

    /// The address actually bound (resolves a requested port `0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Connections accepted so far (each hosts one worker slot).
    pub fn connections(&self) -> u64 {
        self.shared.connections.load(Ordering::Relaxed)
    }

    /// Stream events routed into hosted actors so far.
    pub fn events_routed(&self) -> u64 {
        self.shared.events_routed.load(Ordering::Relaxed)
    }

    /// Connections currently being served.
    pub fn active(&self) -> usize {
        self.shared.active.load(Ordering::Relaxed)
    }

    /// Abruptly shut down every live connection socket (both
    /// directions) and return how many were hit — the chaos hook for
    /// remote-failure tests: the coordinator sees each severed worker
    /// as crashed and runs checkpoint-restore recovery, while this
    /// server keeps accepting the replacement dials.
    pub fn sever(&self) -> usize {
        let streams = self.shared.streams.lock().expect("streams poisoned");
        let mut hit = 0;
        for stream in streams.values() {
            if stream.shutdown(Shutdown::Both).is_ok() {
                hit += 1;
            }
        }
        hit
    }

    /// Freeze every live connection's outbound pump for `d` — nothing
    /// leaves this server (no hits, no checkpoints, no RPC replies, no
    /// liveness pongs) while the sockets stay open and inbound frames
    /// keep being accepted. This is the *hung worker* test hook: unlike
    /// [`WorkerServer::sever`], the coordinator sees no EOF and no
    /// error, only silence — exactly the failure its RPC-deadline /
    /// heartbeat watchdog exists to detect.
    pub fn stall(&self, d: Duration) {
        *self.shared.stall_until.lock().expect("stall poisoned") =
            Some(Instant::now() + d);
    }

    /// Block until the server has served at least one connection and
    /// has had zero active connections for `grace` — the `--once` exit
    /// condition. The grace window bridges the short all-closed gaps a
    /// live session produces (a rescale retires one generation's
    /// connections before the next generation dials; an experiment
    /// driver runs several sessions back to back).
    pub fn wait_idle(&self, grace: Duration) {
        let mut idle_since: Option<Instant> = None;
        loop {
            let served = self.connections() > 0;
            let idle = self.active() == 0;
            if served && idle {
                let t0 = *idle_since.get_or_insert_with(Instant::now);
                if t0.elapsed() >= grace {
                    return;
                }
            } else {
                idle_since = None;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    /// Stop accepting, sever any still-active connection (their
    /// coordinators see a crashed worker), and join every server
    /// thread. Call [`WorkerServer::wait_idle`] first for a graceful
    /// stop.
    pub fn shutdown(mut self) -> Result<()> {
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            h.join()
                .map_err(|_| anyhow::anyhow!("accept loop panicked"))?;
        }
        self.sever();
        let handles: Vec<JoinHandle<()>> = std::mem::take(
            &mut *self.handlers.lock().expect("handlers poisoned"),
        );
        for h in handles {
            h.join()
                .map_err(|_| anyhow::anyhow!("connection handler panicked"))?;
        }
        Ok(())
    }
}

/// Accept connections until told to stop, spawning one handler thread
/// per connection.
fn accept_loop(
    listener: TcpListener,
    shared: &Arc<Shared>,
    handlers: &Arc<Mutex<Vec<JoinHandle<()>>>>,
) {
    while !shared.stop.load(Ordering::SeqCst) {
        let (stream, peer) = match listener.accept() {
            Ok(conn) => conn,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
                continue;
            }
            Err(e) => {
                log::error!("worker server accept failed: {e}");
                std::thread::sleep(ACCEPT_POLL);
                continue;
            }
        };
        // Accepted sockets must block: the reader and pump are plain
        // blocking threads (the listener alone is nonblocking).
        if stream.set_nonblocking(false).is_err() {
            continue;
        }
        let _ = stream.set_nodelay(true);
        let conn_id = shared.connections.fetch_add(1, Ordering::Relaxed);
        shared.active.fetch_add(1, Ordering::SeqCst);
        if let Ok(clone) = stream.try_clone() {
            shared
                .streams
                .lock()
                .expect("streams poisoned")
                .insert(conn_id, clone);
        }
        log::info!("worker server: connection {conn_id} from {peer}");
        let shared2 = Arc::clone(shared);
        let handle = std::thread::Builder::new()
            .name(format!("net-conn-{conn_id}"))
            .spawn(move || {
                if let Err(e) = serve_connection(&shared2, stream) {
                    log::warn!("connection {conn_id}: {e:#}");
                }
                shared2
                    .streams
                    .lock()
                    .expect("streams poisoned")
                    .remove(&conn_id);
                shared2.active.fetch_sub(1, Ordering::SeqCst);
                log::info!("worker server: connection {conn_id} done");
            })
            .expect("spawn connection handler");
        handlers.lock().expect("handlers poisoned").push(handle);
    }
}

/// One pending RPC reply: the receiver half of the bounded(1) reply
/// channel handed to the actor, keyed by the request id to echo.
enum PendingReply {
    Query(u64, Receiver<ReplicaAnswer>),
    Snapshot(u64, Receiver<WorkerSnapshot>),
    Export(u64, Receiver<WorkerExport>),
    /// A liveness pong (always ready — it just echoes the nonce). It
    /// rides the same FIFO as real replies so the pump stays the single
    /// writer and ordering stays trivially correct.
    Pong(u64),
}

/// Host one worker slot for the lifetime of one connection.
fn serve_connection(shared: &Arc<Shared>, stream: TcpStream) -> Result<()> {
    // The reader half is a buffered clone; this thread keeps the write
    // half. The hello is read here (before the reader thread exists) on
    // the same BufReader the reader thread will inherit, so no buffered
    // bytes are lost.
    let mut reader_stream = BufReader::new(
        stream.try_clone().context("cloning the connection")?,
    );
    let hello = match read_frame(&mut reader_stream)
        .context("reading the hello frame")?
    {
        Some(Frame::Hello(h)) => *h,
        Some(_) => bail!("first frame was not a hello"),
        None => bail!("peer hung up before the hello frame"),
    };
    let Hello { ord, v_i, v_u, kill_at_seq, kill_in_checkpoint, cfg } = hello;
    // Host side of the network fault plan: both peers derive the same
    // per-connection fault from the Hello's config; this side sleeps
    // its handshake delay and arms the sever iff the plan put it here.
    let fault =
        NetFaultPlan::from_config(&cfg).map(|plan| plan.connection(ord));
    if let Some(f) = &fault {
        if f.host_delay_ms > 0 {
            std::thread::sleep(Duration::from_millis(f.host_delay_ms));
        }
    }
    let mut link = fault
        .as_ref()
        .map_or_else(FrameChaos::none, |f| FrameChaos::armed(f, Side::Host));
    let ord = ord as usize;
    let grid = StateGrid::new(v_i, v_u)
        .context("rebuilding the state grid from the hello frame")?;
    let chaos = ChaosPolicy::from_parts(kill_at_seq, kill_in_checkpoint);

    // The same plumbing Supervisor::spawn_slot builds for a local slot:
    // one shared wake latch over the event FIFO and the serving lane.
    // The serving lane's capacity sits comfortably above the
    // coordinator's global in-flight cap, so the reader's `try_send`
    // into it can never legitimately fill up — the reader must never
    // block there, because queries and events share one socket and a
    // blocked reader would stall the very events a parked fence waits
    // on.
    let signal = WakeSignal::new();
    let (tx, rx) =
        bounded_with_signal::<WorkerMsg>(cfg.channel_capacity, &signal);
    let (query_tx, query_rx) = bounded_with_signal::<QueryMsg>(
        cfg.serving_max_in_flight + 256,
        &signal,
    );
    let (col_tx, col_rx) = bounded::<CollectorMsg>(1024);
    let (ckpt_tx, ckpt_rx) = if cfg.fault_checkpoint_interval > 0 {
        let (ctx, crx) = bounded(grid.n_lanes() as usize + 64);
        (Some(ctx), Some(crx))
    } else {
        (None, None)
    };
    let actor = WorkerActor::new(
        ord, cfg, grid, rx, query_rx, signal, col_tx, ckpt_tx, chaos,
    );
    let actor_handle = spawn(ord, "worker", move || actor.run());

    let pending: Arc<Mutex<VecDeque<PendingReply>>> =
        Arc::new(Mutex::new(VecDeque::new()));
    let reader_handle = {
        let pending = Arc::clone(&pending);
        let shared = Arc::clone(shared);
        std::thread::Builder::new()
            .name(format!("net-host-reader-{ord}"))
            .spawn(move || {
                reader_loop(reader_stream, tx, query_tx, &pending, &shared)
            })
            .context("spawning the connection reader")?
    };

    let report = pump(
        &stream,
        &mut link,
        shared,
        &col_rx,
        ckpt_rx.as_ref(),
        &pending,
        || actor_handle.is_finished(),
    );

    // Join the actor. A clean report ships as the final frame; a crash
    // (chaos kill or real bug) drops the connection with *no* report —
    // the coordinator's proxy panics on that, which is the contract.
    let mut result = Ok(());
    match actor_handle.join() {
        Ok(Ok(worker_report)) if report.is_ok() => {
            let frame = Frame::Report(Box::new(worker_report));
            if let Err(e) = link.write(&stream, &frame, true) {
                result = Err(e).context("writing the final report");
            }
        }
        Ok(Ok(_)) => {
            // Pump lost the socket first; nowhere to send the report.
            result = report.context("connection pump failed");
        }
        Ok(Err(e)) => {
            log::warn!("hosted worker {ord} failed: {e:#}");
        }
        Err(panic) => {
            log::warn!("hosted worker {ord} crashed: {panic:#}");
        }
    }
    // Close both directions so the peer sees EOF and our reader thread
    // (possibly parked in a blocking read) wakes up.
    let _ = stream.shutdown(Shutdown::Both);
    let _ = reader_handle.join();
    result
}

/// Reader-thread body: translate inbound frames into `WorkerMsg` sends.
/// Exits on `Close` + EOF, on connection loss, or when the actor stops
/// accepting (death — the handler notices via the join).
fn reader_loop(
    mut stream: BufReader<TcpStream>,
    tx: Sender<WorkerMsg>,
    query_tx: Sender<QueryMsg>,
    pending: &Arc<Mutex<VecDeque<PendingReply>>>,
    shared: &Arc<Shared>,
) {
    let mut lanes = Some((tx, query_tx));
    loop {
        let frame = match read_frame(&mut stream) {
            Ok(Some(frame)) => frame,
            Ok(None) => break,
            Err(e) => {
                log::debug!("host reader: {e}");
                break;
            }
        };
        if let Frame::Ping { nonce } = frame {
            // Answer liveness probes even after Close (the actor may
            // still be draining): the pong goes through the pump like
            // any reply, so it also proves the outbound path moves.
            pending
                .lock()
                .expect("pending poisoned")
                .push_back(PendingReply::Pong(nonce));
            continue;
        }
        let Some((sender, qsender)) = lanes.as_ref() else {
            // Frames after Close violate the protocol; drop them and
            // keep draining to EOF so the peer's writes don't block.
            continue;
        };
        let sent = match frame {
            Frame::Events(envs) => {
                let n = envs.len() as u64;
                let mut ok = true;
                for env in envs {
                    // Blocking send: actor backpressure propagates to
                    // the socket, exactly like a local slot's bounded
                    // channel slows the coordinator down.
                    if sender.send(WorkerMsg::Event(env)).is_err() {
                        ok = false;
                        break;
                    }
                }
                shared.events_routed.fetch_add(n, Ordering::Relaxed);
                ok
            }
            Frame::Import { lane, restore_counters, bytes } => sender
                .send(WorkerMsg::Import { lane, bytes, restore_counters })
                .is_ok(),
            Frame::Query { req_id, user, n, fence } => {
                // `try_send`, never `send`: the lane's capacity bound
                // makes Full impossible in a well-behaved session (see
                // `serve_connection`), and Closed means the actor died
                // — both drop the connection loudly rather than block
                // the socket the fence's events arrive on.
                let (rtx, rrx) = bounded::<ReplicaAnswer>(1);
                let ok = qsender
                    .try_send(QueryMsg {
                        user,
                        n: n as usize,
                        fence,
                        reply: rtx,
                    })
                    .is_ok();
                if ok {
                    pending
                        .lock()
                        .expect("pending poisoned")
                        .push_back(PendingReply::Query(req_id, rrx));
                }
                ok
            }
            Frame::Snapshot { req_id } => {
                let (rtx, rrx) = bounded::<WorkerSnapshot>(1);
                let ok = sender
                    .send(WorkerMsg::MetricsSnapshot { reply: rtx })
                    .is_ok();
                if ok {
                    pending
                        .lock()
                        .expect("pending poisoned")
                        .push_back(PendingReply::Snapshot(req_id, rrx));
                }
                ok
            }
            Frame::Export { req_id } => {
                let (rtx, rrx) = bounded::<WorkerExport>(1);
                let ok = sender
                    .send(WorkerMsg::Export { reply: rtx })
                    .is_ok();
                if ok {
                    pending
                        .lock()
                        .expect("pending poisoned")
                        .push_back(PendingReply::Export(req_id, rrx));
                }
                ok
            }
            Frame::Close => {
                // Drop both lane senders: the actor drains and reports
                // (end-of-stream needs the event sender gone; closing
                // the serving lane releases any still-queued reply
                // senders). Keep reading to EOF so a slow peer never
                // blocks on a full socket buffer.
                lanes = None;
                continue;
            }
            _ => {
                log::warn!("host reader: peer sent a worker frame");
                break;
            }
        };
        if !sent {
            // The actor is gone (crash). Stop translating; the handler
            // drops the connection without a report.
            break;
        }
    }
}

/// Pump outbound traffic until the actor exits, preserving the
/// hits-before-checkpoint ordering (module docs). Returns `Err` on
/// socket failure — but only *after* the actor has exited: once a write
/// fails the pump turns into a sink that keeps draining (and
/// discarding) the actor's channels, because an actor blocked sending
/// into a full collector channel nobody drains would never finish and
/// the handler's join would hang forever. All writes go through the
/// host-side chaos `link` (an armed host-side sever surfaces here as a
/// broken write, which is exactly the sink-mode path); a
/// [`WorkerServer::stall`] window freezes the whole pass — nothing is
/// drained or written while it is open.
#[allow(clippy::too_many_arguments)]
fn pump(
    stream: &TcpStream,
    link: &mut FrameChaos,
    shared: &Arc<Shared>,
    col_rx: &Receiver<CollectorMsg>,
    ckpt_rx: Option<&Receiver<crate::engine::actor::CheckpointMsg>>,
    pending: &Arc<Mutex<VecDeque<PendingReply>>>,
    actor_finished: impl Fn() -> bool,
) -> std::io::Result<()> {
    let mut broken: Option<std::io::Error> = None;
    let mut ck = Vec::new();
    let mut co = Vec::new();
    loop {
        if shared.stalled() {
            std::thread::sleep(PUMP_POLL);
            continue;
        }
        let finished = actor_finished();
        // Capture checkpoints FIRST, collector traffic SECOND, then
        // write collector frames before checkpoint frames: a checkpoint
        // seen at the first capture entered its channel after the hit
        // batch that precedes it entered the collector channel, so that
        // batch is in the second capture and ships first.
        if let Some(crx) = ckpt_rx {
            crx.try_drain(&mut ck);
        }
        col_rx.try_drain(&mut co);
        let mut progress = !ck.is_empty() || !co.is_empty();
        for msg in co.drain(..) {
            if broken.is_some() {
                continue; // sink mode: drain, don't write
            }
            let frame = match msg {
                CollectorMsg::Hits(samples) => Frame::Hits(samples),
                CollectorMsg::Done { worker_id } => {
                    Frame::Done { worker_id: worker_id as u64 }
                }
            };
            if let Err(e) = link.write(stream, &frame, true) {
                broken = Some(e);
            }
        }
        for msg in ck.drain(..) {
            if broken.is_some() {
                continue;
            }
            let frame = Frame::Checkpoint {
                ord: msg.ord as u64,
                lane: msg.lane,
                bytes: msg.bytes,
            };
            if let Err(e) = link.write(stream, &frame, true) {
                broken = Some(e);
            }
        }
        // Query replies first, resolved *anywhere* in the queue: the
        // serving lane answers out of order relative to the FIFO RPCs
        // (a fence can park a query past a later snapshot, and a
        // snapshot can be answered while an earlier query is still
        // parked), so front-of-queue discipline would wedge. Eager
        // shipping is safe ordering-wise because serving is a frozen
        // read — a query never produces hits for a checkpoint to cover.
        // A query the actor dropped (end-of-stream, death) leaves a
        // dead, empty reply channel: discard it so the queue cannot
        // wedge behind it.
        let mut answers: Vec<Frame> = Vec::new();
        {
            let mut queue = pending.lock().expect("pending poisoned");
            let mut dropped = false;
            queue.retain(|entry| {
                let PendingReply::Query(req_id, rrx) = entry else {
                    return true;
                };
                let mut out = Vec::new();
                rrx.try_drain(&mut out);
                if let Some(answer) = out.pop() {
                    answers
                        .push(Frame::Answer { req_id: *req_id, answer });
                    return false;
                }
                if rrx.is_ended() || finished || broken.is_some() {
                    dropped = true;
                    return false;
                }
                true
            });
            progress |= dropped;
        }
        for frame in answers {
            progress = true;
            if broken.is_none() {
                if let Err(e) = link.write(stream, &frame, true) {
                    broken = Some(e);
                }
            }
        }
        // Then at most ONE FIFO RPC reply per pass, in request order
        // (the actor is sequential, so these complete in the order they
        // were asked). One per pass keeps the wire faithful to the
        // in-proc ordering: hits the actor flushed before answering the
        // *next* request are picked up by the next pass's collector
        // drain and ship ahead of that reply.
        let reply = {
            let mut queue = pending.lock().expect("pending poisoned");
            match queue.front() {
                None => None,
                Some(front) => {
                    let ready = match front {
                        // Unreachable after the sweep above (every
                        // ready or dead query was removed); a parked
                        // query simply isn't ready yet.
                        PendingReply::Query(..) => None,
                        PendingReply::Snapshot(req_id, rrx) => {
                            let mut out = Vec::new();
                            rrx.try_drain(&mut out);
                            out.pop().map(|snap| Frame::SnapshotReply {
                                req_id: *req_id,
                                snap,
                            })
                        }
                        PendingReply::Export(req_id, rrx) => {
                            let mut out = Vec::new();
                            rrx.try_drain(&mut out);
                            out.pop().map(|export| Frame::ExportReply {
                                req_id: *req_id,
                                export,
                            })
                        }
                        PendingReply::Pong(nonce) => {
                            Some(Frame::Pong { nonce: *nonce })
                        }
                    };
                    if ready.is_some() {
                        queue.pop_front();
                        ready
                    } else if finished || broken.is_some() {
                        // Never going to be answered (the actor died
                        // mid-request) or nowhere to send it: discard.
                        queue.pop_front();
                        progress = true;
                        None
                    } else {
                        None
                    }
                }
            }
        };
        if let Some(frame) = reply {
            progress = true;
            if broken.is_none() {
                // Pongs don't count against a sever-at-frame-N fuse:
                // heartbeat cadence must not move where a data-frame
                // sever lands.
                let counts = !matches!(frame, Frame::Pong { .. });
                if let Err(e) = link.write(stream, &frame, counts) {
                    broken = Some(e);
                }
            }
        }
        if finished
            && !progress
            && pending.lock().expect("pending poisoned").is_empty()
        {
            // The actor exited, a full sweep found nothing queued, and
            // no reply is owed: everything it ever sent is on the wire
            // (or intentionally discarded in sink mode).
            return match broken {
                Some(e) => Err(e),
                None => Ok(()),
            };
        }
        if !progress {
            std::thread::sleep(PUMP_POLL);
        }
    }
}

#[cfg(test)]
mod tests {
    use std::io::Write;

    use super::*;

    fn wait_for(what: &str, cond: impl Fn() -> bool) {
        let deadline = Instant::now() + Duration::from_secs(10);
        while !cond() {
            assert!(Instant::now() < deadline, "timed out waiting: {what}");
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    #[test]
    fn hostile_length_prefix_does_not_kill_the_host() {
        let server = WorkerServer::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr();

        // Connection 1: a length prefix far over the 1 GiB frame cap.
        // The host must reject it loudly (no allocation, no panic) and
        // drop only this connection.
        let mut evil = TcpStream::connect(addr).unwrap();
        evil.write_all(&u32::MAX.to_le_bytes()).unwrap();
        wait_for("evil connection accepted", || server.connections() >= 1);
        wait_for("evil connection dropped", || server.active() == 0);

        // Connection 2: a well-formed frame that is not a Hello — also
        // rejected per-connection, proving the accept loop survived.
        let mut wrong = TcpStream::connect(addr).unwrap();
        let body = Frame::Close.encode();
        let mut out = (body.len() as u32).to_le_bytes().to_vec();
        out.extend_from_slice(&body);
        wrong.write_all(&out).unwrap();
        wait_for("second connection served", || server.connections() >= 2);
        wait_for("second connection dropped", || server.active() == 0);

        drop(evil);
        drop(wrong);
        server.shutdown().unwrap();
    }
}
