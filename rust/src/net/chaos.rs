//! Deterministic network fault injection for the worker transport.
//!
//! A [`NetFaultPlan`] is built from the `[fault.net]` knobs in
//! [`RunConfig`] and derives, purely from `fault.net.seed` and a
//! connection's worker slot ordinal, everything that will go wrong on
//! that connection: how many dial attempts are refused, how long each
//! side stalls before speaking, whether (and after how many frames, and
//! how cleanly) the connection is severed. Both peers hold the same
//! configuration — the plan rides to the host inside the `Hello` frame
//! — so they compute the *same* [`ConnFault`] independently and each
//! side arms only the faults it owns. Same seed, same faults: a failure
//! replays exactly.
//!
//! Ordinals are session-unique and respawn-fresh (a recovered slot gets
//! a new ordinal), so `sever_connections = k` severs exactly the first
//! `k` connections ever opened and every replacement runs clean — the
//! fault budget is bounded and a run with fault tolerance enabled must
//! end byte-identical to a fault-free one.
//!
//! The injection points are deliberately the real failure surfaces:
//! refusals happen before the socket is touched (exactly like a host
//! that is not listening yet), severs go through `Shutdown::Both` so
//! the peer observes an honest half-open teardown, and a mid-frame cut
//! leaves a truncated length-prefixed frame on the wire for the
//! decoder to choke on loudly.

use std::io::Write;
use std::net::{Shutdown, TcpStream};
use std::time::Duration;

use crate::config::RunConfig;
use crate::util::rng::{mix64, Pcg32};

use super::proto::{write_frame_into, Frame};

/// Domain separator for the dial-backoff jitter stream so it never
/// correlates with the per-connection fault draws.
const JITTER_SALT: u64 = 0x6a69_7474_6572;

/// Which peer of a connection executes an armed sever. Each side
/// computes the full [`ConnFault`] and acts only on its own half.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Side {
    /// The coordinator-side proxy (`net/remote.rs`) cuts its writes.
    Coordinator,
    /// The worker host (`net/server.rs`) cuts its writes.
    Host,
}

/// A seeded network fault plan — the deterministic function from
/// (seed, connection ordinal) to that connection's faults.
#[derive(Debug, Clone, Copy)]
pub(crate) struct NetFaultPlan {
    net: crate::config::NetFaultConfig,
}

/// Everything that will go wrong on one connection, computed
/// identically by both peers from the shared plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct ConnFault {
    /// Simulated connection-refused results for the first this-many
    /// dial attempts (never exceeds `fault.dial_retries`; validated at
    /// config parse time).
    pub(crate) dial_refusals: u32,
    /// Coordinator-side stall (ms) after a successful dial, before the
    /// `Hello` goes out.
    pub(crate) dial_delay_ms: u64,
    /// Host-side stall (ms) after decoding the `Hello`, before the
    /// actor is built.
    pub(crate) host_delay_ms: u64,
    /// An armed sever, or `None` for a connection that lives.
    pub(crate) sever: Option<SeverFault>,
}

/// One armed sever on one side of one connection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct SeverFault {
    /// Which peer executes the cut.
    pub(crate) side: Side,
    /// Counted frames that side delivers before cutting (≥ 1).
    pub(crate) after_frames: u64,
    /// Cut mid-frame (length prefix + truncated body) instead of on a
    /// frame boundary.
    pub(crate) mid_frame: bool,
}

impl NetFaultPlan {
    /// The plan armed by `cfg`, or `None` when `[fault.net]` is all
    /// defaults (the transport stays transparent — not even a seeded
    /// zero-delay is drawn, so the no-plan path is byte-for-byte the
    /// pre-chaos code path).
    pub(crate) fn from_config(cfg: &RunConfig) -> Option<NetFaultPlan> {
        if cfg.fault_net.is_noop() {
            None
        } else {
            Some(NetFaultPlan { net: cfg.fault_net })
        }
    }

    /// The faults for the connection hosting worker slot ordinal
    /// `ord`. Pure: both peers call this independently and must agree.
    pub(crate) fn connection(&self, ord: u64) -> ConnFault {
        let mut rng = Pcg32::seeded(self.net.seed ^ mix64(ord));
        let dial_delay_ms = if self.net.delay_ms_max > 0 {
            rng.next_bounded(self.net.delay_ms_max + 1)
        } else {
            0
        };
        let host_delay_ms = if self.net.delay_ms_max > 0 {
            rng.next_bounded(self.net.delay_ms_max + 1)
        } else {
            0
        };
        let sever = (ord < self.net.sever_connections).then(|| {
            let span = self.net.sever_after_frames.max(1);
            SeverFault {
                side: if rng.next_bounded(2) == 0 {
                    Side::Coordinator
                } else {
                    Side::Host
                },
                after_frames: 1 + rng.next_bounded(span),
                mid_frame: self.net.mid_frame_cut,
            }
        });
        ConnFault {
            dial_refusals: self.net.refuse_dials,
            dial_delay_ms,
            host_delay_ms,
            sever,
        }
    }
}

/// Per-connection-side write wrapper that executes an armed sever.
/// Counted frames decrement the fuse; when it reaches zero the frame
/// is dropped (or truncated), the socket is shut down both ways, and
/// the caller gets a `BrokenPipe` — exactly what a real peer death
/// looks like to the write path.
#[derive(Debug)]
pub(crate) struct FrameChaos {
    /// Counted frames still to deliver; `None` = never sever.
    fuse: Option<u64>,
    mid_frame: bool,
    /// Recycled per-connection encode buffer: every delivered frame is
    /// built here, so the steady-state event path never allocates per
    /// write (BENCH_hotpath.json `wire_encode/*` measures the win).
    wbuf: Vec<u8>,
}

impl FrameChaos {
    /// A transparent wrapper (the no-plan / not-my-side case).
    pub(crate) fn none() -> FrameChaos {
        FrameChaos { fuse: None, mid_frame: false, wbuf: Vec::new() }
    }

    /// Arm this side with `fault`'s sever iff it targets `side`.
    pub(crate) fn armed(fault: &ConnFault, side: Side) -> FrameChaos {
        match fault.sever {
            Some(s) if s.side == side => FrameChaos {
                fuse: Some(s.after_frames),
                mid_frame: s.mid_frame,
                wbuf: Vec::new(),
            },
            _ => FrameChaos::none(),
        }
    }

    /// Write one frame through the fault, or execute the sever.
    /// `counts` is false for liveness `Ping`/`Pong` traffic so the
    /// heartbeat cadence cannot shift where a data-frame sever lands.
    pub(crate) fn write(
        &mut self,
        mut stream: &TcpStream,
        frame: &Frame,
        counts: bool,
    ) -> std::io::Result<()> {
        let Some(fuse) = &mut self.fuse else {
            return write_frame_into(&mut stream, frame, &mut self.wbuf);
        };
        if !counts {
            return write_frame_into(&mut stream, frame, &mut self.wbuf);
        }
        if *fuse > 1 {
            *fuse -= 1;
            return write_frame_into(&mut stream, frame, &mut self.wbuf);
        }
        // The fuse burned down: this frame dies instead of going out.
        if self.mid_frame {
            // Honest length prefix, half the body, then the cut — the
            // peer's read_exact hits EOF inside the frame.
            let body = frame.encode();
            let mut partial = Vec::with_capacity(4 + body.len() / 2);
            partial
                .extend_from_slice(&(body.len() as u32).to_le_bytes());
            partial.extend_from_slice(&body[..body.len() / 2]);
            let _ = stream.write_all(&partial);
        }
        let _ = stream.shutdown(Shutdown::Both);
        self.fuse = None;
        Err(std::io::Error::new(
            std::io::ErrorKind::BrokenPipe,
            "connection severed by fault plan",
        ))
    }
}

/// Dial `addr` for slot ordinal `ord` with the configured retry budget:
/// bounded exponential backoff (`fault.dial_backoff_ms * 2^n`, exponent
/// capped) plus seeded jitter between attempts, and the fault plan's
/// injected refusals consumed before the socket is touched. On success
/// the plan's coordinator-side handshake delay has already been slept.
/// The error string names the address and the attempt count.
pub(crate) fn dial_with_backoff(
    addr: &str,
    ord: u64,
    cfg: &RunConfig,
) -> Result<TcpStream, String> {
    let fault =
        NetFaultPlan::from_config(cfg).map(|plan| plan.connection(ord));
    let refusals = fault.map_or(0, |f| f.dial_refusals);
    let mut jitter =
        Pcg32::seeded(cfg.fault_net.seed ^ mix64(ord) ^ JITTER_SALT);
    let attempts = 1 + cfg.fault_dial_retries;
    let mut last_err = String::new();
    for attempt in 0..attempts {
        if attempt > 0 {
            let exp = (attempt - 1).min(6);
            let base = cfg.fault_dial_backoff_ms << exp;
            if base > 0 {
                let ms = base + jitter.next_bounded(base);
                std::thread::sleep(Duration::from_millis(ms));
            }
        }
        if attempt < refusals {
            last_err = "connection refused (injected by fault plan)"
                .to_string();
            continue;
        }
        match TcpStream::connect(addr) {
            Ok(stream) => {
                if let Some(f) = fault {
                    if f.dial_delay_ms > 0 {
                        std::thread::sleep(Duration::from_millis(
                            f.dial_delay_ms,
                        ));
                    }
                }
                return Ok(stream);
            }
            Err(e) => last_err = e.to_string(),
        }
    }
    Err(format!(
        "dial {addr} failed after {attempts} attempt(s): {last_err}"
    ))
}

#[cfg(test)]
mod tests {
    use std::io::Read;
    use std::net::TcpListener;

    use super::*;
    use crate::net::proto::read_frame;

    fn plan_cfg(
        f: impl FnOnce(&mut crate::config::NetFaultConfig),
    ) -> RunConfig {
        let mut cfg = RunConfig::default();
        f(&mut cfg.fault_net);
        cfg
    }

    #[test]
    fn noop_config_builds_no_plan() {
        assert!(NetFaultPlan::from_config(&RunConfig::default()).is_none());
        let cfg = plan_cfg(|n| n.seed = 1);
        assert!(NetFaultPlan::from_config(&cfg).is_some());
    }

    #[test]
    fn plan_is_deterministic_and_respects_the_budget() {
        let cfg = plan_cfg(|n| {
            n.seed = 11;
            n.delay_ms_max = 7;
            n.sever_connections = 3;
            n.sever_after_frames = 20;
            n.mid_frame_cut = true;
            n.refuse_dials = 2;
        });
        let plan = NetFaultPlan::from_config(&cfg).unwrap();
        for ord in 0..16 {
            let a = plan.connection(ord);
            let b = plan.connection(ord);
            assert_eq!(a, b, "same seed+ord must draw the same fault");
            assert!(a.dial_delay_ms <= 7 && a.host_delay_ms <= 7);
            assert_eq!(a.dial_refusals, 2);
            if ord < 3 {
                let s = a.sever.expect("first k conns sever");
                assert!((1..=20).contains(&s.after_frames));
                assert!(s.mid_frame);
            } else {
                assert!(a.sever.is_none(), "ord {ord} must run clean");
            }
        }
        // Different seeds disagree somewhere (sanity, not crypto).
        let other = NetFaultPlan::from_config(&plan_cfg(|n| {
            n.seed = 12;
            n.delay_ms_max = 7;
            n.sever_connections = 3;
            n.sever_after_frames = 20;
        }))
        .unwrap();
        assert!(
            (0..3).any(|o| other.connection(o) != plan.connection(o)),
            "seed must matter"
        );
    }

    #[test]
    fn sever_after_frames_zero_falls_back_to_one() {
        let cfg = plan_cfg(|n| {
            n.seed = 5;
            n.sever_connections = 1;
        });
        let plan = NetFaultPlan::from_config(&cfg).unwrap();
        let s = plan.connection(0).sever.unwrap();
        assert_eq!(s.after_frames, 1);
    }

    #[test]
    fn frame_chaos_cuts_after_the_fused_count() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = TcpStream::connect(addr).unwrap();
        let (mut peer, _) = listener.accept().unwrap();

        let fault = ConnFault {
            dial_refusals: 0,
            dial_delay_ms: 0,
            host_delay_ms: 0,
            sever: Some(SeverFault {
                side: Side::Coordinator,
                after_frames: 2,
                mid_frame: false,
            }),
        };
        let mut chaos = FrameChaos::armed(&fault, Side::Coordinator);
        // Host-side wrapper of the same fault stays transparent.
        assert!(FrameChaos::armed(&fault, Side::Host).fuse.is_none());

        let ping = Frame::Ping { nonce: 1 };
        chaos.write(&writer, &ping, false).unwrap(); // uncounted
        chaos.write(&writer, &Frame::Close, true).unwrap(); // 1st
        let err =
            chaos.write(&writer, &Frame::Close, true).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::BrokenPipe);

        // The peer sees the delivered frames, then a clean EOF —
        // exactly two frames made it out, the third died.
        assert!(matches!(
            read_frame(&mut peer).unwrap(),
            Some(Frame::Ping { nonce: 1 })
        ));
        assert!(matches!(
            read_frame(&mut peer).unwrap(),
            Some(Frame::Close)
        ));
        assert!(read_frame(&mut peer).unwrap().is_none());
    }

    #[test]
    fn mid_frame_cut_leaves_a_truncated_frame() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = TcpStream::connect(addr).unwrap();
        let (mut peer, _) = listener.accept().unwrap();

        let mut chaos = FrameChaos {
            fuse: Some(1),
            mid_frame: true,
        };
        let frame = Frame::Query { req_id: 9, user: 3, n: 10 };
        let err = chaos.write(&writer, &frame, true).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::BrokenPipe);

        // The peer got a length prefix promising more bytes than ever
        // arrive: read_frame must fail loudly, not hang or succeed.
        let res = read_frame(&mut peer);
        assert!(res.is_err(), "truncated frame must error: {res:?}");
        // And the raw stream is closed.
        let mut rest = Vec::new();
        assert_eq!(peer.read_to_end(&mut rest).unwrap(), 0);
    }

    #[test]
    fn dial_backoff_survives_injected_refusals() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let cfg = RunConfig {
            fault_dial_retries: 3,
            fault_dial_backoff_ms: 1,
            ..plan_cfg(|n| {
                n.seed = 3;
                n.refuse_dials = 2;
            })
        };
        let stream = dial_with_backoff(&addr, 0, &cfg).unwrap();
        drop(stream);
        drop(listener);
    }

    #[test]
    fn exhausted_dial_retries_name_the_address() {
        // Bind then drop so the port is (almost surely) dead.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener);
        let cfg = RunConfig {
            fault_dial_retries: 1,
            fault_dial_backoff_ms: 1,
            ..RunConfig::default()
        };
        let err = dial_with_backoff(&addr, 7, &cfg).unwrap_err();
        assert!(err.contains(&addr), "error must name the host: {err}");
        assert!(err.contains("2 attempt"), "{err}");
    }
}
