//! Pluggable worker transport: run a session's workers as in-process
//! threads, as remote processes behind TCP, or a mix — with no behavior
//! change visible above the supervisor.
//!
//! The seam is deliberately narrow. The supervisor already talks to
//! every worker through one bounded `WorkerMsg` FIFO and gets results
//! back through the collector/checkpoint channels plus a join handle;
//! a `Transport` only decides *where the consuming end of that FIFO
//! runs*:
//!
//! * `InProcTransport` — the pre-networking behavior, bit for bit: a
//!   `WorkerActor` on a local thread.
//! * `TcpTransport` — a proxy thread (`remote`) that dials a
//!   [`WorkerServer`] and speaks the frame protocol (`proto`); the
//!   actor runs in the remote process, and connection loss surfaces as
//!   a worker panic so the supervisor's crash recovery works unchanged.
//!
//! Which transport serves which worker slot comes from
//! `[cluster] workers` in the run configuration
//! ([`RunConfig::cluster_workers`]): the list is cycled over slot
//! ordinals, so `["local", "tcp://10.0.0.7:7461"]` alternates local
//! threads with remote workers, and re-dials land on the same address a
//! crashed slot used (`ordinal mod len` is stable across respawns).

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::RunConfig;
use crate::coordinator::router::StateGrid;
use crate::engine::actor::{
    ChaosPolicy, CheckpointMsg, CollectorMsg, QueryMsg, WorkerActor,
    WorkerMsg,
};
use crate::engine::{spawn, Receiver, Sender, WakeSignal, WorkerHandle};
use crate::eval::WorkerReport;

pub(crate) mod chaos;
pub(crate) mod proto;
pub(crate) mod remote;
pub mod server;

pub use server::WorkerServer;

/// Everything a transport needs to stand up one worker slot — the
/// exact argument list of
/// [`WorkerActor::new`](crate::engine::actor::WorkerActor), bundled so
/// it can cross a thread boundary in one move.
pub(crate) struct WorkerBoot {
    /// Session-unique worker ordinal (never reused across respawns).
    pub(crate) ord: usize,
    /// Full run configuration (remote hosts rebuild the actor from it).
    pub(crate) cfg: RunConfig,
    /// The session's fixed lane grid.
    pub(crate) grid: StateGrid,
    /// Consuming end of the slot's `WorkerMsg` FIFO.
    pub(crate) rx: Receiver<WorkerMsg>,
    /// Consuming end of the slot's dedicated serving lane: queries
    /// bypass the event FIFO entirely (see
    /// [`QueryMsg`](crate::engine::actor::QueryMsg)).
    pub(crate) query_rx: Receiver<QueryMsg>,
    /// Shared wakeup latch covering both `rx` and `query_rx`.
    pub(crate) signal: WakeSignal,
    /// Hit batches and `Done` markers flow here.
    pub(crate) col_tx: Sender<CollectorMsg>,
    /// Lane checkpoint frames (fault-tolerant sessions only).
    pub(crate) ckpt_tx: Option<Sender<CheckpointMsg>>,
    /// Crash-injection policy for this slot.
    pub(crate) chaos: ChaosPolicy,
}

/// Where a worker slot's actor runs. Implementations must preserve the
/// in-proc contract exactly: consume the FIFO in order, flush hits
/// before the checkpoint frames that cover them, return the final
/// [`WorkerReport`] from the join, and surface any failure as a panic
/// or `Err` from the joined thread.
pub(crate) trait Transport: Send + Sync {
    /// Stand up one worker slot and return its join handle.
    fn spawn_worker(&self, boot: WorkerBoot) -> WorkerHandle<Result<WorkerReport>>;

    /// Human-readable placement label for logs (`"local"` or the
    /// remote address).
    fn describe(&self) -> String;
}

/// The default transport: the actor runs on a local thread, exactly as
/// every session did before networking existed.
pub(crate) struct InProcTransport;

impl Transport for InProcTransport {
    fn spawn_worker(&self, boot: WorkerBoot) -> WorkerHandle<Result<WorkerReport>> {
        let WorkerBoot {
            ord,
            cfg,
            grid,
            rx,
            query_rx,
            signal,
            col_tx,
            ckpt_tx,
            chaos,
        } = boot;
        let actor = WorkerActor::new(
            ord, cfg, grid, rx, query_rx, signal, col_tx, ckpt_tx, chaos,
        );
        spawn(ord, "worker", move || actor.run())
    }

    fn describe(&self) -> String {
        "local".to_string()
    }
}

/// A remote worker slot behind `tcp://host:port`: the spawned thread is
/// a [`remote`] proxy dialing a [`WorkerServer`] at `addr`.
pub(crate) struct TcpTransport {
    addr: String,
}

impl Transport for TcpTransport {
    fn spawn_worker(&self, boot: WorkerBoot) -> WorkerHandle<Result<WorkerReport>> {
        let addr = self.addr.clone();
        let ord = boot.ord;
        spawn(ord, "worker", move || remote::run_proxy(&addr, boot))
    }

    fn describe(&self) -> String {
        format!("tcp://{}", self.addr)
    }
}

/// Resolve `[cluster] workers` into the transport cycle the supervisor
/// assigns slots from (`ordinal mod len`). An empty list — the default
/// — is a single [`InProcTransport`], preserving pre-networking
/// behavior bit for bit. Entries are `"local"`/`"inproc"` or
/// `"tcp://host:port"`; anything else is a loud error.
pub(crate) fn transport_plan(cfg: &RunConfig) -> Result<Vec<Arc<dyn Transport>>> {
    if cfg.cluster_workers.is_empty() {
        return Ok(vec![Arc::new(InProcTransport)]);
    }
    let mut plan: Vec<Arc<dyn Transport>> =
        Vec::with_capacity(cfg.cluster_workers.len());
    for entry in &cfg.cluster_workers {
        let entry = entry.trim();
        if entry.eq_ignore_ascii_case("local")
            || entry.eq_ignore_ascii_case("inproc")
        {
            plan.push(Arc::new(InProcTransport));
        } else if let Some(addr) = entry.strip_prefix("tcp://") {
            let (host, port) = addr.rsplit_once(':').with_context(|| {
                format!(
                    "cluster worker '{entry}': expected tcp://host:port"
                )
            })?;
            if host.is_empty() {
                bail!("cluster worker '{entry}': empty host");
            }
            port.parse::<u16>().with_context(|| {
                format!("cluster worker '{entry}': bad port '{port}'")
            })?;
            plan.push(Arc::new(TcpTransport { addr: addr.to_string() }));
        } else {
            bail!(
                "cluster worker '{entry}': unknown transport (expected \
                 'local', 'inproc', or 'tcp://host:port')"
            );
        }
    }
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg_with(workers: &[&str]) -> RunConfig {
        RunConfig {
            cluster_workers: workers.iter().map(|s| s.to_string()).collect(),
            ..RunConfig::default()
        }
    }

    #[test]
    fn empty_cluster_is_one_inproc_transport() {
        let plan = transport_plan(&RunConfig::default()).unwrap();
        assert_eq!(plan.len(), 1);
        assert_eq!(plan[0].describe(), "local");
    }

    #[test]
    fn mixed_plan_keeps_entry_order() {
        let plan = transport_plan(&cfg_with(&[
            "local",
            "tcp://127.0.0.1:7461",
            "InProc",
            " tcp://worker-2.example:9000 ",
        ]))
        .unwrap();
        let labels: Vec<String> =
            plan.iter().map(|t| t.describe()).collect();
        assert_eq!(
            labels,
            vec![
                "local",
                "tcp://127.0.0.1:7461",
                "local",
                "tcp://worker-2.example:9000",
            ]
        );
    }

    #[test]
    fn bad_entries_are_loud() {
        for bad in [
            "udp://127.0.0.1:1",
            "tcp://",
            "tcp://:7461",
            "tcp://nohost",
            "tcp://host:notaport",
            "tcp://host:99999",
            "remote",
            "",
        ] {
            let err = transport_plan(&cfg_with(&[bad]))
                .expect_err(&format!("'{bad}' must be rejected"))
                .to_string();
            assert!(
                err.contains("cluster worker"),
                "error for '{bad}' names the entry: {err}"
            );
        }
    }
}
