//! `figures` — regenerate the paper's tables and figures.
//!
//! ```text
//! figures --exp all                 # everything (Table 1, Figs 3-14)
//! figures --exp fig3 --events 200000 --out results
//! ```
//!
//! Each experiment writes long-format CSVs under `results/<exp>/` and
//! prints the paper-style summary rows (see DESIGN.md §4 for the mapping
//! and EXPERIMENTS.md for paper-vs-measured).

use anyhow::Result;

use streamrec::experiments::runner::ExpContext;
use streamrec::experiments::suites::run_experiment;
use streamrec::util::args::Args;
use streamrec::util::logging;

fn main() -> Result<()> {
    logging::init();
    let args = Args::from_env()?;
    let exp = args.get_or("exp", "all");
    let events: u64 = args.get_parse("events")?.unwrap_or(120_000);
    let seed: u64 = args.get_parse("seed")?.unwrap_or(42);
    let out = args.get_or("out", "results");
    let mut ctx = ExpContext::new(&out, events, seed);
    if let Some(cap) = args.get_parse::<u64>("central-cosine-cap")? {
        ctx.central_cosine_cap = cap;
    }
    let t0 = std::time::Instant::now();
    run_experiment(&mut ctx, &exp)?;
    eprintln!(
        "experiment '{exp}' done in {:.1}s; results under {out}/",
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
