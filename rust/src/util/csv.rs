//! Tiny CSV writer/reader for experiment results and dataset files.
//!
//! Writer: header + typed rows, escaping only when needed. Reader: the
//! subset used by the MovieLens/Netflix loaders (no embedded newlines).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Streaming CSV writer.
pub struct CsvWriter {
    out: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    /// Create the file (and parent dirs) and write the header row.
    pub fn create<P: AsRef<Path>>(
        path: P,
        header: &[&str],
    ) -> std::io::Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(Self { out, cols: header.len() })
    }

    /// Write one row; panics (debug) if the column count mismatches.
    pub fn row(&mut self, fields: &[String]) -> std::io::Result<()> {
        debug_assert_eq!(fields.len(), self.cols, "csv column count mismatch");
        let mut first = true;
        for f in fields {
            if !first {
                self.out.write_all(b",")?;
            }
            first = false;
            if f.contains(',') || f.contains('"') || f.contains('\n') {
                write!(self.out, "\"{}\"", f.replace('"', "\"\""))?;
            } else {
                self.out.write_all(f.as_bytes())?;
            }
        }
        self.out.write_all(b"\n")
    }

    /// Flush buffered rows to disk.
    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

/// Format helper (6-decimal float) so experiment code stays terse.
pub fn f(x: f64) -> String {
    format!("{x:.6}")
}

/// Format helper (integer) so experiment code stays terse.
pub fn i(x: u64) -> String {
    x.to_string()
}

/// Split one CSV line (no embedded-newline support — the dataset files the
/// loaders consume never quote newlines).
pub fn split_line(line: &str) -> Vec<String> {
    let mut fields = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes && chars.peek() == Some(&'"') => {
                cur.push('"');
                chars.next();
            }
            '"' => in_quotes = !in_quotes,
            ',' if !in_quotes => {
                fields.push(std::mem::take(&mut cur));
            }
            c => cur.push(c),
        }
    }
    fields.push(cur);
    fields
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_escapes() {
        let dir = std::env::temp_dir().join("streamrec_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        w.row(&["1".into(), "x,y".into()]).unwrap();
        w.row(&["2".into(), "q\"t".into()]).unwrap();
        w.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,\"x,y\"\n2,\"q\"\"t\"\n");
    }

    #[test]
    fn split_plain() {
        assert_eq!(split_line("1,2,3"), vec!["1", "2", "3"]);
        assert_eq!(split_line("a,\"b,c\",d"), vec!["a", "b,c", "d"]);
        assert_eq!(split_line("\"x\"\"y\""), vec!["x\"y"]);
        assert_eq!(split_line(""), vec![""]);
    }
}
