//! Minimal CLI argument parser (offline build has no clap; DESIGN.md §3).
//! Supports `subcommand --key value --flag` style invocations.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed command line: subcommand, positionals, and `--key value` options
/// (`--flag` with no value is stored as "true").
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First bare word, if any.
    pub subcommand: Option<String>,
    /// Bare words after the subcommand.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` / bare `--flag` (stored as "true").
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Self> {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    bail!("bare '--' is not supported");
                }
                // `--key=value` or `--key value` or boolean `--key`.
                if let Some((k, v)) = key.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else {
                    match iter.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = iter.next().unwrap();
                            args.options.insert(key.to_string(), v);
                        }
                        _ => {
                            args.options
                                .insert(key.to_string(), "true".to_string());
                        }
                    }
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(a);
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    /// Parse from the process's actual command line.
    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    /// Raw option value.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Option value with a default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Parsed option value (None when absent, Err on a bad parse).
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{key} {v}: {e}")),
        }
    }

    /// Boolean flag (`--key`, `--key=true|1|yes`).
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("run --dataset ml-like:1000 --ni 4 pos1 --quick");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get("dataset"), Some("ml-like:1000"));
        assert_eq!(a.get_parse::<u64>("ni").unwrap(), Some(4));
        assert!(a.flag("quick"));
        assert_eq!(a.positional, vec!["pos1"]);
        // A bare word after a flag binds to the flag (use --flag=true to
        // force boolean + positional ordering).
        let a = parse("run --quick=true pos1");
        assert!(a.flag("quick"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn equals_style() {
        let a = parse("bench --exp=fig3 --events=500");
        assert_eq!(a.get("exp"), Some("fig3"));
        assert_eq!(a.get_parse::<u64>("events").unwrap(), Some(500));
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse("run --verbose");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn bad_numeric_is_error() {
        let a = parse("run --ni abc");
        assert!(a.get_parse::<u64>("ni").is_err());
    }
}
