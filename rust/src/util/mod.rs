//! Cross-cutting substrates built from scratch for the offline environment:
//! PRNG + samplers, JSON, CSV, logging, histograms, and a tiny
//! property-testing helper (see DESIGN.md §3 for the substitution notes).

pub mod args;
pub mod csv;
pub mod histogram;
pub mod json;
pub mod logging;
pub mod proptest;
pub mod rng;
pub mod wire;

/// Monotonic nanosecond clock used by all metrics.
#[inline]
pub fn now_nanos() -> u64 {
    use std::sync::OnceLock;
    use std::time::Instant;
    static START: OnceLock<Instant> = OnceLock::new();
    START.get_or_init(Instant::now).elapsed().as_nanos() as u64
}
