//! Log-bucketed histogram for latency/size distributions (offline build has
//! no hdrhistogram crate; this is the from-scratch substitute).
//!
//! Values are u64 (nanoseconds, counts, bytes, ...). Buckets grow
//! geometrically: bucket i covers [floor(1.25^i), floor(1.25^(i+1))), which
//! bounds relative quantile error to ~25% while keeping the histogram tiny
//! and mergeable across workers.

/// Geometric-bucket histogram.
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: u128,
    min: u64,
    max: u64,
}

const GROWTH: f64 = 1.25;
// 1.25^220 > 2^64, so 224 buckets cover the full u64 range.
const BUCKETS: usize = 224;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Self {
            counts: vec![0; BUCKETS],
            total: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    #[inline]
    fn bucket(v: u64) -> usize {
        if v <= 1 {
            return 0;
        }
        // log_1.25(v) without float edge cases dominating: fine for metrics.
        ((v as f64).ln() / GROWTH.ln()) as usize
    }

    /// Record one sample.
    #[inline]
    pub fn record(&mut self, v: u64) {
        let b = Self::bucket(v).min(BUCKETS - 1);
        self.counts[b] += 1;
        self.total += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Exact mean of all recorded samples (not bucketed).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.sum as f64 / self.total as f64
    }

    /// Smallest recorded sample (0 when empty).
    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    /// Largest recorded sample.
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Quantile in [0,1] -> approximate value (bucket lower bound).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                let lo = GROWTH.powi(i as i32);
                return lo.min(self.max as f64).max(self.min as f64) as u64;
            }
        }
        self.max
    }

    /// Merge another histogram into this one (for cross-worker aggregation).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Serialize into a wire writer (bucket counts, total, the u128 sum
    /// split into two u64 halves, min, max) — the networked transport
    /// ships per-worker latency histograms home inside the final report.
    pub(crate) fn wire_encode(&self, w: &mut crate::util::wire::WireWriter) {
        w.u64_slice(&self.counts);
        w.u64(self.total);
        w.u64((self.sum >> 64) as u64);
        w.u64(self.sum as u64);
        w.u64(self.min);
        w.u64(self.max);
    }

    /// Decode the counterpart of [`Histogram::wire_encode`]; truncated
    /// or shape-skewed input is a `WireError`, never a panic.
    pub(crate) fn wire_decode(
        r: &mut crate::util::wire::WireReader<'_>,
    ) -> Result<Self, crate::util::wire::WireError> {
        let counts = r.u64_slice()?;
        if counts.len() != BUCKETS {
            return Err(crate::util::wire::WireError {
                pos: 0,
                msg: format!(
                    "histogram has {} buckets, expected {BUCKETS}",
                    counts.len()
                ),
            });
        }
        let total = r.u64()?;
        let sum_hi = r.u64()?;
        let sum_lo = r.u64()?;
        let min = r.u64()?;
        let max = r.u64()?;
        Ok(Self {
            counts,
            total,
            sum: ((sum_hi as u128) << 64) | sum_lo as u128,
            min,
            max,
        })
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.1} p50={} p99={} max={}",
            self.total,
            self.mean(),
            self.quantile(0.5),
            self.quantile(0.99),
            self.max()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn mean_exact() {
        let mut h = Histogram::new();
        for v in [10u64, 20, 30] {
            h.record(v);
        }
        assert!((h.mean() - 20.0).abs() < 1e-9);
        assert_eq!(h.min(), 10);
        assert_eq!(h.max(), 30);
    }

    #[test]
    fn quantile_monotone_and_bounded() {
        let mut h = Histogram::new();
        for v in 1..=10_000u64 {
            h.record(v);
        }
        let p50 = h.quantile(0.5);
        let p90 = h.quantile(0.9);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p90 && p90 <= p99);
        // ~25% relative bucket error allowed.
        assert!((p50 as f64) > 3500.0 && (p50 as f64) < 6500.0, "p50={p50}");
        assert!((p99 as f64) > 7300.0, "p99={p99}");
    }

    #[test]
    fn wire_round_trip_is_exact() {
        use crate::util::wire::{WireReader, WireWriter};
        let mut h = Histogram::new();
        for v in [0u64, 1, 17, 1_000_000, u64::MAX / 3] {
            h.record(v);
        }
        let mut w = WireWriter::new();
        h.wire_encode(&mut w);
        let bytes = w.into_bytes();
        let mut r = WireReader::new(&bytes);
        let back = Histogram::wire_decode(&mut r).unwrap();
        assert!(r.is_done());
        assert_eq!(back.count(), h.count());
        assert_eq!(back.min(), h.min());
        assert_eq!(back.max(), h.max());
        assert_eq!(back.sum, h.sum, "u128 sum survives the u64 halves");
        assert_eq!(back.counts, h.counts);
        // Truncation errors loudly at every strict prefix.
        for cut in 0..bytes.len() {
            let mut r = WireReader::new(&bytes[..cut]);
            assert!(Histogram::wire_decode(&mut r).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn merge_equals_combined() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut c = Histogram::new();
        for v in 0..1000u64 {
            if v % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            c.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), c.count());
        assert_eq!(a.quantile(0.5), c.quantile(0.5));
        assert_eq!(a.max(), c.max());
    }
}
