//! Minimal JSON reader/writer (offline build has no serde; DESIGN.md §3).
//!
//! The reader covers the subset the artifact `manifest.json` uses (objects,
//! arrays, strings, numbers, booleans, null); the writer is used by the
//! experiment harness to emit result files.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` | `false`.
    Bool(bool),
    /// Any JSON number (always stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys, so serialization is deterministic).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric value truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Member lookup, if this is an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

/// Parse failure with the byte offset it occurred at.
#[derive(Debug)]
pub struct JsonError {
    /// Byte offset of the failure.
    pub pos: usize,
    /// What the parser expected.
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(
                                char::from_u32(cp)
                                    .unwrap_or(char::REPLACEMENT_CHARACTER),
                            );
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a run of plain bytes at once.
                    let start = self.i;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

/// Incremental JSON writer for result emission.
#[derive(Default)]
pub struct JsonWriter {
    out: String,
}

impl JsonWriter {
    /// Empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Consume the writer, returning the serialized document.
    pub fn finish(self) -> String {
        self.out
    }

    /// Append one value (recursively).
    pub fn write_value(&mut self, v: &Json) {
        match v {
            Json::Null => self.out.push_str("null"),
            Json::Bool(b) => self.out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(self.out, "{}", *n as i64);
                } else {
                    let _ = write!(self.out, "{n}");
                }
            }
            Json::Str(s) => self.write_str(s),
            Json::Arr(a) => {
                self.out.push('[');
                for (k, x) in a.iter().enumerate() {
                    if k > 0 {
                        self.out.push(',');
                    }
                    self.write_value(x);
                }
                self.out.push(']');
            }
            Json::Obj(m) => {
                self.out.push('{');
                for (k, (key, x)) in m.iter().enumerate() {
                    if k > 0 {
                        self.out.push(',');
                    }
                    self.write_str(key);
                    self.out.push(':');
                    self.write_value(x);
                }
                self.out.push('}');
            }
        }
    }

    fn write_str(&mut self, s: &str) {
        self.out.push('"');
        for c in s.chars() {
            match c {
                '"' => self.out.push_str("\\\""),
                '\\' => self.out.push_str("\\\\"),
                '\n' => self.out.push_str("\\n"),
                '\t' => self.out.push_str("\\t"),
                '\r' => self.out.push_str("\\r"),
                c if (c as u32) < 0x20 => {
                    let _ = write!(self.out, "\\u{:04x}", c as u32);
                }
                c => self.out.push(c),
            }
        }
        self.out.push('"');
    }
}

/// Convenience: serialize a value to a string.
pub fn to_string(v: &Json) -> String {
    let mut w = JsonWriter::new();
    w.write_value(v);
    w.finish()
}

/// Convenience constructor: object from (key, value) pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Convenience constructor: number.
pub fn num(n: f64) -> Json {
    Json::Num(n)
}

/// Convenience constructor: string.
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

/// Convenience constructor: array of numbers.
pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let src = r#"{"a": 1, "b": [1.5, -2, true, null], "c": {"d": "x\ny"}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 4);
        assert_eq!(
            v.get("c").unwrap().get("d").unwrap().as_str(),
            Some("x\ny")
        );
        let text = to_string(&v);
        let v2 = Json::parse(&text).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn parses_manifest_like() {
        let src = r#"{"latent_k": 10, "artifacts": [{"name": "isgd_b1",
            "file": "isgd_b1.hlo.txt", "kind": "isgd", "b": 1, "k": 10,
            "inputs": [{"shape": [1, 10], "dtype": "f32"}]}]}"#;
        let v = Json::parse(src).unwrap();
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("isgd_b1"));
        let shape = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[1].as_usize(), Some(10));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Ab""#).unwrap();
        assert_eq!(v.as_str(), Some("Ab"));
    }
}
