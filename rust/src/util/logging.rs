//! Minimal `log` backend: timestamped stderr logger with a level filter
//! from `STREAMREC_LOG` (error|warn|info|debug|trace; default info).

use std::io::Write;
use std::time::{SystemTime, UNIX_EPOCH};

use log::{Level, LevelFilter, Log, Metadata, Record};

struct StderrLogger {
    level: LevelFilter,
}

impl Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let now = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .unwrap_or_default();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        let _ = writeln!(
            std::io::stderr().lock(),
            "[{:>10}.{:03} {} {}] {}",
            now.as_secs(),
            now.subsec_millis(),
            lvl,
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent; later calls are no-ops).
pub fn init() {
    let level = match std::env::var("STREAMREC_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    let logger = Box::new(StderrLogger { level });
    if log::set_boxed_logger(logger).is_ok() {
        log::set_max_level(level);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
