//! Compact little-endian binary framing for state migration (the
//! rescale path serializes whole model lanes; JSON would be ~4x the
//! bytes for the f32-heavy ISGD state and parsing cost scales with the
//! pause the migration is trying to keep short).
//!
//! The format is deliberately primitive: fixed-width scalars, `u32`
//! length prefixes for variable-length sections, no alignment, no
//! compression. Every reader method is bounds-checked and returns a
//! typed error instead of panicking, so a corrupt or truncated snapshot
//! surfaces as an `Err` at import time rather than a worker panic.

/// Error raised by [`WireReader`] on truncated or malformed input.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireError {
    /// Byte offset the failed read started at.
    pub pos: usize,
    /// Human-readable description of what was expected.
    pub msg: String,
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "wire decode error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for WireError {}

/// Append-only encoder over a growable byte buffer.
#[derive(Debug, Default)]
pub struct WireWriter {
    buf: Vec<u8>,
}

impl WireWriter {
    /// Fresh empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fresh empty writer whose buffer is pre-sized for `cap` bytes, so
    /// an encoder that knows its output size up front pays one exact
    /// allocation instead of a sequence of growth doublings.
    pub fn with_capacity(cap: usize) -> Self {
        Self { buf: Vec::with_capacity(cap) }
    }

    /// Fresh *empty* writer that recycles `buf`'s allocation (the vector
    /// is cleared, its capacity kept). Paired with
    /// [`WireWriter::into_bytes`] this lets a hot encode loop — e.g. the
    /// per-frame TCP write path — reuse one buffer across iterations
    /// instead of allocating per frame.
    pub fn from_vec(mut buf: Vec<u8>) -> Self {
        buf.clear();
        Self { buf }
    }

    /// Reserve room for at least `additional` more bytes.
    pub fn reserve(&mut self, additional: usize) {
        self.buf.reserve(additional);
    }

    /// Bytes written so far.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when nothing has been written.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Consume the writer, returning the encoded bytes.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Append one byte.
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Append a `u32` (little endian).
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u64` (little endian).
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append an `f32` bit pattern (little endian); round-trips NaNs and
    /// signed zeros exactly, which "bit-identical migration" requires.
    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u32`-length-prefixed slice of f32s.
    pub fn f32_slice(&mut self, vs: &[f32]) {
        self.u32(vs.len() as u32);
        for &v in vs {
            self.f32(v);
        }
    }

    /// Append a `u32`-length-prefixed slice of u64s.
    pub fn u64_slice(&mut self, vs: &[u64]) {
        self.u32(vs.len() as u32);
        for &v in vs {
            self.u64(v);
        }
    }

    /// Append raw bytes verbatim (no length prefix) — used to nest an
    /// already-framed payload (e.g. a model partition inside a lane
    /// checkpoint frame) without re-encoding it.
    pub fn bytes(&mut self, bs: &[u8]) {
        self.buf.extend_from_slice(bs);
    }

    /// Append a `u32`-length-prefixed byte slice — unlike
    /// [`WireWriter::bytes`], the counterpart read knows exactly where
    /// the payload ends, so a frame can carry several of them and any
    /// truncation is detectable (the networked-transport framing relies
    /// on this: no trailing-`rest` payloads on the wire).
    pub fn byte_slice(&mut self, bs: &[u8]) {
        self.u32(bs.len() as u32);
        self.buf.extend_from_slice(bs);
    }

    /// Append a `u32`-length-prefixed UTF-8 string.
    pub fn string(&mut self, s: &str) {
        self.byte_slice(s.as_bytes());
    }
}

/// Bounds-checked decoder over an encoded byte slice.
#[derive(Debug)]
pub struct WireReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> WireReader<'a> {
    /// Start decoding `buf` from offset 0.
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// True once every byte has been consumed.
    pub fn is_done(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError {
                pos: self.pos,
                msg: format!(
                    "need {n} bytes for {what}, {} left",
                    self.remaining()
                ),
            });
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    /// Read one byte.
    pub fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1, "u8")?[0])
    }

    /// Read a little-endian `u32`.
    pub fn u32(&mut self) -> Result<u32, WireError> {
        let b = self.take(4, "u32")?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Read a little-endian `u64`.
    pub fn u64(&mut self) -> Result<u64, WireError> {
        let b = self.take(8, "u64")?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    /// Read a little-endian `f32` bit pattern.
    pub fn f32(&mut self) -> Result<f32, WireError> {
        let b = self.take(4, "f32")?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Read a `u32`-length-prefixed f32 slice.
    pub fn f32_slice(&mut self) -> Result<Vec<f32>, WireError> {
        let n = self.u32()? as usize;
        let mut out = Vec::with_capacity(n.min(self.remaining() / 4 + 1));
        for _ in 0..n {
            out.push(self.f32()?);
        }
        Ok(out)
    }

    /// Read a `u32`-length-prefixed u64 slice.
    pub fn u64_slice(&mut self) -> Result<Vec<u64>, WireError> {
        let n = self.u32()? as usize;
        let mut out = Vec::with_capacity(n.min(self.remaining() / 8 + 1));
        for _ in 0..n {
            out.push(self.u64()?);
        }
        Ok(out)
    }

    /// Consume and return every remaining byte — the counterpart of
    /// [`WireWriter::bytes`] for a nested trailing payload.
    pub fn rest(&mut self) -> &'a [u8] {
        let out = &self.buf[self.pos..];
        self.pos = self.buf.len();
        out
    }

    /// Read a `u32`-length-prefixed byte slice (the counterpart of
    /// [`WireWriter::byte_slice`]). A length prefix larger than the
    /// remaining buffer is a bounds error, never an allocation of the
    /// claimed size.
    pub fn byte_slice(&mut self) -> Result<Vec<u8>, WireError> {
        let n = self.u32()? as usize;
        Ok(self.take(n, "byte slice")?.to_vec())
    }

    /// Read a `u32`-length-prefixed UTF-8 string (the counterpart of
    /// [`WireWriter::string`]); invalid UTF-8 is a [`WireError`].
    pub fn string(&mut self) -> Result<String, WireError> {
        let pos = self.pos;
        let bytes = self.byte_slice()?;
        String::from_utf8(bytes).map_err(|e| WireError {
            pos,
            msg: format!("invalid UTF-8 in string: {e}"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let mut w = WireWriter::new();
        w.u8(7);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX - 3);
        w.f32(-0.0);
        w.f32(f32::NAN);
        let bytes = w.into_bytes();
        let mut r = WireReader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        // Bit-exact: signed zero and NaN payload survive.
        assert_eq!(r.f32().unwrap().to_bits(), (-0.0f32).to_bits());
        assert!(r.f32().unwrap().is_nan());
        assert!(r.is_done());
    }

    #[test]
    fn slice_roundtrip() {
        let mut w = WireWriter::new();
        w.f32_slice(&[1.5, -2.25, 3.0]);
        w.u64_slice(&[9, 8, 7, 6]);
        w.f32_slice(&[]);
        let bytes = w.into_bytes();
        let mut r = WireReader::new(&bytes);
        assert_eq!(r.f32_slice().unwrap(), vec![1.5, -2.25, 3.0]);
        assert_eq!(r.u64_slice().unwrap(), vec![9, 8, 7, 6]);
        assert_eq!(r.f32_slice().unwrap(), Vec::<f32>::new());
        assert!(r.is_done());
    }

    #[test]
    fn truncated_input_errors_instead_of_panicking() {
        let mut w = WireWriter::new();
        w.u64(42);
        let bytes = w.into_bytes();
        let mut r = WireReader::new(&bytes[..5]);
        let err = r.u64().unwrap_err();
        assert_eq!(err.pos, 0);
        assert!(err.to_string().contains("need 8 bytes"));
    }

    #[test]
    fn raw_bytes_and_rest_round_trip() {
        let mut w = WireWriter::new();
        w.u32(7);
        w.bytes(&[1, 2, 3, 4, 5]);
        let bytes = w.into_bytes();
        let mut r = WireReader::new(&bytes);
        assert_eq!(r.u32().unwrap(), 7);
        assert_eq!(r.rest(), &[1, 2, 3, 4, 5]);
        assert!(r.is_done());
        assert_eq!(r.rest(), &[] as &[u8], "rest after rest is empty");
    }

    #[test]
    fn byte_slice_and_string_round_trip() {
        let mut w = WireWriter::new();
        w.byte_slice(&[9, 8, 7]);
        w.string("tcp://127.0.0.1:7461");
        w.byte_slice(&[]);
        w.string("");
        let bytes = w.into_bytes();
        let mut r = WireReader::new(&bytes);
        assert_eq!(r.byte_slice().unwrap(), vec![9, 8, 7]);
        assert_eq!(r.string().unwrap(), "tcp://127.0.0.1:7461");
        assert_eq!(r.byte_slice().unwrap(), Vec::<u8>::new());
        assert_eq!(r.string().unwrap(), "");
        assert!(r.is_done());
    }

    #[test]
    fn byte_slice_truncation_and_bad_utf8_error() {
        let mut w = WireWriter::new();
        w.byte_slice(&[1, 2, 3, 4]);
        let bytes = w.into_bytes();
        // Every strict prefix must fail loudly.
        for cut in 0..bytes.len() {
            let mut r = WireReader::new(&bytes[..cut]);
            assert!(r.byte_slice().is_err(), "prefix of {cut} bytes");
        }
        // A hostile length prefix is a bounds error, not an allocation.
        let mut w = WireWriter::new();
        w.u32(u32::MAX);
        let bytes = w.into_bytes();
        assert!(WireReader::new(&bytes).byte_slice().is_err());
        // Invalid UTF-8 surfaces as a WireError with the right offset.
        let mut w = WireWriter::new();
        w.byte_slice(&[0xFF, 0xFE]);
        let bytes = w.into_bytes();
        let err = WireReader::new(&bytes).string().unwrap_err();
        assert_eq!(err.pos, 0);
        assert!(err.to_string().contains("UTF-8"));
    }

    #[test]
    fn recycled_and_presized_writers_encode_identically() {
        let encode = |mut w: WireWriter| {
            w.u8(3);
            w.u64(0xFEED_FACE_CAFE_BEEF);
            w.byte_slice(&[7, 7, 7]);
            w.into_bytes()
        };
        let fresh = encode(WireWriter::new());
        assert_eq!(encode(WireWriter::with_capacity(64)), fresh);
        // from_vec clears stale content but keeps the allocation.
        let recycled = Vec::from([9u8; 128]);
        let cap = recycled.capacity();
        let w = WireWriter::from_vec(recycled);
        assert!(w.is_empty());
        let bytes = encode(w);
        assert_eq!(bytes, fresh);
        assert!(bytes.capacity() >= cap, "allocation was recycled");
    }

    #[test]
    fn hostile_length_prefix_does_not_overallocate() {
        // A length prefix claiming 2^32-1 elements over a 4-byte body
        // must fail cleanly (and the with_capacity guard keeps the
        // attempted allocation proportional to the real buffer).
        let mut w = WireWriter::new();
        w.u32(u32::MAX);
        let bytes = w.into_bytes();
        let mut r = WireReader::new(&bytes);
        assert!(r.f32_slice().is_err());
    }
}
