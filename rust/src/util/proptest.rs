//! Tiny property-testing helper (the offline build has no proptest crate;
//! DESIGN.md §3). Runs a property over N seeded random cases and, on
//! failure, reports the first failing seed so the case can be replayed
//! deterministically with `check_seeded`.
//!
//! ```
//! use streamrec::util::proptest::forall;
//! forall("add_commutes", 200, |rng| {
//!     let a = rng.next_bounded(1000) as i64;
//!     let b = rng.next_bounded(1000) as i64;
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::Pcg32;

/// Run `prop` over `cases` seeded PRNGs; panic with the failing seed on the
/// first failure (the property itself should panic/assert on violation).
pub fn forall(name: &str, cases: u64, mut prop: impl FnMut(&mut Pcg32)) {
    for case in 0..cases {
        let seed = splitmix_case_seed(name, case);
        let mut rng = Pcg32::seeded(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || prop(&mut rng),
        ));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| {
                    payload.downcast_ref::<&str>().map(|s| s.to_string())
                })
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Replay a single case by seed (for debugging a forall failure).
pub fn check_seeded(seed: u64, mut prop: impl FnMut(&mut Pcg32)) {
    let mut rng = Pcg32::seeded(seed);
    prop(&mut rng);
}

fn splitmix_case_seed(name: &str, case: u64) -> u64 {
    // Stable across runs: hash of the property name + case index.
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    super::rng::mix64(h ^ case)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall("trivial", 50, |rng| {
            let x = rng.next_bounded(100);
            assert!(x < 100);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always_fails'")]
    fn reports_failure_with_seed() {
        forall("always_fails", 10, |_| panic!("boom"));
    }

    #[test]
    fn seeds_stable_across_runs() {
        assert_eq!(
            splitmix_case_seed("x", 3),
            splitmix_case_seed("x", 3)
        );
        assert_ne!(
            splitmix_case_seed("x", 3),
            splitmix_case_seed("y", 3)
        );
    }
}
