//! Deterministic PRNG + samplers (no external `rand` crate in the offline
//! build, so this is a from-scratch substrate; see DESIGN.md §3).
//!
//! * [`SplitMix64`] — seeding / stateless hashing.
//! * [`Pcg32`] — the workhorse generator (PCG-XSH-RR 64/32, O'Neill 2014).
//! * Gaussian via Box–Muller, Zipf via rejection-inversion (Hörmann &
//!   Derflinger 1996), the samplers the synthetic dataset generator needs.

/// SplitMix64: tiny, full-period 2^64 generator. Used to expand one user
/// seed into independent stream seeds and as a stateless integer mixer.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Generator starting at `seed`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Stateless SplitMix64 finalizer — used as a hash for id scrambling.
#[inline]
pub fn mix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG-XSH-RR 64/32: small-state, statistically strong, fast. The main
/// generator behind the synthetic data and model initialization.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Generator from a (seed, stream-id) pair — PCG's standard init.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Self { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Seed from a single value, deriving the stream id via SplitMix64.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = sm.next_u64();
        let inc = sm.next_u64();
        Self::new(s, inc)
    }

    /// Raw `(state, inc)` words — the generator's complete state, used by
    /// the migration path to serialize a model's RNG so rescaled workers
    /// continue the *same* random stream (bit-identical future draws).
    pub fn snapshot(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from a [`Pcg32::snapshot`] pair.
    pub fn restore(state: u64, inc: u64) -> Self {
        Self { state, inc }
    }

    /// Next 32 random bits (the native PCG output).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 random bits (two native outputs).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, bound) — Lemire's unbiased method.
    #[inline]
    pub fn next_bounded(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (bound as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return hi;
            }
        }
    }

    /// Standard normal via Box–Muller (one value per call; the pair's
    /// second half is discarded — simplicity over throughput here, the
    /// generator is not on the request path).
    pub fn next_gaussian(&mut self) -> f64 {
        let u1 = loop {
            let u = self.next_f64();
            if u > 1e-12 {
                break u;
            }
        };
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_bounded(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// Zipf(n, e) sampler over {0, .., n-1} by rejection-inversion
/// (Hörmann & Derflinger 1996; same algorithm as rand_distr / Apache
/// commons' RejectionInversionZipfSampler). Heavy-tailed item popularity
/// and user activity in the synthetic datasets come from this.
#[derive(Debug, Clone)]
pub struct Zipf {
    n: f64,
    /// Exponent of the distribution (p(k) ∝ k^-e).
    exponent: f64,
    /// Precomputed acceptance threshold (NOT the exponent).
    s: f64,
    h_integral_x1: f64,
    h_integral_n: f64,
}

impl Zipf {
    /// Sampler over `{0, .., n-1}` with exponent `e >= 0`.
    pub fn new(n: u64, exponent: f64) -> Self {
        assert!(n >= 1, "Zipf needs n >= 1");
        assert!(exponent >= 0.0, "Zipf exponent must be >= 0");
        let nf = n as f64;
        let h_integral_n = Self::h_integral(nf + 0.5, exponent);
        let h_integral_x1 = Self::h_integral(1.5, exponent) - 1.0;
        // Threshold for the fast-accept branch: s = 2 - H^-1(H(2.5) - h(2)).
        let s = 2.0
            - Self::h_integral_inverse(
                Self::h_integral(2.5, exponent) - Self::h(2.0, exponent),
                exponent,
            );
        Self { n: nf, exponent, s, h_integral_x1, h_integral_n }
    }

    /// H(x) = integral of h(x) = x^-e (log-form for e = 1).
    fn h_integral(x: f64, e: f64) -> f64 {
        let log_x = x.ln();
        helper2((1.0 - e) * log_x) * log_x
    }

    fn h(x: f64, e: f64) -> f64 {
        (-e * x.ln()).exp()
    }

    fn h_integral_inverse(x: f64, e: f64) -> f64 {
        let mut t = x * (1.0 - e);
        if t < -1.0 {
            t = -1.0;
        }
        (helper1(t) * x).exp()
    }

    /// Sample a rank in [0, n): rank 0 is the most popular.
    pub fn sample(&self, rng: &mut Pcg32) -> u64 {
        loop {
            let u = self.h_integral_n
                + rng.next_f64() * (self.h_integral_x1 - self.h_integral_n);
            let x = Self::h_integral_inverse(u, self.exponent);
            let k = (x + 0.5).floor().clamp(1.0, self.n);
            // Fast accept near the inverse; otherwise the exact check.
            if k - x <= self.s
                || u >= Self::h_integral(k + 0.5, self.exponent)
                    - Self::h(k, self.exponent)
            {
                return k as u64 - 1;
            }
        }
    }
}

/// helper1(x) = log1p(x)/x, stable near 0.
fn helper1(x: f64) -> f64 {
    if x.abs() > 1e-8 {
        x.ln_1p() / x
    } else {
        1.0 - x * (0.5 - x * (1.0 / 3.0 - 0.25 * x))
    }
}

/// helper2(x) = (exp(x)-1)/x, stable near 0.
fn helper2(x: f64) -> f64 {
    if x.abs() > 1e-8 {
        x.exp_m1() / x
    } else {
        1.0 + x * 0.5 * (1.0 + x / 3.0 * (1.0 + 0.25 * x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcg32_deterministic() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn pcg32_snapshot_restore_continues_stream() {
        let mut a = Pcg32::seeded(99);
        for _ in 0..17 {
            a.next_u64();
        }
        let (state, inc) = a.snapshot();
        let mut b = Pcg32::restore(state, inc);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn pcg32_distinct_seeds_diverge() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_mean_close() {
        let mut rng = Pcg32::seeded(7);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn bounded_is_in_range_and_covers() {
        let mut rng = Pcg32::seeded(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.next_bounded(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg32::seeded(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_rank0_most_popular() {
        let zipf = Zipf::new(1000, 1.1);
        let mut rng = Pcg32::seeded(13);
        let mut counts = vec![0u64; 1000];
        for _ in 0..200_000 {
            counts[zipf.sample(&mut rng) as usize] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[500]);
        // Roughly power-law: count(0)/count(9) ≈ 10^1.1 ≈ 12.6 (loose).
        let ratio = counts[0] as f64 / counts[9].max(1) as f64;
        assert!(ratio > 5.0 && ratio < 40.0, "ratio={ratio}");
    }

    #[test]
    fn zipf_s_zero_is_uniformish() {
        let zipf = Zipf::new(100, 0.0);
        let mut rng = Pcg32::seeded(17);
        let mut counts = vec![0u64; 100];
        for _ in 0..100_000 {
            counts[zipf.sample(&mut rng) as usize] += 1;
        }
        let max = *counts.iter().max().unwrap() as f64;
        let min = *counts.iter().min().unwrap() as f64;
        assert!(max / min < 1.5, "max={max} min={min}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(19);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn mix64_distinct() {
        assert_ne!(mix64(0), mix64(1));
        assert_ne!(mix64(1), mix64(2));
    }
}
