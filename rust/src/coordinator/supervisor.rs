//! The worker supervisor: spawn/respawn, liveness, checkpoints, and
//! exactly-once crash recovery.
//!
//! The [`Cluster`](crate::coordinator::Cluster) owns the *session* —
//! routing, buffering, the public API. This module owns the *workers*:
//! it spawns each generation's
//! [`WorkerActor`](crate::engine::actor::WorkerActor)s through the
//! session's [`Transport`] plan (local threads, remote TCP peers, or a
//! mix — `[cluster] workers`), detects crashes (a failed channel send,
//! a [`WorkerHandle::is_finished`] liveness scan, or a panic surfacing
//! at join), and brings a crashed worker back so the session never
//! notices. Remote placement is crash-transparent too: a lost
//! connection panics the proxy thread standing in for the worker, so
//! both detection paths fire unchanged, and the respawn re-dials the
//! same address (placement is `slot mod transports`).
//!
//! # The recovery contract
//!
//! With `fault.checkpoint_interval > 0` the supervisor maintains, on the
//! coordinator side:
//!
//! * a **checkpoint store** — the latest lane frame of every lane,
//!   pushed by workers over a dedicated channel (non-blocking on the
//!   worker side, drained here on every flush), each stamped with the
//!   lane's high-watermark `seq`;
//! * a **bounded replay log** — the last `fault.replay_log_capacity`
//!   accepted envelopes, in global order. An envelope may be evicted
//!   once a checkpoint covers it; evicting an *uncovered* envelope is
//!   remembered per lane, and a recovery that would need it fails loudly
//!   instead of silently losing an event.
//!
//! Recovery of a dead worker slot is then: reap (fold its channel
//! counters into the retained base so transport totals never regress,
//! join the thread, log the panic) → respawn (a fresh actor with chaos
//! disarmed) → restore (send every owned lane's latest checkpoint as an
//! `Import` that also restores the lane's counters) → replay (walk the
//! log once, re-sending each owned lane's suffix past its checkpoint
//! watermark). FIFO ordering puts imports before replay and replay
//! before any future event, and the per-lane watermark filters both
//! here and in the actor, so every event is applied **exactly once** —
//! a recovered session's hits, recall curve, and answers are
//! byte-identical to a never-crashed run
//! (`tests/fault_tolerance.rs`).
//!
//! With fault tolerance disabled (the default), a worker death is what
//! it always was: a loud, unrecoverable session error.

use std::collections::BTreeMap;
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::config::{RunConfig, Topology};
use crate::coordinator::router::{Router, StateGrid};
use crate::coordinator::serving::ServingState;
use crate::engine::actor::{
    lane_frame_watermark, zero_lane_frame_counters, ChaosPolicy,
    CheckpointMsg, CollectorMsg, Envelope, QueryMsg, WorkerExport, WorkerMsg,
};
use crate::engine::{
    bounded, bounded_with_signal, ChannelStats, Receiver, Sender, WakeSignal,
    WorkerHandle,
};
use crate::eval::WorkerReport;
use crate::net::{Transport, WorkerBoot};

/// Cumulative fault-tolerance counters, surfaced in `ClusterMetrics` and
/// `RunReport`.
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct FaultStats {
    /// Completed crash recoveries.
    pub(crate) recoveries: u64,
    /// Total serialized lane-frame bytes received as checkpoints.
    pub(crate) checkpoint_bytes: u64,
    /// Envelopes re-sent from the replay log by recoveries.
    pub(crate) replayed_events: u64,
    /// Total ns spent inside recovery (reap + respawn + restore +
    /// replay) — the fault-tolerance analog of `rescale_pause_ns`.
    pub(crate) recovery_pause_ns: u64,
}

/// One physical worker slot of the current generation. `tx`/`handle`
/// become `None` only while the slot is being reaped or at shutdown.
struct WorkerSlot {
    /// Session-unique worker id (keeps counting across generations and
    /// recoveries).
    ord: usize,
    tx: Option<Sender<WorkerMsg>>,
    /// Sending half of the slot's dedicated serving lane. The serving
    /// plan holds its own clone; this one exists so a respawn can hand
    /// the *fresh* pair to [`ServingState::on_recover`].
    query_tx: Option<Sender<QueryMsg>>,
    handle: Option<WorkerHandle<Result<WorkerReport>>>,
    /// Root cause captured when this slot's worker was reaped. The slot
    /// keeps it only while unrecovered (fault tolerance off), so a later
    /// `finish` can still surface *why* the session is dead even though
    /// the join already consumed the panic.
    cause: Option<String>,
    /// Consecutive recoveries of this slot within [`RESPAWN_WINDOW`]
    /// (carried into the replacement slot). A deterministic failure —
    /// one the restored worker re-hits on replay — would otherwise turn
    /// the ingest path into a silent infinite crash/recover loop; the
    /// probe paths are already bounded by their retry counts.
    respawns: u32,
    /// When this slot was last respawned by a recovery.
    last_respawn: Option<Instant>,
}

/// Consecutive same-slot recoveries tolerated within [`RESPAWN_WINDOW`]
/// before the supervisor gives up loudly.
const RESPAWN_LIMIT: u32 = 8;

/// Rolling window for [`RESPAWN_LIMIT`]: respawns further apart than
/// this are treated as independent incidents, not a crash loop.
const RESPAWN_WINDOW: std::time::Duration = std::time::Duration::from_secs(30);

/// Latest checkpoint of one lane.
struct Checkpoint {
    /// High-watermark seq the frame covers (`None` = frame predates any
    /// event; replay starts from zero).
    watermark: Option<u64>,
    /// The encoded lane frame.
    bytes: Vec<u8>,
}

/// Bounded ring of the most recently accepted envelopes.
struct ReplayLog {
    buf: VecDeque<Envelope>,
    capacity: usize,
}

impl ReplayLog {
    fn new(capacity: usize) -> Self {
        Self { buf: VecDeque::new(), capacity: capacity.max(1) }
    }

    /// Append; returns the envelope evicted to make room, if any.
    fn push(&mut self, env: Envelope) -> Option<Envelope> {
        let evicted = if self.buf.len() >= self.capacity {
            self.buf.pop_front()
        } else {
            None
        };
        self.buf.push_back(env);
        evicted
    }
}

/// Spawns, watches, checkpoints, and recovers the worker plane.
pub(crate) struct Supervisor {
    /// Where worker slots run: cycled by slot index (`wid % len`), so
    /// respawns keep their placement. Always non-empty — the default
    /// plan is a single in-proc transport.
    transports: Vec<Arc<dyn Transport>>,
    /// Configuration echo; the topology field tracks rescales.
    cfg: RunConfig,
    grid: StateGrid,
    /// Master collector sender cloned into every spawned actor; dropped
    /// at shutdown so the collector sees end-of-stream.
    col_tx: Option<Sender<CollectorMsg>>,
    /// Checkpoint channel: cloned into actors, drained here.
    ckpt_tx: Sender<CheckpointMsg>,
    ckpt_rx: Receiver<CheckpointMsg>,
    slots: Vec<WorkerSlot>,
    /// lane → latest checkpoint.
    store: BTreeMap<u64, Checkpoint>,
    replay: ReplayLog,
    /// Per lane: newest ingested seq + 1 (0 = the lane has no events).
    /// Sized `n_lanes` when fault tolerance is enabled, empty otherwise.
    lane_last: Vec<u64>,
    /// lane → newest replay-log eviction not covered by any checkpoint.
    /// A recovery whose replay floor is at or below this seq would lose
    /// events and fails loudly instead.
    lost: BTreeMap<u64, u64>,
    /// Armed chaos policy for freshly spawned generations; disarmed for
    /// good by the first recovery (the kill fired).
    chaos: ChaosPolicy,
    next_ord: usize,
    /// Channel counters of dead/retired channels, folded in so totals
    /// never regress (`ChannelStats::absorb`). Event-FIFO channels only;
    /// the serving lanes keep their own books.
    chan_base: ChannelStats,
    stats: FaultStats,
    /// The session's serving plane, once attached: a recovery swaps the
    /// replacement worker's fresh senders into the live plan and
    /// invalidates the cache columns the slot hosts. `None` until
    /// [`Supervisor::attach_serving`] (and in supervisor-only tests).
    serving: Option<Arc<ServingState>>,
}

impl Supervisor {
    /// Supervisor for a fresh session. Spawn the first generation with
    /// [`Supervisor::spawn_generation`].
    pub(crate) fn new(
        cfg: &RunConfig,
        grid: StateGrid,
        col_tx: Sender<CollectorMsg>,
        transports: Vec<Arc<dyn Transport>>,
    ) -> Self {
        debug_assert!(!transports.is_empty(), "empty transport plan");
        let enabled = cfg.fault_checkpoint_interval > 0;
        let (ckpt_tx, ckpt_rx) =
            bounded::<CheckpointMsg>(grid.n_lanes() as usize + 64);
        Self {
            transports,
            cfg: cfg.clone(),
            grid,
            col_tx: Some(col_tx),
            ckpt_tx,
            ckpt_rx,
            slots: Vec::new(),
            store: BTreeMap::new(),
            replay: ReplayLog::new(cfg.fault_replay_log_capacity),
            lane_last: vec![0; if enabled { grid.n_lanes() as usize } else { 0 }],
            lost: BTreeMap::new(),
            chaos: ChaosPolicy::from_config(cfg),
            next_ord: 0,
            chan_base: ChannelStats::default(),
            stats: FaultStats::default(),
            serving: None,
        }
    }

    /// Attach the session's serving plane so recoveries can refresh its
    /// senders in place and invalidate affected cache columns.
    pub(crate) fn attach_serving(&mut self, serving: Arc<ServingState>) {
        self.serving = Some(serving);
    }

    /// Is checkpoint/replay fault tolerance on (`fault.checkpoint_interval
    /// > 0`)?
    pub(crate) fn enabled(&self) -> bool {
        self.cfg.fault_checkpoint_interval > 0
    }

    /// Cumulative fault-tolerance counters.
    pub(crate) fn stats(&self) -> FaultStats {
        self.stats
    }

    /// Track a rescale's topology change (respawned actors inherit it).
    pub(crate) fn set_topology(&mut self, t: Topology) {
        self.cfg.topology = t;
    }

    /// Workers in the current generation.
    pub(crate) fn n_workers(&self) -> usize {
        self.slots.len()
    }

    /// Spawn a fresh generation of `n_c` workers (the previous one must
    /// have been retired).
    pub(crate) fn spawn_generation(&mut self, n_c: usize) {
        debug_assert!(self.slots.is_empty(), "previous generation not retired");
        let chaos = self.chaos;
        let mut slots = Vec::with_capacity(n_c);
        for wid in 0..n_c {
            slots.push(self.spawn_slot(wid, chaos));
        }
        self.slots = slots;
    }

    /// Stand up one worker slot via its transport. `wid` is the slot
    /// index in the generation — `wid % transports.len()` picks the
    /// placement, so a respawned slot re-dials the same address its
    /// predecessor used.
    fn spawn_slot(&mut self, wid: usize, chaos: ChaosPolicy) -> WorkerSlot {
        let ord = self.next_ord;
        self.next_ord += 1;
        // Both inputs share one wake signal so the actor can sleep on a
        // single latch while draining either (see `WakeSignal`).
        let signal = WakeSignal::new();
        let (tx, rx) = bounded_with_signal::<WorkerMsg>(
            self.cfg.channel_capacity,
            &signal,
        );
        let (query_tx, query_rx) = bounded_with_signal::<QueryMsg>(
            self.cfg.serving_queue_capacity,
            &signal,
        );
        let col_tx = self
            .col_tx
            .as_ref()
            .expect("spawn after shutdown")
            .clone();
        let ckpt_tx = if self.enabled() {
            Some(self.ckpt_tx.clone())
        } else {
            None
        };
        let transport = &self.transports[wid % self.transports.len()];
        log::debug!(
            "supervisor: slot {wid} spawns worker {ord} on {}",
            transport.describe()
        );
        let boot = WorkerBoot {
            ord,
            cfg: self.cfg.clone(),
            grid: self.grid,
            rx,
            query_rx,
            signal,
            col_tx,
            ckpt_tx,
            chaos,
        };
        let handle = transport.spawn_worker(boot);
        WorkerSlot {
            ord,
            tx: Some(tx),
            query_tx: Some(query_tx),
            handle: Some(handle),
            cause: None,
            respawns: 0,
            last_respawn: None,
        }
    }

    /// Clone slot `wid`'s data-plane senders (event FIFO + serving lane)
    /// for the serving plan. `None` while the slot is reaped.
    pub(crate) fn slot_senders(
        &self,
        wid: usize,
    ) -> Option<(Sender<WorkerMsg>, Sender<QueryMsg>)> {
        let slot = self.slots.get(wid)?;
        match (&slot.tx, &slot.query_tx) {
            (Some(tx), Some(qtx)) => Some((tx.clone(), qtx.clone())),
            _ => None,
        }
    }

    /// Bookkeep one accepted envelope (fault-tolerant sessions only):
    /// remember the lane's newest seq and append to the replay log,
    /// tracking any eviction that no checkpoint covers.
    pub(crate) fn record_ingest(&mut self, env: Envelope, lane: u64) {
        self.lane_last[lane as usize] = env.seq + 1;
        if let Some(evicted) = self.replay.push(env) {
            let elane =
                self.grid.lane(evicted.rating.user, evicted.rating.item);
            let covered = self
                .store
                .get(&elane)
                .and_then(|c| c.watermark)
                .is_some_and(|w| evicted.seq <= w);
            if !covered {
                self.lost.insert(elane, evicted.seq);
            }
        }
    }

    /// Absorb every checkpoint queued by the workers (non-blocking).
    pub(crate) fn drain_checkpoints(&mut self) {
        let mut buf: Vec<CheckpointMsg> = Vec::new();
        if self.ckpt_rx.try_drain(&mut buf) == 0 {
            return;
        }
        for msg in buf {
            self.stats.checkpoint_bytes += msg.bytes.len() as u64;
            let watermark = lane_frame_watermark(&msg.bytes);
            log::trace!(
                "checkpoint: lane {} from worker {} ({} bytes, watermark {:?})",
                msg.lane,
                msg.ord,
                msg.bytes.len(),
                watermark,
            );
            self.store_checkpoint(msg.lane, watermark, msg.bytes);
        }
    }

    /// Adopt a frame as a lane's checkpoint — monotone in the watermark:
    /// a stale frame (e.g. one a retiring generation queued before its
    /// export, drained after the rescale installed fresher zero-counter
    /// frames) must never overwrite a newer snapshot of the lane, or a
    /// later recovery would restore pre-baseline counters and replay an
    /// already-covered prefix.
    fn store_checkpoint(
        &mut self,
        lane: u64,
        watermark: Option<u64>,
        bytes: Vec<u8>,
    ) {
        if let Some(existing) = self.store.get(&lane) {
            // Option ordering: None < Some(_), so a watermark-less frame
            // never replaces a real one.
            if watermark < existing.watermark {
                return;
            }
        }
        if let Some(w) = watermark {
            // The lane is covered again up to `w`: forget older
            // uncovered evictions.
            if self.lost.get(&lane).is_some_and(|&s| s <= w) {
                self.lost.remove(&lane);
            }
        }
        self.store.insert(lane, Checkpoint { watermark, bytes });
    }

    /// Send a probe (`MetricsSnapshot`), recovering a dead worker
    /// once and re-sending. Fault-tolerant sessions only.
    pub(crate) fn send_probe(
        &mut self,
        wid: usize,
        msg: WorkerMsg,
        router: &Router,
    ) -> Result<()> {
        let msg = match &self.slots[wid].tx {
            Some(tx) => match tx.send(msg) {
                Ok(()) => return Ok(()),
                Err(e) => e.0,
            },
            None => msg,
        };
        self.recover(wid, router)?;
        let sent = self
            .slots[wid]
            .tx
            .as_ref()
            .is_some_and(|tx| tx.send(msg).is_ok());
        if !sent {
            bail!("worker {wid} died again immediately after recovery");
        }
        Ok(())
    }

    /// Fire-and-forget send; `false` if the worker is gone (the old,
    /// non-recovering behavior — used when fault tolerance is off, and
    /// for rescale imports to freshly spawned workers).
    pub(crate) fn probe(&self, wid: usize, msg: WorkerMsg) -> bool {
        self.slots[wid]
            .tx
            .as_ref()
            .is_some_and(|tx| tx.send(msg).is_ok())
    }

    /// Liveness scan: recover every worker whose thread has exited.
    /// Returns how many were recovered. Safe to call with route buffers
    /// still holding envelopes: every buffered envelope was accepted (so
    /// it is in the replay log, and the recovery re-sends it), and the
    /// buffered copy that arrives later carries a seq at or below the
    /// restored lane watermark, so the actor's exactly-once filter drops
    /// it.
    pub(crate) fn heal(&mut self, router: &Router) -> Result<u64> {
        let mut recovered = 0u64;
        for wid in 0..self.slots.len() {
            let dead = match (&self.slots[wid].tx, &self.slots[wid].handle) {
                (Some(_), Some(h)) => h.is_finished(),
                _ => true,
            };
            if dead {
                self.recover(wid, router)?;
                recovered += 1;
            }
        }
        Ok(recovered)
    }

    /// Reap a dead worker and bring its slot back: fold channel
    /// counters, join (logging the panic), respawn, restore from
    /// checkpoints, replay the suffix.
    pub(crate) fn recover(&mut self, wid: usize, router: &Router) -> Result<()> {
        if let Some(tx) = self.slots[wid].tx.take() {
            // Satellite guarantee: a crashed generation's transport
            // counters survive into metrics/finish via the absorb path.
            self.chan_base.absorb(&tx.metrics());
        }
        // The dead worker's serving lane closes with it; the plan's
        // stale clone keeps returning `Closed` until the refresh below.
        drop(self.slots[wid].query_tx.take());
        let ord = self.slots[wid].ord;
        let cause = match self.slots[wid].handle.take() {
            Some(h) => match h.join() {
                Err(panic) => panic.to_string(),
                Ok(Err(e)) => format!("worker error: {e}"),
                Ok(Ok(_)) => {
                    // A clean exit needs every sender gone — impossible
                    // while this supervisor holds one. Drop the report:
                    // the replacement re-owns the lanes and their
                    // checkpointed counters.
                    log::error!(
                        "worker {ord} exited cleanly mid-session (bug?)"
                    );
                    "exited cleanly mid-session".to_string()
                }
            },
            None => "already reaped".to_string(),
        };
        log::warn!("supervisor: worker {ord} (slot {wid}) is down — {cause}");
        self.slots[wid].cause = Some(cause.clone());
        if !self.enabled() {
            bail!(
                "worker {ord} died mid-stream ({cause}); fault tolerance is \
                 disabled (set fault.checkpoint_interval > 0 to enable \
                 checkpoint/replay recovery)"
            );
        }
        self.respawn_restore(wid, router)
    }

    /// Respawn a slot and rebuild its lanes: latest checkpoint of every
    /// owned lane (counters restored), then the watermark-filtered
    /// suffix from the replay log.
    fn respawn_restore(&mut self, wid: usize, router: &Router) -> Result<()> {
        // Both callers gate on the knob before dispatching here (recover
        // bails with the panic cause, finish_join re-raises the panic).
        debug_assert!(self.enabled(), "respawn_restore with fault tolerance off");
        let t0 = Instant::now();
        // Absorb everything queued — including the dead worker's final
        // checkpoints (queued messages survive a dropped sender).
        self.drain_checkpoints();

        // Plan the restore *before* touching the slot: per owned lane,
        // check replay availability, stage the checkpoint to import, and
        // compute the replay floor (first seq the checkpoint does not
        // cover). If the replay log cannot cover a lane, bail while the
        // slot still holds the dead worker — every later session
        // operation then keeps failing loudly, instead of an innocent-
        // looking empty replacement silently losing model state.
        let grid = self.grid;
        let mut imports: Vec<(u64, Vec<u8>)> = Vec::new();
        let mut floors: BTreeMap<u64, u64> = BTreeMap::new();
        for lane in 0..grid.n_lanes() {
            if grid.owner(lane, router) != wid {
                continue;
            }
            let last = self.lane_last.get(lane as usize).copied().unwrap_or(0);
            let ckpt = self.store.get(&lane);
            if last == 0 && ckpt.is_none() {
                continue; // the lane never existed
            }
            let start = ckpt.and_then(|c| c.watermark).map_or(0, |w| w + 1);
            if let Some(&lost) = self.lost.get(&lane) {
                if start <= lost {
                    bail!(
                        "recovery impossible: the replay log (capacity {}) \
                         evicted event {lost} of lane {lane}, which no \
                         checkpoint covers — raise fault.replay_log_capacity \
                         or lower fault.checkpoint_interval",
                        self.replay.capacity
                    );
                }
            }
            if let Some(c) = ckpt {
                imports.push((lane, c.bytes.clone()));
            }
            if last > start {
                floors.insert(lane, start);
            }
        }

        // Crash-loop guard: a failure the restored worker deterministically
        // re-hits on replay (a real model bug, a poisoned input) would
        // otherwise crash/recover forever with only warnings as evidence.
        let now = Instant::now();
        let recent = self.slots[wid]
            .last_respawn
            .is_some_and(|t| now.duration_since(t) < RESPAWN_WINDOW);
        let respawns =
            if recent { self.slots[wid].respawns + 1 } else { 1 };
        if respawns > RESPAWN_LIMIT {
            bail!(
                "worker slot {wid} died {respawns} times within {:?} — the \
                 failure recurs after restore + replay, so it is not \
                 recoverable by respawning (likely a deterministic bug)",
                RESPAWN_WINDOW
            );
        }

        // The injected kill (if any) has fired; never arm a replacement,
        // or the replayed suffix would re-trigger it.
        self.chaos = ChaosPolicy::none();
        let mut slot = self.spawn_slot(wid, ChaosPolicy::none());
        slot.respawns = respawns;
        slot.last_respawn = Some(now);
        self.slots[wid] = slot;

        // Restore phase: install the staged checkpoints (counters
        // restored — the crashed worker's report is gone, the replacement
        // re-owns them).
        let restored = imports.len() as u64;
        let mut restored_bytes = 0u64;
        for (lane, bytes) in imports {
            restored_bytes += bytes.len() as u64;
            let msg = WorkerMsg::Import { lane, bytes, restore_counters: true };
            let sent = self.slots[wid]
                .tx
                .as_ref()
                .is_some_and(|tx| tx.send(msg).is_ok());
            if !sent {
                bail!("replacement worker {wid} died during restore");
            }
        }

        // Replay phase: one pass over the log in global order, re-sending
        // each owned lane's suffix. FIFO puts all of it behind the
        // imports and ahead of any future event.
        let mut replayed = 0u64;
        for env in self.replay.buf.iter() {
            let lane = grid.lane(env.rating.user, env.rating.item);
            let floor = match floors.get(&lane) {
                Some(&f) => f,
                None => continue,
            };
            if env.seq < floor {
                continue;
            }
            let sent = self.slots[wid]
                .tx
                .as_ref()
                .is_some_and(|tx| tx.send(WorkerMsg::Event(*env)).is_ok());
            if !sent {
                bail!("replacement worker {wid} died during replay");
            }
            replayed += 1;
        }
        // Hand the replacement's fresh senders to the serving plane (in
        // place — the plan Arc is only rebuilt at rescale) and
        // invalidate the cache columns this slot hosts.
        if let Some(serving) = self.serving.clone() {
            if let Some((tx, qtx)) = self.slot_senders(wid) {
                serving.on_recover(wid, tx, qtx, router);
            }
        }
        let pause_ns = t0.elapsed().as_nanos() as u64;
        self.stats.recoveries += 1;
        self.stats.replayed_events += replayed;
        self.stats.recovery_pause_ns += pause_ns;
        log::info!(
            "supervisor: slot {wid} recovered as worker {} — {restored} \
             lanes restored ({restored_bytes} bytes), {replayed} events \
             replayed in {:.2} ms",
            self.slots[wid].ord,
            pause_ns as f64 / 1e6,
        );
        Ok(())
    }

    /// Fan an `Export` out to every worker and gather all replies,
    /// recovering workers that die before or during the drain — the
    /// rescale's first half, made crash-proof. Every returned export
    /// covers the complete accepted prefix of the stream.
    pub(crate) fn export_all(
        &mut self,
        router: &Router,
    ) -> Result<Vec<WorkerExport>> {
        let n = self.slots.len();
        let mut exports: Vec<Option<WorkerExport>> = Vec::new();
        exports.resize_with(n, || None);
        let mut pending: Vec<usize> = (0..n).collect();
        let mut rounds = 0usize;
        while !pending.is_empty() {
            rounds += 1;
            if rounds > n + 2 {
                bail!("rescale: workers keep dying during the export drain");
            }
            let (reply_tx, reply_rx) =
                bounded::<WorkerExport>(pending.len().max(1));
            for &wid in &pending {
                let msg = WorkerMsg::Export { reply: reply_tx.clone() };
                if !self.probe(wid, msg) {
                    if !self.enabled() {
                        bail!("rescale: worker {wid} already dead");
                    }
                    self.recover(wid, router)?;
                    let msg = WorkerMsg::Export { reply: reply_tx.clone() };
                    if !self.probe(wid, msg) {
                        bail!(
                            "rescale: worker {wid} died again after recovery"
                        );
                    }
                }
            }
            drop(reply_tx);
            let answers = reply_rx.recv_n(pending.len());
            for ex in answers {
                let wid = self
                    .slots
                    .iter()
                    .position(|s| s.ord == ex.ord)
                    .ok_or_else(|| {
                        anyhow!("export from unknown worker {}", ex.ord)
                    })?;
                exports[wid] = Some(ex);
            }
            pending.retain(|&wid| exports[wid].is_none());
            if !pending.is_empty() {
                // Died mid-drain, after events but before the export
                // reply. Recover (restore + replay rebuilds the same
                // prefix) and ask again next round.
                if !self.enabled() {
                    bail!(
                        "rescale: {} of {n} workers died mid-drain",
                        pending.len()
                    );
                }
                for &wid in &pending {
                    self.recover(wid, router)?;
                }
            }
        }
        Ok(exports.into_iter().flatten().collect())
    }

    /// Adopt a rescale's exports as the lanes' current checkpoints, with
    /// counters zeroed to match the importing generation's fresh
    /// baselines (the retiring generation keeps its totals in its
    /// retired reports). Keeps recovery exact across the cutover without
    /// waiting for the new workers' first periodic checkpoints.
    pub(crate) fn install_rescale_checkpoints(
        &mut self,
        exports: &[WorkerExport],
    ) {
        if !self.enabled() {
            return;
        }
        // First absorb everything the retiring generation queued during
        // its export drain — every one of its `try_send`s happened before
        // its `Export` reply, so after `export_all` returns the channel
        // holds the old generation's complete checkpoint tail. Draining
        // now (before the zero-counter installs below, and before the new
        // generation exists) guarantees no stale old-baseline frame can
        // land on top of a fresh one later.
        self.drain_checkpoints();
        for export in exports {
            for snap in &export.lanes {
                // Deliberate copy: the new owner imports the original
                // frame (counters intact but ignored), while the store
                // needs the zero-counter variant — two necessarily
                // distinct buffers, alive together only for the already
                // stop-the-world cutover.
                let mut bytes = snap.bytes.clone();
                zero_lane_frame_counters(&mut bytes);
                let watermark = lane_frame_watermark(&bytes);
                self.store_checkpoint(snap.lane, watermark, bytes);
            }
        }
    }

    /// Retire the current generation after its exports are in hand: fold
    /// channel counters into the base, close every input, join every
    /// worker, and return their final reports.
    pub(crate) fn retire_generation(&mut self) -> Result<Vec<WorkerReport>> {
        self.chan_base = self.channel_stats();
        let slots = std::mem::take(&mut self.slots);
        let mut reports = Vec::with_capacity(slots.len());
        for mut slot in slots {
            drop(slot.tx.take());
            drop(slot.query_tx.take());
            let handle = slot.handle.take().expect("slot joined twice");
            reports.push(handle.join()??);
        }
        Ok(reports)
    }

    /// Shutdown path: close every input and join, recovering (and then
    /// draining) any worker that panics during its final drain so its
    /// lanes' events still land in exactly one report.
    pub(crate) fn finish_join(
        &mut self,
        router: &Router,
    ) -> Result<Vec<WorkerReport>> {
        let mut reports = Vec::with_capacity(self.slots.len());
        for wid in 0..self.slots.len() {
            let mut attempts = 0;
            loop {
                if let Some(tx) = self.slots[wid].tx.take() {
                    // Fold the channel's counters before closing it, so
                    // the final report's transport totals include every
                    // channel — including replacements spawned by a
                    // final-drain recovery, whose traffic would otherwise
                    // vanish with the dropped sender.
                    self.chan_base.absorb(&tx.metrics());
                }
                drop(self.slots[wid].query_tx.take());
                let handle = match self.slots[wid].handle.take() {
                    Some(h) => h,
                    // Already reaped: an earlier unrecovered crash (fault
                    // tolerance off) consumed the handle; re-surface the
                    // root cause captured at reap time — the flush that
                    // detected the death may have had its error merely
                    // logged by the caller.
                    None => {
                        let cause = self.slots[wid]
                            .cause
                            .clone()
                            .unwrap_or_else(|| "cause unknown".to_string());
                        bail!(
                            "worker slot {wid} crashed earlier ({cause}) and \
                             could not be recovered (fault tolerance is \
                             disabled)"
                        );
                    }
                };
                match handle.join() {
                    Ok(result) => {
                        reports.push(result?);
                        break;
                    }
                    Err(panic) => {
                        if !self.enabled() {
                            // The old contract: surface the panic itself.
                            return Err(panic);
                        }
                        attempts += 1;
                        if attempts > 2 {
                            return Err(panic.context(format!(
                                "worker slot {wid} keeps dying in the final \
                                 drain"
                            )));
                        }
                        log::warn!(
                            "finish: {panic}; recovering worker slot {wid}"
                        );
                        self.respawn_restore(wid, router)?;
                    }
                }
            }
        }
        self.slots.clear();
        Ok(reports)
    }

    /// Aggregate channel counters: dead/retired channels' totals plus
    /// the live per-worker data channels.
    pub(crate) fn channel_stats(&self) -> ChannelStats {
        let mut total = self.chan_base;
        for slot in &self.slots {
            if let Some(tx) = &slot.tx {
                total.absorb(&tx.metrics());
            }
        }
        total
    }

    /// Drop the supervisor's collector sender so the collector can see
    /// end-of-stream once the cluster's master clone goes too.
    pub(crate) fn close_collector(&mut self) {
        self.col_tx = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::types::Rating;

    fn env(seq: u64, user: u64, item: u64) -> Envelope {
        Envelope { seq, rating: Rating::new(user, item, 5.0, seq) }
    }

    #[test]
    fn replay_log_ring_evicts_in_fifo_order() {
        let mut log = ReplayLog::new(3);
        assert!(log.push(env(0, 1, 1)).is_none());
        assert!(log.push(env(1, 1, 1)).is_none());
        assert!(log.push(env(2, 1, 1)).is_none());
        let evicted = log.push(env(3, 1, 1)).expect("over capacity");
        assert_eq!(evicted.seq, 0);
        assert_eq!(log.buf.front().unwrap().seq, 1);
        assert_eq!(log.buf.back().unwrap().seq, 3);
    }

    #[test]
    fn uncovered_evictions_are_remembered_and_cleared() {
        let cfg = RunConfig {
            fault_checkpoint_interval: 8,
            fault_replay_log_capacity: 2,
            ..RunConfig::default()
        };
        let grid = StateGrid::for_config(&cfg).unwrap(); // 1x1: lane 0
        let (col_tx, _col_rx) = bounded::<CollectorMsg>(4);
        let transports = crate::net::transport_plan(&cfg).unwrap();
        let mut sup = Supervisor::new(&cfg, grid, col_tx, transports);
        sup.record_ingest(env(0, 1, 1), 0);
        sup.record_ingest(env(1, 1, 1), 0);
        assert!(sup.lost.is_empty(), "nothing evicted yet");
        sup.record_ingest(env(2, 1, 1), 0);
        assert_eq!(sup.lost.get(&0), Some(&0), "seq 0 evicted uncovered");
        sup.record_ingest(env(3, 1, 1), 0);
        assert_eq!(sup.lost.get(&0), Some(&1), "newest uncovered wins");
        assert_eq!(sup.lane_last[0], 4);
        // A checkpoint at/above the uncovered seq clears the lane.
        sup.store_checkpoint(0, Some(1), Vec::new());
        assert_eq!(sup.lost.get(&0), None, "watermark 1 covers seq 1");
        sup.record_ingest(env(4, 1, 1), 0);
        // seq 2 was evicted; watermark 1 < 2, uncovered again.
        assert_eq!(sup.lost.get(&0), Some(&2));
        sup.store_checkpoint(0, Some(3), Vec::new());
        assert_eq!(sup.lost.get(&0), None, "watermark 3 covers seq 2");
        sup.record_ingest(env(5, 1, 1), 0);
        // seq 3 evicted, covered by watermark 3: nothing is recorded.
        assert_eq!(sup.lost.get(&0), None);
        // Monotonicity: a stale frame never replaces a fresher snapshot.
        sup.store_checkpoint(0, Some(2), vec![9]);
        assert_eq!(sup.store.get(&0).unwrap().watermark, Some(3));
        assert!(sup.store.get(&0).unwrap().bytes.is_empty());
    }
}
