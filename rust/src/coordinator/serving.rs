//! The serving plane: lock-free concurrent reads over a live ingesting
//! cluster.
//!
//! PR 4 froze the read path (`StreamingRecommender::serve` never trains),
//! which makes queries *logically* side-effect free — but they still rode
//! the per-worker event FIFO, so every query queued behind ingest
//! backpressure and every caller needed `&mut Cluster`. This module
//! splits the planes:
//!
//! * **Dedicated query lane.** Each worker slot has a second bounded
//!   channel carrying [`QueryMsg`] only. Queries bypass the event FIFO
//!   entirely; a read-your-writes *fence* (the slot's `last_routed`
//!   sequence, captured under the route lock) keeps them from observing
//!   less than the ingested prefix — the actor parks a query until its
//!   applied watermark reaches the fence (see `engine::actor`).
//! * **Shared ownership.** The routing table and per-slot senders live in
//!   a [`ServingPlan`] behind an `Arc`, so any number of threads can
//!   snapshot it and fan out concurrently while ingest proceeds.
//!   [`ServingHandle::recommend`] takes `&self`.
//! * **Sharded serving cache.** Answers are cached per user, validated by
//!   `(topology epoch, column generation, column event count)`. A rescale
//!   bumps the epoch, a crash recovery bumps the generation of every
//!   column the dead worker hosted, and any ingest for the user's virtual
//!   column advances its event count — so a cached answer can never be
//!   served across an epoch bump, a recovery, or past the configured
//!   staleness budget (`serving.cache_max_staleness`, default 0: any
//!   write to the column invalidates).
//! * **Admission control.** At most `serving.max_in_flight` queries run
//!   concurrently; beyond that (or when a worker's query queue is full)
//!   the query is *shed* — a fast, counted error instead of unbounded
//!   queueing. Shed totals surface in `ClusterMetrics`.
//!
//! # Locking
//!
//! Every mutex here (`plan`, per-slot `senders`, per-slot `route`, cache
//! shards) is a *leaf* lock: nothing acquires the supervisor — or any
//! other lock — while holding one. The supervisor lock MAY be held while
//! taking a leaf lock (recovery refreshes senders via
//! [`ServingState::on_recover`]); the reverse order would deadlock and is
//! never used. The one subtle rule: flushing a slot's route buffer sends
//! `WorkerMsg` batches *while holding that slot's route lock*, so two
//! concurrent flushers can never interleave a worker's batches — the
//! actor's exactly-once watermark filter requires per-worker sends to
//! stay in routed order.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::config::RunConfig;
use crate::coordinator::router::{Router, StateGrid};
use crate::coordinator::supervisor::Supervisor;
use crate::data::types::{ItemId, UserId};
use crate::engine::actor::{QueryMsg, ReplicaAnswer, WorkerMsg};
use crate::engine::{bounded, Sender, TrySendError};
use crate::eval::merge::merge_topn;
use crate::util::rng::mix64;

/// How long a query keeps retrying through worker deaths and rescale
/// cutovers before giving up (degraded answer or error).
const RETRY_WINDOW: Duration = Duration::from_secs(5);
/// Pause between retry attempts while the plan is mid-cutover.
const RETRY_PAUSE: Duration = Duration::from_micros(500);
/// Heal rounds that actually recovered a worker before a query settles
/// for a degraded (partial-replica) answer.
const MAX_HEALS: u32 = 3;

/// A slot's pending outbound event batch plus the read-your-writes
/// fence.
pub(crate) struct RouteState {
    /// Envelopes routed to this slot but not yet flushed to its FIFO.
    pub(crate) buf: Vec<WorkerMsg>,
    /// `seq + 1` of the newest envelope ever routed to this slot
    /// (`0` = none). Captured as the fence of every query fanned out to
    /// the slot: once flushed (same critical section), the actor holds
    /// the query until it has applied at least that prefix.
    pub(crate) last_routed: u64,
}

/// Per-worker-slot serving endpoints: the event FIFO and query lane
/// senders (refreshed in place when a crashed slot is recovered) plus
/// the slot's route buffer.
pub(crate) struct SlotServing {
    /// `(event FIFO, query lane)`. A recovery swaps both under this
    /// lock; fan-outs clone them out, so a stale pair at worst fails
    /// with `Closed` and the caller retries against the refreshed pair.
    senders: Mutex<(Sender<WorkerMsg>, Sender<QueryMsg>)>,
    /// See [`RouteState`]. Lock order: leaf (never acquire anything
    /// else while held); sends happen *inside* the critical section.
    pub(crate) route: Mutex<RouteState>,
}

impl SlotServing {
    pub(crate) fn new(
        event_tx: Sender<WorkerMsg>,
        query_tx: Sender<QueryMsg>,
        batch_capacity: usize,
    ) -> Self {
        Self {
            senders: Mutex::new((event_tx, query_tx)),
            route: Mutex::new(RouteState {
                buf: Vec::with_capacity(batch_capacity),
                last_routed: 0,
            }),
        }
    }

    /// Clone the current sender pair (brief leaf lock).
    pub(crate) fn senders(&self) -> (Sender<WorkerMsg>, Sender<QueryMsg>) {
        let guard = self.senders.lock().expect("senders lock");
        (guard.0.clone(), guard.1.clone())
    }

    fn set_senders(
        &self,
        event_tx: Sender<WorkerMsg>,
        query_tx: Sender<QueryMsg>,
    ) {
        *self.senders.lock().expect("senders lock") = (event_tx, query_tx);
    }
}

/// An immutable snapshot of the physical topology's serving endpoints:
/// the router plus one [`SlotServing`] per worker. Swapped atomically
/// (as an `Arc`) at rescale; *senders inside slots* are refreshed in
/// place at crash recovery, so the plan survives worker deaths.
pub(crate) struct ServingPlan {
    /// Router of this plan's topology epoch.
    pub(crate) router: Router,
    /// One entry per worker slot, indexed by `WorkerId`.
    pub(crate) slots: Vec<SlotServing>,
}

impl ServingPlan {
    /// The shut-down plan: no slots, so every sender clone the plan held
    /// is dropped and the workers see end-of-stream.
    pub(crate) fn empty(router: Router) -> Arc<Self> {
        Arc::new(Self { router, slots: Vec::new() })
    }
}

/// One cached merged answer.
struct CacheEntry {
    /// Topology epoch the answer was computed under.
    epoch: u64,
    /// The user's column generation at fan-out time (bumped per
    /// recovery touching the column).
    gen: u64,
    /// The column's ingested-event count *before* the fan-out
    /// (conservative: the answer reflects at least this prefix).
    events: u64,
    /// Requested list length; a shorter request is served as a prefix
    /// (see `eval::merge` — truncation yields a prefix of the longer
    /// merge), a longer one misses.
    n: usize,
    items: Vec<ItemId>,
}

/// Decrement-on-drop guard for the in-flight admission counter.
struct InFlight<'a>(&'a AtomicU64);

impl Drop for InFlight<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Shared, thread-safe state of the serving plane. One per session,
/// behind an `Arc` held by the `Cluster`, the supervisor (for recovery
/// refresh), and every [`ServingHandle`].
pub(crate) struct ServingState {
    grid: StateGrid,
    /// Current plan; callers snapshot the `Arc` and work lock-free.
    plan: Mutex<Arc<ServingPlan>>,
    /// Mirrors `plan.router.epoch()` for lock-free cache validation.
    epoch: AtomicU64,
    /// Per virtual user column (`grid.v_u` entries): events ingested.
    col_events: Vec<AtomicU64>,
    /// Per virtual user column: bumped when a recovery restores any
    /// lane of the column, invalidating cached answers built on the
    /// pre-crash replicas.
    col_gen: Vec<AtomicU64>,
    in_flight: AtomicU64,
    shed: AtomicU64,
    cache_hits: AtomicU64,
    degraded: AtomicU64,
    /// Sharded `(user -> CacheEntry)` map; shard by `mix64(user)`.
    cache: Vec<Mutex<HashMap<UserId, CacheEntry>>>,
    shard_mask: u64,
    max_in_flight: u64,
    max_staleness: u64,
    fault_enabled: bool,
}

impl ServingState {
    /// Build the serving plane for a fresh session. `serving.cache_shards`
    /// is rounded up to a power of two so shard selection is a mask.
    pub(crate) fn new(
        cfg: &RunConfig,
        grid: StateGrid,
        plan: Arc<ServingPlan>,
    ) -> Self {
        let shards = cfg.serving_cache_shards.next_power_of_two() as usize;
        let v_u = grid.v_u() as usize;
        Self {
            grid,
            epoch: AtomicU64::new(plan.router.epoch()),
            plan: Mutex::new(plan),
            col_events: (0..v_u).map(|_| AtomicU64::new(0)).collect(),
            col_gen: (0..v_u).map(|_| AtomicU64::new(0)).collect(),
            in_flight: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            degraded: AtomicU64::new(0),
            cache: (0..shards).map(|_| Mutex::new(HashMap::new())).collect(),
            shard_mask: shards as u64 - 1,
            max_in_flight: cfg.serving_max_in_flight as u64,
            max_staleness: cfg.serving_cache_max_staleness,
            fault_enabled: cfg.fault_checkpoint_interval > 0,
        }
    }

    /// Snapshot the current plan.
    pub(crate) fn plan(&self) -> Arc<ServingPlan> {
        self.plan.lock().expect("plan lock").clone()
    }

    /// Install a rescale's fresh plan. The epoch bump implicitly
    /// invalidates every cached answer; the stale entries are also
    /// dropped eagerly to free their memory.
    pub(crate) fn install_plan(&self, plan: Arc<ServingPlan>) {
        self.epoch.store(plan.router.epoch(), Ordering::Release);
        *self.plan.lock().expect("plan lock") = plan;
        for shard in &self.cache {
            shard.lock().expect("cache shard").clear();
        }
    }

    /// Shutdown: swap in the empty plan so every plan-held sender clone
    /// drops. Required before `Supervisor::finish_join` — the actors
    /// exit on end-of-stream, which needs *all* event senders gone.
    pub(crate) fn shutdown(&self) {
        let mut plan = self.plan.lock().expect("plan lock");
        *plan = ServingPlan::empty(plan.router);
    }

    /// Count one accepted envelope against its user's column (cache
    /// staleness bookkeeping). Called by ingest *before* the envelope
    /// is buffered, so a cache entry validated after this bump can
    /// never hide the write.
    pub(crate) fn note_ingest(&self, user: UserId) {
        let col = self.grid.user_col(user) as usize;
        self.col_events[col].fetch_add(1, Ordering::Release);
    }

    /// Crash-recovery hook (called by the supervisor with its own lock
    /// held — leaf locks only in here): hand the replacement worker's
    /// fresh senders to the live plan and invalidate the cache columns
    /// the slot hosts.
    pub(crate) fn on_recover(
        &self,
        wid: usize,
        event_tx: Sender<WorkerMsg>,
        query_tx: Sender<QueryMsg>,
        router: &Router,
    ) {
        let plan = self.plan();
        if let Some(slot) = plan.slots.get(wid) {
            slot.set_senders(event_tx, query_tx);
        }
        // One generation bump per affected column, not per lane.
        let mut touched = vec![false; self.col_gen.len()];
        for lane in 0..self.grid.n_lanes() {
            if self.grid.owner(lane, router) == wid {
                touched[self.grid.lane_col(lane) as usize] = true;
            }
        }
        for (col, hit) in touched.into_iter().enumerate() {
            if hit {
                self.col_gen[col].fetch_add(1, Ordering::Release);
            }
        }
    }

    /// Envelopes routed but not yet flushed, across all slots.
    pub(crate) fn buffered(&self) -> u64 {
        self.plan()
            .slots
            .iter()
            .map(|s| s.route.lock().expect("route lock").buf.len() as u64)
            .sum()
    }

    /// Queries shed by admission control or full worker queues.
    pub(crate) fn shed_total(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    /// Queries answered from the serving cache.
    pub(crate) fn cache_hit_total(&self) -> u64 {
        self.cache_hits.load(Ordering::Relaxed)
    }

    /// Queries answered from a partial replica set after repeated
    /// worker failures.
    pub(crate) fn degraded_total(&self) -> u64 {
        self.degraded.load(Ordering::Relaxed)
    }

    fn admit(&self) -> Option<InFlight<'_>> {
        let prev = self.in_flight.fetch_add(1, Ordering::AcqRel);
        let guard = InFlight(&self.in_flight);
        if prev >= self.max_in_flight {
            drop(guard);
            None
        } else {
            Some(guard)
        }
    }

    fn shard(&self, user: UserId) -> &Mutex<HashMap<UserId, CacheEntry>> {
        &self.cache[(mix64(user) & self.shard_mask) as usize]
    }

    fn cache_get(
        &self,
        user: UserId,
        col: usize,
        n: usize,
    ) -> Option<Vec<ItemId>> {
        let epoch = self.epoch.load(Ordering::Acquire);
        let gen = self.col_gen[col].load(Ordering::Acquire);
        let events = self.col_events[col].load(Ordering::Acquire);
        let map = self.shard(user).lock().expect("cache shard");
        let e = map.get(&user)?;
        let fresh = e.epoch == epoch
            && e.gen == gen
            && events.saturating_sub(e.events) <= self.max_staleness
            && n <= e.n;
        if !fresh {
            return None;
        }
        self.cache_hits.fetch_add(1, Ordering::Relaxed);
        Some(e.items.iter().take(n).copied().collect())
    }

    #[allow(clippy::too_many_arguments)]
    fn cache_put(
        &self,
        user: UserId,
        col: usize,
        epoch: u64,
        gen: u64,
        events: u64,
        n: usize,
        items: &[ItemId],
    ) {
        // Re-validate against the *current* generation: a recovery or
        // rescale that landed mid-fan-out means this answer may predate
        // restored state — drop it rather than cache it.
        if self.epoch.load(Ordering::Acquire) != epoch
            || self.col_gen[col].load(Ordering::Acquire) != gen
        {
            return;
        }
        self.shard(user).lock().expect("cache shard").insert(
            user,
            CacheEntry { epoch, gen, events, n, items: items.to_vec() },
        );
    }

    /// The concurrent read path: admission, cache probe, then a fenced
    /// fan-out to the user's replica workers over their query lanes.
    /// Safe to call from any number of threads while ingest proceeds.
    pub(crate) fn recommend(
        &self,
        sup: &Mutex<Supervisor>,
        user: UserId,
        n: usize,
    ) -> Result<Vec<ItemId>> {
        if n == 0 {
            return Ok(Vec::new());
        }
        let _in_flight = match self.admit() {
            Some(guard) => guard,
            None => {
                self.shed.fetch_add(1, Ordering::Relaxed);
                bail!(
                    "query shed: {} queries already in flight \
                     (serving.max_in_flight)",
                    self.max_in_flight
                );
            }
        };
        let col = self.grid.user_col(user) as usize;
        if let Some(items) = self.cache_get(user, col, n) {
            return Ok(items);
        }
        // Over-fetch per replica: local lists shrink under the global
        // exclusion of items other replicas saw the user consume.
        let fetch = n.saturating_mul(2);
        let deadline = Instant::now() + RETRY_WINDOW;
        let mut heals = 0u32;
        let mut replica_count = 0usize;
        let mut partial: Vec<ReplicaAnswer> = Vec::new();
        loop {
            let plan = self.plan();
            if plan.slots.is_empty() {
                bail!("recommend(user {user}): the session has shut down");
            }
            let epoch = plan.router.epoch();
            let gen_before = self.col_gen[col].load(Ordering::Acquire);
            let events_before = self.col_events[col].load(Ordering::Acquire);
            let replicas = plan.router.user_workers(user);
            replica_count = replicas.len();
            let (reply_tx, reply_rx) =
                bounded::<ReplicaAnswer>(replicas.len().max(1));
            let mut asked = 0usize;
            let mut dead = false;
            for &wid in &replicas {
                let slot = &plan.slots[wid];
                let (event_tx, query_tx) = slot.senders();
                // Flush the slot's pending events and capture the fence
                // in one critical section: the fence must cover exactly
                // the routed-and-flushed prefix, and the send must not
                // interleave with a concurrent flusher's batch.
                let fence = {
                    let mut route = slot.route.lock().expect("route lock");
                    if !route.buf.is_empty()
                        && event_tx.send_many(&mut route.buf).is_err()
                    {
                        dead = true;
                    }
                    route.last_routed
                };
                if dead {
                    break;
                }
                let q =
                    QueryMsg { user, n: fetch, fence, reply: reply_tx.clone() };
                match query_tx.try_send(q) {
                    Ok(()) => asked += 1,
                    Err(TrySendError::Full(_)) => {
                        self.shed.fetch_add(1, Ordering::Relaxed);
                        bail!(
                            "query shed: worker {wid}'s query queue is full \
                             (serving.queue_capacity)"
                        );
                    }
                    Err(TrySendError::Closed(_)) => {
                        dead = true;
                        break;
                    }
                }
            }
            drop(reply_tx);
            if !dead {
                let answers = reply_rx.recv_n(asked);
                if answers.len() == asked {
                    let items = merge_answers(&answers, n);
                    self.cache_put(
                        user,
                        col,
                        epoch,
                        gen_before,
                        events_before,
                        n,
                        &items,
                    );
                    return Ok(items);
                }
                // A replica died after accepting the query (its parked
                // reply sender dropped with it) — keep what answered.
                if !answers.is_empty() {
                    partial = answers;
                }
            }
            // Failure: a closed lane or a lost reply. With fault
            // tolerance on, heal recovers dead slots (refreshing the
            // plan's senders in place). `recovered == 0` means nothing
            // was dead — the plan is mid-rescale-cutover — so the retry
            // is free; only real recoveries count toward the degraded
            // fallback.
            if self.fault_enabled {
                let recovered =
                    sup.lock().expect("supervisor lock").heal(&plan.router)?;
                if recovered > 0 {
                    heals += 1;
                    if heals > MAX_HEALS {
                        break;
                    }
                }
            }
            if Instant::now() >= deadline {
                break;
            }
            std::thread::sleep(RETRY_PAUSE);
        }
        if self.fault_enabled && !partial.is_empty() {
            self.degraded.fetch_add(1, Ordering::Relaxed);
            log::warn!(
                "recommend(user {user}): replicas kept failing; serving a \
                 degraded answer merged from {} of {replica_count} replicas",
                partial.len(),
            );
            return Ok(merge_answers(&partial, n));
        }
        bail!(
            "recommend(user {user}): no complete replica answer within \
             {RETRY_WINDOW:?} ({heals} heal rounds) — worker dead{}",
            if self.fault_enabled {
                " despite recovery"
            } else {
                " and fault tolerance is disabled"
            }
        )
    }
}

/// A cloneable, thread-safe handle onto a session's query plane.
/// Obtained from [`Cluster::serving`](crate::coordinator::Cluster::serving);
/// stays valid across rescales and crash recoveries, and fails cleanly
/// ("session has shut down") after [`Cluster::finish`].
///
/// ```no_run
/// # use streamrec::config::RunConfig;
/// # use streamrec::coordinator::Cluster;
/// # fn main() -> anyhow::Result<()> {
/// let mut cluster = Cluster::spawn(&RunConfig::default())?;
/// let serving = cluster.serving();
/// let reader = std::thread::spawn(move || serving.recommend(7, 10));
/// // ...ingest on this thread while `reader` queries concurrently...
/// # Ok(()) }
/// ```
pub struct ServingHandle {
    pub(crate) state: Arc<ServingState>,
    pub(crate) sup: Arc<Mutex<Supervisor>>,
}

impl Clone for ServingHandle {
    fn clone(&self) -> Self {
        Self { state: self.state.clone(), sup: self.sup.clone() }
    }
}

impl ServingHandle {
    /// Global top-`n` for `user` — the concurrent, fenced, cached read
    /// path. See [`ServingState::recommend`] for the full contract.
    pub fn recommend(&self, user: UserId, n: usize) -> Result<Vec<ItemId>> {
        self.state.recommend(&self.sup, user, n)
    }
}

/// Merge replica answers into a global top-`n`: union the per-replica
/// rated sets (global "never recommend a consumed item") and rank-merge
/// the per-lane lists (`eval::merge::merge_topn`).
pub(crate) fn merge_answers(
    answers: &[ReplicaAnswer],
    n: usize,
) -> Vec<ItemId> {
    let exclude: HashSet<ItemId> =
        answers.iter().flat_map(|a| a.rated.iter().copied()).collect();
    let lists: Vec<Vec<ItemId>> =
        answers.iter().flat_map(|a| a.lists.iter().cloned()).collect();
    merge_topn(&lists, &exclude, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Receiver;

    fn test_state(
        max_staleness: u64,
        max_in_flight: u64,
    ) -> (ServingState, Receiver<WorkerMsg>, Receiver<QueryMsg>) {
        let cfg = RunConfig {
            serving_cache_max_staleness: max_staleness,
            serving_max_in_flight: max_in_flight,
            ..RunConfig::default()
        };
        let grid = StateGrid::for_config(&cfg).unwrap();
        let router = Router::new(cfg.topology);
        let (tx, rx) = bounded::<WorkerMsg>(16);
        let (qtx, qrx) = bounded::<QueryMsg>(16);
        let plan = Arc::new(ServingPlan {
            router,
            slots: vec![SlotServing::new(tx, qtx, 8)],
        });
        (ServingState::new(&cfg, grid, plan), rx, qrx)
    }

    fn put(st: &ServingState, user: UserId, n: usize, items: &[ItemId]) {
        let col = st.grid.user_col(user) as usize;
        let epoch = st.epoch.load(Ordering::Acquire);
        let gen = st.col_gen[col].load(Ordering::Acquire);
        let events = st.col_events[col].load(Ordering::Acquire);
        st.cache_put(user, col, epoch, gen, events, n, items);
    }

    fn get(st: &ServingState, user: UserId, n: usize) -> Option<Vec<ItemId>> {
        let col = st.grid.user_col(user) as usize;
        st.cache_get(user, col, n)
    }

    #[test]
    fn cache_roundtrip_and_prefix_serving() {
        let (st, _rx, _qrx) = test_state(0, 4);
        put(&st, 7, 3, &[10, 20, 30]);
        assert_eq!(get(&st, 7, 3), Some(vec![10, 20, 30]));
        // A shorter request is a prefix of the cached merge...
        assert_eq!(get(&st, 7, 2), Some(vec![10, 20]));
        // ...a longer one must recompute.
        assert_eq!(get(&st, 7, 4), None);
        assert_eq!(st.cache_hit_total(), 2);
    }

    #[test]
    fn ingest_into_column_invalidates_under_strict_staleness() {
        let (st, _rx, _qrx) = test_state(0, 4);
        put(&st, 7, 2, &[1, 2]);
        // A different user in a different column leaves the entry alone.
        st.note_ingest(8);
        assert!(get(&st, 7, 2).is_some());
        // Any write to user 7's own column kills it (staleness 0).
        st.note_ingest(7);
        assert_eq!(get(&st, 7, 2), None);
    }

    #[test]
    fn staleness_budget_tolerates_bounded_writes() {
        let (st, _rx, _qrx) = test_state(2, 4);
        put(&st, 7, 2, &[1, 2]);
        st.note_ingest(7);
        st.note_ingest(7);
        assert!(get(&st, 7, 2).is_some(), "2 writes within budget 2");
        st.note_ingest(7);
        assert_eq!(get(&st, 7, 2), None, "3rd write exceeds the budget");
    }

    #[test]
    fn epoch_bump_invalidates_everything() {
        let (st, _rx, _qrx) = test_state(u64::MAX, 4);
        put(&st, 7, 2, &[1, 2]);
        assert!(get(&st, 7, 2).is_some());
        // A rescale installs a plan with a bumped router epoch.
        let plan = st.plan();
        let next = Router::with_epoch(
            RunConfig::default().topology,
            plan.router.epoch() + 1,
        );
        st.install_plan(ServingPlan::empty(next));
        assert_eq!(get(&st, 7, 2), None, "cross-epoch serve forbidden");
    }

    #[test]
    fn column_generation_bump_invalidates_column_only() {
        let (st, _rx, _qrx) = test_state(u64::MAX, 4);
        put(&st, 7, 2, &[1, 2]);
        let col = st.grid.user_col(7) as usize;
        st.col_gen[col].fetch_add(1, Ordering::Release);
        assert_eq!(get(&st, 7, 2), None, "recovered column must recompute");
    }

    #[test]
    fn stale_put_after_invalidation_is_dropped() {
        let (st, _rx, _qrx) = test_state(u64::MAX, 4);
        let col = st.grid.user_col(7) as usize;
        let epoch = st.epoch.load(Ordering::Acquire);
        let gen = st.col_gen[col].load(Ordering::Acquire);
        // Invalidation lands while the fan-out is in flight...
        st.col_gen[col].fetch_add(1, Ordering::Release);
        // ...so the put (validated against its pre-fan-out generation)
        // must not install the possibly-pre-recovery answer.
        st.cache_put(7, col, epoch, gen, 0, 2, &[1, 2]);
        assert_eq!(get(&st, 7, 2), None);
    }

    #[test]
    fn admission_sheds_beyond_max_in_flight() {
        let (st, _rx, _qrx) = test_state(0, 2);
        let a = st.admit();
        let b = st.admit();
        assert!(a.is_some() && b.is_some());
        assert!(st.admit().is_none(), "3rd concurrent query is refused");
        drop(a);
        assert!(st.admit().is_some(), "slot freed on guard drop");
    }

    #[test]
    fn merge_answers_excludes_across_replicas() {
        // Replica A knows the user rated item 3; replica B still ranks
        // it first. The union exclusion must strip it globally.
        let a = ReplicaAnswer { lists: vec![vec![1, 2]], rated: vec![3] };
        let b = ReplicaAnswer { lists: vec![vec![3, 4]], rated: vec![] };
        let merged = merge_answers(&[a, b], 10);
        assert!(!merged.contains(&3));
        assert!(merged.contains(&1) && merged.contains(&4));
    }

    #[test]
    fn shutdown_empties_the_plan_and_fails_queries_cleanly() {
        let (st, _rx, _qrx) = test_state(0, 4);
        st.shutdown();
        assert_eq!(st.plan().slots.len(), 0);
        assert_eq!(st.buffered(), 0);
    }
}
