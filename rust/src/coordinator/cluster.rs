//! The long-lived cluster session — the control plane that turns the
//! crate from a benchmark script into a servable system.
//!
//! [`Cluster::spawn`] brings up the shared-nothing workers of Figure 1 and
//! keeps them alive across an *unbounded* stream: [`Cluster::ingest`]
//! pushes events through the Algorithm-1 router with backpressure,
//! [`Cluster::recommend`] is the online serving path (fan a query out to
//! every replica of the user over its dedicated query lane, merge the
//! per-replica top-N lists) — callable through `&self`, and concurrently
//! from any number of threads via [`Cluster::serving`] handles while
//! ingest proceeds, [`Cluster::metrics`] snapshots live counters without
//! stopping (or flushing) anything, [`Cluster::rescale`] migrates the
//! running system to a different worker topology without losing an event
//! or a bit of model state, and [`Cluster::finish`] drains, joins, and
//! returns the final [`RunReport`] — exactly what the old one-shot
//! `run_pipeline` produced.
//!
//! This module is deliberately thin: it owns routing and the session
//! lifecycle. The worker loop — the `WorkerMsg`/`QueryMsg` protocols,
//! the per-lane models, checkpointing — lives in `engine/actor.rs`;
//! worker spawning, liveness, crash detection, and recovery live in
//! `coordinator/supervisor.rs`; and the concurrent query plane (plan,
//! route buffers, cache, admission) lives in `coordinator/serving.rs`.
//!
//! # The two planes
//!
//! Workers consume two channels. The **event FIFO** carries `WorkerMsg`:
//! `Event` (prequential test-then-train), `MetricsSnapshot` (live
//! counters), `Export` (terminal: serialize every hosted lane and drain
//! out), and `Import` (install a lane frame ahead of any later event).
//! Control probes sit at their FIFO position among the events, so a
//! snapshot observes exactly the events flushed before it and an
//! `Export` covers the complete accepted prefix.
//!
//! The **query lane** carries [`QueryMsg`](crate::engine::actor::QueryMsg)
//! only. Queries bypass the event FIFO — they never queue behind ingest
//! backpressure — and carry a read-your-writes *fence*: the `seq + 1` of
//! the last event routed to that worker, captured in the same critical
//! section that flushes the worker's route buffer. The actor holds a
//! query until its applied watermark reaches the fence, so bypassing the
//! FIFO never lets a query observe *less* than the ingested prefix —
//! only sooner. Because the serve path is a frozen read (it never
//! trains), query timing cannot perturb worker state, which is what
//! makes the bypass sound (`tests/serving_equivalence.rs` pins this).
//!
//! # The batched data plane
//!
//! The transport is micro-batched end to end, because per-event channel
//! crossings (one mutex acquisition + one condvar wakeup each) are what
//! caps ingest throughput once the models are fast:
//!
//! * **Coordinator side** — [`Cluster::ingest`] does not send; it appends
//!   the routed envelope to the worker's *route buffer* (inside the
//!   serving plan, under that slot's route lock) and flushes the buffer
//!   with one bulk [`Sender::send_many`] (one lock, one wakeup) when it
//!   reaches `cfg.ingest_batch_size`.
//! * **Worker side** — the worker loop drains everything queued in one
//!   critical section: wake once, process a whole window of envelopes in
//!   FIFO order. Prequential accounting stays strictly per-event; only
//!   the transport is batched.
//! * **Ordering is batch-size-invariant** — a query's fence covers the
//!   flushed prefix, the fan-out flushes the replica's buffer itself,
//!   and [`Cluster::finish`] flushes every tail, so reports, hit
//!   sequences, and recommendations are identical for any
//!   `ingest_batch_size` (property-tested in
//!   `tests/batching_equivalence.rs`).
//!
//! Note what is *not* flushed anymore: [`Cluster::metrics`] observes the
//! stream without touching route buffers (`processed + buffered ==
//! ingested`), and a query flushes only the queried user's replica
//! workers — an idle worker's buffer is never disturbed by another
//! user's traffic. [`Cluster::flush`] forces every buffer out when a
//! caller wants `processed == ingested` exactly.
//!
//! # Lanes: state partitioning vs worker placement
//!
//! Model state is not owned by workers directly. It is partitioned on the
//! fixed virtual [`StateGrid`] into *lanes* — one independent model per
//! virtual grid cell — and each physical worker hosts the group of lanes
//! the current topology assigns to it ([`StateGrid::owner`]). With the
//! default configuration the state grid equals the spawn topology, every
//! worker hosts exactly one lane, and the system is indistinguishable
//! from the paper's. The indirection earns its keep twice: at
//! [`Cluster::rescale`], which *moves whole lanes* between workers
//! instead of splitting or merging model state, and at crash recovery,
//! which restores whole lanes from their checkpoints — see
//! ARCHITECTURE.md for the full walkthrough.
//!
//! # The rescale protocol (pause → flush → drain → migrate → resume)
//!
//! 1. **Pause**: `rescale(&mut self, ..)` pauses ingest (exclusive
//!    borrow); concurrent [`ServingHandle`] queries keep running against
//!    the old plan until the cutover swaps it, then retry against the
//!    new one.
//! 2. **Flush**: every route buffer is bulk-sent, so each worker's FIFO
//!    holds the complete accepted prefix of the stream.
//! 3. **Drain**: an `Export` probe queues behind those events on every
//!    FIFO; each worker finishes its prefix, serializes its lanes (lane
//!    frames wrapping
//!    [`StreamingRecommender::export_partition`](crate::algorithms::StreamingRecommender::export_partition)
//!    — factor rows, rated sets, co-occurrence rows, caches, RNG stream,
//!    plus the lane's forgetting clock and watermark), replies, and
//!    exits. The old workers' final reports are retained (`retired`) so
//!    no `processed`/`hits` accounting is lost. A worker that dies
//!    during the drain is recovered and re-asked (fault-tolerant
//!    sessions).
//! 4. **Migrate**: a fresh [`Router`] is installed with its epoch bumped,
//!    new workers spawn, and every lane snapshot is sent as an `Import`
//!    to the worker that owns the lane under the new topology. A barrier
//!    probe confirms every import is applied *before* the new serving
//!    plan goes live — a concurrent query can never observe a
//!    pre-import (empty) lane.
//! 5. **Resume**: subsequent `ingest` routes through the new grid; the
//!    epoch bump invalidates every cached answer.
//!
//! Zero event loss and before/after recommendation equality are
//! property-tested in `tests/rescale_equivalence.rs`; the pause-time cost
//! is measured by `benches/rescale.rs`.
//!
//! # Fault tolerance (checkpoint / replay, exactly-once)
//!
//! With `fault.checkpoint_interval > 0`, workers checkpoint each lane
//! every N events (the same lane-frame format rescaling uses, stamped
//! with the lane's high-watermark `seq`), and the coordinator keeps a
//! bounded replay log of recent envelopes. A worker crash — detected by
//! a failed send, a liveness scan, or a panic at join — is then
//! *invisible*: the supervisor respawns the worker, restores its lanes
//! from their latest checkpoints, replays the watermark-filtered suffix
//! from the log, refreshes the serving plan's senders in place, and
//! resumes. Replayed events re-evaluate to identical prequential
//! outcomes (lane state is deterministic), and the collector
//! deduplicates by global sequence number, so a recovered session's
//! hits, recall curve, and answers are byte-identical to a never-crashed
//! run (`tests/fault_tolerance.rs`; recovery pause is measured by
//! `benches/recovery.rs`). With the default `fault.checkpoint_interval
//! = 0` a worker death is what it always was: a loud session error.
//!
//! # The serving path (replicated-user read)
//!
//! A user's state is replicated across the `n_i` workers of its grid
//! column ([`Router::user_workers`]) — each replica learned from the
//! *item rows* it owns, so no single worker can rank the whole catalog
//! for the user. `recommend` therefore fans the query out to all
//! replicas over their query lanes, gathers each replica's per-lane
//! ranked top-N lists plus the locally-rated item sets over a reply
//! channel ([`Receiver::recv_n`]), and merges with the rank-aware
//! [`merge_topn`](crate::eval::merge_topn), excluding items the user
//! rated on *any* replica. Because the per-lane lists are invariant
//! under lane placement, the merged answer is identical before and after
//! any rescale — or any crash recovery. Repeated queries for hot users
//! are answered from a sharded cache validated by (epoch, column
//! generation, column write count) — see `coordinator/serving.rs` for
//! admission control and shedding.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::config::{RunConfig, Topology};
use crate::coordinator::router::{Router, StateGrid};
use crate::coordinator::serving::{
    ServingHandle, ServingPlan, ServingState, SlotServing,
};
use crate::coordinator::supervisor::Supervisor;
use crate::data::types::{ItemId, Rating, UserId};
use crate::engine::actor::{CollectorMsg, Envelope, WorkerExport, WorkerMsg};
use crate::engine::{bounded, spawn, Receiver, Sender, WorkerHandle};
use crate::eval::{RunReport, WindowStat, WindowedRecall, WorkerReport};

pub use crate::engine::actor::WorkerSnapshot;

/// Live cluster-level snapshot returned by [`Cluster::metrics`].
#[derive(Debug, Clone)]
pub struct ClusterMetrics {
    /// Events accepted by [`Cluster::ingest`] so far.
    pub ingested: u64,
    /// Events fully processed across workers, including workers retired
    /// by earlier rescales. The snapshot probe rides the event FIFO and
    /// no longer forces a flush, so `processed + buffered == ingested`
    /// at the moment the snapshot is answered (a recovered worker's
    /// restored + replayed lanes cover its predecessor's work exactly).
    /// Call [`Cluster::flush`] first when `processed == ingested` is
    /// wanted.
    pub processed: u64,
    /// Events accepted but still sitting in route buffers (not yet
    /// bulk-sent to their workers).
    pub buffered: u64,
    /// Prequential hits so far (including retired workers).
    pub hits: u64,
    /// Lifetime online recall so far (hits / processed).
    pub recall: f64,
    /// Serving queries answered so far (including retired workers). A
    /// serving-traffic diagnostic, not an exactly-once counter: a
    /// crashed worker's tally is not checkpointed (it can dip after a
    /// recovery), and a recovery retry re-asks the surviving replicas of
    /// an in-flight fan-out (it can also count a little high around a
    /// crash). Cache hits never reach a worker, so they are *not*
    /// counted here — see [`ClusterMetrics::cache_hits`].
    pub queries: u64,
    /// Queries refused by admission control — the in-flight limit
    /// (`serving.max_in_flight`) or a full worker query queue
    /// (`serving.queue_capacity`). Shed queries return an error
    /// immediately instead of queueing unboundedly.
    pub shed_queries: u64,
    /// Queries answered from the serving cache without any worker
    /// fan-out.
    pub cache_hits: u64,
    /// Total ns senders spent blocked on backpressure so far.
    pub backpressure_ns: u64,
    /// Total ns worker receivers spent waiting for messages so far.
    pub recv_blocked_ns: u64,
    /// Mean messages per channel send (1.0 = unbatched;
    /// tracks how much transport cost `ingest_batch_size` amortizes).
    /// Counts *all* event-FIFO sends: snapshot/export probes are
    /// singletons, so probe-heavy sessions read lower than their event
    /// batching — pure ingest runs (the bench) read clean. The query
    /// lanes keep their own books and are excluded here.
    pub mean_send_batch: f64,
    /// Completed [`Cluster::rescale`] calls.
    pub rescales: u64,
    /// Total serialized lane bytes moved by rescales.
    pub migrated_bytes: u64,
    /// Total ns the session spent inside rescale cutovers (ingest is
    /// paused for exactly this long, summed; concurrent queries retry
    /// across the cutover).
    pub rescale_pause_ns: u64,
    /// Completed crash recoveries (0 unless `fault.checkpoint_interval`
    /// is set and a worker actually died).
    pub recoveries: u64,
    /// Total serialized lane-frame bytes received as checkpoints.
    pub checkpoint_bytes: u64,
    /// Envelopes re-sent from the replay log by crash recoveries.
    pub replayed_events: u64,
    /// Total ns spent inside crash recoveries (reap + respawn + restore
    /// + replay).
    pub recovery_pause_ns: u64,
    /// Resident logical state bytes summed over live workers — the
    /// figure a `[memory]` budget bounds. Exact as of the snapshot
    /// replies: each worker re-measures its lanes and re-enforces its
    /// budget right before answering, so with spill enabled every
    /// worker's contribution is `<=` its budget by construction.
    pub resident_bytes: u64,
    /// Total logical state bytes over live workers, resident + spilled
    /// — the paper's memory metric in bytes, placement-independent
    /// (retired workers exported their lanes, so nothing is counted
    /// twice).
    pub state_bytes: u64,
    /// Lanes currently parked in the disk tier across live workers.
    pub spilled_lanes: u64,
    /// Logical bytes of those spilled lanes (their `state_bytes` at
    /// spill time).
    pub spilled_bytes: u64,
    /// Cumulative cold-lane spills to the disk tier (live + retired
    /// workers). `0` unless a `[memory]` budget forced tiering.
    pub spills: u64,
    /// Cumulative spilled-lane fault-ins (live + retired workers).
    pub spill_faultins: u64,
    /// [`Cluster::recommend`] calls answered *degraded*: replicas kept
    /// dying across the full retry budget, so the answer was merged
    /// from the surviving replicas only (fault-tolerant sessions; a
    /// healthy or fully-recovered session never degrades, so this stays
    /// 0 for every fault plan the recovery budget can absorb).
    pub degraded_queries: u64,
    /// Current topology version: 0 at spawn, +1 per rescale.
    pub router_epoch: u64,
    /// Per-live-worker detail, sorted by worker id (retired workers'
    /// totals are folded into the aggregates above; their final reports
    /// appear in [`RunReport::retired`] after [`Cluster::finish`]).
    pub workers: Vec<WorkerSnapshot>,
}

/// What the collector thread returns at join: the sampled cumulative
/// recall curve, the tumbling-window (time-local) recall series, and
/// the total hit count.
type CollectorOutput = (Vec<(u64, f64)>, Vec<WindowStat>, u64);

/// Outcome of one [`Cluster::rescale`]: what moved and what it cost.
#[derive(Debug, Clone)]
pub struct RescaleReport {
    /// Topology before the rescale.
    pub from: Topology,
    /// Topology after the rescale.
    pub to: Topology,
    /// Worker count before.
    pub from_workers: usize,
    /// Worker count after.
    pub to_workers: usize,
    /// Lane snapshots migrated (only lanes that had state; untouched
    /// virtual cells have nothing to move).
    pub lanes_moved: u64,
    /// Serialized state bytes moved.
    pub bytes_moved: u64,
    /// Wall-clock ns the cutover took — the window during which ingest
    /// was paused (concurrent queries retry across it).
    pub pause_ns: u64,
    /// Router epoch now live (bumped by this rescale).
    pub epoch: u64,
}

/// A running shared-nothing cluster: ingest, serve, observe, rescale,
/// recover, finish.
pub struct Cluster {
    label: String,
    /// Configuration echo; worker generations spawned by rescale reuse it
    /// (only the topology changes across generations).
    cfg: RunConfig,
    /// The fixed virtual grid state is partitioned on (see [`StateGrid`]).
    grid: StateGrid,
    router: Router,
    /// Owns the worker slots: spawn/respawn, liveness, checkpoints,
    /// replay, recovery. Shared with every [`ServingHandle`] so the
    /// concurrent query path can heal dead workers.
    sup: Arc<Mutex<Supervisor>>,
    /// The concurrent query plane: plan, route buffers, cache,
    /// admission. Shared with the supervisor (recovery refresh) and
    /// every [`ServingHandle`].
    serving: Arc<ServingState>,
    /// Ingest-side snapshot of the current plan (identical to the one
    /// inside `serving` between rescales; replaced at each cutover).
    plan: Arc<ServingPlan>,
    /// Flush threshold (`cfg.ingest_batch_size`, clamped to >= 1).
    batch_size: usize,
    /// `fault.checkpoint_interval > 0`, cached so the ingest hot path
    /// skips the supervisor lock entirely when fault tolerance is off.
    fault_enabled: bool,
    collector: Option<WorkerHandle<CollectorOutput>>,
    /// Master clone handed to the supervisor (which clones it into each
    /// worker generation); dropped in [`Cluster::finish`] so the
    /// collector sees end-of-stream only after the last generation
    /// drained.
    col_tx: Option<Sender<CollectorMsg>>,
    /// Final reports of workers retired by rescales.
    retired: Vec<WorkerReport>,
    /// Set once [`Cluster::metrics`] has logged the `[memory]`-budget-
    /// without-eviction-policy footgun warning, so a metrics polling
    /// loop doesn't spam it.
    memory_warned: AtomicBool,
    /// Wall clock starts at the first ingest (matches the old
    /// `run_pipeline` accounting, which excluded worker spawn).
    started: Option<Instant>,
    seq: u64,
    route_ns: u64,
    rescales: u64,
    migrated_bytes: u64,
    rescale_pause_ns: u64,
}

/// Outcome of one [`Cluster::probe_round`] fan-out.
enum ProbeRound<T> {
    /// Every asked worker answered (an empty vector means no targeted
    /// worker was alive — only possible without fault tolerance).
    Full(Vec<T>),
    /// A worker died *after* its probe was queued (its reply channel
    /// died with it); the supervisor healed the slot, and these are the
    /// answers the surviving workers produced. Callers retry — the
    /// restored worker answers over the same accepted prefix.
    Partial(#[allow(dead_code)] Vec<T>),
}

/// Build the serving plan for a freshly spawned generation: clone each
/// slot's sender pair out of the supervisor.
fn build_plan(
    sup: &Supervisor,
    router: Router,
    batch_size: usize,
) -> Arc<ServingPlan> {
    let slots = (0..router.n_c())
        .map(|wid| {
            let (tx, qtx) = sup
                .slot_senders(wid)
                .expect("freshly spawned generation has both senders");
            SlotServing::new(tx, qtx, batch_size)
        })
        .collect();
    Arc::new(ServingPlan { router, slots })
}

impl Cluster {
    /// Start the workers and collector for `cfg`'s topology; the cluster
    /// stays up until [`Cluster::finish`] (or drop).
    pub fn spawn(cfg: &RunConfig) -> Result<Self> {
        Self::spawn_labeled(cfg, "cluster")
    }

    /// [`Cluster::spawn`] with a report label (experiment harness tag).
    pub fn spawn_labeled(cfg: &RunConfig, label: &str) -> Result<Self> {
        let grid = StateGrid::for_config(cfg)?;
        let router = Router::new(cfg.topology);
        let n_c = router.n_c();
        log::info!(
            "cluster '{label}': n_i={} -> {} workers, state grid {}x{} \
             ({} lanes), {} backend, forgetting={}, fault tolerance={}",
            cfg.topology.n_i,
            n_c,
            grid.v_i(),
            grid.v_u(),
            grid.n_lanes(),
            cfg.backend.name(),
            cfg.forgetting.name(),
            if cfg.fault_checkpoint_interval > 0 {
                "on"
            } else {
                "off"
            },
        );

        // Where each worker slot runs: local threads unless the config
        // lists `[cluster] workers` entries to cycle over.
        let transports = crate::net::transport_plan(cfg)?;
        if !cfg.cluster_workers.is_empty() {
            let labels: Vec<String> =
                transports.iter().map(|t| t.describe()).collect();
            log::info!(
                "cluster '{label}': worker placement cycle = [{}]",
                labels.join(", ")
            );
        }

        // Channels: coordinator -> workers (bounded, backpressured),
        // workers -> collector (bounded; hit batches are small).
        let (col_tx, col_rx) = bounded::<CollectorMsg>(n_c * 4 + 16);

        // Collector runs on its own thread so worker hit-batches never
        // block; it sizes its bitmaps dynamically because a session has no
        // up-front event count.
        let recall_window = cfg.recall_window;
        let sample_every = cfg.sample_every.max(1) as u64;
        let collector = spawn(usize::MAX, "collector", move || {
            collect(col_rx, recall_window, sample_every)
        });

        let batch_size = cfg.ingest_batch_size.max(1);
        let mut sup = Supervisor::new(cfg, grid, col_tx.clone(), transports);
        sup.spawn_generation(n_c);
        let plan = build_plan(&sup, router, batch_size);
        let serving = Arc::new(ServingState::new(cfg, grid, plan.clone()));
        sup.attach_serving(serving.clone());
        Ok(Self {
            label: label.to_string(),
            cfg: cfg.clone(),
            grid,
            router,
            sup: Arc::new(Mutex::new(sup)),
            serving,
            plan,
            batch_size,
            fault_enabled: cfg.fault_checkpoint_interval > 0,
            collector: Some(collector),
            col_tx: Some(col_tx),
            retired: Vec::new(),
            memory_warned: AtomicBool::new(false),
            started: None,
            seq: 0,
            route_ns: 0,
            rescales: 0,
            migrated_bytes: 0,
            rescale_pause_ns: 0,
        })
    }

    /// Number of workers in the cluster (current topology).
    pub fn n_workers(&self) -> usize {
        self.sup.lock().expect("supervisor lock").n_workers()
    }

    /// The Algorithm-1 router for the *current* topology (e.g. to inspect
    /// a user's replica set). Its [`Router::epoch`] advances on every
    /// rescale, so cached routing decisions can be revalidated.
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// The fixed virtual state grid lanes are partitioned on.
    pub fn state_grid(&self) -> StateGrid {
        self.grid
    }

    /// Events accepted so far (including events still in route buffers —
    /// a query's fence covers them once its replica's buffer flushes).
    pub fn ingested(&self) -> u64 {
        self.seq
    }

    /// A cloneable, thread-safe handle onto the query plane: call
    /// [`ServingHandle::recommend`] from any number of threads while
    /// this `Cluster` keeps ingesting (or rescaling) on its own thread.
    /// Handles stay valid across rescales and crash recoveries and fail
    /// cleanly after [`Cluster::finish`].
    pub fn serving(&self) -> ServingHandle {
        ServingHandle { state: self.serving.clone(), sup: self.sup.clone() }
    }

    /// Route one event into its worker's buffer; the buffer moves to the
    /// worker in one bulk send once it holds `ingest_batch_size` events.
    /// Blocks only when a flush hits a full worker channel (backpressure).
    ///
    /// Error reporting is flush-grained: an `Ok` means the event is
    /// accepted (buffered or sent), and a dead worker surfaces at the
    /// flush that hits it — up to `ingest_batch_size - 1` events after
    /// the death — or at the next query/flush/finish, whichever comes
    /// first. On a fault-tolerant session a dead worker does not surface
    /// at all: the flush recovers it and the stream continues.
    pub fn ingest(&mut self, rating: Rating) -> Result<()> {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
        let t0 = Instant::now();
        let target = self.router.route(rating.user, rating.item);
        self.route_ns += t0.elapsed().as_nanos() as u64;
        let env = Envelope { seq: self.seq, rating };
        if self.fault_enabled {
            // Fault bookkeeping: every *accepted* envelope enters the
            // replay log before it can reach a worker, so nothing a
            // crash destroys (queued or buffered) is ever unrecoverable.
            let lane = self.grid.lane(rating.user, rating.item);
            self.sup
                .lock()
                .expect("supervisor lock")
                .record_ingest(env, lane);
        }
        // Count the write against the user's column *before* buffering,
        // so a cached answer validated later can never hide it.
        self.serving.note_ingest(rating.user);
        let needs_flush = {
            let mut route =
                self.plan.slots[target].route.lock().expect("route lock");
            route.buf.push(WorkerMsg::Event(env));
            route.last_routed = env.seq + 1;
            route.buf.len() >= self.batch_size
        };
        self.seq += 1;
        if needs_flush {
            self.flush_slot(target)?;
        }
        Ok(())
    }

    /// Ingest a slice of events in stream order. The tail that does not
    /// fill a route buffer stays buffered; it is flushed by the next
    /// query fan-out that targets the worker, the next ingest that fills
    /// the buffer, [`Cluster::flush`], or [`Cluster::finish`].
    pub fn ingest_batch(&mut self, events: &[Rating]) -> Result<()> {
        for &rating in events {
            self.ingest(rating)?;
        }
        Ok(())
    }

    /// Bulk-send one worker's route buffer (one lock, one wakeup; the
    /// send happens inside the route critical section so concurrent
    /// flushers — query fan-outs — can never interleave the worker's
    /// batches). A dead worker is healed in place when fault tolerance
    /// is on (the buffered envelopes are in the replay log, so the
    /// recovery re-delivers them); otherwise the death is a loud error.
    fn flush_slot(&self, wid: usize) -> Result<()> {
        loop {
            let slot = &self.plan.slots[wid];
            let (event_tx, _) = slot.senders();
            let sent = {
                let mut route = slot.route.lock().expect("route lock");
                if route.buf.is_empty() {
                    return Ok(());
                }
                event_tx.send_many(&mut route.buf).is_ok()
            };
            {
                let mut sup = self.sup.lock().expect("supervisor lock");
                if self.fault_enabled {
                    sup.drain_checkpoints();
                }
                if sent {
                    return Ok(());
                }
                // `heal`, not `recover`: a concurrent query fan-out may
                // have recovered the slot already (our sender clone was
                // just stale) — heal only reaps workers that are
                // actually down, then the retry picks up the refreshed
                // senders. Bails loudly when fault tolerance is off or
                // the crash loops.
                sup.heal(&self.router)?;
            }
        }
    }

    /// Flush every route buffer now — afterwards (and until the next
    /// ingest) `processed == ingested` holds for [`Cluster::metrics`].
    /// [`Cluster::finish`] and [`Cluster::rescale`] call this
    /// internally; interactive sessions only need it when they want
    /// exact live counters.
    pub fn flush(&mut self) -> Result<()> {
        self.flush_all()
    }

    fn flush_all(&self) -> Result<()> {
        for wid in 0..self.plan.slots.len() {
            self.flush_slot(wid)?;
        }
        Ok(())
    }

    /// One fan-out probe round over the event FIFOs (used by
    /// [`Cluster::metrics`]): send `make(reply)` to each target worker —
    /// recovering dead workers first on fault-tolerant sessions,
    /// skipping them otherwise — and gather the replies. Probes queue
    /// behind previously *flushed* events (route buffers are not
    /// touched).
    ///
    /// Returns [`ProbeRound::Partial`] when a worker died *after* its
    /// probe was queued (the reply channel died with it) and was
    /// healed: the caller retries — the restored worker answers over
    /// the same accepted prefix. An empty [`ProbeRound::Full`] reply
    /// set means no targeted worker was alive (only possible without
    /// fault tolerance).
    fn probe_round<T>(
        &self,
        targets: &[usize],
        make: &dyn Fn(Sender<T>) -> WorkerMsg,
    ) -> Result<ProbeRound<T>> {
        let (reply_tx, reply_rx) = bounded::<T>(targets.len().max(1));
        let mut asked = 0usize;
        {
            let mut sup = self.sup.lock().expect("supervisor lock");
            for &wid in targets {
                let msg = make(reply_tx.clone());
                if self.fault_enabled {
                    sup.send_probe(wid, msg, &self.router)?;
                    asked += 1;
                } else if sup.probe(wid, msg) {
                    // A failed send returns (and drops) the message
                    // together with its reply-sender clone, so recv_n
                    // below can't deadlock on a dead worker.
                    asked += 1;
                }
            }
        }
        drop(reply_tx);
        if asked == 0 {
            return Ok(ProbeRound::Full(Vec::new()));
        }
        let replies = reply_rx.recv_n(asked);
        if replies.len() < asked && self.fault_enabled {
            self.sup.lock().expect("supervisor lock").heal(&self.router)?;
            return Ok(ProbeRound::Partial(replies));
        }
        Ok(ProbeRound::Full(replies))
    }

    /// Online serving: global top-`n` for `user`, answered while the
    /// stream is live — through `&self`, so any number of threads can
    /// query concurrently (see [`Cluster::serving`] for a handle that
    /// queries while *this* thread keeps ingesting).
    ///
    /// Fans the query out to every replica of the user (its grid column,
    /// [`Router::user_workers`]) over the dedicated query lanes; each
    /// replica answers from its local lane models; the per-lane ranked
    /// lists are merged rank-aware into a global top-N that excludes
    /// items the user has rated on *any* replica. A user unknown to
    /// every replica yields an empty list (cold start).
    ///
    /// Read-your-writes: the fan-out flushes each replica's route buffer
    /// and fences the query on the flushed prefix, so the answer
    /// reflects every previously ingested event — other workers'
    /// buffers are not touched. Repeat queries for a hot user are
    /// answered from the serving cache while their column is unchanged.
    ///
    /// Admission control: at most `serving.max_in_flight` queries run at
    /// once and each worker's query queue is bounded; beyond either
    /// limit the query errors immediately ("query shed", counted in
    /// [`ClusterMetrics::shed_queries`]) instead of queueing without
    /// bound.
    ///
    /// Rescale- and recovery-invariant: the merged answer depends only on
    /// the per-lane lists, not on how lanes are placed on workers, so the
    /// same session state yields the same answer under any topology and
    /// across any crash recovery (property-tested in
    /// `tests/rescale_equivalence.rs` and `tests/fault_tolerance.rs`).
    /// Graceful degradation when replicas keep dying past the retry
    /// budget is described in `coordinator/serving.rs` (counted in
    /// [`ClusterMetrics::degraded_queries`]).
    pub fn recommend(&self, user: UserId, n: usize) -> Result<Vec<ItemId>> {
        self.serving.recommend(&self.sup, user, n)
    }

    /// Live metrics without shutdown — and without disturbing the data
    /// plane: every worker answers a snapshot probe that rides its event
    /// FIFO behind the already-flushed events; route buffers are left
    /// alone, so `processed + buffered == ingested` (call
    /// [`Cluster::flush`] first for `processed == ingested` exactly).
    /// Workers retired by earlier rescales contribute their final totals
    /// to the aggregates; a crashed-and-recovered worker's replacement
    /// reports its restored counters, so the identity holds across
    /// recoveries too.
    pub fn metrics(&self) -> Result<ClusterMetrics> {
        // The [memory] footgun: a budget with no eviction policy means
        // pressure sweeps can't shed anything and every over-budget
        // lane goes straight to disk. Legal (results stay identical)
        // but almost never intended — warn once per session. The
        // scenario driver refuses the combination outright.
        if let Some(msg) = self.cfg.memory_footgun() {
            if !self.memory_warned.swap(true, Ordering::Relaxed) {
                log::warn!("cluster '{}': {msg}", self.label);
            }
        }
        for _attempt in 0..3 {
            let n = self.sup.lock().expect("supervisor lock").n_workers();
            let targets: Vec<usize> = (0..n).collect();
            let mut workers = match self.probe_round(&targets, &|reply| {
                WorkerMsg::MetricsSnapshot { reply }
            })? {
                ProbeRound::Full(workers) => workers,
                // A worker died mid-probe; healed, retry. (No degraded
                // path here: a partial aggregate would silently under-
                // count, which is worse than retrying.)
                ProbeRound::Partial(_) => continue,
            };
            workers.sort_by_key(|w| w.worker_id);
            let mut processed: u64 = workers.iter().map(|w| w.processed).sum();
            let mut hits: u64 = workers.iter().map(|w| w.hits).sum();
            let mut queries: u64 = workers.iter().map(|w| w.queries).sum();
            let resident_bytes: u64 =
                workers.iter().map(|w| w.state_bytes).sum();
            let spilled_lanes: u64 =
                workers.iter().map(|w| w.spilled_lanes).sum();
            let spilled_bytes: u64 =
                workers.iter().map(|w| w.spilled_bytes).sum();
            let mut spills: u64 = workers.iter().map(|w| w.spills).sum();
            let mut spill_faultins: u64 =
                workers.iter().map(|w| w.spill_faultins).sum();
            for w in &self.retired {
                processed += w.processed;
                hits += w.hits;
                queries += w.queries;
                spills += w.spills;
                spill_faultins += w.spill_faultins;
            }
            let (chan, fault) = {
                let sup = self.sup.lock().expect("supervisor lock");
                (sup.channel_stats(), sup.stats())
            };
            return Ok(ClusterMetrics {
                ingested: self.seq,
                processed,
                buffered: self.serving.buffered(),
                hits,
                recall: hits as f64 / (processed.max(1)) as f64,
                queries,
                shed_queries: self.serving.shed_total(),
                cache_hits: self.serving.cache_hit_total(),
                backpressure_ns: chan.blocked_ns,
                recv_blocked_ns: chan.recv_blocked_ns,
                mean_send_batch: chan.mean_send_batch(),
                rescales: self.rescales,
                migrated_bytes: self.migrated_bytes,
                rescale_pause_ns: self.rescale_pause_ns,
                recoveries: fault.recoveries,
                checkpoint_bytes: fault.checkpoint_bytes,
                replayed_events: fault.replayed_events,
                recovery_pause_ns: fault.recovery_pause_ns,
                resident_bytes,
                state_bytes: resident_bytes + spilled_bytes,
                spilled_lanes,
                spilled_bytes,
                spills,
                spill_faultins,
                degraded_queries: self.serving.degraded_total(),
                router_epoch: self.router.epoch(),
                workers,
            });
        }
        anyhow::bail!("metrics: workers kept dying across 3 recoveries")
    }

    /// Live elastic rescale: migrate the running session to
    /// `new_topology` with zero event loss and exact model state.
    ///
    /// The new topology must be compatible with the session's
    /// [`StateGrid`] (its `n_i` divides the grid's rows and its `n_ciw`
    /// the grid's columns) — with the default grid that means any
    /// topology whose grid divides the spawn grid; set `rescale.max_n_i`
    /// at spawn to reserve headroom for scaling *out* beyond the spawn
    /// size. See the module docs for the cutover protocol and
    /// ARCHITECTURE.md for the design.
    ///
    /// Costs one full pause of ingest (concurrent [`ServingHandle`]
    /// queries keep retrying across the cutover and resume against the
    /// new plan); the report says how long and how many bytes. On a
    /// fault-tolerant session a worker crash before or during the drain
    /// is recovered and the cutover proceeds; otherwise — or after an
    /// unrecoverable error — the session should be considered lost and
    /// [`Cluster::finish`] will surface the root cause.
    pub fn rescale(&mut self, new_topology: Topology) -> Result<RescaleReport> {
        self.rescale_inner(new_topology, &mut |_| {})
    }

    /// Stable fingerprint of the full model state: drains the cluster
    /// through a same-topology rescale (so every lane is serialized over
    /// the complete accepted prefix) and hashes the sorted lane frames.
    /// Two sessions that processed the same stream — regardless of
    /// query traffic, batch size, placement, rescale history, or crash
    /// recoveries — fingerprint identically; serving is a frozen read,
    /// so queries can never perturb it (`tests/serving_equivalence.rs`).
    ///
    /// Costs a full cutover pause (and bumps the router epoch like any
    /// rescale); the session continues normally afterwards.
    pub fn state_fingerprint(&mut self) -> Result<u64> {
        let topology = self.cfg.topology;
        let mut lanes: Vec<(u64, Vec<u8>)> = Vec::new();
        self.rescale_inner(topology, &mut |export| {
            for snap in &export.lanes {
                lanes.push((snap.lane, snap.bytes.clone()));
            }
        })?;
        lanes.sort_by(|a, b| a.0.cmp(&b.0));
        // FNV-1a over (lane id, frame bytes) in lane order — placement-
        // independent by construction.
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut eat = |b: u8| {
            h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
        };
        for (lane, bytes) in &lanes {
            for b in lane.to_le_bytes() {
                eat(b);
            }
            for &b in bytes {
                eat(b);
            }
        }
        Ok(h)
    }

    /// The rescale cutover, parameterized over an export inspector so
    /// [`Cluster::state_fingerprint`] can hash the lane frames without a
    /// second drain.
    fn rescale_inner(
        &mut self,
        new_topology: Topology,
        inspect: &mut dyn FnMut(&WorkerExport),
    ) -> Result<RescaleReport> {
        let t0 = Instant::now();
        if !self.grid.supports(new_topology) {
            anyhow::bail!(
                "topology n_i={} n_ciw={} does not divide the state grid \
                 {}x{}; spawn with rescale.max_n_i to reserve headroom",
                new_topology.n_i,
                new_topology.n_ciw(),
                self.grid.v_i(),
                self.grid.v_u(),
            );
        }
        let from = self.cfg.topology;
        let from_workers = self.sup.lock().expect("supervisor lock").n_workers();
        log::info!(
            "cluster '{}': rescale n_i {} -> {} ({} -> {} workers)",
            self.label,
            from.n_i,
            new_topology.n_i,
            from_workers,
            new_topology.n_c(),
        );

        // Pause + flush: push every buffered event onto its FIFO so the
        // Export probe below queues behind the complete accepted prefix.
        // (A worker found dead here is recovered by the flush itself.)
        self.flush_all()?;

        // Drain + export: each worker finishes its queue, snapshots its
        // lanes, replies, and exits (crash-proof on fault-tolerant
        // sessions: a worker dying mid-drain is recovered and re-asked).
        // Concurrent queries that hit the retiring generation fail with
        // `Closed` and retry until the new plan is live.
        let exports = {
            let mut sup = self.sup.lock().expect("supervisor lock");
            let exports = sup.export_all(&self.router)?;

            // The exports double as fresh checkpoints (counters zeroed to
            // the new generation's baseline), so recovery stays exact
            // across the cutover without waiting for new periodic
            // checkpoints.
            sup.install_rescale_checkpoints(&exports);

            // Retire the old generation: fold its channel counters into
            // the base, close its channels, and keep its final reports.
            let mut retiring = sup.retire_generation()?;
            self.retired.append(&mut retiring);
            exports
        };
        for export in &exports {
            inspect(export);
        }

        // Install the new topology (epoch bump) and spawn the new
        // generation.
        self.router =
            Router::with_epoch(new_topology, self.router.epoch() + 1);
        self.cfg.topology = new_topology;
        let n_c = self.router.n_c();
        let plan = {
            let mut sup = self.sup.lock().expect("supervisor lock");
            sup.set_topology(new_topology);
            sup.spawn_generation(n_c);
            build_plan(&sup, self.router, self.batch_size)
        };

        // Re-route every lane to its owner under the new grid, then run
        // a barrier probe: the imports must be *applied* before the new
        // plan goes live, or a concurrent query (whose fence is still 0
        // on the fresh slots) could be answered from a pre-import,
        // empty lane.
        let mut lanes_moved = 0u64;
        let mut bytes_moved = 0u64;
        {
            let sup = self.sup.lock().expect("supervisor lock");
            for export in exports {
                for snap in export.lanes {
                    let target = self.grid.owner(snap.lane, &self.router);
                    lanes_moved += 1;
                    bytes_moved += snap.bytes.len() as u64;
                    let msg = WorkerMsg::Import {
                        lane: snap.lane,
                        bytes: snap.bytes,
                        restore_counters: false,
                    };
                    if !sup.probe(target, msg) {
                        anyhow::bail!(
                            "rescale: new worker {target} died during import"
                        );
                    }
                }
            }
            let (ack_tx, ack_rx) = bounded::<WorkerSnapshot>(n_c.max(1));
            for wid in 0..n_c {
                let msg =
                    WorkerMsg::MetricsSnapshot { reply: ack_tx.clone() };
                if !sup.probe(wid, msg) {
                    anyhow::bail!(
                        "rescale: new worker {wid} died before activation"
                    );
                }
            }
            drop(ack_tx);
            if ack_rx.recv_n(n_c).len() < n_c {
                anyhow::bail!(
                    "rescale: a new worker died during the import barrier"
                );
            }
        }

        // Activate: queries now fan out to the new generation; the epoch
        // bump invalidates every cached answer.
        self.serving.install_plan(plan.clone());
        self.plan = plan;

        let pause_ns = t0.elapsed().as_nanos() as u64;
        self.rescales += 1;
        self.migrated_bytes += bytes_moved;
        self.rescale_pause_ns += pause_ns;
        let report = RescaleReport {
            from,
            to: new_topology,
            from_workers,
            to_workers: n_c,
            lanes_moved,
            bytes_moved,
            pause_ns,
            epoch: self.router.epoch(),
        };
        log::info!(
            "cluster '{}': rescale done — {} lanes / {} bytes moved in \
             {:.1} ms (epoch {})",
            self.label,
            lanes_moved,
            bytes_moved,
            pause_ns as f64 / 1e6,
            report.epoch,
        );
        Ok(report)
    }

    /// Drain in-flight events, join workers and collector, and assemble
    /// the final [`RunReport`] — the same aggregate the one-shot
    /// `run_pipeline` returns. A worker that panics during the final
    /// drain of a fault-tolerant session is recovered, drained, and
    /// reported by its replacement. In-flight [`ServingHandle`] queries
    /// complete first (the workers drain them before exiting); queries
    /// issued after this call fail with "session has shut down".
    ///
    /// Note on `throughput`: the wall-clock window runs from the first
    /// ingest to this call, so for an interactive session it includes
    /// serving fan-outs, metrics probes, rescale pauses, recovery pauses,
    /// and caller think-time — it is *session* throughput. Only a pure
    /// ingest run (what `run_pipeline` does) reads as ingest throughput.
    pub fn finish(mut self) -> Result<RunReport> {
        // Flush the buffered tail first — the drain guarantee covers every
        // accepted event. With fault tolerance on, the flush itself
        // recovers dead workers, so an error here is terminal; without
        // it, keep going so the join below surfaces the root cause.
        if let Err(e) = self.flush_all() {
            if self.fault_enabled {
                return Err(e);
            }
            log::warn!("finish: final flush failed ({e}); joining workers");
        }
        // Retire the serving plan: every plan-held sender clone must
        // drop before the join below, because the actors exit on
        // end-of-stream (all event senders gone). Queries already in
        // flight hold a plan snapshot and complete normally; later ones
        // fail cleanly.
        self.serving.shutdown();
        self.plan = ServingPlan::empty(self.router);
        let (n_workers, joined, chan, fault) = {
            let mut sup = self.sup.lock().expect("supervisor lock");
            let n_workers = sup.n_workers();
            // Close worker inputs; workers drain and report via join. A
            // panic in the final drain is recovered (respawn + restore +
            // replay) and the replacement joined instead. Each channel's
            // counters are folded into the retained base at the moment
            // its input closes.
            let joined = sup.finish_join(&self.router);
            let chan = sup.channel_stats();
            let fault = sup.stats();
            sup.close_collector();
            (n_workers, joined, chan, fault)
        };
        let mut workers = joined?;
        let wall_secs = self
            .started
            .map(|t| t.elapsed().as_secs_f64())
            .unwrap_or(0.0);
        // Drop every collector sender only after the last generation's
        // workers are gone; the collector then sees end-of-stream.
        drop(self.col_tx.take());
        let (recall_curve, windowed_recall, hits) = self
            .collector
            .take()
            .expect("collector joined twice")
            .join()?;
        workers.sort_by_key(|w| w.worker_id);
        let mut retired = std::mem::take(&mut self.retired);
        retired.sort_by_key(|w| w.worker_id);
        let events = self.seq;
        // Memory rollups: retired workers exported their lanes (their
        // state_bytes reads zero), so the live sum is the whole story;
        // spill/fault-in counters are lifetime totals on both sides.
        let state_bytes: u64 = workers.iter().map(|w| w.state_bytes).sum();
        let spills: u64 = workers
            .iter()
            .chain(retired.iter())
            .map(|w| w.spills)
            .sum();
        let spill_faultins: u64 = workers
            .iter()
            .chain(retired.iter())
            .map(|w| w.spill_faultins)
            .sum();
        Ok(RunReport {
            label: self.label.clone(),
            n_workers,
            events,
            hits,
            wall_secs,
            throughput: events as f64 / wall_secs.max(1e-9),
            avg_recall: hits as f64 / events.max(1) as f64,
            recall_curve,
            windowed_recall,
            workers,
            retired,
            route_ns_per_event: self.route_ns as f64 / events.max(1) as f64,
            backpressure_ns: chan.blocked_ns,
            recv_blocked_ns: chan.recv_blocked_ns,
            mean_send_batch: chan.mean_send_batch(),
            rescales: self.rescales,
            migrated_bytes: self.migrated_bytes,
            rescale_pause_ns: self.rescale_pause_ns,
            recoveries: fault.recoveries,
            checkpoint_bytes: fault.checkpoint_bytes,
            replayed_events: fault.replayed_events,
            recovery_pause_ns: fault.recovery_pause_ns,
            state_bytes,
            spills,
            spill_faultins,
        })
    }
}

/// Collector: reassembles the global prequential curve from per-worker
/// hit batches. Workers interleave arbitrarily; the moving average is
/// computed in global sequence order at the end (hit bits are buffered in
/// a dense bitmap — 1 bit per event — grown on demand because a live
/// session has no up-front event count).
///
/// Idempotent by sequence number: a crash recovery replays the suffix
/// past the dead worker's checkpoints, so an outcome can arrive twice.
/// Replay is deterministic (same lane state ⇒ same outcome), so the
/// first arrival stands and duplicates are dropped — `total_hits` and
/// the curve are exactly those of a never-crashed run.
///
/// Returns the moving-average curve, the tumbling-window (time-local)
/// recall series bucketed by global sequence number, and the hit total.
fn collect(
    rx: Receiver<CollectorMsg>,
    window: usize,
    sample_every: u64,
) -> CollectorOutput {
    let mut bits: Vec<u8> = Vec::new();
    let mut seen: Vec<u8> = Vec::new();
    let mut n_events = 0u64;
    let mut total_hits = 0u64;
    while let Some(msg) = rx.recv() {
        match msg {
            CollectorMsg::Hits(batch) => {
                for s in batch {
                    let (byte, bit) = ((s.seq / 8) as usize, s.seq % 8);
                    if byte >= bits.len() {
                        bits.resize(byte + 1, 0);
                        seen.resize(byte + 1, 0);
                    }
                    let mask = 1u8 << bit;
                    if seen[byte] & mask != 0 {
                        // Duplicate from a recovery replay.
                        continue;
                    }
                    seen[byte] |= mask;
                    if s.hit {
                        bits[byte] |= mask;
                        total_hits += 1;
                    }
                    n_events = n_events.max(s.seq + 1);
                }
            }
            CollectorMsg::Done { worker_id } => {
                log::debug!("worker {worker_id} drained");
            }
        }
    }
    // Global moving-average curve (skipping unseen slots would hide lost
    // events — they count as misses, which is the honest accounting),
    // plus the tumbling-window series over the same bits.
    let mut ma = crate::eval::MovingRecall::new(window.max(1));
    let mut windowed = WindowedRecall::new(window.max(1) as u64);
    let mut curve = Vec::new();
    for seq in 0..n_events {
        let (byte, bit) = ((seq / 8) as usize, seq % 8);
        debug_assert!(
            seen[byte] & (1 << bit) != 0,
            "event {seq} never evaluated"
        );
        let hit = bits[byte] & (1 << bit) != 0;
        ma.push(hit);
        windowed.push(seq, hit);
        if seq % sample_every == 0 || seq + 1 == n_events {
            curve.push((seq, ma.value()));
        }
    }
    (curve, windowed.into_stats(), total_hits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{RunConfig, Topology};
    use crate::data::synth::{SyntheticConfig, SyntheticStream};

    fn small_events(n: u64) -> Vec<Rating> {
        SyntheticStream::new(SyntheticConfig::netflix_like(n, 11)).collect()
    }

    fn cfg(n_i: u64) -> RunConfig {
        RunConfig {
            topology: Topology::new(n_i, 0).unwrap(),
            sample_every: 100,
            ..RunConfig::default()
        }
    }

    #[test]
    fn session_interleaves_ingest_serve_metrics() {
        let events = small_events(3000);
        let mut cluster = Cluster::spawn_labeled(&cfg(2), "t-session").unwrap();
        assert_eq!(cluster.n_workers(), 4);
        let hot = events[0].user;
        let mut served = 0usize;
        for chunk in events.chunks(500) {
            cluster.ingest_batch(chunk).unwrap();
            let recs = cluster.recommend(hot, 10).unwrap();
            served += usize::from(!recs.is_empty());
            let m = cluster.metrics().unwrap();
            assert_eq!(
                m.processed + m.buffered,
                cluster.ingested(),
                "every accepted event is processed or buffered"
            );
        }
        assert!(served > 0, "a seen user must eventually get answers");
        let report = cluster.finish().unwrap();
        assert_eq!(report.events, 3000);
        assert_eq!(
            report.workers.iter().map(|w| w.processed).sum::<u64>(),
            3000
        );
        // The windowed (time-local) series reconciles with the
        // cumulative totals, and per-worker windows cover every event.
        assert_eq!(
            report.windowed_recall.iter().map(|w| w.hits).sum::<u64>(),
            report.hits
        );
        assert_eq!(
            report.windowed_recall.iter().map(|w| w.events).sum::<u64>(),
            3000
        );
        assert_eq!(
            report
                .workers
                .iter()
                .flat_map(|w| &w.windows)
                .map(|w| w.events)
                .sum::<u64>(),
            3000
        );
    }

    #[test]
    fn metrics_counts_queries_and_monotone_progress() {
        let events = small_events(1000);
        let mut cluster = Cluster::spawn(&cfg(2)).unwrap();
        cluster.ingest_batch(&events[..500]).unwrap();
        let m1 = cluster.metrics().unwrap();
        assert_eq!(m1.ingested, 500);
        assert_eq!(m1.processed + m1.buffered, 500, "no-flush accounting");
        assert_eq!(m1.queries, 0);
        // An explicit flush makes the live counter exact.
        cluster.flush().unwrap();
        let m1 = cluster.metrics().unwrap();
        assert_eq!(m1.processed, 500);
        assert_eq!(m1.buffered, 0);
        let _ = cluster.recommend(events[0].user, 10).unwrap();
        cluster.ingest_batch(&events[500..]).unwrap();
        cluster.flush().unwrap();
        let m2 = cluster.metrics().unwrap();
        assert_eq!(m2.processed, 1000);
        assert!(m2.hits >= m1.hits);
        // One fan-out = one answered query per replica of the user.
        let n_i = 2u64;
        assert_eq!(m2.queries, n_i);
        assert_eq!(m2.shed_queries, 0);
        assert_eq!(m2.cache_hits, 0);
        assert_eq!(m2.workers.len(), 4);
        assert_eq!(m2.rescales, 0);
        assert_eq!(m2.recoveries, 0);
        assert_eq!(m2.degraded_queries, 0);
        assert_eq!(m2.router_epoch, 0);
        let report = cluster.finish().unwrap();
        assert_eq!(report.hits, m2.hits, "final report matches last snapshot");
    }

    #[test]
    fn memory_budget_spills_and_accounting_reconciles() {
        // A 1-byte budget makes every lane over-budget, so the whole
        // working set tiers out to disk — the degenerate case that
        // exercises every accounting identity at once: counters must
        // keep counting while lanes are on disk, the reported resident
        // must respect the budget, and later traffic must fault lanes
        // back in transparently.
        let events = small_events(1500);
        let mut c = cfg(2);
        c.memory_budget_bytes = 1;
        c.memory_check_events = 8;
        let mut cluster = Cluster::spawn_labeled(&c, "t-mem").unwrap();
        cluster.ingest_batch(&events[..1000]).unwrap();
        cluster.flush().unwrap();
        let m = cluster.metrics().unwrap();
        assert_eq!(m.processed, 1000, "spilled lanes keep counting");
        assert_eq!(m.resident_bytes, 0, "budget enforced before the reply");
        assert!(m.spills > 0);
        assert!(m.spilled_lanes > 0);
        assert!(m.spilled_bytes > 0);
        assert_eq!(m.state_bytes, m.resident_bytes + m.spilled_bytes);
        assert_eq!(
            m.state_bytes,
            m.workers
                .iter()
                .map(|w| w.state_bytes + w.spilled_bytes)
                .sum::<u64>(),
            "cluster rollup equals the per-worker sums"
        );
        // Later events touch spilled lanes: transparent fault-ins.
        cluster.ingest_batch(&events[1000..]).unwrap();
        cluster.flush().unwrap();
        let m2 = cluster.metrics().unwrap();
        assert_eq!(m2.processed, 1500);
        assert!(m2.spill_faultins > 0, "ingest faulted lanes back in");
        assert!(m2.spills >= m.spills, "spill counter is monotone");
        // Serving still works against tiered lanes (fault-in on query).
        let recs = cluster.recommend(events[0].user, 5).unwrap();
        assert!(!recs.is_empty());
        let report = cluster.finish().unwrap();
        assert_eq!(report.events, 1500);
        assert!(report.spills >= m2.spills);
        assert!(report.spill_faultins >= m2.spill_faultins);
        assert!(report.state_bytes > 0, "spilled lanes stay in the rollup");
        assert_eq!(
            report.workers.iter().map(|w| w.processed).sum::<u64>(),
            1500,
            "no events lost to tiering"
        );
    }

    #[test]
    fn recommend_flushes_only_replica_buffers() {
        // Regression (query-plane split): a query must flush only the
        // queried user's replica workers — an idle worker's ingest
        // buffer stays untouched by another user's traffic.
        let mut c = cfg(2);
        c.ingest_batch_size = 10_000; // nothing auto-flushes
        let mut cluster = Cluster::spawn(&c).unwrap();
        // n_ciw = 2: user 0 lives on workers {0, 2}, user 1 on {1, 3}.
        for i in 0..40u64 {
            cluster.ingest(Rating::new(0, i, 4.0, i)).unwrap();
            cluster.ingest(Rating::new(1, i, 4.0, i)).unwrap();
        }
        let m = cluster.metrics().unwrap();
        assert_eq!(m.processed, 0, "metrics must not flush");
        assert_eq!(m.buffered, 80);
        let _ = cluster.recommend(0, 5).unwrap();
        let m = cluster.metrics().unwrap();
        assert_eq!(m.processed, 40, "only user 0's replicas were flushed");
        assert_eq!(m.buffered, 40, "user 1's buffers are untouched");
        cluster.flush().unwrap();
        let m = cluster.metrics().unwrap();
        assert_eq!(m.processed, 80);
        assert_eq!(m.buffered, 0);
        let report = cluster.finish().unwrap();
        assert_eq!(report.events, 80);
    }

    #[test]
    fn repeat_query_hits_the_serving_cache() {
        let events = small_events(800);
        let mut cluster = Cluster::spawn(&cfg(2)).unwrap();
        cluster.ingest_batch(&events).unwrap();
        let hot = events[0].user;
        let first = cluster.recommend(hot, 10).unwrap();
        let second = cluster.recommend(hot, 10).unwrap();
        assert_eq!(first, second, "cached answer identical");
        // A shorter request is served as a prefix of the cached merge.
        let shorter = cluster.recommend(hot, 3).unwrap();
        assert_eq!(shorter, first[..3.min(first.len())].to_vec());
        let m = cluster.metrics().unwrap();
        assert_eq!(m.cache_hits, 2);
        assert_eq!(m.queries, 2, "only the first query fanned out (n_i=2)");
        assert_eq!(m.shed_queries, 0);
        // Any new event for the user's column invalidates the entry
        // (strict staleness default), forcing a fresh fan-out.
        cluster.ingest(events[0]).unwrap();
        let _ = cluster.recommend(hot, 10).unwrap();
        let m = cluster.metrics().unwrap();
        assert_eq!(m.cache_hits, 2, "stale entry recomputed, not served");
        assert_eq!(m.queries, 4);
    }

    #[test]
    fn serving_handle_queries_concurrently_with_ingest() {
        // The tentpole contract in miniature: reader threads hammer the
        // query plane through ServingHandle while the owner ingests.
        let events = small_events(4000);
        let mut cluster = Cluster::spawn_labeled(&cfg(2), "t-conc").unwrap();
        let handle = cluster.serving();
        let users: Vec<u64> = events.iter().take(16).map(|e| e.user).collect();
        std::thread::scope(|s| {
            let readers: Vec<_> = (0..2)
                .map(|t| {
                    let handle = handle.clone();
                    let users = users.clone();
                    s.spawn(move || {
                        for i in 0..200usize {
                            let u = users[(t * 7 + i) % users.len()];
                            handle.recommend(u, 5).unwrap();
                        }
                    })
                })
                .collect();
            cluster.ingest_batch(&events).unwrap();
            for r in readers {
                r.join().unwrap();
            }
        });
        let m = cluster.metrics().unwrap();
        assert_eq!(
            m.shed_queries, 0,
            "2 readers never trip the default admission limit"
        );
        assert_eq!(m.processed + m.buffered, 4000);
        let report = cluster.finish().unwrap();
        assert_eq!(report.events, 4000);
    }

    #[test]
    fn timing_split_is_live() {
        let events = small_events(2000);
        let mut cluster = Cluster::spawn(&cfg(1)).unwrap();
        cluster.ingest_batch(&events).unwrap();
        let report = cluster.finish().unwrap();
        let w = &report.workers[0];
        assert!(w.update_ns > 0, "update half must be measured");
        assert!(w.recommend_ns > 0, "recommend half must be measured");
    }

    #[test]
    fn finish_without_ingest_is_empty_report() {
        let cluster = Cluster::spawn(&cfg(2)).unwrap();
        let report = cluster.finish().unwrap();
        assert_eq!(report.events, 0);
        assert_eq!(report.hits, 0);
        assert!(report.recall_curve.is_empty());
        assert_eq!(report.n_workers, 4);
        assert!(report.retired.is_empty());
        assert_eq!(report.rescales, 0);
        assert_eq!(report.recoveries, 0);
        assert_eq!(report.checkpoint_bytes, 0);
    }

    #[test]
    fn rescale_scale_in_and_out_loses_nothing() {
        // Spawn at n_i=2 with a 4x4 state-grid ceiling, scale out to
        // n_i=4, back in to n_i=1, and out again — every event must be
        // processed exactly once and the final report must account for
        // every generation.
        let events = small_events(2400);
        let mut c = cfg(2);
        c.rescale_max_n_i = 4;
        let mut cluster = Cluster::spawn_labeled(&c, "t-rescale").unwrap();
        assert_eq!(cluster.n_workers(), 4);
        assert_eq!(cluster.state_grid().n_lanes(), 16);

        cluster.ingest_batch(&events[..800]).unwrap();
        let r1 = cluster.rescale(Topology::new(4, 0).unwrap()).unwrap();
        assert_eq!(r1.from_workers, 4);
        assert_eq!(r1.to_workers, 16);
        assert_eq!(r1.epoch, 1);
        assert!(r1.bytes_moved > 0);
        assert_eq!(cluster.n_workers(), 16);
        let m = cluster.metrics().unwrap();
        assert_eq!(m.processed, 800, "no events lost in scale-out");
        assert_eq!(m.buffered, 0, "rescale flushed every buffer");
        assert_eq!(m.rescales, 1);
        assert_eq!(m.router_epoch, 1);

        cluster.ingest_batch(&events[800..1600]).unwrap();
        let r2 = cluster.rescale(Topology::new(1, 0).unwrap()).unwrap();
        assert_eq!(r2.to_workers, 1);
        let m = cluster.metrics().unwrap();
        assert_eq!(m.processed, 1600, "no events lost in scale-in");
        assert_eq!(m.workers.len(), 1);
        // The single worker hosts every lane the stream has touched
        // (lanes are built lazily, so count the distinct virtual cells).
        let touched: std::collections::HashSet<(u64, u64)> = events[..1600]
            .iter()
            .map(|e| (e.item % 4, e.user % 4))
            .collect();
        assert_eq!(m.workers[0].lanes, touched.len() as u64);

        cluster.ingest_batch(&events[1600..]).unwrap();
        let report = cluster.finish().unwrap();
        assert_eq!(report.events, 2400);
        assert_eq!(report.rescales, 2);
        assert!(report.migrated_bytes >= r1.bytes_moved + r2.bytes_moved);
        let total: u64 = report
            .workers
            .iter()
            .chain(report.retired.iter())
            .map(|w| w.processed)
            .sum();
        assert_eq!(total, 2400, "live + retired workers cover the stream");
        // 4 + 16 retired, 1 live.
        assert_eq!(report.retired.len(), 20);
        assert_eq!(report.n_workers, 1);
    }

    #[test]
    fn rescale_rejects_incompatible_topology() {
        let mut c = cfg(2);
        c.rescale_max_n_i = 4;
        let mut cluster = Cluster::spawn(&c).unwrap();
        let err =
            cluster.rescale(Topology::new(3, 0).unwrap()).unwrap_err();
        assert!(err.to_string().contains("state grid"), "{err}");
        // Session is still healthy after a rejected (pre-flight) rescale.
        cluster.ingest_batch(&small_events(100)).unwrap();
        let report = cluster.finish().unwrap();
        assert_eq!(report.events, 100);
    }

    #[test]
    fn default_grid_allows_divisor_rescale_only() {
        // Without a ceiling the state grid equals the spawn topology:
        // n_i=4 can host n_i in {1, 2, 4} but not grow to 8.
        let events = small_events(600);
        let mut cluster = Cluster::spawn(&cfg(4)).unwrap();
        cluster.ingest_batch(&events).unwrap();
        assert!(cluster.rescale(Topology::new(8, 0).unwrap()).is_err());
        cluster.rescale(Topology::new(2, 0).unwrap()).unwrap();
        assert_eq!(cluster.n_workers(), 4);
        let m = cluster.metrics().unwrap();
        assert_eq!(m.processed, 600);
        cluster.rescale(Topology::new(4, 0).unwrap()).unwrap();
        assert_eq!(cluster.n_workers(), 16);
        let report = cluster.finish().unwrap();
        assert_eq!(report.events, 600);
    }

    #[test]
    fn state_fingerprint_is_query_invariant() {
        // Two sessions over the same stream; one serves queries along
        // the way. The frozen-read guarantee means the model state —
        // and therefore the fingerprint — is byte-identical.
        let events = small_events(1200);
        let mut quiet = Cluster::spawn_labeled(&cfg(2), "t-fp-q").unwrap();
        quiet.ingest_batch(&events).unwrap();
        let fp_quiet = quiet.state_fingerprint().unwrap();
        quiet.finish().unwrap();

        let mut noisy = Cluster::spawn_labeled(&cfg(2), "t-fp-n").unwrap();
        for chunk in events.chunks(200) {
            noisy.ingest_batch(chunk).unwrap();
            let _ = noisy.recommend(chunk[0].user, 10).unwrap();
        }
        let fp_noisy = noisy.state_fingerprint().unwrap();
        assert_eq!(fp_quiet, fp_noisy, "queries perturbed model state");
        // The fingerprint drain is a real cutover: the session keeps
        // working afterwards.
        noisy.ingest_batch(&events[..100]).unwrap();
        let report = noisy.finish().unwrap();
        assert_eq!(report.events, 1300);
    }

    #[test]
    fn crash_recovery_mid_stream_is_exactly_once() {
        let events = small_events(2000);
        let mut c = cfg(2);
        c.fault_checkpoint_interval = 32;
        c.fault_chaos_kill_seq = Some(700);
        let mut cluster = Cluster::spawn_labeled(&c, "t-fault").unwrap();
        cluster.ingest_batch(&events[..1000]).unwrap();
        cluster.flush().unwrap();
        let m = cluster.metrics().unwrap();
        assert_eq!(m.ingested, 1000);
        assert_eq!(m.processed, 1000, "no event lost across the crash");
        assert_eq!(m.recoveries, 1, "exactly one worker died");
        assert_eq!(
            m.degraded_queries, 0,
            "a successful recovery never degrades serving"
        );
        // The killed event itself was never applied pre-crash, so the
        // replay is never empty.
        assert!(m.replayed_events >= 1, "{}", m.replayed_events);
        assert!(m.checkpoint_bytes > 0, "checkpoints flowed");
        assert_eq!(m.workers.len(), 4, "replacement fills the slot");
        cluster.ingest_batch(&events[1000..]).unwrap();
        let report = cluster.finish().unwrap();
        assert_eq!(report.events, 2000);
        assert_eq!(report.recoveries, 1);
        assert!(report.recovery_pause_ns > 0);
        let total: u64 =
            report.workers.iter().map(|w| w.processed).sum();
        assert_eq!(total, 2000, "restored counters + replay cover all");
    }

    #[test]
    fn crash_channel_counters_never_regress() {
        // Satellite guarantee: the dead worker's ChannelStats fold into
        // the base via `absorb`, so transport totals stay monotone
        // across a recovery.
        let events = small_events(1500);
        let mut c = cfg(2);
        c.fault_checkpoint_interval = 64;
        c.fault_chaos_kill_seq = Some(900);
        let mut cluster = Cluster::spawn(&c).unwrap();
        cluster.ingest_batch(&events[..800]).unwrap();
        let m1 = cluster.metrics().unwrap();
        assert_eq!(m1.recoveries, 0);
        cluster.ingest_batch(&events[800..]).unwrap();
        let m2 = cluster.metrics().unwrap();
        assert_eq!(m2.recoveries, 1);
        assert!(
            m2.recv_blocked_ns >= m1.recv_blocked_ns,
            "recv wait must not regress: {} -> {}",
            m1.recv_blocked_ns,
            m2.recv_blocked_ns
        );
        assert!(m2.backpressure_ns >= m1.backpressure_ns);
        let report = cluster.finish().unwrap();
        assert_eq!(report.events, 1500);
    }

    #[test]
    fn crash_during_final_drain_is_recovered() {
        // The kill seq is the very last event: the worker dies while
        // draining after finish() closed the inputs, so the panic
        // surfaces at join — and the replacement still reports.
        let events = small_events(1200);
        let mut c = cfg(2);
        c.fault_checkpoint_interval = 16;
        c.fault_chaos_kill_seq = Some(1199);
        let mut cluster = Cluster::spawn(&c).unwrap();
        cluster.ingest_batch(&events).unwrap();
        let report = cluster.finish().unwrap();
        assert_eq!(report.events, 1200);
        assert_eq!(report.recoveries, 1);
        let total: u64 =
            report.workers.iter().map(|w| w.processed).sum();
        assert_eq!(total, 1200);
    }

    #[test]
    fn crash_without_fault_tolerance_is_loud() {
        // Default config: no checkpoints, no replay log — a worker death
        // is an unrecoverable, explicit session error (the old contract).
        let events = small_events(1000);
        let mut c = cfg(2);
        c.fault_chaos_kill_seq = Some(300);
        let mut cluster = Cluster::spawn(&c).unwrap();
        let ingested = cluster.ingest_batch(&events);
        let finished = cluster.finish();
        let err = match (ingested, finished) {
            (Err(e), _) => e,
            (Ok(()), Err(e)) => e,
            (Ok(()), Ok(_)) => panic!("a killed worker must surface"),
        };
        let msg = format!("{err:#}");
        assert!(
            msg.contains("chaos") || msg.contains("died"),
            "root cause surfaced: {msg}"
        );
    }

    #[test]
    fn replay_log_exhaustion_fails_loudly_not_silently() {
        // A replay log too small to cover the checkpoint gap must turn
        // recovery into an explicit error — never a silent event loss.
        let events = small_events(1200);
        let mut c = cfg(1);
        c.fault_checkpoint_interval = 100_000; // effectively: first-event checkpoints only
        c.fault_replay_log_capacity = 8;
        c.fault_chaos_kill_seq = Some(1000);
        let mut cluster = Cluster::spawn(&c).unwrap();
        let ingested = cluster.ingest_batch(&events);
        let finished = match ingested {
            Err(e) => Err(e),
            Ok(()) => cluster.finish().map(|_| ()),
        };
        let err = finished.expect_err("recovery must refuse to lose events");
        assert!(
            format!("{err:#}").contains("replay log"),
            "actionable error: {err:#}"
        );
    }
}
