//! The long-lived cluster session — the control plane that turns the
//! crate from a benchmark script into a servable system.
//!
//! [`Cluster::spawn`] brings up the shared-nothing workers of Figure 1 and
//! keeps them alive across an *unbounded* stream: [`Cluster::ingest`]
//! pushes events through the Algorithm-1 router with backpressure,
//! [`Cluster::recommend`] is the online serving path (fan a query out to
//! every replica of the user, merge the per-replica top-N lists),
//! [`Cluster::metrics`] snapshots live counters without stopping anything,
//! and [`Cluster::finish`] drains, joins, and returns the final
//! [`RunReport`] — exactly what the old one-shot `run_pipeline` produced.
//!
//! # The worker protocol
//!
//! Workers no longer consume a bare event stream; they speak
//! [`WorkerMsg`]:
//!
//! * `Event` — one stream element; prequential test-then-train, the
//!   learning loop.
//! * `Query` — answer a recommendation from the local model over a reply
//!   channel; serving never trains (it may refresh read-side caches in
//!   the bounded-staleness cosine mode).
//! * `MetricsSnapshot` — report live counters over a reply channel.
//!
//! All three share the per-worker FIFO channel, which gives queries and
//! snapshots a useful consistency guarantee for free: a query observes
//! every event ingested before it (per worker), because it queues behind
//! them.
//!
//! # The batched data plane
//!
//! The transport is micro-batched end to end, because per-event channel
//! crossings (one mutex acquisition + one condvar wakeup each) are what
//! caps ingest throughput once the models are fast:
//!
//! * **Coordinator side** — [`Cluster::ingest`] does not send; it appends
//!   the routed envelope to a per-worker *route buffer* and flushes that
//!   worker's buffer with one bulk [`Sender::send_many`] (one lock, one
//!   wakeup) when it reaches `cfg.ingest_batch_size`.
//! * **Worker side** — the worker loop drains everything queued in one
//!   critical section ([`Receiver::recv_many`]): wake once, process a
//!   whole window of envelopes in FIFO order. Prequential accounting
//!   stays strictly per-event; only the transport is batched.
//! * **Ordering is batch-size-invariant** — every route buffer is
//!   flushed before any `Query` or `MetricsSnapshot` is sent and in
//!   [`Cluster::finish`], so a query still observes every event ingested
//!   before it and the drain guarantee is untouched. Reports, hit
//!   sequences, and recommendations are identical for any
//!   `ingest_batch_size` (property-tested in
//!   `tests/batching_equivalence.rs`).
//!
//! Per-event semantics are unchanged; `ingest_batch_size = 1` degenerates
//! to the old send-per-event plane.
//!
//! # The serving path (replicated-user read)
//!
//! A user's state is replicated across the `n_i` workers of its grid
//! column ([`Router::user_workers`]) — each replica learned from the
//! *item rows* it owns, so no single worker can rank the whole catalog
//! for the user. `recommend` therefore fans the query out to all
//! replicas, gathers each local ranked top-N plus the locally-rated item
//! set over a reply channel ([`Receiver::recv_n`]), and merges with the
//! rank-aware [`merge_topn`], excluding items the user rated on *any*
//! replica.

use std::collections::HashSet;
use std::time::Instant;

use anyhow::Result;

use crate::algorithms::build_model;
use crate::config::RunConfig;
use crate::coordinator::router::Router;
use crate::data::types::{ItemId, Rating, StateSizes, UserId};
use crate::engine::{bounded, spawn, Receiver, Sender, WorkerHandle};
use crate::eval::{merge_topn, HitSample, Prequential, RunReport, WorkerReport};
use crate::state::ForgetClock;
use crate::util::histogram::Histogram;

/// Event envelope: global sequence number + the rating.
#[derive(Debug, Clone, Copy)]
struct Envelope {
    seq: u64,
    rating: Rating,
}

/// Everything a worker can be asked to do (the control-plane protocol).
enum WorkerMsg {
    /// One stream event (the learning loop).
    Event(Envelope),
    /// Online recommendation query (the serving loop). Answered from the
    /// local model over `reply`; never *trains* the model. (It may
    /// refresh read-side caches: the bounded-staleness cosine mode
    /// rebuilds stale neighborhoods on read, so query timing can shift
    /// *when* those rebuilds happen. ISGD serving is fully read-only.)
    Query { user: UserId, n: usize, reply: Sender<ReplicaAnswer> },
    /// Live counter snapshot over `reply`; never blocks the stream for
    /// longer than one reply-channel send.
    MetricsSnapshot { reply: Sender<WorkerSnapshot> },
}

/// One replica's answer to a query. Reply arrival order is irrelevant:
/// [`merge_topn`]'s key (best rank, votes, item id) is order-independent,
/// as is the union of the rated sets.
struct ReplicaAnswer {
    /// Ranked local top-N (local rated items already excluded).
    items: Vec<ItemId>,
    /// Items this user has rated on this replica, for global exclusion.
    rated: Vec<ItemId>,
}

/// Message from workers to the collector.
enum CollectorMsg {
    /// A batch of prequential outcomes.
    Hits(Vec<HitSample>),
    /// Worker finished draining (reports travel via thread join).
    Done { worker_id: usize },
}

/// Live per-worker counters — a moment-in-time view of what
/// [`WorkerReport`] reports at shutdown.
#[derive(Debug, Clone)]
pub struct WorkerSnapshot {
    pub worker_id: usize,
    /// Events processed so far.
    pub processed: u64,
    /// Prequential hits so far.
    pub hits: u64,
    /// Serving queries answered so far.
    pub queries: u64,
    /// Current state-entry counts.
    pub state: StateSizes,
}

/// Live cluster-level snapshot returned by [`Cluster::metrics`].
#[derive(Debug, Clone)]
pub struct ClusterMetrics {
    /// Events accepted by [`Cluster::ingest`] so far.
    pub ingested: u64,
    /// Events fully processed across workers (== `ingested` at the moment
    /// the snapshot is answered: the probe rides behind the flushed
    /// buffers on the per-worker FIFO).
    pub processed: u64,
    /// Prequential hits so far.
    pub hits: u64,
    /// Lifetime online recall so far (hits / processed).
    pub recall: f64,
    /// Serving queries answered so far.
    pub queries: u64,
    /// Total ns senders spent blocked on backpressure so far.
    pub backpressure_ns: u64,
    /// Total ns worker receivers spent waiting for messages so far.
    pub recv_blocked_ns: u64,
    /// Mean messages per channel send across workers (1.0 = unbatched;
    /// tracks how much transport cost `ingest_batch_size` amortizes).
    /// Counts *all* data-channel sends: query/snapshot probes and the
    /// partial flushes they force are singletons, so probe-heavy
    /// sessions read lower than their event batching — pure ingest runs
    /// (the bench) read clean.
    pub mean_send_batch: f64,
    /// Per-worker detail, sorted by worker id.
    pub workers: Vec<WorkerSnapshot>,
}

/// A running shared-nothing cluster: ingest, serve, observe, finish.
pub struct Cluster {
    label: String,
    router: Router,
    worker_txs: Vec<Sender<WorkerMsg>>,
    /// Per-worker route buffers: envelopes accumulate here and move in
    /// bulk (`send_many`) once a buffer reaches `batch_size` — or earlier
    /// when a query/metrics probe needs read-your-writes ordering.
    route_bufs: Vec<Vec<WorkerMsg>>,
    /// Flush threshold (`cfg.ingest_batch_size`, clamped to >= 1).
    batch_size: usize,
    handles: Vec<WorkerHandle<Result<WorkerReport>>>,
    collector: Option<WorkerHandle<(Vec<(u64, f64)>, u64)>>,
    /// Wall clock starts at the first ingest (matches the old
    /// `run_pipeline` accounting, which excluded worker spawn).
    started: Option<Instant>,
    seq: u64,
    route_ns: u64,
}

impl Cluster {
    /// Start the workers and collector for `cfg`'s topology; the cluster
    /// stays up until [`Cluster::finish`] (or drop).
    pub fn spawn(cfg: &RunConfig) -> Result<Self> {
        Self::spawn_labeled(cfg, "cluster")
    }

    /// [`Cluster::spawn`] with a report label (experiment harness tag).
    pub fn spawn_labeled(cfg: &RunConfig, label: &str) -> Result<Self> {
        let router = Router::new(cfg.topology);
        let n_c = router.n_c();
        log::info!(
            "cluster '{label}': n_i={} -> {} workers, {} backend, \
             forgetting={}",
            cfg.topology.n_i,
            n_c,
            cfg.backend.name(),
            cfg.forgetting.name(),
        );

        // Channels: coordinator -> workers (bounded, backpressured),
        // workers -> collector (bounded; hit batches are small).
        let mut worker_txs: Vec<Sender<WorkerMsg>> = Vec::with_capacity(n_c);
        let mut handles = Vec::with_capacity(n_c);
        let (col_tx, col_rx) = bounded::<CollectorMsg>(n_c * 4 + 16);
        for wid in 0..n_c {
            let (tx, rx) = bounded::<WorkerMsg>(cfg.channel_capacity);
            worker_txs.push(tx);
            let cfg = cfg.clone();
            let col_tx = col_tx.clone();
            handles.push(spawn(wid, "worker", move || {
                worker_loop(wid, &cfg, rx, col_tx)
            }));
        }
        drop(col_tx);

        // Collector runs on its own thread so worker hit-batches never
        // block; it sizes its bitmaps dynamically because a session has no
        // up-front event count.
        let recall_window = cfg.recall_window;
        let sample_every = cfg.sample_every.max(1) as u64;
        let collector = spawn(usize::MAX, "collector", move || {
            collect(col_rx, recall_window, sample_every)
        });

        let batch_size = cfg.ingest_batch_size.max(1);
        let route_bufs =
            (0..n_c).map(|_| Vec::with_capacity(batch_size)).collect();
        Ok(Self {
            label: label.to_string(),
            router,
            worker_txs,
            route_bufs,
            batch_size,
            handles,
            collector: Some(collector),
            started: None,
            seq: 0,
            route_ns: 0,
        })
    }

    /// Number of workers in the cluster.
    pub fn n_workers(&self) -> usize {
        self.worker_txs.len()
    }

    /// The Algorithm-1 router (e.g. to inspect a user's replica set).
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Events accepted so far (including events still in route buffers —
    /// they are on the per-worker FIFO before any later query or probe).
    pub fn ingested(&self) -> u64 {
        self.seq
    }

    /// Route one event into its worker's buffer; the buffer moves to the
    /// worker in one bulk send once it holds `ingest_batch_size` events.
    /// Blocks only when a flush hits a full worker channel (backpressure).
    ///
    /// Error reporting is flush-grained: an `Ok` means the event is
    /// accepted (buffered or sent), and a dead worker surfaces at the
    /// flush that hits it — up to `ingest_batch_size - 1` events after
    /// the death — or at the next query/metrics/finish, whichever comes
    /// first.
    pub fn ingest(&mut self, rating: Rating) -> Result<()> {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
        let t0 = Instant::now();
        let target = self.router.route(rating.user, rating.item);
        self.route_ns += t0.elapsed().as_nanos() as u64;
        let env = Envelope { seq: self.seq, rating };
        self.route_bufs[target].push(WorkerMsg::Event(env));
        self.seq += 1;
        if self.route_bufs[target].len() >= self.batch_size {
            self.flush_worker(target)?;
        }
        Ok(())
    }

    /// Ingest a slice of events in stream order. The tail that does not
    /// fill a route buffer stays buffered; it is flushed by the next
    /// query/metrics probe, the next ingest that fills the buffer, or
    /// [`Cluster::finish`].
    pub fn ingest_batch(&mut self, events: &[Rating]) -> Result<()> {
        for &rating in events {
            self.ingest(rating)?;
        }
        Ok(())
    }

    /// Bulk-send one worker's route buffer (one lock, one wakeup).
    fn flush_worker(&mut self, wid: usize) -> Result<()> {
        if self.route_bufs[wid].is_empty() {
            return Ok(());
        }
        let buf = &mut self.route_bufs[wid];
        if self.worker_txs[wid].send_many(buf).is_err() {
            anyhow::bail!("worker {wid} died mid-stream");
        }
        Ok(())
    }

    /// Flush every route buffer. Runs before any `Query` or
    /// `MetricsSnapshot` send and in [`Cluster::finish`] so reads keep
    /// their read-your-writes guarantee: the probe queues behind every
    /// previously ingested event on each per-worker FIFO.
    fn flush_all(&mut self) -> Result<()> {
        for wid in 0..self.route_bufs.len() {
            self.flush_worker(wid)?;
        }
        Ok(())
    }

    /// Online serving: global top-`n` for `user`, answered while the
    /// stream is live.
    ///
    /// Fans the query out to every replica of the user (its grid column,
    /// [`Router::user_workers`]); each replica answers from its local
    /// model over a reply channel; the per-replica ranked lists are merged
    /// rank-aware into a global top-N that excludes items the user has
    /// rated on *any* replica. A user unknown to every replica yields an
    /// empty list (cold start).
    ///
    /// Read-your-writes: all route buffers are flushed first, so the
    /// query queues behind every previously ingested event — including
    /// events that were still buffered — on each replica's FIFO.
    pub fn recommend(&mut self, user: UserId, n: usize) -> Result<Vec<ItemId>> {
        self.flush_all()?;
        let replicas = self.router.user_workers(user);
        // Over-fetch per replica: a replica cannot know which of its
        // candidates the user consumed on *other* replicas, and the global
        // exclusion below would otherwise under-fill the merged top-N.
        // (On the PJRT backend the compiled artifact's overfetch bound may
        // clip very large requests for heavy raters — the replica then
        // degrades to fewer candidates, it never errors.)
        let fetch = n.saturating_mul(2);
        let (reply_tx, reply_rx) = bounded::<ReplicaAnswer>(replicas.len());
        let mut asked = 0usize;
        for &wid in &replicas {
            let msg =
                WorkerMsg::Query { user, n: fetch, reply: reply_tx.clone() };
            // A failed send returns (and drops) the message together with
            // its reply-sender clone, so recv_n below can't deadlock on a
            // dead replica.
            if self.worker_txs[wid].send(msg).is_ok() {
                asked += 1;
            }
        }
        drop(reply_tx);
        if asked == 0 {
            anyhow::bail!("no replica of user {user} is alive");
        }
        let answers = reply_rx.recv_n(asked);
        let exclude: HashSet<ItemId> = answers
            .iter()
            .flat_map(|a| a.rated.iter().copied())
            .collect();
        let lists: Vec<Vec<ItemId>> =
            answers.into_iter().map(|a| a.items).collect();
        Ok(merge_topn(&lists, &exclude, n))
    }

    /// Live metrics without shutdown: every worker answers a snapshot
    /// probe; route buffers are flushed first and the probe queues behind
    /// the flushed events (per-worker FIFO), so the aggregate reflects
    /// the whole prefix of the stream accepted before this call.
    pub fn metrics(&mut self) -> Result<ClusterMetrics> {
        self.flush_all()?;
        let (reply_tx, reply_rx) =
            bounded::<WorkerSnapshot>(self.worker_txs.len());
        let mut asked = 0usize;
        for tx in &self.worker_txs {
            let msg = WorkerMsg::MetricsSnapshot { reply: reply_tx.clone() };
            if tx.send(msg).is_ok() {
                asked += 1;
            }
        }
        drop(reply_tx);
        let mut workers = reply_rx.recv_n(asked);
        workers.sort_by_key(|w| w.worker_id);
        let processed: u64 = workers.iter().map(|w| w.processed).sum();
        let hits: u64 = workers.iter().map(|w| w.hits).sum();
        let queries: u64 = workers.iter().map(|w| w.queries).sum();
        let chan = self.channel_stats();
        Ok(ClusterMetrics {
            ingested: self.seq,
            processed,
            hits,
            recall: hits as f64 / (processed.max(1)) as f64,
            queries,
            backpressure_ns: chan.blocked_ns,
            recv_blocked_ns: chan.recv_blocked_ns,
            mean_send_batch: chan.mean_send_batch(),
            workers,
        })
    }

    /// Aggregate channel counters across the per-worker data channels.
    fn channel_stats(&self) -> crate::engine::ChannelStats {
        let mut total = crate::engine::ChannelStats::default();
        for tx in &self.worker_txs {
            let st = tx.metrics();
            total.sent += st.sent;
            total.send_batches += st.send_batches;
            total.blocked_ns += st.blocked_ns;
            total.recv_blocked_ns += st.recv_blocked_ns;
            total.received += st.received;
            total.recv_batches += st.recv_batches;
            total.high_water = total.high_water.max(st.high_water);
        }
        total
    }

    /// Drain in-flight events, join workers and collector, and assemble
    /// the final [`RunReport`] — the same aggregate the one-shot
    /// `run_pipeline` returns.
    ///
    /// Note on `throughput`: the wall-clock window runs from the first
    /// ingest to this call, so for an interactive session it includes
    /// serving fan-outs, metrics probes, and caller think-time — it is
    /// *session* throughput. Only a pure ingest run (what `run_pipeline`
    /// does) reads as ingest throughput.
    pub fn finish(mut self) -> Result<RunReport> {
        // Flush the buffered tail first — the drain guarantee covers every
        // accepted event. A flush failure means a worker already died; keep
        // going so the join below surfaces the root cause.
        if let Err(e) = self.flush_all() {
            log::warn!("finish: final flush failed ({e}); joining workers");
        }
        // Snapshot channel counters before closing (excludes the workers'
        // final idle wait between last event and shutdown).
        let chan = self.channel_stats();
        // Close worker inputs; workers drain and report via join.
        self.worker_txs.clear();
        let mut workers: Vec<WorkerReport> =
            Vec::with_capacity(self.handles.len());
        for h in self.handles.drain(..) {
            workers.push(h.join()??);
        }
        let wall_secs = self
            .started
            .map(|t| t.elapsed().as_secs_f64())
            .unwrap_or(0.0);
        let (recall_curve, hits) = self
            .collector
            .take()
            .expect("collector joined twice")
            .join()?;
        workers.sort_by_key(|w| w.worker_id);
        let events = self.seq;
        Ok(RunReport {
            label: self.label.clone(),
            n_workers: workers.len(),
            events,
            hits,
            wall_secs,
            throughput: events as f64 / wall_secs.max(1e-9),
            avg_recall: hits as f64 / events.max(1) as f64,
            recall_curve,
            workers,
            route_ns_per_event: self.route_ns as f64 / events.max(1) as f64,
            backpressure_ns: chan.blocked_ns,
            recv_blocked_ns: chan.recv_blocked_ns,
            mean_send_batch: chan.mean_send_batch(),
        })
    }
}

/// Worker body: prequential learning loop + serving + snapshots over one
/// local model.
///
/// Drain-based: each wakeup moves *everything* queued into a local inbox
/// in one critical section ([`Receiver::recv_many`]), then works through
/// it in FIFO order — the train loop stays per-event (prequential
/// accounting is unchanged) but lock transitions and condvar wakeups are
/// amortized over the window, and the ISGD/cosine update loops run
/// back-to-back over a resident inbox instead of interleaving with
/// channel crossings. Queries and snapshots sit at their FIFO position
/// inside the drained window, so they observe exactly the events
/// ingested before them.
fn worker_loop(
    wid: usize,
    cfg: &RunConfig,
    rx: Receiver<WorkerMsg>,
    col_tx: Sender<CollectorMsg>,
) -> Result<WorkerReport> {
    let mut model = build_model(cfg, wid)?;
    let mut preq = Prequential::new(cfg.top_n, cfg.recall_window);
    let mut clock = ForgetClock::new(cfg.forgetting);
    let mut latency = Histogram::new();
    let mut batch: Vec<HitSample> = Vec::with_capacity(256);
    let mut inbox: Vec<WorkerMsg> =
        Vec::with_capacity(cfg.ingest_batch_size.clamp(1, 4096));
    let mut processed = 0u64;
    let mut evicted = 0u64;
    let mut queries = 0u64;
    let mut recommend_ns = 0u64;
    let mut update_ns = 0u64;

    while rx.recv_many(&mut inbox, usize::MAX) {
        for msg in inbox.drain(..) {
            match msg {
                WorkerMsg::Event(env) => {
                    let out = preq.step(model.as_mut(), &env.rating);
                    latency.record(out.recommend_ns + out.update_ns);
                    recommend_ns += out.recommend_ns;
                    update_ns += out.update_ns;
                    processed += 1;
                    batch.push(HitSample { seq: env.seq, hit: out.hit });
                    if batch.len() >= 256 {
                        let full = std::mem::replace(
                            &mut batch,
                            Vec::with_capacity(256),
                        );
                        let _ = col_tx.send(CollectorMsg::Hits(full));
                    }
                    if let Some(kind) = clock.on_event(env.rating.ts) {
                        evicted += model.sweep(kind);
                    }
                }
                WorkerMsg::Query { user, n, reply } => {
                    // Serving never trains the model and never enters the
                    // prequential accounting. (Cosine fast mode may
                    // rebuild read-side neighborhood caches here; see
                    // WorkerMsg docs.)
                    queries += 1;
                    let items = model.recommend(user, n);
                    let rated = model.rated_items(user);
                    let _ = reply.send(ReplicaAnswer { items, rated });
                }
                WorkerMsg::MetricsSnapshot { reply } => {
                    let _ = reply.send(WorkerSnapshot {
                        worker_id: wid,
                        processed,
                        hits: preq.recall().hits(),
                        queries,
                        state: model.state_sizes(),
                    });
                }
            }
        }
    }
    if !batch.is_empty() {
        let _ = col_tx.send(CollectorMsg::Hits(batch));
    }
    let report = WorkerReport {
        worker_id: wid,
        processed,
        hits: preq.recall().hits(),
        state: model.state_sizes(),
        latency,
        sweeps: clock.sweeps(),
        evicted,
        recommend_ns,
        update_ns,
    };
    let _ = col_tx.send(CollectorMsg::Done { worker_id: wid });
    Ok(report)
}

/// Collector: reassembles the global prequential curve from per-worker
/// hit batches. Workers interleave arbitrarily; the moving average is
/// computed in global sequence order at the end (hit bits are buffered in
/// a dense bitmap — 1 bit per event — grown on demand because a live
/// session has no up-front event count).
fn collect(
    rx: Receiver<CollectorMsg>,
    window: usize,
    sample_every: u64,
) -> (Vec<(u64, f64)>, u64) {
    let mut bits: Vec<u8> = Vec::new();
    let mut seen: Vec<u8> = Vec::new();
    let mut n_events = 0u64;
    let mut total_hits = 0u64;
    while let Some(msg) = rx.recv() {
        match msg {
            CollectorMsg::Hits(batch) => {
                for s in batch {
                    let (byte, bit) = ((s.seq / 8) as usize, s.seq % 8);
                    if byte >= bits.len() {
                        bits.resize(byte + 1, 0);
                        seen.resize(byte + 1, 0);
                    }
                    seen[byte] |= 1 << bit;
                    if s.hit {
                        bits[byte] |= 1 << bit;
                        total_hits += 1;
                    }
                    n_events = n_events.max(s.seq + 1);
                }
            }
            CollectorMsg::Done { worker_id } => {
                log::debug!("worker {worker_id} drained");
            }
        }
    }
    // Global moving-average curve (skipping unseen slots would hide lost
    // events — they count as misses, which is the honest accounting).
    let mut ma = crate::eval::MovingRecall::new(window.max(1));
    let mut curve = Vec::new();
    for seq in 0..n_events {
        let (byte, bit) = ((seq / 8) as usize, seq % 8);
        debug_assert!(
            seen[byte] & (1 << bit) != 0,
            "event {seq} never evaluated"
        );
        ma.push(bits[byte] & (1 << bit) != 0);
        if seq % sample_every == 0 || seq + 1 == n_events {
            curve.push((seq, ma.value()));
        }
    }
    (curve, total_hits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{RunConfig, Topology};
    use crate::data::synth::{SyntheticConfig, SyntheticStream};

    fn small_events(n: u64) -> Vec<Rating> {
        SyntheticStream::new(SyntheticConfig::netflix_like(n, 11)).collect()
    }

    fn cfg(n_i: u64) -> RunConfig {
        RunConfig {
            topology: Topology::new(n_i, 0).unwrap(),
            sample_every: 100,
            ..RunConfig::default()
        }
    }

    #[test]
    fn session_interleaves_ingest_serve_metrics() {
        let events = small_events(3000);
        let mut cluster = Cluster::spawn_labeled(&cfg(2), "t-session").unwrap();
        assert_eq!(cluster.n_workers(), 4);
        let hot = events[0].user;
        let mut served = 0usize;
        for chunk in events.chunks(500) {
            cluster.ingest_batch(chunk).unwrap();
            let recs = cluster.recommend(hot, 10).unwrap();
            served += usize::from(!recs.is_empty());
            let m = cluster.metrics().unwrap();
            assert_eq!(m.processed, cluster.ingested(), "FIFO snapshot");
        }
        assert!(served > 0, "a seen user must eventually get answers");
        let report = cluster.finish().unwrap();
        assert_eq!(report.events, 3000);
        assert_eq!(
            report.workers.iter().map(|w| w.processed).sum::<u64>(),
            3000
        );
    }

    #[test]
    fn metrics_counts_queries_and_monotone_progress() {
        let events = small_events(1000);
        let mut cluster = Cluster::spawn(&cfg(2)).unwrap();
        cluster.ingest_batch(&events[..500]).unwrap();
        let m1 = cluster.metrics().unwrap();
        assert_eq!(m1.ingested, 500);
        assert_eq!(m1.processed, 500);
        assert_eq!(m1.queries, 0);
        let _ = cluster.recommend(events[0].user, 10).unwrap();
        cluster.ingest_batch(&events[500..]).unwrap();
        let m2 = cluster.metrics().unwrap();
        assert_eq!(m2.processed, 1000);
        assert!(m2.hits >= m1.hits);
        // One fan-out = one answered query per replica of the user.
        let n_i = 2u64;
        assert_eq!(m2.queries, n_i);
        assert_eq!(m2.workers.len(), 4);
        let report = cluster.finish().unwrap();
        assert_eq!(report.hits, m2.hits, "final report matches last snapshot");
    }

    #[test]
    fn timing_split_is_live() {
        let events = small_events(2000);
        let mut cluster = Cluster::spawn(&cfg(1)).unwrap();
        cluster.ingest_batch(&events).unwrap();
        let report = cluster.finish().unwrap();
        let w = &report.workers[0];
        assert!(w.update_ns > 0, "update half must be measured");
        assert!(w.recommend_ns > 0, "recommend half must be measured");
    }

    #[test]
    fn finish_without_ingest_is_empty_report() {
        let cluster = Cluster::spawn(&cfg(2)).unwrap();
        let report = cluster.finish().unwrap();
        assert_eq!(report.events, 0);
        assert_eq!(report.hits, 0);
        assert!(report.recall_curve.is_empty());
        assert_eq!(report.n_workers, 4);
    }
}
