//! One-shot pipeline runs — the batch compatibility wrapper over the
//! long-lived [`Cluster`] session API.
//!
//! Historically this module *was* the system: `run_pipeline` spun workers
//! up, drove a full in-memory event slice through the router, and tore
//! everything down per call. That machinery now lives in
//! [`crate::coordinator::cluster`]; `run_pipeline` survives unchanged in
//! signature and semantics as `spawn -> ingest_batch -> finish` so the
//! experiment harness, examples, benches, and tests keep working.
//!
//! New code that wants online serving or live metrics should hold a
//! [`Cluster`] instead (see the crate docs for the migration note).

use anyhow::Result;

use crate::config::RunConfig;
use crate::coordinator::cluster::Cluster;
use crate::data::types::Rating;
use crate::eval::RunReport;

/// Run one full pipeline over `events`; returns the aggregated report.
///
/// `label` tags the report for the experiment harness. Equivalent to
/// [`Cluster::spawn`] + [`Cluster::ingest_batch`] + [`Cluster::finish`].
/// Ingest rides the micro-batched data plane (`cfg.ingest_batch_size`
/// envelopes per bulk channel send); `finish` flushes the buffered tail,
/// and the report is identical for any batch size (see
/// `tests/batching_equivalence.rs`).
pub fn run_pipeline(
    cfg: &RunConfig,
    events: &[Rating],
    label: &str,
) -> Result<RunReport> {
    log::info!("pipeline '{label}': {} events (one-shot)", events.len());
    let mut cluster = Cluster::spawn_labeled(cfg, label)?;
    cluster.ingest_batch(events)?;
    cluster.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Algorithm, Forgetting, RunConfig, Topology};
    use crate::data::synth::{SyntheticConfig, SyntheticStream};

    fn small_events(n: u64) -> Vec<Rating> {
        SyntheticStream::new(SyntheticConfig::netflix_like(n, 11)).collect()
    }

    fn cfg(n_i: u64) -> RunConfig {
        RunConfig {
            topology: Topology::new(n_i, 0).unwrap(),
            sample_every: 100,
            ..RunConfig::default()
        }
    }

    #[test]
    fn central_isgd_runs_end_to_end() {
        let events = small_events(2000);
        let report = run_pipeline(&cfg(1), &events, "t-central").unwrap();
        assert_eq!(report.events, 2000);
        assert_eq!(report.n_workers, 1);
        assert_eq!(
            report.workers.iter().map(|w| w.processed).sum::<u64>(),
            2000
        );
        assert!(report.throughput > 0.0);
        assert!(!report.recall_curve.is_empty());
        // Recall in [0, 1].
        assert!(report.avg_recall >= 0.0 && report.avg_recall <= 1.0);
    }

    #[test]
    fn distributed_processes_every_event_exactly_once() {
        let events = small_events(3000);
        let report = run_pipeline(&cfg(2), &events, "t-ni2").unwrap();
        assert_eq!(report.n_workers, 4);
        assert_eq!(
            report.workers.iter().map(|w| w.processed).sum::<u64>(),
            3000
        );
        // Every worker got some load (router coverage).
        for w in &report.workers {
            assert!(w.processed > 0, "worker {} starved", w.worker_id);
        }
    }

    #[test]
    fn distributed_state_smaller_than_central() {
        let events = small_events(4000);
        let central = run_pipeline(&cfg(1), &events, "c").unwrap();
        let dist = run_pipeline(&cfg(2), &events, "d").unwrap();
        assert!(
            dist.mean_user_state() < central.mean_user_state(),
            "per-worker user state must shrink: {} vs {}",
            dist.mean_user_state(),
            central.mean_user_state()
        );
    }

    #[test]
    fn cosine_pipeline_runs() {
        let events = small_events(1500);
        let mut c = cfg(2);
        c.algorithm = Algorithm::Cosine;
        let report = run_pipeline(&c, &events, "t-cos").unwrap();
        assert_eq!(report.events, 1500);
        assert!(report.workers.iter().all(|w| w.state.aux > 0));
    }

    #[test]
    fn forgetting_bounds_state() {
        let events = small_events(4000);
        let mut with = cfg(1);
        with.forgetting = Forgetting::Lfu { trigger_events: 500, min_freq: 2 };
        let without = run_pipeline(&cfg(1), &events, "nof").unwrap();
        let forg = run_pipeline(&with, &events, "lfu").unwrap();
        assert!(forg.workers[0].sweeps > 0);
        assert!(forg.workers[0].evicted > 0);
        assert!(
            forg.mean_user_state() < without.mean_user_state(),
            "LFU must shrink user state"
        );
    }
}
