//! The distributed pipeline: source -> splitting & replication router ->
//! shared-nothing workers -> collector (Figure 1 of the paper).
//!
//! The driver thread plays the Flink source + partitioner: it walks the
//! timestamp-ordered event stream, routes each `<user, item, rating>`
//! with Algorithm 1, and pushes it down the target worker's bounded
//! channel (backpressure included). Each worker owns a full
//! [`StreamingRecommender`] instance — model state is never shared or
//! synchronized across workers (the HOGWILD!-style argument the paper
//! leans on) — runs the prequential evaluator over its local sub-stream,
//! applies the forgetting policy, and reports hits + state sizes back.
//!
//! The central baseline is the same pipeline with one worker.

use std::time::Instant;

use anyhow::Result;

use crate::algorithms::build_model;
use crate::config::RunConfig;
use crate::coordinator::router::Router;
use crate::data::types::Rating;
use crate::engine::{bounded, spawn, Receiver, Sender};
use crate::eval::{HitSample, Prequential, RunReport, WorkerReport};
use crate::state::ForgetClock;
use crate::util::histogram::Histogram;

/// Event envelope: global sequence number + the rating.
#[derive(Debug, Clone, Copy)]
struct Envelope {
    seq: u64,
    rating: Rating,
}

/// Message from workers to the collector.
enum CollectorMsg {
    /// A batch of prequential outcomes.
    Hits(Vec<HitSample>),
    /// Worker finished draining (reports travel via thread join).
    Done { worker_id: usize },
}

/// Run one full pipeline over `events`; returns the aggregated report.
///
/// `label` tags the report for the experiment harness.
pub fn run_pipeline(
    cfg: &RunConfig,
    events: &[Rating],
    label: &str,
) -> Result<RunReport> {
    let router = Router::new(cfg.topology);
    let n_c = router.n_c();
    log::info!(
        "pipeline '{label}': {} events, n_i={} -> {} workers, {} backend, \
         forgetting={}",
        events.len(),
        cfg.topology.n_i,
        n_c,
        cfg.backend.name(),
        cfg.forgetting.name(),
    );

    // Channels: driver -> workers (bounded, backpressured), workers ->
    // collector (bounded; hit batches are small).
    let mut worker_txs: Vec<Sender<Envelope>> = Vec::with_capacity(n_c);
    let mut handles = Vec::with_capacity(n_c);
    let (col_tx, col_rx) = bounded::<CollectorMsg>(n_c * 4 + 16);

    for wid in 0..n_c {
        let (tx, rx) = bounded::<Envelope>(cfg.channel_capacity);
        worker_txs.push(tx);
        let cfg = cfg.clone();
        let col_tx = col_tx.clone();
        handles.push(spawn(wid, "worker", move || {
            worker_loop(wid, &cfg, rx, col_tx)
        }));
    }
    drop(col_tx);

    // Collector runs on its own thread so worker hit-batches never block.
    let n_events = events.len() as u64;
    let recall_window = cfg.recall_window;
    let sample_every = cfg.sample_every.max(1) as u64;
    let collector = spawn(usize::MAX, "collector", move || {
        collect(col_rx, n_events, recall_window, sample_every)
    });

    // ---- Drive the stream (the hot loop of the leader). ----
    let start = Instant::now();
    let mut route_ns = 0u64;
    for (seq, &rating) in events.iter().enumerate() {
        let t0 = Instant::now();
        let target = router.route(rating.user, rating.item);
        route_ns += t0.elapsed().as_nanos() as u64;
        let env = Envelope { seq: seq as u64, rating };
        if worker_txs[target].send(env).is_err() {
            anyhow::bail!("worker {target} died mid-stream");
        }
    }
    // Close inputs; workers drain and report.
    let backpressure_ns: u64 =
        worker_txs.iter().map(|tx| tx.metrics().1).sum();
    drop(worker_txs);

    let mut workers: Vec<WorkerReport> = Vec::with_capacity(n_c);
    for h in handles {
        workers.push(h.join()??);
    }
    let wall_secs = start.elapsed().as_secs_f64();
    let (recall_curve, hits) = collector.join()?;

    workers.sort_by_key(|w| w.worker_id);
    let events_u64 = events.len() as u64;
    Ok(RunReport {
        label: label.to_string(),
        n_workers: n_c,
        events: events_u64,
        hits,
        wall_secs,
        throughput: events_u64 as f64 / wall_secs.max(1e-9),
        avg_recall: hits as f64 / events_u64.max(1) as f64,
        recall_curve,
        workers,
        route_ns_per_event: route_ns as f64 / events_u64.max(1) as f64,
        backpressure_ns,
    })
}

/// Worker body: prequential loop + forgetting over a local model.
fn worker_loop(
    wid: usize,
    cfg: &RunConfig,
    rx: Receiver<Envelope>,
    col_tx: Sender<CollectorMsg>,
) -> Result<WorkerReport> {
    let mut model = build_model(cfg, wid)?;
    let mut preq = Prequential::new(cfg.top_n, cfg.recall_window);
    let mut clock = ForgetClock::new(cfg.forgetting);
    let mut latency = Histogram::new();
    let mut batch: Vec<HitSample> = Vec::with_capacity(256);
    let mut processed = 0u64;
    let mut evicted = 0u64;
    let mut recommend_ns = 0u64; // split kept via latency only; see below
    let update_ns = 0u64;

    while let Some(env) = rx.recv() {
        let t0 = Instant::now();
        let hit = preq.step(model.as_mut(), &env.rating);
        let dt = t0.elapsed().as_nanos() as u64;
        latency.record(dt);
        recommend_ns += dt;
        processed += 1;
        batch.push(HitSample { seq: env.seq, hit });
        if batch.len() >= 256 {
            let full = std::mem::replace(&mut batch, Vec::with_capacity(256));
            let _ = col_tx.send(CollectorMsg::Hits(full));
        }
        if let Some(kind) = clock.on_event(env.rating.ts) {
            evicted += model.sweep(kind);
        }
    }
    if !batch.is_empty() {
        let _ = col_tx.send(CollectorMsg::Hits(batch));
    }
    let report = WorkerReport {
        worker_id: wid,
        processed,
        hits: preq.recall().hits(),
        state: model.state_sizes(),
        latency,
        sweeps: clock.sweeps(),
        evicted,
        recommend_ns,
        update_ns,
    };
    let _ = col_tx.send(CollectorMsg::Done { worker_id: wid });
    Ok(report)
}

/// Collector: reassembles the global prequential curve from per-worker
/// hit batches. Workers interleave arbitrarily; the moving average is
/// computed in global sequence order at the end (hit bits are buffered
/// in a dense bitmap — 1 bit per event).
fn collect(
    rx: Receiver<CollectorMsg>,
    n_events: u64,
    window: usize,
    sample_every: u64,
) -> (Vec<(u64, f64)>, u64) {
    let mut bits = vec![0u8; (n_events as usize).div_ceil(8)];
    let mut seen = vec![0u8; (n_events as usize).div_ceil(8)];
    let mut total_hits = 0u64;
    while let Some(msg) = rx.recv() {
        match msg {
            CollectorMsg::Hits(batch) => {
                for s in batch {
                    let (byte, bit) = ((s.seq / 8) as usize, s.seq % 8);
                    seen[byte] |= 1 << bit;
                    if s.hit {
                        bits[byte] |= 1 << bit;
                        total_hits += 1;
                    }
                }
            }
            CollectorMsg::Done { worker_id } => {
                log::debug!("worker {worker_id} drained");
            }
        }
    }
    // Global moving-average curve (skipping unseen slots would hide lost
    // events — they count as misses, which is the honest accounting).
    let mut ma = crate::eval::MovingRecall::new(window.max(1));
    let mut curve = Vec::new();
    for seq in 0..n_events {
        let (byte, bit) = ((seq / 8) as usize, seq % 8);
        debug_assert!(
            seen[byte] & (1 << bit) != 0,
            "event {seq} never evaluated"
        );
        ma.push(bits[byte] & (1 << bit) != 0);
        if seq % sample_every == 0 || seq + 1 == n_events {
            curve.push((seq, ma.value()));
        }
    }
    (curve, total_hits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Algorithm, Forgetting, RunConfig, Topology};
    use crate::data::synth::{SyntheticConfig, SyntheticStream};

    fn small_events(n: u64) -> Vec<Rating> {
        SyntheticStream::new(SyntheticConfig::netflix_like(n, 11)).collect()
    }

    fn cfg(n_i: u64) -> RunConfig {
        RunConfig {
            topology: Topology::new(n_i, 0).unwrap(),
            sample_every: 100,
            ..RunConfig::default()
        }
    }

    #[test]
    fn central_isgd_runs_end_to_end() {
        let events = small_events(2000);
        let report = run_pipeline(&cfg(1), &events, "t-central").unwrap();
        assert_eq!(report.events, 2000);
        assert_eq!(report.n_workers, 1);
        assert_eq!(
            report.workers.iter().map(|w| w.processed).sum::<u64>(),
            2000
        );
        assert!(report.throughput > 0.0);
        assert!(!report.recall_curve.is_empty());
        // Recall in [0, 1].
        assert!(report.avg_recall >= 0.0 && report.avg_recall <= 1.0);
    }

    #[test]
    fn distributed_processes_every_event_exactly_once() {
        let events = small_events(3000);
        let report = run_pipeline(&cfg(2), &events, "t-ni2").unwrap();
        assert_eq!(report.n_workers, 4);
        assert_eq!(
            report.workers.iter().map(|w| w.processed).sum::<u64>(),
            3000
        );
        // Every worker got some load (router coverage).
        for w in &report.workers {
            assert!(w.processed > 0, "worker {} starved", w.worker_id);
        }
    }

    #[test]
    fn distributed_state_smaller_than_central() {
        let events = small_events(4000);
        let central = run_pipeline(&cfg(1), &events, "c").unwrap();
        let dist = run_pipeline(&cfg(2), &events, "d").unwrap();
        assert!(
            dist.mean_user_state() < central.mean_user_state(),
            "per-worker user state must shrink: {} vs {}",
            dist.mean_user_state(),
            central.mean_user_state()
        );
    }

    #[test]
    fn cosine_pipeline_runs() {
        let events = small_events(1500);
        let mut c = cfg(2);
        c.algorithm = Algorithm::Cosine;
        let report = run_pipeline(&c, &events, "t-cos").unwrap();
        assert_eq!(report.events, 1500);
        assert!(report.workers.iter().all(|w| w.state.aux > 0));
    }

    #[test]
    fn forgetting_bounds_state() {
        let events = small_events(4000);
        let mut with = cfg(1);
        with.forgetting = Forgetting::Lfu { trigger_events: 500, min_freq: 2 };
        let without = run_pipeline(&cfg(1), &events, "nof").unwrap();
        let forg = run_pipeline(&with, &events, "lfu").unwrap();
        assert!(forg.workers[0].sweeps > 0);
        assert!(forg.workers[0].evicted > 0);
        assert!(
            forg.mean_user_state() < without.mean_user_state(),
            "LFU must shrink user state"
        );
    }
}
