//! The Splitting & Replication router — Algorithm 1, the paper's core
//! routing contribution.
//!
//! # The scheme
//!
//! Workers form a logical grid of `n_i` item rows x `n_ciw = n_c / n_i`
//! user columns (`n_c = n_i^2 + w * n_i`, Section 4). An incoming
//! `<user, item, rating>` tuple is routed by:
//!
//! ```text
//! itemHash = item mod n_i          // which item split (grid row)
//! userHash = user mod n_ciw        // which user slice  (grid column)
//! worker   = itemHash * n_ciw + userHash
//! ```
//!
//! Consequences, exactly as the paper motivates:
//! * each `(user, item)` pair lands on **exactly one** worker,
//! * an item's state is **replicated** across the `n_ciw` workers of its
//!   row (one replica per user slice it co-occurs with),
//! * a user's state is **replicated** across the `n_i` workers of its
//!   column (one replica per item split), and
//! * replicas are never synchronized — each worker learns from its local
//!   neighborhood only (shared-nothing; the HOGWILD!-style argument).
//!
//! # Faithfulness note (Algorithm 1 typos)
//!
//! The paper's printed candidate formulas are
//! `itemHash * n_ciw + x (x < n_ciw)` and `userHash + y * n_c + w
//! (y < n_i)` with `n_ciw = n_c/n_i + w`. For `w > 0` these sets cannot
//! intersect inside `0..n_c` (the user candidates escape the grid), and
//! `n_ciw = n_c/n_i + w = n_i + 2w` over-counts the columns. Both are
//! evidently typos for the grid scheme above: for every configuration the
//! paper evaluates (`w = 0`, `n_i ∈ {2,4,6}`, `n_c = n_i^2`) the printed
//! and corrected formulas agree, and only the corrected ones satisfy the
//! paper's own stated invariants ("each user-item pair hits only one
//! node", every worker utilized). [`Router::route_candidates`] implements
//! the corrected candidate-list + intersection construction literally;
//! [`Router::route`] is the algebraically-equal closed form used on the
//! hot path (a proptest pins their equivalence).

use crate::config::Topology;
use crate::data::types::{ItemId, UserId};

/// Worker index in `0..n_c`.
pub type WorkerId = usize;

/// Stateless splitting-and-replication router.
#[derive(Debug, Clone, Copy)]
pub struct Router {
    n_i: u64,
    n_ciw: u64,
    n_c: u64,
    epoch: u64,
}

impl Router {
    /// Router for `topology` at epoch 0 (the spawn-time grid).
    pub fn new(topology: Topology) -> Self {
        Self::with_epoch(topology, 0)
    }

    /// Router for `topology` stamped with a topology `epoch`. Every
    /// [`Cluster::rescale`](crate::coordinator::Cluster::rescale) installs
    /// a fresh router with the epoch bumped by one, so any externally
    /// cached routing decision (a replica set from
    /// [`Router::user_workers`], a worker id from [`Router::route`]) can
    /// be revalidated cheaply: same epoch ⇒ still valid.
    pub fn with_epoch(topology: Topology, epoch: u64) -> Self {
        let n_i = topology.n_i;
        let n_ciw = topology.n_ciw();
        let n_c = topology.n_c();
        debug_assert_eq!(n_i * n_ciw, n_c, "grid must tile the cluster");
        Self { n_i, n_ciw, n_c, epoch }
    }

    /// Topology version: 0 at spawn, +1 per rescale.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Total worker count `n_c`.
    pub fn n_c(&self) -> usize {
        self.n_c as usize
    }

    /// Item splits / replication factor `n_i` (grid rows).
    pub fn n_i(&self) -> u64 {
        self.n_i
    }

    /// Workers per item split `n_ciw` (grid columns).
    pub fn n_ciw(&self) -> u64 {
        self.n_ciw
    }

    /// Hot-path routing: closed form of Algorithm 1.
    #[inline]
    pub fn route(&self, user: UserId, item: ItemId) -> WorkerId {
        let item_hash = item % self.n_i;
        let user_hash = user % self.n_ciw;
        (item_hash * self.n_ciw + user_hash) as WorkerId
    }

    /// Literal Algorithm 1: build both candidate lists, intersect, take
    /// the first element. Kept for tests/benches as the specification.
    pub fn route_candidates(&self, user: UserId, item: ItemId) -> WorkerId {
        let item_hash = item % self.n_i;
        let user_hash = user % self.n_ciw;
        let item_candidates: Vec<u64> =
            (0..self.n_ciw).map(|x| item_hash * self.n_ciw + x).collect();
        let user_candidates: Vec<u64> =
            (0..self.n_i).map(|y| user_hash + y * self.n_ciw).collect();
        let key = item_candidates
            .iter()
            .find(|c| user_candidates.contains(c))
            .copied()
            .expect("candidate lists always intersect in the grid scheme");
        key as WorkerId
    }

    /// All workers holding a replica of this item (its grid row).
    pub fn item_workers(&self, item: ItemId) -> Vec<WorkerId> {
        let item_hash = item % self.n_i;
        (0..self.n_ciw)
            .map(|x| (item_hash * self.n_ciw + x) as WorkerId)
            .collect()
    }

    /// All workers holding a replica of this user (its grid column).
    pub fn user_workers(&self, user: UserId) -> Vec<WorkerId> {
        let user_hash = user % self.n_ciw;
        (0..self.n_i)
            .map(|y| (user_hash + y * self.n_ciw) as WorkerId)
            .collect()
    }
}

/// The *state grid*: the fixed virtual `v_i x v_u` grid that model state
/// is partitioned on, independent of how many physical workers currently
/// exist — the mechanism that makes live rescaling exact.
///
/// This is the same trick Flink's key groups / max-parallelism use: pick
/// the finest partitioning once at spawn, make it the unit of state
/// ownership ("lane"), and let every physical topology own a *group* of
/// lanes. An event `<user, item>` belongs to lane
/// `(item mod v_i, user mod v_u)` forever; a physical grid of
/// `n_i x n_ciw` workers hosts lane `(a, b)` on worker
/// `(a mod n_i, b mod n_ciw)`. Rescaling then never splits or merges
/// model state — it *moves whole lanes*, which is exact by construction:
/// the same lane models process the same events and answer the same
/// queries regardless of which worker they live on.
///
/// A physical topology is compatible iff `n_i` divides `v_i` and `n_ciw`
/// divides `v_u` — that makes the physical route
/// ([`Router::route`]) agree with lane ownership:
/// `(i mod v_i) mod n_i == i mod n_i` exactly when `n_i | v_i`.
///
/// By default (`rescale.max_n_i = 0`) the state grid equals the spawn
/// topology, which reproduces the paper's behavior bit-for-bit and allows
/// rescaling to any divisor topology. Setting `rescale.max_n_i` (the
/// Flink "max parallelism" analog) fixes a finer grid so the cluster can
/// later grow *beyond* its spawn size; the trade-off is that model
/// granularity is that of the finest grid from the start (documented in
/// ARCHITECTURE.md).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StateGrid {
    v_i: u64,
    v_u: u64,
}

impl StateGrid {
    /// Build a `v_i x v_u` state grid (both must be >= 1).
    pub fn new(v_i: u64, v_u: u64) -> anyhow::Result<Self> {
        if v_i == 0 || v_u == 0 {
            anyhow::bail!("state grid dimensions must be >= 1");
        }
        Ok(Self { v_i, v_u })
    }

    /// State grid for a run: the spawn topology itself unless
    /// `rescale.max_n_i` fixes a finer ceiling grid (which the spawn
    /// topology must then divide).
    pub fn for_config(cfg: &crate::config::RunConfig) -> anyhow::Result<Self> {
        let t = cfg.topology;
        if cfg.rescale_max_n_i == 0 {
            return Self::new(t.n_i, t.n_ciw());
        }
        let v_i = cfg.rescale_max_n_i;
        let v_u = cfg.rescale_max_n_i + cfg.rescale_max_w;
        let grid = Self::new(v_i, v_u)?;
        if !grid.supports(t) {
            anyhow::bail!(
                "spawn topology n_i={} n_ciw={} does not divide the \
                 rescale ceiling grid {}x{} (rescale.max_n_i/max_w)",
                t.n_i,
                t.n_ciw(),
                v_i,
                v_u,
            );
        }
        Ok(grid)
    }

    /// Item-split count of the virtual grid (rows).
    pub fn v_i(&self) -> u64 {
        self.v_i
    }

    /// User-slice count of the virtual grid (columns).
    pub fn v_u(&self) -> u64 {
        self.v_u
    }

    /// Total lane count `v_i * v_u`.
    pub fn n_lanes(&self) -> u64 {
        self.v_i * self.v_u
    }

    /// Lane id owning the `<user, item>` pair: `row * v_u + col`.
    #[inline]
    pub fn lane(&self, user: UserId, item: ItemId) -> u64 {
        (item % self.v_i) * self.v_u + user % self.v_u
    }

    /// Grid row (item split) of a lane id.
    #[inline]
    pub fn lane_row(&self, lane: u64) -> u64 {
        lane / self.v_u
    }

    /// Grid column (user slice) of a lane id.
    #[inline]
    pub fn lane_col(&self, lane: u64) -> u64 {
        lane % self.v_u
    }

    /// The virtual column every replica of `user` lives in.
    #[inline]
    pub fn user_col(&self, user: UserId) -> u64 {
        user % self.v_u
    }

    /// Can a cluster with this state grid run physical topology `t`?
    pub fn supports(&self, t: Topology) -> bool {
        self.v_i % t.n_i == 0 && self.v_u % t.n_ciw() == 0
    }

    /// Physical worker hosting `lane` under `router`'s topology.
    #[inline]
    pub fn owner(&self, lane: u64, router: &Router) -> WorkerId {
        let row = self.lane_row(lane) % router.n_i();
        let col = self.lane_col(lane) % router.n_ciw();
        (row * router.n_ciw() + col) as WorkerId
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    fn topo(n_i: u64, w: u64) -> Router {
        Router::new(Topology::new(n_i, w).unwrap())
    }

    #[test]
    fn paper_configs_grid_shape() {
        for n_i in [2u64, 4, 6] {
            let r = topo(n_i, 0);
            assert_eq!(r.n_c(), (n_i * n_i) as usize);
            assert_eq!(r.n_ciw(), n_i);
        }
    }

    #[test]
    fn route_in_range_and_deterministic() {
        let r = topo(4, 0);
        for u in 0..100u64 {
            for i in 0..100u64 {
                let k = r.route(u, i);
                assert!(k < r.n_c());
                assert_eq!(k, r.route(u, i));
            }
        }
    }

    #[test]
    fn closed_form_equals_algorithm1_literal() {
        forall("router_closed_form", 500, |rng| {
            let n_i = 1 + rng.next_bounded(6);
            let w = rng.next_bounded(4);
            let r = topo(n_i, w);
            let u = rng.next_u64();
            let i = rng.next_u64();
            assert_eq!(r.route(u, i), r.route_candidates(u, i));
        });
    }

    #[test]
    fn pair_hits_exactly_one_worker() {
        // The routed worker is in BOTH replica sets, and is unique.
        forall("router_unique_intersection", 300, |rng| {
            let n_i = 1 + rng.next_bounded(6);
            let w = rng.next_bounded(3);
            let r = topo(n_i, w);
            let u = rng.next_u64();
            let i = rng.next_u64();
            let key = r.route(u, i);
            let iw = r.item_workers(i);
            let uw = r.user_workers(u);
            let inter: Vec<_> =
                iw.iter().filter(|k| uw.contains(k)).collect();
            assert_eq!(inter, vec![&key]);
        });
    }

    #[test]
    fn replica_counts_match_section4() {
        let r = topo(4, 0);
        // Items replicated over n_ciw workers, users over n_i workers.
        assert_eq!(r.item_workers(123).len(), 4);
        assert_eq!(r.user_workers(456).len(), 4);
        let r = topo(2, 1); // n_c = 6, grid 2x3
        assert_eq!(r.n_c(), 6);
        assert_eq!(r.item_workers(9).len(), 3);
        assert_eq!(r.user_workers(9).len(), 2);
    }

    #[test]
    fn all_workers_reachable_under_uniform_keys() {
        forall("router_covers_cluster", 50, |rng| {
            let n_i = 1 + rng.next_bounded(5);
            let w = rng.next_bounded(3);
            let r = topo(n_i, w);
            let mut hit = vec![false; r.n_c()];
            for _ in 0..r.n_c() * 64 {
                hit[r.route(rng.next_u64(), rng.next_u64())] = true;
            }
            assert!(
                hit.iter().all(|&h| h),
                "every worker must receive load (n_i={n_i} w={w})"
            );
        });
    }

    #[test]
    fn state_grid_owner_agrees_with_physical_route() {
        // The load-bearing rescale invariant: for every compatible
        // physical topology, the worker Algorithm 1 routes an event to
        // IS the worker hosting the event's lane.
        forall("grid_owner_vs_route", 300, |rng| {
            let v_i = 1 + rng.next_bounded(8);
            let v_u_extra = rng.next_bounded(4);
            let v_u = v_i + v_u_extra;
            let grid = StateGrid::new(v_i, v_u).unwrap();
            // Random compatible topology: divisors of (v_i, v_u).
            let n_i = divisor_of(v_i, rng);
            let n_ciw = divisor_of(v_u, rng);
            let w = n_ciw.saturating_sub(n_i);
            if n_i + w != n_ciw {
                return; // Topology encodes n_ciw = n_i + w; skip others.
            }
            let r = Router::new(Topology::new(n_i, w).unwrap());
            assert!(grid.supports(Topology::new(n_i, w).unwrap()));
            for _ in 0..64 {
                let u = rng.next_u64();
                let i = rng.next_u64();
                let lane = grid.lane(u, i);
                assert!(lane < grid.n_lanes());
                assert_eq!(
                    grid.owner(lane, &r),
                    r.route(u, i),
                    "v=({v_i},{v_u}) topo=({n_i},{w})"
                );
            }
        });
    }

    fn divisor_of(n: u64, rng: &mut crate::util::rng::Pcg32) -> u64 {
        let divs: Vec<u64> = (1..=n).filter(|d| n % d == 0).collect();
        divs[rng.next_bounded(divs.len() as u64) as usize]
    }

    #[test]
    fn state_grid_default_equals_spawn_topology() {
        use crate::config::RunConfig;
        let mut cfg = RunConfig {
            topology: Topology::new(2, 0).unwrap(),
            ..RunConfig::default()
        };
        let grid = StateGrid::for_config(&cfg).unwrap();
        assert_eq!((grid.v_i(), grid.v_u()), (2, 2));
        assert_eq!(grid.n_lanes(), 4);
        // Ceiling grid: finer than spawn, must be divisible.
        cfg.rescale_max_n_i = 4;
        let grid = StateGrid::for_config(&cfg).unwrap();
        assert_eq!((grid.v_i(), grid.v_u()), (4, 4));
        assert!(grid.supports(Topology::new(1, 0).unwrap()));
        assert!(grid.supports(Topology::new(4, 0).unwrap()));
        assert!(!grid.supports(Topology::new(3, 0).unwrap()));
        // Spawn topology that does not divide the ceiling is rejected.
        cfg.topology = Topology::new(3, 0).unwrap();
        assert!(StateGrid::for_config(&cfg).is_err());
    }

    #[test]
    fn router_epoch_round_trips() {
        let t = Topology::new(2, 0).unwrap();
        assert_eq!(Router::new(t).epoch(), 0);
        assert_eq!(Router::with_epoch(t, 7).epoch(), 7);
    }

    #[test]
    fn central_topology_routes_everything_to_worker_zero() {
        let r = topo(1, 0);
        assert_eq!(r.n_c(), 1);
        for x in 0..50u64 {
            assert_eq!(r.route(x * 7919, x * 104_729), 0);
        }
    }

    #[test]
    fn same_user_same_column_same_item_same_row() {
        let r = topo(3, 0);
        let u = 42u64;
        // All of user u's events land in u's grid column.
        let col = (u % r.n_ciw()) as usize;
        for i in 0..100u64 {
            assert_eq!(r.route(u, i) % r.n_ciw() as usize, col);
        }
        let i = 99u64;
        let row = (i % r.n_i()) as usize;
        for u in 0..100u64 {
            assert_eq!(r.route(u, i) / r.n_ciw() as usize, row);
        }
    }
}
