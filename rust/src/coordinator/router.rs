//! The Splitting & Replication router — Algorithm 1, the paper's core
//! routing contribution.
//!
//! # The scheme
//!
//! Workers form a logical grid of `n_i` item rows x `n_ciw = n_c / n_i`
//! user columns (`n_c = n_i^2 + w * n_i`, Section 4). An incoming
//! `<user, item, rating>` tuple is routed by:
//!
//! ```text
//! itemHash = item mod n_i          // which item split (grid row)
//! userHash = user mod n_ciw        // which user slice  (grid column)
//! worker   = itemHash * n_ciw + userHash
//! ```
//!
//! Consequences, exactly as the paper motivates:
//! * each `(user, item)` pair lands on **exactly one** worker,
//! * an item's state is **replicated** across the `n_ciw` workers of its
//!   row (one replica per user slice it co-occurs with),
//! * a user's state is **replicated** across the `n_i` workers of its
//!   column (one replica per item split), and
//! * replicas are never synchronized — each worker learns from its local
//!   neighborhood only (shared-nothing; the HOGWILD!-style argument).
//!
//! # Faithfulness note (Algorithm 1 typos)
//!
//! The paper's printed candidate formulas are
//! `itemHash * n_ciw + x (x < n_ciw)` and `userHash + y * n_c + w
//! (y < n_i)` with `n_ciw = n_c/n_i + w`. For `w > 0` these sets cannot
//! intersect inside `0..n_c` (the user candidates escape the grid), and
//! `n_ciw = n_c/n_i + w = n_i + 2w` over-counts the columns. Both are
//! evidently typos for the grid scheme above: for every configuration the
//! paper evaluates (`w = 0`, `n_i ∈ {2,4,6}`, `n_c = n_i^2`) the printed
//! and corrected formulas agree, and only the corrected ones satisfy the
//! paper's own stated invariants ("each user-item pair hits only one
//! node", every worker utilized). [`Router::route_candidates`] implements
//! the corrected candidate-list + intersection construction literally;
//! [`Router::route`] is the algebraically-equal closed form used on the
//! hot path (a proptest pins their equivalence).

use crate::config::Topology;
use crate::data::types::{ItemId, UserId};

/// Worker index in `0..n_c`.
pub type WorkerId = usize;

/// Stateless splitting-and-replication router.
#[derive(Debug, Clone, Copy)]
pub struct Router {
    n_i: u64,
    n_ciw: u64,
    n_c: u64,
}

impl Router {
    pub fn new(topology: Topology) -> Self {
        let n_i = topology.n_i;
        let n_ciw = topology.n_ciw();
        let n_c = topology.n_c();
        debug_assert_eq!(n_i * n_ciw, n_c, "grid must tile the cluster");
        Self { n_i, n_ciw, n_c }
    }

    pub fn n_c(&self) -> usize {
        self.n_c as usize
    }

    pub fn n_i(&self) -> u64 {
        self.n_i
    }

    pub fn n_ciw(&self) -> u64 {
        self.n_ciw
    }

    /// Hot-path routing: closed form of Algorithm 1.
    #[inline]
    pub fn route(&self, user: UserId, item: ItemId) -> WorkerId {
        let item_hash = item % self.n_i;
        let user_hash = user % self.n_ciw;
        (item_hash * self.n_ciw + user_hash) as WorkerId
    }

    /// Literal Algorithm 1: build both candidate lists, intersect, take
    /// the first element. Kept for tests/benches as the specification.
    pub fn route_candidates(&self, user: UserId, item: ItemId) -> WorkerId {
        let item_hash = item % self.n_i;
        let user_hash = user % self.n_ciw;
        let item_candidates: Vec<u64> =
            (0..self.n_ciw).map(|x| item_hash * self.n_ciw + x).collect();
        let user_candidates: Vec<u64> =
            (0..self.n_i).map(|y| user_hash + y * self.n_ciw).collect();
        let key = item_candidates
            .iter()
            .find(|c| user_candidates.contains(c))
            .copied()
            .expect("candidate lists always intersect in the grid scheme");
        key as WorkerId
    }

    /// All workers holding a replica of this item (its grid row).
    pub fn item_workers(&self, item: ItemId) -> Vec<WorkerId> {
        let item_hash = item % self.n_i;
        (0..self.n_ciw)
            .map(|x| (item_hash * self.n_ciw + x) as WorkerId)
            .collect()
    }

    /// All workers holding a replica of this user (its grid column).
    pub fn user_workers(&self, user: UserId) -> Vec<WorkerId> {
        let user_hash = user % self.n_ciw;
        (0..self.n_i)
            .map(|y| (user_hash + y * self.n_ciw) as WorkerId)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    fn topo(n_i: u64, w: u64) -> Router {
        Router::new(Topology::new(n_i, w).unwrap())
    }

    #[test]
    fn paper_configs_grid_shape() {
        for n_i in [2u64, 4, 6] {
            let r = topo(n_i, 0);
            assert_eq!(r.n_c(), (n_i * n_i) as usize);
            assert_eq!(r.n_ciw(), n_i);
        }
    }

    #[test]
    fn route_in_range_and_deterministic() {
        let r = topo(4, 0);
        for u in 0..100u64 {
            for i in 0..100u64 {
                let k = r.route(u, i);
                assert!(k < r.n_c());
                assert_eq!(k, r.route(u, i));
            }
        }
    }

    #[test]
    fn closed_form_equals_algorithm1_literal() {
        forall("router_closed_form", 500, |rng| {
            let n_i = 1 + rng.next_bounded(6);
            let w = rng.next_bounded(4);
            let r = topo(n_i, w);
            let u = rng.next_u64();
            let i = rng.next_u64();
            assert_eq!(r.route(u, i), r.route_candidates(u, i));
        });
    }

    #[test]
    fn pair_hits_exactly_one_worker() {
        // The routed worker is in BOTH replica sets, and is unique.
        forall("router_unique_intersection", 300, |rng| {
            let n_i = 1 + rng.next_bounded(6);
            let w = rng.next_bounded(3);
            let r = topo(n_i, w);
            let u = rng.next_u64();
            let i = rng.next_u64();
            let key = r.route(u, i);
            let iw = r.item_workers(i);
            let uw = r.user_workers(u);
            let inter: Vec<_> =
                iw.iter().filter(|k| uw.contains(k)).collect();
            assert_eq!(inter, vec![&key]);
        });
    }

    #[test]
    fn replica_counts_match_section4() {
        let r = topo(4, 0);
        // Items replicated over n_ciw workers, users over n_i workers.
        assert_eq!(r.item_workers(123).len(), 4);
        assert_eq!(r.user_workers(456).len(), 4);
        let r = topo(2, 1); // n_c = 6, grid 2x3
        assert_eq!(r.n_c(), 6);
        assert_eq!(r.item_workers(9).len(), 3);
        assert_eq!(r.user_workers(9).len(), 2);
    }

    #[test]
    fn all_workers_reachable_under_uniform_keys() {
        forall("router_covers_cluster", 50, |rng| {
            let n_i = 1 + rng.next_bounded(5);
            let w = rng.next_bounded(3);
            let r = topo(n_i, w);
            let mut hit = vec![false; r.n_c()];
            for _ in 0..r.n_c() * 64 {
                hit[r.route(rng.next_u64(), rng.next_u64())] = true;
            }
            assert!(
                hit.iter().all(|&h| h),
                "every worker must receive load (n_i={n_i} w={w})"
            );
        });
    }

    #[test]
    fn central_topology_routes_everything_to_worker_zero() {
        let r = topo(1, 0);
        assert_eq!(r.n_c(), 1);
        for x in 0..50u64 {
            assert_eq!(r.route(x * 7919, x * 104_729), 0);
        }
    }

    #[test]
    fn same_user_same_column_same_item_same_row() {
        let r = topo(3, 0);
        let u = 42u64;
        // All of user u's events land in u's grid column.
        let col = (u % r.n_ciw()) as usize;
        for i in 0..100u64 {
            assert_eq!(r.route(u, i) % r.n_ciw() as usize, col);
        }
        let i = 99u64;
        let row = (i % r.n_i()) as usize;
        for u in 0..100u64 {
            assert_eq!(r.route(u, i) / r.n_ciw() as usize, row);
        }
    }
}
