//! The paper's system contribution: the splitting & replication router
//! (Algorithm 1), the long-lived [`Cluster`] session that drives
//! shared-nothing streaming recommenders (Figures 1-2) and serves online
//! queries over the user replicas, and the one-shot [`run_pipeline`]
//! compatibility wrapper.

pub mod cluster;
pub mod pipeline;
pub mod router;

pub use cluster::{Cluster, ClusterMetrics, WorkerSnapshot};
pub use pipeline::run_pipeline;
pub use router::{Router, WorkerId};
