//! The paper's system contribution: the splitting & replication router
//! (Algorithm 1) and the leader/worker pipeline that drives shared-nothing
//! streaming recommenders (Figures 1-2).

pub mod pipeline;
pub mod router;

pub use pipeline::run_pipeline;
pub use router::{Router, WorkerId};
