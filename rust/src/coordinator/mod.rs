//! The paper's system contribution: the splitting & replication router
//! (Algorithm 1), the long-lived [`Cluster`] session that drives
//! shared-nothing streaming recommenders (Figures 1-2), serves online
//! queries over the user replicas, rescales live via lane migration
//! on the virtual [`StateGrid`], and survives worker crashes via the
//! supervisor's checkpoint/replay recovery — plus the one-shot
//! [`run_pipeline`] compatibility wrapper.

pub mod cluster;
pub mod pipeline;
pub mod router;
pub(crate) mod serving;
pub(crate) mod supervisor;

pub use cluster::{Cluster, ClusterMetrics, RescaleReport, WorkerSnapshot};
pub use serving::ServingHandle;
pub use pipeline::run_pipeline;
pub use router::{Router, StateGrid, WorkerId};
