//! Shared experiment runner: a cache of pipeline runs keyed by
//! (algorithm, dataset, n_i, forgetting) so that figures reusing the same
//! configurations (e.g. Fig 3 recall / Fig 4 memory / Fig 8 throughput
//! all view the same DISGD runs) execute each run once.

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::Result;

use crate::config::{Algorithm, Backend, Forgetting, RunConfig, Topology};
use crate::coordinator::run_pipeline;
use crate::data::types::Rating;
use crate::data::DatasetSpec;
use crate::eval::RunReport;
use crate::util::csv::CsvWriter;

/// Forgetting policy selector used in run keys.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Policy {
    /// No forgetting (the paper's base configuration).
    None,
    /// Least-recently-used eviction.
    Lru,
    /// Least-frequently-used eviction.
    Lfu,
    /// Gradual forgetting — the paper's future-work extension.
    Decay,
}

impl Policy {
    /// Canonical policy name used in labels and CSV columns.
    pub fn name(&self) -> &'static str {
        match self {
            Policy::None => "none",
            Policy::Lru => "lru",
            Policy::Lfu => "lfu",
            Policy::Decay => "decay",
        }
    }
}

/// Cache key for one pipeline run.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct RunKey {
    /// Algorithm under test.
    pub algo: Algorithm,
    /// Dataset id ("ml-like" | "nf-like").
    pub dataset: String,
    /// Replication factor (1 = central baseline).
    pub n_i: u64,
    /// Forgetting policy.
    pub policy: Policy,
}

impl RunKey {
    /// Human-readable run label, e.g. `isgd-ml-like-ni4-lru`.
    pub fn label(&self) -> String {
        let topo = if self.n_i == 1 {
            "central".to_string()
        } else {
            format!("ni{}", self.n_i)
        };
        format!(
            "{}-{}-{}-{}",
            self.algo.name(),
            self.dataset,
            topo,
            self.policy.name()
        )
    }
}

/// Experiment context: datasets, run cache, output directory, scale knobs.
pub struct ExpContext {
    /// Directory results are written under (`results/<exp>/`).
    pub out_dir: PathBuf,
    /// Stream length per dataset.
    pub events: u64,
    /// Event cap for the central cosine baseline (the paper's central
    /// ML-25M job was killed after 11 days at 8356 records; we cap it
    /// instead and report partial throughput the same way).
    pub central_cosine_cap: u64,
    /// Dataset + model seed.
    pub seed: u64,
    /// Scoring backend every run uses.
    pub backend: Backend,
    datasets: HashMap<String, Vec<Rating>>,
    cache: HashMap<RunKey, RunReport>,
}

impl ExpContext {
    /// Context writing under `out_dir` with `events`-long streams.
    pub fn new(out_dir: &str, events: u64, seed: u64) -> Self {
        Self {
            out_dir: PathBuf::from(out_dir),
            events,
            central_cosine_cap: (events / 8).max(2000),
            seed,
            backend: Backend::Native,
            datasets: HashMap::new(),
            cache: HashMap::new(),
        }
    }

    /// Lazily materialize a dataset ("ml-like" | "nf-like").
    pub fn dataset(&mut self, name: &str) -> Result<&[Rating]> {
        if !self.datasets.contains_key(name) {
            let spec = DatasetSpec::parse(
                &format!("{name}:{}", self.events),
                self.seed,
            )?;
            let events = spec.load()?;
            self.datasets.insert(name.to_string(), events);
        }
        Ok(self.datasets.get(name).unwrap())
    }

    /// Paper-tuned forgetting parameters, scaled to the synthetic clock.
    /// LRU is tuned for recall (gentle, time-based); LFU is tuned
    /// aggressively for memory (count-based), as in Section 5.2.
    pub fn policy_config(&self, policy: Policy) -> Forgetting {
        match policy {
            Policy::None => Forgetting::None,
            Policy::Lru => Forgetting::Lru {
                trigger_secs: 86_400,          // scan daily (event time)
                max_idle_secs: 5 * 86_400,     // forget after 5 idle days
            },
            Policy::Lfu => Forgetting::Lfu {
                trigger_events: 10_000,        // scan every 10k records
                min_freq: 2,                   // aggressive: drop singletons
            },
            Policy::Decay => Forgetting::Decay {
                trigger_events: 10_000,
                factor: 0.9,
            },
        }
    }

    /// Run (or fetch from cache) one configuration.
    pub fn run(&mut self, key: RunKey) -> Result<RunReport> {
        if let Some(r) = self.cache.get(&key) {
            return Ok(r.clone());
        }
        let forgetting = self.policy_config(key.policy);
        let cfg = RunConfig {
            algorithm: key.algo,
            backend: self.backend,
            topology: Topology::new(key.n_i, 0)?,
            forgetting,
            seed: self.seed,
            ..RunConfig::default()
        };
        let label = key.label();
        // Reproduce the paper's capped central-cosine baseline.
        let cap = if key.algo == Algorithm::Cosine && key.n_i == 1 {
            self.central_cosine_cap as usize
        } else {
            usize::MAX
        };
        let events = self.dataset(&key.dataset)?;
        let slice = &events[..events.len().min(cap)];
        let capped = slice.len() != events.len();
        if capped {
            log::warn!(
                "{label}: central cosine capped at {} events (paper's \
                 central ML job never finished either)",
                slice.len()
            );
        }
        let slice = slice.to_vec();
        let report = run_pipeline(&cfg, &slice, &label)?;
        log::info!("{}", report.summary());
        self.cache.insert(key.clone(), report.clone());
        Ok(report)
    }

    /// Run the standard configuration sweep for one algorithm + dataset:
    /// central + n_i in {2,4,6}, for each policy in `policies`.
    pub fn sweep(
        &mut self,
        algo: Algorithm,
        dataset: &str,
        policies: &[Policy],
    ) -> Result<Vec<(RunKey, RunReport)>> {
        let mut out = Vec::new();
        for &policy in policies {
            for n_i in [1u64, 2, 4, 6] {
                let key = RunKey {
                    algo,
                    dataset: dataset.to_string(),
                    n_i,
                    policy,
                };
                let report = self.run(key.clone())?;
                out.push((key, report));
            }
        }
        Ok(out)
    }

    /// Create a CSV writer under `results/<exp>/`.
    pub fn csv(&self, exp: &str, file: &str, header: &[&str]) -> Result<CsvWriter> {
        let path = self.out_dir.join(exp).join(file);
        Ok(CsvWriter::create(path, header)?)
    }
}

/// Write recall curves for a set of runs into one long-format CSV.
pub fn write_recall_curves(
    w: &mut CsvWriter,
    runs: &[(RunKey, RunReport)],
) -> Result<()> {
    for (key, report) in runs {
        for (seq, recall) in &report.recall_curve {
            w.row(&[
                key.dataset.clone(),
                key.label(),
                key.n_i.to_string(),
                key.policy.name().to_string(),
                seq.to_string(),
                format!("{recall:.6}"),
            ])?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Write per-worker final state sizes (the paper's memory distributions).
pub fn write_state_distribution(
    w: &mut CsvWriter,
    runs: &[(RunKey, RunReport)],
) -> Result<()> {
    for (key, report) in runs {
        for worker in &report.workers {
            w.row(&[
                key.dataset.clone(),
                key.label(),
                key.n_i.to_string(),
                key.policy.name().to_string(),
                worker.worker_id.to_string(),
                worker.state.users.to_string(),
                worker.state.items.to_string(),
                worker.state.aux.to_string(),
            ])?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Write throughput rows.
pub fn write_throughput(
    w: &mut CsvWriter,
    runs: &[(RunKey, RunReport)],
) -> Result<()> {
    for (key, report) in runs {
        w.row(&[
            key.dataset.clone(),
            key.label(),
            key.n_i.to_string(),
            key.policy.name().to_string(),
            report.events.to_string(),
            format!("{:.6}", report.wall_secs),
            format!("{:.1}", report.throughput),
            format!("{:.6}", report.avg_recall),
        ])?;
    }
    w.flush()?;
    Ok(())
}

/// CSV header for recall-curve files.
pub const RECALL_HEADER: [&str; 6] =
    ["dataset", "config", "n_i", "policy", "seq", "recall_ma"];
/// CSV header for per-worker state-distribution files.
pub const STATE_HEADER: [&str; 8] = [
    "dataset", "config", "n_i", "policy", "worker", "users", "items", "aux",
];
/// CSV header for throughput files.
pub const THROUGHPUT_HEADER: [&str; 8] = [
    "dataset", "config", "n_i", "policy", "events", "wall_secs",
    "events_per_sec", "avg_recall",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_key_labels() {
        let k = RunKey {
            algo: Algorithm::Isgd,
            dataset: "ml-like".into(),
            n_i: 1,
            policy: Policy::None,
        };
        assert_eq!(k.label(), "isgd-ml-like-central-none");
        let k = RunKey { n_i: 4, policy: Policy::Lru, ..k };
        assert_eq!(k.label(), "isgd-ml-like-ni4-lru");
    }

    #[test]
    fn context_caches_runs() {
        let mut ctx = ExpContext::new("/tmp/streamrec_exp_test", 2000, 5);
        let key = RunKey {
            algo: Algorithm::Isgd,
            dataset: "nf-like".into(),
            n_i: 2,
            policy: Policy::None,
        };
        let a = ctx.run(key.clone()).unwrap();
        let b = ctx.run(key).unwrap();
        assert_eq!(a.events, b.events);
        assert_eq!(a.hits, b.hits);
        assert_eq!(ctx.cache.len(), 1);
    }

    #[test]
    fn central_cosine_is_capped() {
        let mut ctx = ExpContext::new("/tmp/streamrec_exp_test2", 4000, 5);
        ctx.central_cosine_cap = 500;
        let key = RunKey {
            algo: Algorithm::Cosine,
            dataset: "nf-like".into(),
            n_i: 1,
            policy: Policy::None,
        };
        let r = ctx.run(key).unwrap();
        assert_eq!(r.events, 500);
    }
}
