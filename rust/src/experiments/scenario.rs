//! Declarative drift-scenario experiments — the `streamrec experiment`
//! driver.
//!
//! A *scenario file* is one TOML document describing a grid of runs:
//! datasets × algorithms × topologies, all sharing one drift shape
//! (`[drift]`), one model/forgetting/fault configuration (the regular
//! `RunConfig` tables), and optionally a mid-stream rescale and a chaos
//! kill — the paper-style "baseline `n_i = 1` vs distributed grids"
//! comparison, rebuilt on the live [`Cluster`] session API instead of
//! the one-shot pipeline.
//!
//! Each run drives the full stream through a session, captures the
//! [`RunReport`] (cumulative curve + tumbling-window recall), condenses
//! the windowed series into a [`DriftResponse`] (pre-drift / dip /
//! recovered), writes one per-window CSV per run, and emits a
//! `BENCH_drift.json` summary next to the other `BENCH_*` result files.
//! Schemas are documented in docs/EXPERIMENTS.md; the scenario TOML
//! keys in docs/CONFIG.md.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::{parse_toml_subset, Algorithm, RunConfig, Topology};
use crate::coordinator::Cluster;
use crate::data::drift::{frac_seq, DriftConfig};
use crate::data::types::Rating;
use crate::data::DatasetSpec;
use crate::eval::{drift_response, DriftResponse, RunReport};
use crate::util::csv::CsvWriter;
use crate::util::json::{num, obj, s, to_string, Json};

/// Optional mid-stream elastic rescale (`[rescale] at / to_n_i` in the
/// scenario file): at stream fraction `at`, distributed runs cut over to
/// topology `to_n_i`. The `n_i = 1` baseline is left alone — it exists
/// to be the fixed comparison point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MidStreamRescale {
    /// Stream fraction the cutover fires at.
    pub at: f64,
    /// Target replication factor.
    pub to_n_i: u64,
}

/// A parsed scenario file: the run grid plus everything the runs share.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Scenario id (labels, result files).
    pub name: String,
    /// Events per run (appended to bare dataset names; an explicit
    /// `name:events` spec in `datasets` wins).
    pub events: u64,
    /// Dataset + model seed shared by every run.
    pub seed: u64,
    /// Dataset specs in the grid (`ml-like`, `nf-like`, or full
    /// `DatasetSpec` strings).
    pub datasets: Vec<String>,
    /// Algorithms in the grid.
    pub algorithms: Vec<Algorithm>,
    /// Replication factors in the grid; `1` (the central baseline) is
    /// always included.
    pub topologies: Vec<u64>,
    /// Tumbling-window size for the windowed recall curves (also becomes
    /// the runs' `recall_window`).
    pub window_events: u64,
    /// Directory the per-window CSVs are written under.
    pub out_dir: String,
    /// Path of the JSON summary (`BENCH_drift.json` by convention).
    pub bench_out: String,
    /// The drift shape layered over every run's stream.
    pub drift: DriftConfig,
    /// Optional mid-stream rescale applied to distributed runs.
    pub rescale: Option<MidStreamRescale>,
    /// Optional chaos kill scheduled as a stream fraction
    /// (`fault.chaos_kill_at`): resolved against each stream's actual
    /// length at run time (an explicit `name:events` dataset spec can
    /// differ from `events`), overriding `fault.chaos_kill_seq`.
    pub chaos_kill_at: Option<f64>,
    /// Shared run configuration (model/forgetting/engine/fault tables of
    /// the same file; topology and recall_window are overridden per run).
    pub base: RunConfig,
}

impl Scenario {
    /// Parse a scenario file from disk.
    pub fn from_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        let text =
            std::fs::read_to_string(path.as_ref()).with_context(|| {
                format!("reading scenario {}", path.as_ref().display())
            })?;
        Self::from_toml(&text)
    }

    /// Parse from TOML-subset text. The same document feeds three
    /// parsers: `RunConfig::from_toml` (shared run knobs),
    /// `DriftConfig` (`[drift]`), and the `[experiment]` grid keys here.
    pub fn from_toml(text: &str) -> Result<Self> {
        let kv = parse_toml_subset(text)?;
        let mut base = RunConfig::from_toml(text)?;
        let drift = DriftConfig::from_kv(&kv)?;
        let get = |k: &str| kv.get(k);
        let str_or = |k: &str, d: &str| -> Result<String> {
            Ok(match get(k) {
                Some(v) => v.str()?.to_string(),
                None => d.to_string(),
            })
        };
        let int_or = |k: &str, d: i64| -> Result<i64> {
            Ok(match get(k) {
                Some(v) => v.int()?,
                None => d,
            })
        };

        let name = str_or("experiment.name", "drift")?;
        let events = int_or("experiment.events", 20_000)?.max(1) as u64;
        let seed = int_or("experiment.seed", base.seed as i64)? as u64;
        let window_events =
            int_or("experiment.window_events", 1_000)?.max(1) as u64;
        let datasets = list(&str_or("experiment.datasets", "ml-like")?);
        let algorithms = list(&str_or("experiment.algorithms", "isgd")?)
            .iter()
            .map(|a| Algorithm::parse(a))
            .collect::<Result<Vec<_>>>()?;
        let mut topologies: Vec<u64> =
            list(&str_or("experiment.topologies", "1,2")?)
                .iter()
                .map(|t| {
                    t.parse::<u64>()
                        .map_err(|e| anyhow::anyhow!("topology '{t}': {e}"))
                })
                .collect::<Result<Vec<_>>>()?;
        if !topologies.contains(&1) {
            // The paper's comparison is always against the central run.
            topologies.insert(0, 1);
        }
        // Repeated grid entries would produce colliding labels (and
        // overwrite each other's CSVs), so drop them up front.
        dedup_in_place(&mut topologies);
        let mut datasets = datasets;
        dedup_in_place(&mut datasets);
        let out_dir =
            str_or("experiment.out_dir", &format!("results/{name}"))?;
        let bench_out = str_or("experiment.bench_out", "BENCH_drift.json")?;

        let rescale = match get("rescale.to_n_i") {
            Some(v) => {
                let to_n_i = v.int()?.max(1) as u64;
                let at = match get("rescale.at") {
                    Some(v) => v.frac().context("rescale.at")?,
                    None => 0.5,
                };
                Some(MidStreamRescale { at, to_n_i })
            }
            None => None,
        };

        // A chaos kill can be scheduled as a stream fraction; it is
        // resolved against each stream's actual length at run time, so
        // it stays aligned with the drift schedule even for explicit
        // `name:events` dataset specs.
        let chaos_kill_at = get("fault.chaos_kill_at")
            .map(|v| v.frac().context("fault.chaos_kill_at"))
            .transpose()?;
        if (base.fault_chaos_kill_seq.is_some() || chaos_kill_at.is_some())
            && base.fault_checkpoint_interval == 0
        {
            bail!(
                "scenario schedules a chaos kill but fault tolerance is \
                 off; set fault.checkpoint_interval > 0 (or drop the kill)"
            );
        }
        // The [memory] footgun is an error here (not just the warning
        // Cluster::metrics logs): a scenario is a batch grid nobody is
        // watching, so a cap whose pressure sweeps cannot evict
        // anything would silently churn every lane through the disk
        // tier for the whole grid.
        if let Some(msg) = base.memory_footgun() {
            bail!("scenario: {msg}");
        }
        // A chaos kill composes with remote workers: the kill fires
        // inside whichever slot hosts the chosen sequence number (the
        // placement cycle decides whether that is a local thread or a
        // remote host's actor), and either way the supervisor's
        // recovery path restores it — for a remote slot, by re-dialing
        // under the `[fault]` backoff budget. Deterministic
        // *connection*-level failure is `[fault.net]`'s job.
        base.seed = seed;

        let sc = Self {
            name,
            events,
            seed,
            datasets,
            algorithms,
            topologies,
            window_events,
            out_dir,
            bench_out,
            drift,
            rescale,
            chaos_kill_at,
            base,
        };
        sc.validate()?;
        Ok(sc)
    }

    fn validate(&self) -> Result<()> {
        if self.datasets.is_empty() {
            bail!("experiment.datasets must name at least one dataset");
        }
        if self.algorithms.is_empty() {
            bail!("experiment.algorithms must name at least one algorithm");
        }
        for &n_i in &self.topologies {
            if n_i == 0 {
                bail!("experiment.topologies entries must be >= 1");
            }
        }
        Ok(())
    }

    /// First stream position the configured drift changes preferences
    /// at, if a shape is configured.
    pub fn drift_seq(&self) -> Option<u64> {
        self.drift.kind.map(|k| k.drift_seq(self.events))
    }
}

/// Split a comma list (`"isgd, cosine"`) into trimmed non-empty items.
fn list(s: &str) -> Vec<String> {
    s.split(',')
        .map(|x| x.trim().to_string())
        .filter(|x| !x.is_empty())
        .collect()
}

/// Drop repeated entries, keeping first-occurrence order.
fn dedup_in_place<T: PartialEq>(v: &mut Vec<T>) {
    let mut i = 0;
    while i < v.len() {
        if v[..i].contains(&v[i]) {
            v.remove(i);
        } else {
            i += 1;
        }
    }
}

fn gcd(a: u64, b: u64) -> u64 {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

fn lcm(a: u64, b: u64) -> u64 {
    a / gcd(a, b) * b
}

/// One completed grid cell.
#[derive(Debug, Clone)]
pub struct ScenarioRun {
    /// Run label (`{algo}-{dataset}-ni{n}-{drift}`).
    pub label: String,
    /// Dataset id of the cell.
    pub dataset: String,
    /// Algorithm of the cell.
    pub algorithm: Algorithm,
    /// Replication factor of the cell (1 = central baseline).
    pub n_i: u64,
    /// The condensed windowed-recall drift response, when the scenario
    /// has a drift point with at least one window on each side.
    pub response: Option<DriftResponse>,
    /// The full run report (cumulative + windowed curves, counters).
    pub report: RunReport,
}

/// All grid cells of one scenario execution plus where the artifacts
/// were written.
#[derive(Debug, Clone)]
pub struct ScenarioOutcome {
    /// Completed runs, grid order (datasets × algorithms × topologies).
    pub runs: Vec<ScenarioRun>,
    /// Path the JSON summary was written to.
    pub bench_path: PathBuf,
    /// Directory the per-window CSVs were written under.
    pub out_dir: PathBuf,
}

/// Execute every grid cell of `sc`: stream (with drift) → session →
/// windowed curves → CSV + JSON artifacts. See the module docs.
pub fn run_scenario(sc: &Scenario) -> Result<ScenarioOutcome> {
    let drift_name =
        sc.drift.kind.map(|k| k.name()).unwrap_or("none");
    let mut datasets: HashMap<String, (String, Vec<Rating>)> = HashMap::new();
    let mut runs = Vec::new();

    for ds in &sc.datasets {
        // Bare names get the scenario's event budget; explicit specs win.
        let spec_str = if ds.contains(':') {
            ds.clone()
        } else {
            format!("{ds}:{}", sc.events)
        };
        if !datasets.contains_key(&spec_str) {
            let spec = DatasetSpec::parse(&spec_str, sc.seed)?;
            let events = spec.load_with_drift(&sc.drift)?;
            datasets.insert(spec_str.clone(), (spec.name(), events));
        }
        let (ds_name, events) = datasets.get(&spec_str).unwrap().clone();
        let total = events.len() as u64;
        // Every stream-fraction schedule (drift response anchor, chaos
        // kill) resolves against the *stream's* length — an explicit
        // `name:events` spec can differ from the scenario-wide budget.
        let drift_seq = sc.drift.kind.map(|k| k.drift_seq(total));
        // Labels must be collision-free: an explicit-events spec keeps
        // its event count in the tag (`ml-like-6000`), a bare name (the
        // common case) stays pretty.
        let ds_tag = if ds.contains(':') {
            spec_str.replace(&[':', '/', '\\', '.'][..], "-")
        } else {
            ds_name.clone()
        };

        for &algo in &sc.algorithms {
            for &n_i in &sc.topologies {
                let label = format!(
                    "{}-{}-ni{}-{}",
                    algo.name(),
                    ds_tag,
                    n_i,
                    drift_name
                );
                let mut cfg = sc.base.clone();
                cfg.algorithm = algo;
                cfg.topology = Topology::new(n_i, 0)?;
                cfg.recall_window = sc.window_events as usize;
                if let Some(at) = sc.chaos_kill_at {
                    cfg.fault_chaos_kill_seq =
                        Some(frac_seq(at, total).min(total.saturating_sub(1)));
                }
                let rescale = sc.rescale.filter(|_| n_i > 1);
                if let Some(r) = rescale {
                    if cfg.rescale_max_n_i == 0 {
                        cfg.rescale_max_n_i = lcm(n_i, r.to_n_i);
                    }
                }

                log::info!(
                    "scenario '{}': running {label} ({} events)",
                    sc.name,
                    events.len()
                );
                let mut cluster = Cluster::spawn_labeled(&cfg, &label)?;
                match rescale {
                    Some(r) => {
                        let cut = frac_seq(r.at, total) as usize;
                        cluster.ingest_batch(&events[..cut])?;
                        cluster.rescale(Topology::new(r.to_n_i, 0)?)?;
                        cluster.ingest_batch(&events[cut..])?;
                    }
                    None => cluster.ingest_batch(&events)?,
                }
                let report = cluster.finish()?;

                let response = drift_seq
                    .and_then(|at| drift_response(&report.windowed_recall, at));
                write_window_csv(&sc.out_dir, &label, &report)?;
                runs.push(ScenarioRun {
                    label,
                    dataset: ds_name.clone(),
                    algorithm: algo,
                    n_i,
                    response,
                    report,
                });
            }
        }
    }

    let bench_path = write_bench_json(sc, drift_name, &runs)?;
    Ok(ScenarioOutcome {
        runs,
        bench_path,
        out_dir: PathBuf::from(&sc.out_dir),
    })
}

/// Per-run tumbling-window curve: `<out_dir>/<label>_windows.csv`.
fn write_window_csv(
    out_dir: &str,
    label: &str,
    report: &RunReport,
) -> Result<()> {
    let path = Path::new(out_dir).join(format!("{label}_windows.csv"));
    let mut w = CsvWriter::create(
        &path,
        &["window", "start_seq", "events", "hits", "recall"],
    )?;
    for stat in &report.windowed_recall {
        w.row(&[
            stat.index.to_string(),
            stat.start_seq.to_string(),
            stat.events.to_string(),
            stat.hits.to_string(),
            format!("{:.6}", stat.recall()),
        ])?;
    }
    w.flush()?;
    Ok(())
}

/// The scenario summary JSON (one row per grid cell), written to
/// `sc.bench_out` — schema documented in docs/EXPERIMENTS.md.
fn write_bench_json(
    sc: &Scenario,
    drift_name: &str,
    runs: &[ScenarioRun],
) -> Result<PathBuf> {
    let rows: Vec<Json> = runs
        .iter()
        .map(|r| {
            let mut pairs = vec![
                ("label", s(&r.label)),
                ("dataset", s(&r.dataset)),
                ("algorithm", s(r.algorithm.name())),
                ("n_i", num(r.n_i as f64)),
                ("events", num(r.report.events as f64)),
                ("hits", num(r.report.hits as f64)),
                ("avg_recall", num(r.report.avg_recall)),
                ("throughput_ev_s", num(r.report.throughput)),
                ("rescales", num(r.report.rescales as f64)),
                ("recoveries", num(r.report.recoveries as f64)),
                ("replayed_events", num(r.report.replayed_events as f64)),
                ("state_bytes", num(r.report.state_bytes as f64)),
                ("spills", num(r.report.spills as f64)),
                ("spill_faultins", num(r.report.spill_faultins as f64)),
            ];
            if let Some(resp) = r.response {
                pairs.push(("pre_drift_recall", num(resp.pre)));
                pairs.push(("dip_recall", num(resp.dip)));
                pairs.push(("recovered_recall", num(resp.recovered)));
                pairs.push(("drift_window", num(resp.drift_window as f64)));
            }
            obj(pairs)
        })
        .collect();
    let doc = obj(vec![
        ("bench", s("drift scenario grid")),
        ("scenario", s(&sc.name)),
        ("drift", s(drift_name)),
        ("events", num(sc.events as f64)),
        ("seed", num(sc.seed as f64)),
        ("window_events", num(sc.window_events as f64)),
        ("memory_budget_bytes", num(sc.base.memory_budget_bytes as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    let path = PathBuf::from(&sc.bench_out);
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(&path, to_string(&doc) + "\n")?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Forgetting;
    use crate::data::drift::DriftKind;

    #[test]
    fn parses_a_full_scenario() {
        let text = r#"
            [experiment]
            name = "abrupt-smoke"
            events = 4000
            seed = 9
            datasets = "nf-like, ml-like"
            algorithms = "isgd,cosine"
            topologies = "2,4"
            window_events = 250
            out_dir = "results/x"
            bench_out = "results/x/BENCH_drift.json"

            [drift]
            kind = "abrupt"
            at = 0.5

            [rescale]
            at = 0.75
            to_n_i = 4

            [forgetting]
            kind = "lfu"
            trigger_events = 500
            min_freq = 2
        "#;
        let sc = Scenario::from_toml(text).unwrap();
        assert_eq!(sc.name, "abrupt-smoke");
        assert_eq!(sc.events, 4000);
        assert_eq!(sc.seed, 9);
        assert_eq!(sc.datasets, vec!["nf-like", "ml-like"]);
        assert_eq!(sc.algorithms, vec![Algorithm::Isgd, Algorithm::Cosine]);
        // The central baseline is always prepended.
        assert_eq!(sc.topologies, vec![1, 2, 4]);
        assert_eq!(sc.window_events, 250);
        assert_eq!(sc.drift.kind, Some(DriftKind::Abrupt { at: 0.5 }));
        assert_eq!(
            sc.rescale,
            Some(MidStreamRescale { at: 0.75, to_n_i: 4 })
        );
        assert!(matches!(sc.base.forgetting, Forgetting::Lfu { .. }));
        assert_eq!(sc.drift_seq(), Some(2000));
    }

    #[test]
    fn defaults_are_sane() {
        let sc = Scenario::from_toml("").unwrap();
        assert_eq!(sc.topologies, vec![1, 2]);
        assert_eq!(sc.algorithms, vec![Algorithm::Isgd]);
        assert!(sc.drift.kind.is_none());
        assert!(sc.rescale.is_none());
        assert_eq!(sc.bench_out, "BENCH_drift.json");
        assert!(sc.drift_seq().is_none());
    }

    #[test]
    fn chaos_kill_fraction_parses_and_needs_ft() {
        let ok = Scenario::from_toml(
            "[experiment]\nevents = 1000\n\
             [fault]\ncheckpoint_interval = 32\nchaos_kill_at = 0.5",
        )
        .unwrap();
        // Resolved per stream at run time, not at parse time (explicit
        // `name:events` specs can differ from `experiment.events`).
        assert_eq!(ok.chaos_kill_at, Some(0.5));
        assert_eq!(ok.base.fault_chaos_kill_seq, None);
        let err = Scenario::from_toml(
            "[experiment]\nevents = 1000\n[fault]\nchaos_kill_at = 0.5",
        );
        assert!(err.is_err(), "chaos without FT must be rejected");
        assert!(Scenario::from_toml(
            "[experiment]\nevents = 1000\n\
             [fault]\ncheckpoint_interval = 32\nchaos_kill_at = 1.5",
        )
        .is_err());
    }

    #[test]
    fn chaos_kill_allows_remote_workers() {
        // Since the transport grew dial backoff + reconnection, a chaos
        // kill composes with remote placement: the killed slot is
        // recovered by re-dialing. The scenario parser must accept the
        // combination (it used to reject it).
        let ok = Scenario::from_toml(
            "[experiment]\nevents = 1000\n\
             [fault]\ncheckpoint_interval = 32\nchaos_kill_at = 0.5\n\
             [cluster]\nworkers = [\"local\", \"tcp://127.0.0.1:7461\"]",
        )
        .unwrap();
        assert_eq!(ok.base.cluster_workers.len(), 2);
        assert_eq!(ok.chaos_kill_at, Some(0.5));
        // FT is still required for any chaos kill, remote or not.
        assert!(Scenario::from_toml(
            "[experiment]\nevents = 1000\n[fault]\nchaos_kill_at = 0.5\n\
             [cluster]\nworkers = [\"tcp://127.0.0.1:7461\"]",
        )
        .is_err());
    }

    #[test]
    fn memory_cap_without_policy_is_rejected_loudly() {
        // The footgun satellite: a [memory] budget whose pressure
        // sweeps cannot evict anything (no [forgetting] policy) is an
        // error for the batch driver, with a message naming the fix.
        let err = Scenario::from_toml("[memory]\nbudget_bytes = 4096")
            .expect_err("cap without a forgetting policy must be rejected");
        let msg = format!("{err:#}");
        assert!(msg.contains("[forgetting]"), "message names the cause");
        assert!(msg.contains("lru/lfu/decay"), "message names the fix");
        // Any eviction policy makes the same cap acceptable.
        let ok = Scenario::from_toml(
            "[memory]\nbudget_bytes = 4096\n\
             [forgetting]\nkind = \"lru\"",
        )
        .unwrap();
        assert_eq!(ok.base.memory_budget_bytes, 4096);
    }

    #[test]
    fn grid_lists_are_deduplicated() {
        let sc = Scenario::from_toml(
            "[experiment]\ndatasets = \"ml-like, ml-like\"\n\
             topologies = \"2,1,2\"",
        )
        .unwrap();
        assert_eq!(sc.datasets, vec!["ml-like"]);
        assert_eq!(sc.topologies, vec![2, 1]);
    }

    #[test]
    fn rejects_bad_grids() {
        assert!(Scenario::from_toml(
            "[experiment]\nalgorithms = \"bogus\""
        )
        .is_err());
        assert!(Scenario::from_toml(
            "[experiment]\ntopologies = \"0\""
        )
        .is_err());
        assert!(Scenario::from_toml(
            "[experiment]\ntopologies = \"x\""
        )
        .is_err());
        assert!(Scenario::from_toml(
            "[rescale]\nat = 1.5\nto_n_i = 2"
        )
        .is_err());
    }

    #[test]
    fn lcm_grid_ceiling() {
        assert_eq!(lcm(2, 4), 4);
        assert_eq!(lcm(3, 2), 6);
        assert_eq!(lcm(1, 5), 5);
    }
}
