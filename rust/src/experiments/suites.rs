//! One function per paper artifact (Table 1, Figures 3-14). Each runs the
//! needed configurations via the shared run cache, writes `results/<id>/`
//! CSVs, and prints the paper-style comparison summary.

use anyhow::Result;

use crate::config::Algorithm;
use crate::data::stats::DatasetStats;
use crate::experiments::runner::{
    write_recall_curves, write_state_distribution, write_throughput,
    ExpContext, Policy, RunKey, RECALL_HEADER, STATE_HEADER,
    THROUGHPUT_HEADER,
};

const DATASETS: [&str; 2] = ["ml-like", "nf-like"];

/// Table 1: dataset characteristics after filtering.
pub fn table1(ctx: &mut ExpContext) -> Result<()> {
    println!("== Table 1: dataset characteristics ==");
    println!(
        "| {:13} | {:8} | {:7} | {:6} | {:6} | {:7} | {:7} |",
        "Dataset", "Ratings", "Users", "Items", "r/user", "r/item", "Sparsity"
    );
    let mut w = ctx.csv(
        "table1",
        "table1.csv",
        &[
            "dataset", "ratings", "users", "items", "avg_ratings_per_user",
            "avg_ratings_per_item", "sparsity_pct",
        ],
    )?;
    for name in DATASETS {
        let events = ctx.dataset(name)?;
        let stats = DatasetStats::compute(name, events);
        println!("{}", stats.table_row());
        w.row(&[
            stats.name.clone(),
            stats.ratings.to_string(),
            stats.users.to_string(),
            stats.items.to_string(),
            format!("{:.2}", stats.avg_ratings_per_user),
            format!("{:.2}", stats.avg_ratings_per_item),
            format!("{:.4}", stats.sparsity_pct),
        ])?;
    }
    w.flush()?;
    Ok(())
}

/// Shared DISGD suite: Figs 3 (recall), 4 (memory), 8 (throughput).
fn disgd_base(ctx: &mut ExpContext) -> Result<Vec<(RunKey, crate::eval::RunReport)>> {
    let mut runs = Vec::new();
    for ds in DATASETS {
        runs.extend(ctx.sweep(Algorithm::Isgd, ds, &[Policy::None])?);
    }
    Ok(runs)
}

fn disgd_forgetting(
    ctx: &mut ExpContext,
) -> Result<Vec<(RunKey, crate::eval::RunReport)>> {
    let mut runs = Vec::new();
    for ds in DATASETS {
        runs.extend(
            ctx.sweep(Algorithm::Isgd, ds, &[Policy::Lru, Policy::Lfu])?,
        );
    }
    Ok(runs)
}

/// Fig 3: moving-average Recall@10, ISGD (central) vs DISGD, n_i∈{2,4,6}.
pub fn fig3(ctx: &mut ExpContext) -> Result<()> {
    let runs = disgd_base(ctx)?;
    let mut w = ctx.csv("fig3", "recall_curves.csv", &RECALL_HEADER)?;
    write_recall_curves(&mut w, &runs)?;
    println!("== Fig 3: DISGD recall vs central (avg over stream) ==");
    summarize_recall(&runs);
    Ok(())
}

/// Fig 4: memory (state entries) distributions for DISGD.
pub fn fig4(ctx: &mut ExpContext) -> Result<()> {
    let runs = disgd_base(ctx)?;
    let mut w = ctx.csv("fig4", "state_distribution.csv", &STATE_HEADER)?;
    write_state_distribution(&mut w, &runs)?;
    println!("== Fig 4: DISGD per-worker state sizes (mean across workers) ==");
    summarize_state(&runs);
    Ok(())
}

/// Fig 5: effect of LRU/LFU forgetting on DISGD recall.
pub fn fig5(ctx: &mut ExpContext) -> Result<()> {
    let mut runs = disgd_base(ctx)?;
    runs.extend(disgd_forgetting(ctx)?);
    let mut w = ctx.csv("fig5", "recall_curves.csv", &RECALL_HEADER)?;
    write_recall_curves(&mut w, &runs)?;
    println!("== Fig 5: DISGD forgetting effect on recall ==");
    summarize_recall(&runs);
    Ok(())
}

/// Fig 6: LFU vs LRU one-to-one recall comparison (DISGD).
pub fn fig6(ctx: &mut ExpContext) -> Result<()> {
    let runs = disgd_forgetting(ctx)?;
    let mut w = ctx.csv("fig6", "recall_curves.csv", &RECALL_HEADER)?;
    write_recall_curves(&mut w, &runs)?;
    println!("== Fig 6: DISGD LRU vs LFU per n_i ==");
    summarize_recall(&runs);
    Ok(())
}

/// Fig 7: forgetting effect on memory (DISGD, ml-like).
pub fn fig7(ctx: &mut ExpContext) -> Result<()> {
    let mut runs: Vec<_> = disgd_base(ctx)?
        .into_iter()
        .filter(|(k, _)| k.dataset == "ml-like")
        .collect();
    runs.extend(
        disgd_forgetting(ctx)?
            .into_iter()
            .filter(|(k, _)| k.dataset == "ml-like"),
    );
    let mut w = ctx.csv("fig7", "state_distribution.csv", &STATE_HEADER)?;
    write_state_distribution(&mut w, &runs)?;
    println!("== Fig 7: DISGD forgetting effect on state (ml-like) ==");
    summarize_state(&runs);
    Ok(())
}

/// Fig 8: throughput, DISGD vs central with and without forgetting.
pub fn fig8(ctx: &mut ExpContext) -> Result<()> {
    let mut runs = disgd_base(ctx)?;
    runs.extend(disgd_forgetting(ctx)?);
    let mut w = ctx.csv("fig8", "throughput.csv", &THROUGHPUT_HEADER)?;
    write_throughput(&mut w, &runs)?;
    println!("== Fig 8: DISGD throughput vs central ==");
    summarize_throughput(&runs);
    Ok(())
}

/// Shared DICS suites (Figs 9-14).
fn dics_base(ctx: &mut ExpContext) -> Result<Vec<(RunKey, crate::eval::RunReport)>> {
    let mut runs = Vec::new();
    for ds in DATASETS {
        runs.extend(ctx.sweep(Algorithm::Cosine, ds, &[Policy::None])?);
    }
    Ok(runs)
}

fn dics_forgetting(
    ctx: &mut ExpContext,
) -> Result<Vec<(RunKey, crate::eval::RunReport)>> {
    let mut runs = Vec::new();
    for ds in DATASETS {
        runs.extend(
            ctx.sweep(Algorithm::Cosine, ds, &[Policy::Lru, Policy::Lfu])?,
        );
    }
    Ok(runs)
}

/// Fig 9: recall, cosine central vs DICS.
pub fn fig9(ctx: &mut ExpContext) -> Result<()> {
    let runs = dics_base(ctx)?;
    let mut w = ctx.csv("fig9", "recall_curves.csv", &RECALL_HEADER)?;
    write_recall_curves(&mut w, &runs)?;
    println!("== Fig 9: DICS recall vs central ==");
    summarize_recall(&runs);
    Ok(())
}

/// Fig 10: memory distributions for DICS.
pub fn fig10(ctx: &mut ExpContext) -> Result<()> {
    let runs = dics_base(ctx)?;
    let mut w = ctx.csv("fig10", "state_distribution.csv", &STATE_HEADER)?;
    write_state_distribution(&mut w, &runs)?;
    println!("== Fig 10: DICS per-worker state sizes ==");
    summarize_state(&runs);
    Ok(())
}

/// Fig 11: forgetting effect on DICS recall.
pub fn fig11(ctx: &mut ExpContext) -> Result<()> {
    let mut runs = dics_base(ctx)?;
    runs.extend(dics_forgetting(ctx)?);
    let mut w = ctx.csv("fig11", "recall_curves.csv", &RECALL_HEADER)?;
    write_recall_curves(&mut w, &runs)?;
    println!("== Fig 11: DICS forgetting effect on recall ==");
    summarize_recall(&runs);
    Ok(())
}

/// Fig 12: LFU vs LRU one-to-one (DICS).
pub fn fig12(ctx: &mut ExpContext) -> Result<()> {
    let runs = dics_forgetting(ctx)?;
    let mut w = ctx.csv("fig12", "recall_curves.csv", &RECALL_HEADER)?;
    write_recall_curves(&mut w, &runs)?;
    println!("== Fig 12: DICS LRU vs LFU per n_i ==");
    summarize_recall(&runs);
    Ok(())
}

/// Fig 13: forgetting effect on memory (DICS, nf-like).
pub fn fig13(ctx: &mut ExpContext) -> Result<()> {
    let mut runs: Vec<_> = dics_base(ctx)?
        .into_iter()
        .filter(|(k, _)| k.dataset == "nf-like")
        .collect();
    runs.extend(
        dics_forgetting(ctx)?
            .into_iter()
            .filter(|(k, _)| k.dataset == "nf-like"),
    );
    let mut w = ctx.csv("fig13", "state_distribution.csv", &STATE_HEADER)?;
    write_state_distribution(&mut w, &runs)?;
    println!("== Fig 13: DICS forgetting effect on state (nf-like) ==");
    summarize_state(&runs);
    Ok(())
}

/// Fig 14: throughput, DICS vs central.
pub fn fig14(ctx: &mut ExpContext) -> Result<()> {
    let mut runs = dics_base(ctx)?;
    runs.extend(dics_forgetting(ctx)?);
    let mut w = ctx.csv("fig14", "throughput.csv", &THROUGHPUT_HEADER)?;
    write_throughput(&mut w, &runs)?;
    println!("== Fig 14: DICS throughput vs central ==");
    summarize_throughput(&runs);
    Ok(())
}

/// Extension experiment (paper Section 6 future work): gradual
/// forgetting (decay) head-to-head with LRU/LFU on both algorithms.
pub fn ext_forgetting(ctx: &mut ExpContext) -> Result<()> {
    let mut runs = Vec::new();
    for algo in [Algorithm::Isgd, Algorithm::Cosine] {
        for ds in DATASETS {
            for policy in [Policy::None, Policy::Lru, Policy::Lfu, Policy::Decay] {
                let key = RunKey {
                    algo,
                    dataset: ds.to_string(),
                    n_i: 2,
                    policy,
                };
                let report = ctx.run(key.clone())?;
                runs.push((key, report));
            }
        }
    }
    let mut w = ctx.csv("ext_forgetting", "throughput.csv", &THROUGHPUT_HEADER)?;
    write_throughput(&mut w, &runs)?;
    let mut w = ctx.csv("ext_forgetting", "state.csv", &STATE_HEADER)?;
    write_state_distribution(&mut w, &runs)?;
    println!("== EXT: gradual forgetting (decay) vs LRU/LFU at n_i=2 ==");
    summarize_recall(&runs);
    summarize_state(&runs);
    Ok(())
}

/// Run every experiment (the `--exp all` path).
pub fn all(ctx: &mut ExpContext) -> Result<()> {
    table1(ctx)?;
    fig3(ctx)?;
    fig4(ctx)?;
    fig5(ctx)?;
    fig6(ctx)?;
    fig7(ctx)?;
    fig8(ctx)?;
    fig9(ctx)?;
    fig10(ctx)?;
    fig11(ctx)?;
    fig12(ctx)?;
    fig13(ctx)?;
    fig14(ctx)?;
    Ok(())
}

/// Dispatch by experiment id.
pub fn run_experiment(ctx: &mut ExpContext, id: &str) -> Result<()> {
    match id {
        "all" => all(ctx),
        "table1" => table1(ctx),
        "fig3" => fig3(ctx),
        "fig4" => fig4(ctx),
        "fig5" => fig5(ctx),
        "fig6" => fig6(ctx),
        "fig7" => fig7(ctx),
        "fig8" => fig8(ctx),
        "fig9" => fig9(ctx),
        "fig10" => fig10(ctx),
        "fig11" => fig11(ctx),
        "fig12" => fig12(ctx),
        "fig13" => fig13(ctx),
        "fig14" => fig14(ctx),
        "ext-forgetting" => ext_forgetting(ctx),
        other => anyhow::bail!(
            "unknown experiment '{other}' (table1|fig3..fig14|ext-forgetting|all)"
        ),
    }
}

fn summarize_recall(runs: &[(RunKey, crate::eval::RunReport)]) {
    for (key, r) in runs {
        println!(
            "  {:40} avg_recall={:.4} (events={})",
            key.label(),
            r.avg_recall,
            r.events
        );
    }
}

fn summarize_state(runs: &[(RunKey, crate::eval::RunReport)]) {
    for (key, r) in runs {
        println!(
            "  {:40} users(mean)={:>10.1} items(mean)={:>9.1} aux(mean)={:>10.1}",
            key.label(),
            r.mean_user_state(),
            r.mean_item_state(),
            r.mean_aux_state()
        );
    }
}

fn summarize_throughput(runs: &[(RunKey, crate::eval::RunReport)]) {
    // Speedup vs the central run of the same (algo, dataset).
    for (key, r) in runs {
        let central = runs.iter().find(|(k, _)| {
            k.algo == key.algo && k.dataset == key.dataset && k.n_i == 1
                && k.policy == Policy::None
        });
        let speedup = central
            .map(|(_, c)| r.throughput / c.throughput.max(1e-9))
            .unwrap_or(f64::NAN);
        println!(
            "  {:40} {:>12.0} ev/s  speedup_vs_central={:>8.1}x",
            key.label(),
            r.throughput,
            speedup
        );
    }
}
