//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation section (DESIGN.md §4 maps each experiment id to the paper
//! artifact; `runner`/`suites`, driven by the `figures` binary), plus
//! the declarative drift-scenario driver behind `streamrec experiment`
//! (`scenario`). Results land in `results/<exp>/*.csv` and `BENCH_*`
//! JSON summaries; docs/EXPERIMENTS.md documents every schema.

pub mod runner;
pub mod scenario;
pub mod suites;

pub use runner::{ExpContext, RunKey};
pub use scenario::{run_scenario, Scenario, ScenarioOutcome, ScenarioRun};
