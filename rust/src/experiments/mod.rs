//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation section (DESIGN.md §4 maps each experiment id to the paper
//! artifact). Results land in `results/<exp>/*.csv` plus a printed
//! paper-style summary; EXPERIMENTS.md records paper-vs-measured.

pub mod runner;
pub mod suites;

pub use runner::{ExpContext, RunKey};
