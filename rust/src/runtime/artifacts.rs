//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. `manifest.json` enumerates every AOT-lowered HLO-text
//! artifact with its static shapes; the runtime picks the smallest bucket
//! that fits the live state.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// One lowered artifact variant.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// Artifact id, e.g. `topn_b1_m1024`.
    pub name: String,
    /// Absolute path of the HLO-text file.
    pub file: PathBuf,
    /// "topn" | "isgd" | "recupd".
    pub kind: String,
    /// User-batch rows.
    pub b: usize,
    /// Item-capacity bucket (0 for isgd variants).
    pub m: usize,
    /// Latent dimension.
    pub k: usize,
    /// Over-fetched top-N length (0 for isgd variants).
    pub n: usize,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the manifest (and artifacts) live in.
    pub dir: PathBuf,
    /// Latent dimension the artifacts were compiled for.
    pub latent_k: usize,
    /// Over-fetched top-N length compiled into the scoring artifacts.
    pub topn_overfetch: usize,
    /// Item-capacity buckets compiled (ascending).
    pub m_buckets: Vec<usize>,
    /// User-batch sizes compiled.
    pub b_sizes: Vec<usize>,
    /// Every lowered variant.
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        let v = Json::parse(&text).context("parsing manifest.json")?;
        let get_usize = |j: &Json, k: &str| -> Result<usize> {
            j.get(k)
                .and_then(|x| x.as_usize())
                .ok_or_else(|| anyhow!("manifest missing numeric '{k}'"))
        };
        let arts = v
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
        let mut artifacts = Vec::new();
        for a in arts {
            artifacts.push(ArtifactMeta {
                name: a
                    .get("name")
                    .and_then(|x| x.as_str())
                    .ok_or_else(|| anyhow!("artifact missing name"))?
                    .to_string(),
                file: dir.join(
                    a.get("file")
                        .and_then(|x| x.as_str())
                        .ok_or_else(|| anyhow!("artifact missing file"))?,
                ),
                kind: a
                    .get("kind")
                    .and_then(|x| x.as_str())
                    .unwrap_or("unknown")
                    .to_string(),
                b: get_usize(a, "b")?,
                m: a.get("m").and_then(|x| x.as_usize()).unwrap_or(0),
                k: get_usize(a, "k")?,
                n: a.get("n").and_then(|x| x.as_usize()).unwrap_or(0),
            });
        }
        let buckets = v
            .get("m_buckets")
            .and_then(|x| x.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
            .unwrap_or_default();
        let b_sizes = v
            .get("b_sizes")
            .and_then(|x| x.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
            .unwrap_or_else(|| vec![1]);
        Ok(Self {
            latent_k: get_usize(&v, "latent_k")?,
            topn_overfetch: get_usize(&v, "topn_overfetch")?,
            m_buckets: buckets,
            b_sizes,
            artifacts,
            dir,
        })
    }

    /// Find a specific variant.
    pub fn find(&self, kind: &str, b: usize, m: usize) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.kind == kind && a.b == b && a.m == m)
    }

    /// Smallest bucket that can hold `rows` live items (None if the state
    /// has outgrown every compiled bucket — callers fall back to native).
    pub fn bucket_for(&self, rows: usize) -> Option<usize> {
        self.m_buckets.iter().copied().find(|&b| rows <= b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"latent_k": 10, "topn_overfetch": 50,
                "m_buckets": [1024, 4096], "b_sizes": [1, 32],
                "artifacts": [
                  {"name": "isgd_b1", "file": "isgd_b1.hlo.txt",
                   "kind": "isgd", "b": 1, "k": 10},
                  {"name": "topn_b1_m1024", "file": "topn_b1_m1024.hlo.txt",
                   "kind": "topn", "b": 1, "m": 1024, "k": 10, "n": 50}
                ]}"#,
        )
        .unwrap();
    }

    #[test]
    fn loads_and_finds() {
        let dir = std::env::temp_dir().join("streamrec_manifest_test");
        write_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.latent_k, 10);
        assert_eq!(m.topn_overfetch, 50);
        let a = m.find("topn", 1, 1024).unwrap();
        assert_eq!(a.n, 50);
        assert!(a.file.ends_with("topn_b1_m1024.hlo.txt"));
        assert!(m.find("topn", 1, 4096).is_none());
        assert!(m.find("isgd", 1, 0).is_some());
    }

    #[test]
    fn bucket_selection() {
        let dir = std::env::temp_dir().join("streamrec_manifest_test2");
        write_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.bucket_for(0), Some(1024));
        assert_eq!(m.bucket_for(1024), Some(1024));
        assert_eq!(m.bucket_for(1025), Some(4096));
        assert_eq!(m.bucket_for(5000), None);
    }

    #[test]
    fn missing_dir_is_actionable_error() {
        let err = Manifest::load("/nonexistent/streamrec").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
