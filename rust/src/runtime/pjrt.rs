//! PJRT runtime: loads the AOT-compiled HLO-text artifacts and executes
//! them on the XLA CPU client — the Rust end of the three-layer bridge
//! (Python lowers once at build time; this module is the only thing that
//! touches the compiled model on the request path).
//!
//! Thread-model: the xla crate's handles are `Rc`-based (`!Send`), so one
//! [`PjrtEngine`] is constructed per worker thread. Executables are
//! compiled lazily per (kind, bucket) and memoized. The worker-local item
//! matrix is kept device-resident and re-uploaded only when the slab's
//! version counter moves (see EXPERIMENTS.md §Perf for the effect).

use std::collections::HashMap;

use anyhow::{anyhow, Context, Result};

use crate::runtime::artifacts::Manifest;
use crate::runtime::backend::{NativeBackend, Scored, ScoringBackend};
use crate::state::VectorSlab;

/// Lazily-compiled executables + device caches for one worker thread.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    manifest: Manifest,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Device-resident copy of the item slab: (version, capacity, buffers).
    items_cache: Option<ItemsCache>,
    /// Executions run (counter for EXPERIMENTS.md §Perf).
    pub exec_calls: u64,
    /// Slab uploads to device (counter for EXPERIMENTS.md §Perf).
    pub uploads: u64,
    /// Artifacts compiled (counter for EXPERIMENTS.md §Perf).
    pub compile_count: u64,
}

struct ItemsCache {
    version: u64,
    capacity: usize,
    items: xla::PjRtBuffer,
    valid: xla::PjRtBuffer,
}

impl PjrtEngine {
    /// Create the CPU client and load the manifest from `artifacts_dir`.
    pub fn new(artifacts_dir: &str) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))?;
        log::info!(
            "pjrt engine up: platform={} artifacts={}",
            client.platform_name(),
            manifest.artifacts.len()
        );
        Ok(Self {
            client,
            manifest,
            exes: HashMap::new(),
            items_cache: None,
            exec_calls: 0,
            uploads: 0,
            compile_count: 0,
        })
    }

    /// The loaded artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (memoized) the artifact named `name`.
    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.exes.contains_key(name) {
            let meta = self
                .manifest
                .artifacts
                .iter()
                .find(|a| a.name == name)
                .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
            let path = meta
                .file
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 artifact path"))?
                .to_string();
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing HLO text {path}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            self.compile_count += 1;
            log::debug!("compiled artifact {name}");
            self.exes.insert(name.to_string(), exe);
        }
        Ok(self.exes.get(name).unwrap())
    }

    fn f32_literal(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
        xla::Literal::vec1(data)
            .reshape(dims)
            .map_err(|e| anyhow!("reshape {dims:?}: {e:?}"))
    }

    /// Refresh the device-resident item matrix if the slab moved.
    fn ensure_items_uploaded(&mut self, slab: &VectorSlab) -> Result<()> {
        let fresh = match &self.items_cache {
            Some(c) => {
                c.version == slab.version() && c.capacity == slab.capacity()
            }
            None => false,
        };
        if fresh {
            return Ok(());
        }
        let cap = slab.capacity();
        let k = slab.k();
        let devices = self.client.devices();
        let device = &devices[0];
        let items = self
            .client
            .buffer_from_host_buffer(slab.data(), &[cap, k], Some(device))
            .map_err(|e| anyhow!("uploading items: {e:?}"))?;
        let valid = self
            .client
            .buffer_from_host_buffer(slab.valid(), &[cap], Some(device))
            .map_err(|e| anyhow!("uploading valid mask: {e:?}"))?;
        self.items_cache = Some(ItemsCache {
            version: slab.version(),
            capacity: slab.capacity(),
            items,
            valid,
        });
        self.uploads += 1;
        Ok(())
    }

    /// Execute the `topn_b1_m{bucket}` artifact against the slab.
    /// Returns up to `overfetch` (row, score) pairs, descending.
    pub fn topn(
        &mut self,
        u: &[f32],
        slab: &VectorSlab,
    ) -> Result<Vec<Scored>> {
        let cap = slab.capacity();
        if self.manifest.find("topn", 1, cap).is_none() {
            anyhow::bail!("no topn artifact for bucket {cap}");
        }
        self.ensure_items_uploaded(slab)?;
        let name = format!("topn_b1_m{cap}");
        let k = slab.k();
        // Upload the user vector, then run fully on device buffers.
        let devices = self.client.devices();
        let device = &devices[0];
        let u_buf = self
            .client
            .buffer_from_host_buffer(u, &[1, k], Some(device))
            .map_err(|e| anyhow!("uploading user vec: {e:?}"))?;
        self.executable(&name)?; // ensure compiled (drops &mut borrow)
        let exe = self.exes.get(&name).unwrap();
        let cache = self.items_cache.as_ref().unwrap();
        let outs = exe
            .execute_b(&[&u_buf, &cache.items, &cache.valid])
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        self.exec_calls += 1;
        let lit = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result: {e:?}"))?;
        let parts = lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let values: Vec<f32> =
            parts[0].to_vec().map_err(|e| anyhow!("values: {e:?}"))?;
        let indices: Vec<i32> =
            parts[1].to_vec().map_err(|e| anyhow!("indices: {e:?}"))?;
        Ok(values
            .into_iter()
            .zip(indices)
            .filter(|(v, _)| *v > -1e8) // drop padding-masked entries
            .map(|(score, row)| Scored { row: row as usize, score })
            .collect())
    }

    /// Execute the fused `isgd_b1` artifact; mutates `u`/`i` in place and
    /// returns the prediction error.
    pub fn isgd_step(
        &mut self,
        u: &mut [f32],
        i: &mut [f32],
        eta: f32,
        lam: f32,
    ) -> Result<f32> {
        let k = u.len() as i64;
        let u_lit = Self::f32_literal(u, &[1, k])?;
        let i_lit = Self::f32_literal(i, &[1, k])?;
        let hp = Self::f32_literal(&[eta, lam], &[1, 2])?;
        let exe = self.executable("isgd_b1")?;
        let outs = exe
            .execute(&[u_lit, i_lit, hp])
            .map_err(|e| anyhow!("executing isgd_b1: {e:?}"))?;
        self.exec_calls += 1;
        let lit = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result: {e:?}"))?;
        let parts = lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let u_new: Vec<f32> =
            parts[0].to_vec().map_err(|e| anyhow!("u_new: {e:?}"))?;
        let i_new: Vec<f32> =
            parts[1].to_vec().map_err(|e| anyhow!("i_new: {e:?}"))?;
        let err: Vec<f32> =
            parts[2].to_vec().map_err(|e| anyhow!("err: {e:?}"))?;
        u.copy_from_slice(&u_new);
        i.copy_from_slice(&i_new);
        Ok(err[0])
    }
}

/// [`ScoringBackend`] over the PJRT engine, with automatic native fallback
/// when the item state outgrows the largest compiled bucket.
pub struct PjrtBackend {
    engine: PjrtEngine,
    native: NativeBackend,
    max_bucket: usize,
    /// Times the backend fell back to native (state outgrew the compiled
    /// buckets, or an execute failed).
    pub fallbacks: u64,
}

impl PjrtBackend {
    /// Engine + native fallback over the artifacts in `artifacts_dir`.
    pub fn new(artifacts_dir: &str) -> Result<Self> {
        let engine = PjrtEngine::new(artifacts_dir)?;
        let max_bucket =
            engine.manifest.m_buckets.iter().copied().max().unwrap_or(0);
        Ok(Self {
            engine,
            native: NativeBackend::new(),
            max_bucket,
            fallbacks: 0,
        })
    }

    /// The underlying engine (perf counters, manifest).
    pub fn engine(&self) -> &PjrtEngine {
        &self.engine
    }
}

impl ScoringBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn topn_into(
        &mut self,
        u: &[f32],
        slab: &VectorSlab,
        n: usize,
        out: &mut Vec<Scored>,
    ) {
        if slab.capacity() > self.max_bucket {
            self.fallbacks += 1;
            return self.native.topn_into(u, slab, n, out);
        }
        match self.engine.topn(u, slab) {
            Ok(scored) => {
                // The PJRT execute allocates its own result literals;
                // the caller scratch still amortizes the truncated copy.
                out.clear();
                out.extend(scored.into_iter().take(n));
            }
            Err(e) => {
                // A failed execute is a bug, not a recoverable condition —
                // but degrade gracefully rather than poisoning the worker.
                log::error!("pjrt topn failed ({e:#}); native fallback");
                self.fallbacks += 1;
                self.native.topn_into(u, slab, n, out);
            }
        }
    }

    fn isgd_step(
        &mut self,
        u: &mut [f32],
        i: &mut [f32],
        eta: f32,
        lam: f32,
    ) -> f32 {
        match self.engine.isgd_step(u, i, eta, lam) {
            Ok(err) => err,
            Err(e) => {
                log::error!("pjrt isgd failed ({e:#}); native fallback");
                self.fallbacks += 1;
                self.native.isgd_step(u, i, eta, lam)
            }
        }
    }
}

/// Factory for per-worker-thread backend construction.
pub fn make_backend(
    backend: crate::config::Backend,
    artifacts_dir: &str,
) -> Result<Box<dyn ScoringBackend>> {
    match backend {
        crate::config::Backend::Native => Ok(Box::new(NativeBackend::new())),
        crate::config::Backend::Pjrt => Ok(Box::new(
            PjrtBackend::new(artifacts_dir)
                .context("constructing PJRT backend")?,
        )),
    }
}
