//! Scoring/update backends for the ISGD hot path.
//!
//! Both backends operate on the same memory layout (`VectorSlab`'s padded
//! matrix + validity mask), so they are interchangeable and cross-checked
//! to 1e-4 by integration tests:
//!
//! * [`NativeBackend`] — hand-written Rust loops; used by the large figure
//!   sweeps and as the fallback when state outgrows the compiled buckets.
//! * `PjrtBackend` (in [`super::pjrt`]) — executes the AOT-compiled
//!   JAX/Pallas artifacts via the PJRT CPU client.
//!
//! Backends are constructed *inside* each worker thread (factory pattern)
//! because the xla crate's client handles are `!Send`.

use crate::state::VectorSlab;

/// A scored candidate: worker-local slab row + score.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Scored {
    /// Slab row of the candidate item.
    pub row: usize,
    /// Dot-product score `u . row`.
    pub score: f32,
}

/// The numeric contract of Algorithm 2 (scoring + the fused ISGD step).
pub trait ScoringBackend {
    /// Backend name for reports ("native" | "pjrt").
    fn name(&self) -> &'static str;

    /// Top-`n` valid slab rows by `u . row` (descending), written into
    /// the caller-owned `out` (cleared first). `n` is the over-fetched
    /// length; the caller filters already-rated items. Callers on the
    /// serving hot path keep `out` alive across queries so the
    /// steady-state cost is pure scoring — no allocation per call.
    fn topn_into(
        &mut self,
        u: &[f32],
        slab: &VectorSlab,
        n: usize,
        out: &mut Vec<Scored>,
    );

    /// Convenience wrapper over [`ScoringBackend::topn_into`] returning
    /// a fresh exact-sized `Vec` — one allocation per call. Tests,
    /// examples, and the hot-path bench's baseline rows use this; the
    /// serving path threads a reused scratch through `topn_into`.
    fn topn(&mut self, u: &[f32], slab: &VectorSlab, n: usize) -> Vec<Scored> {
        let mut out = Vec::with_capacity(n);
        self.topn_into(u, slab, n, &mut out);
        out
    }

    /// Fused ISGD step (Equations 2-4, sequential semantics). Mutates
    /// `u` and `i` in place and returns the prediction error.
    fn isgd_step(&mut self, u: &mut [f32], i: &mut [f32], eta: f32, lam: f32)
        -> f32;
}

/// Pure-Rust backend. Stateless: the candidate heap lives in the
/// caller-owned `out` buffer of [`ScoringBackend::topn_into`].
#[derive(Debug, Default)]
pub struct NativeBackend;

impl NativeBackend {
    /// Fresh backend.
    pub fn new() -> Self {
        Self
    }
}

/// Min-heap helpers over `Scored.score` (std BinaryHeap needs Ord, which
/// f32 lacks; two tiny sift functions are cheaper than a wrapper type).
fn heapify_min(xs: &mut [Scored]) {
    for i in (0..xs.len() / 2).rev() {
        sift_down_min(xs, i);
    }
}

fn sift_down_min(xs: &mut [Scored], mut i: usize) {
    let n = xs.len();
    loop {
        let (l, r) = (2 * i + 1, 2 * i + 2);
        let mut smallest = i;
        if l < n && xs[l].score < xs[smallest].score {
            smallest = l;
        }
        if r < n && xs[r].score < xs[smallest].score {
            smallest = r;
        }
        if smallest == i {
            return;
        }
        xs.swap(i, smallest);
        i = smallest;
    }
}

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // K is 10-16; a straight loop autovectorizes fine at this size.
    let mut s = 0.0f32;
    for (x, y) in a.iter().zip(b.iter()) {
        s += x * y;
    }
    s
}

impl ScoringBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn topn_into(
        &mut self,
        u: &[f32],
        slab: &VectorSlab,
        n: usize,
        out: &mut Vec<Scored>,
    ) {
        out.clear();
        if n == 0 {
            return;
        }
        let k = slab.k();
        let data = slab.data();
        let valid = slab.valid();
        // §Perf iteration 2 (see EXPERIMENTS.md): 4-row-unrolled dots
        // (independent accumulators beat one horizontal-sum chain at
        // K=10) + a threshold-gated size-n binary heap. Once the heap is
        // warm, almost no row beats the threshold (~n·ln(M) expected
        // replacements), so the steady-state cost is pure scoring.
        // §Perf iteration 3: the heap lives in the caller's `out` and is
        // sorted in place — zero copies, zero allocations once the
        // caller's scratch is warm (BENCH_hotpath.json `topn/*` rows).
        let cands = out;
        let mut threshold = f32::NEG_INFINITY;
        let hw = slab.high_water();

        #[inline]
        fn offer(
            cands: &mut Vec<Scored>,
            threshold: &mut f32,
            n: usize,
            row: usize,
            score: f32,
        ) {
            if cands.len() < n {
                cands.push(Scored { row, score });
                // Establish the sift-down heap once full.
                if cands.len() == n {
                    heapify_min(cands);
                    *threshold = cands[0].score;
                }
            } else if score > *threshold {
                cands[0] = Scored { row, score };
                sift_down_min(cands, 0);
                *threshold = cands[0].score;
            }
        }

        let mut row = 0;
        while row + 4 <= hw {
            let base = row * k;
            let quad = &data[base..base + 4 * k];
            let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
            for d in 0..k {
                let ud = u[d];
                s0 += ud * quad[d];
                s1 += ud * quad[k + d];
                s2 += ud * quad[2 * k + d];
                s3 += ud * quad[3 * k + d];
            }
            for (i, s) in [s0, s1, s2, s3].into_iter().enumerate() {
                if valid[row + i] != 0.0 {
                    offer(cands, &mut threshold, n, row + i, s);
                }
            }
            row += 4;
        }
        for r in row..hw {
            if valid[r] != 0.0 {
                let s = dot(u, &data[r * k..r * k + k]);
                offer(cands, &mut threshold, n, r, s);
            }
        }
        cands.sort_unstable_by(|a, b| b.score.total_cmp(&a.score));
    }

    fn isgd_step(
        &mut self,
        u: &mut [f32],
        i: &mut [f32],
        eta: f32,
        lam: f32,
    ) -> f32 {
        let err = 1.0 - dot(u, i);
        for d in 0..u.len() {
            u[d] += eta * (err * i[d] - lam * u[d]);
        }
        // Sequential semantics: item update uses the UPDATED user vector
        // (Algorithm 2 statement order; matches kernels/ref.py).
        for d in 0..i.len() {
            i[d] += eta * (err * u[d] - lam * i[d]);
        }
        err
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    fn slab_with(rows: &[(u64, Vec<f32>)]) -> VectorSlab {
        let mut s = VectorSlab::new(rows[0].1.len());
        for (id, v) in rows {
            s.insert(*id, v, 0);
        }
        s
    }

    #[test]
    fn topn_orders_descending_and_skips_invalid() {
        let mut slab = slab_with(&[
            (1, vec![1.0, 0.0]),
            (2, vec![2.0, 0.0]),
            (3, vec![3.0, 0.0]),
            (4, vec![4.0, 0.0]),
        ]);
        slab.remove(4); // most-scoring row made invalid
        let mut be = NativeBackend::new();
        let got = be.topn(&[1.0, 0.0], &slab, 2);
        assert_eq!(got.len(), 2);
        assert_eq!(slab.id_at(got[0].row), Some(3));
        assert_eq!(slab.id_at(got[1].row), Some(2));
        assert!(got[0].score >= got[1].score);
    }

    #[test]
    fn topn_handles_fewer_rows_than_n() {
        let slab = slab_with(&[(1, vec![1.0, 1.0])]);
        let mut be = NativeBackend::new();
        let got = be.topn(&[0.5, 0.5], &slab, 10);
        assert_eq!(got.len(), 1);
        assert!((got[0].score - 1.0).abs() < 1e-6);
    }

    #[test]
    fn topn_matches_full_sort_reference() {
        // One backend and ONE scratch buffer survive the whole property
        // run: every iteration draws a different slab and a different
        // `n`, so the reused-scratch path is exercised across calls with
        // shrinking and growing `n` — exactly how the serving hot path
        // uses it — and must stay identical to the allocating wrapper
        // and to a full-sort reference.
        let mut be = NativeBackend::new();
        let mut scratch: Vec<Scored> = Vec::new();
        forall("native_topn_vs_sort", 100, |rng| {
            let k = 4;
            let rows = 1 + rng.next_bounded(200) as usize;
            let n = 1 + rng.next_bounded(20) as usize;
            let mut slab = VectorSlab::new(k);
            for id in 0..rows as u64 {
                let v: Vec<f32> =
                    (0..k).map(|_| rng.next_f32() - 0.5).collect();
                slab.insert(id, &v, 0);
            }
            let u: Vec<f32> = (0..k).map(|_| rng.next_f32() - 0.5).collect();
            be.topn_into(&u, &slab, n, &mut scratch);
            let got = scratch.clone();
            // The allocating convenience wrapper is the same answer.
            assert_eq!(be.topn(&u, &slab, n), got);

            // Reference: full sort.
            let mut all: Vec<Scored> = (0..slab.capacity())
                .filter(|&r| slab.valid()[r] == 1.0)
                .map(|r| Scored {
                    row: r,
                    score: dot(&u, &slab.data()[r * k..r * k + k]),
                })
                .collect();
            all.sort_unstable_by(|a, b| b.score.total_cmp(&a.score));
            all.truncate(n);
            let got_scores: Vec<f32> = got.iter().map(|s| s.score).collect();
            let want_scores: Vec<f32> = all.iter().map(|s| s.score).collect();
            assert_eq!(got_scores.len(), want_scores.len());
            for (g, w) in got_scores.iter().zip(want_scores.iter()) {
                assert!((g - w).abs() < 1e-6, "{got_scores:?} {want_scores:?}");
            }
        });
    }

    #[test]
    fn topn_into_clears_stale_scratch_and_handles_n_zero() {
        let slab = slab_with(&[(1, vec![1.0, 0.0]), (2, vec![2.0, 0.0])]);
        let mut be = NativeBackend::new();
        // Stale content (from a previous larger query) must not leak.
        let mut scratch = vec![Scored { row: 99, score: 9.9 }; 8];
        be.topn_into(&[1.0, 0.0], &slab, 1, &mut scratch);
        assert_eq!(scratch.len(), 1);
        assert_eq!(slab.id_at(scratch[0].row), Some(2));
        // n = 0 is a clean empty answer, not an index panic.
        be.topn_into(&[1.0, 0.0], &slab, 0, &mut scratch);
        assert!(scratch.is_empty());
        assert!(be.topn(&[1.0, 0.0], &slab, 0).is_empty());
    }

    #[test]
    fn isgd_step_matches_oracle_algebra() {
        // Mirror of python ref.isgd_update_ref for one pair.
        let mut be = NativeBackend::new();
        let mut u = vec![0.1f32, -0.2, 0.3];
        let mut i = vec![0.05f32, 0.1, -0.15];
        let (eta, lam) = (0.05f32, 0.01f32);
        let u0 = u.clone();
        let i0 = i.clone();
        let err = be.isgd_step(&mut u, &mut i, eta, lam);
        let want_err =
            1.0 - (u0[0] * i0[0] + u0[1] * i0[1] + u0[2] * i0[2]);
        assert!((err - want_err).abs() < 1e-6);
        for d in 0..3 {
            let u_new = u0[d] + eta * (want_err * i0[d] - lam * u0[d]);
            assert!((u[d] - u_new).abs() < 1e-6);
            let i_new = i0[d] + eta * (want_err * u_new - lam * i0[d]);
            assert!((i[d] - i_new).abs() < 1e-6);
        }
    }

    #[test]
    fn repeated_steps_converge() {
        let mut be = NativeBackend::new();
        let mut u = vec![0.1f32; 10];
        let mut i = vec![0.1f32; 10];
        let mut last = f32::MAX;
        for _ in 0..300 {
            last = be.isgd_step(&mut u, &mut i, 0.1, 0.001);
        }
        assert!(last.abs() < 0.05, "err={last}");
    }
}
