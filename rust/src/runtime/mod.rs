//! Runtime layer: the Rust end of the AOT bridge. Loads HLO-text
//! artifacts produced by `python/compile/aot.py`, compiles them on the
//! PJRT CPU client, and exposes them behind the [`backend::ScoringBackend`]
//! trait next to the pure-Rust native backend.

pub mod artifacts;
pub mod backend;
pub mod pjrt;

pub use artifacts::Manifest;
pub use backend::{NativeBackend, Scored, ScoringBackend};
pub use pjrt::{make_backend, PjrtBackend, PjrtEngine};
