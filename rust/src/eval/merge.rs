//! Rank-aware merge of per-replica top-N lists — the coordinator half of
//! the replicated-user read path (Section 4). A user's state lives on the
//! `n_i` workers of its grid column; each replica answers a query with the
//! ranked top-N of its *local* model, and the coordinator merges those
//! lists into one global top-N.
//!
//! Merge key, per item: `(best rank across replicas, replica votes desc,
//! item id)`. Best-rank-first preserves each replica's own ordering (an
//! item a replica ranks above another stays above it unless a different
//! replica disagrees more strongly), votes reward cross-replica agreement
//! on ties, and the item-id tail makes the result fully deterministic.
//!
//! Items in `exclude` never appear — the caller passes the union of the
//! user's rated items across *all* replicas, enforcing globally the
//! "never recommend a consumed item" rule each replica can only enforce
//! locally (a rating lands on exactly one worker, so the other replicas
//! of the user have no idea the item was consumed).

use std::collections::{HashMap, HashSet};

use crate::data::types::ItemId;

/// Merge ranked per-replica lists into a global top-`n`.
///
/// Returns fewer than `n` items when the union of the (filtered) inputs
/// is smaller than `n`; empty inputs merge to an empty list.
///
/// **Truncation is a prefix**: for the same inputs, `merge_topn(.., k)`
/// equals the first `k` items of `merge_topn(.., n)` for any `k <= n`
/// (the full ranking is computed, then truncated). The serving cache
/// relies on this to answer a shorter request from a cached longer
/// merge without recomputing.
pub fn merge_topn(
    lists: &[Vec<ItemId>],
    exclude: &HashSet<ItemId>,
    n: usize,
) -> Vec<ItemId> {
    // item -> (best rank, replica votes)
    let mut best: HashMap<ItemId, (usize, usize)> = HashMap::new();
    for list in lists {
        for (rank, &item) in list.iter().enumerate() {
            if exclude.contains(&item) {
                continue;
            }
            let entry = best.entry(item).or_insert((rank, 0));
            entry.0 = entry.0.min(rank);
            entry.1 += 1;
        }
    }
    let mut scored: Vec<(usize, usize, ItemId)> = best
        .into_iter()
        .map(|(item, (rank, votes))| (rank, votes, item))
        .collect();
    scored.sort_unstable_by(|a, b| {
        a.0.cmp(&b.0).then(b.1.cmp(&a.1)).then(a.2.cmp(&b.2))
    });
    scored.truncate(n);
    scored.into_iter().map(|(_, _, item)| item).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn no_exclude() -> HashSet<ItemId> {
        HashSet::new()
    }

    #[test]
    fn single_list_is_identity_up_to_truncation() {
        let list = vec![5u64, 3, 9, 1, 7];
        assert_eq!(merge_topn(&[list.clone()], &no_exclude(), 10), list);
        assert_eq!(merge_topn(&[list], &no_exclude(), 3), vec![5, 3, 9]);
    }

    #[test]
    fn empty_inputs_merge_empty() {
        assert!(merge_topn(&[], &no_exclude(), 10).is_empty());
        assert!(merge_topn(&[vec![], vec![]], &no_exclude(), 10).is_empty());
    }

    #[test]
    fn best_rank_across_replicas_wins() {
        // Replica A ranks 100 first; replica B ranks 200 first and 100
        // nowhere. 100 and 200 tie on best rank 0; A also lists 300 at
        // rank 1, so 300 sorts after both.
        let a = vec![100u64, 300];
        let b = vec![200u64];
        let merged = merge_topn(&[a, b], &no_exclude(), 10);
        assert_eq!(merged, vec![100, 200, 300]);
    }

    #[test]
    fn votes_break_rank_ties() {
        // 7 appears at rank 1 on two replicas; 8 at rank 1 on one.
        // 7 must come first among the rank-1 items.
        let a = vec![1u64, 7];
        let b = vec![2u64, 7];
        let c = vec![3u64, 8];
        let merged = merge_topn(&[a, b, c], &no_exclude(), 10);
        let pos = |x: u64| merged.iter().position(|&i| i == x).unwrap();
        assert!(pos(7) < pos(8), "{merged:?}");
    }

    #[test]
    fn excluded_items_never_surface() {
        let exclude: HashSet<ItemId> = [3u64, 9].into_iter().collect();
        let merged =
            merge_topn(&[vec![3u64, 1, 9, 2], vec![9u64, 3, 4]], &exclude, 10);
        assert!(!merged.contains(&3));
        assert!(!merged.contains(&9));
        assert_eq!(merged.first(), Some(&1));
    }

    #[test]
    fn deterministic_for_identical_inputs() {
        let lists =
            vec![vec![4u64, 8, 15], vec![16u64, 23, 42], vec![8u64, 42, 4]];
        let a = merge_topn(&lists, &no_exclude(), 5);
        let b = merge_topn(&lists, &no_exclude(), 5);
        assert_eq!(a, b);
    }

    #[test]
    fn no_duplicates_in_merge() {
        let merged = merge_topn(
            &[vec![1u64, 2, 3], vec![3u64, 2, 1], vec![2u64, 9]],
            &no_exclude(),
            10,
        );
        let set: HashSet<ItemId> = merged.iter().copied().collect();
        assert_eq!(set.len(), merged.len(), "{merged:?}");
    }

    #[test]
    fn truncation_is_a_prefix_of_the_longer_merge() {
        // The property the serving cache leans on: a shorter request is
        // exactly a prefix of the longer merge over the same inputs.
        use crate::util::proptest::forall;
        forall("merge_truncation_prefix", 100, |rng| {
            let n_lists = 1 + rng.next_bounded(4) as usize;
            let lists: Vec<Vec<ItemId>> = (0..n_lists)
                .map(|_| {
                    let len = rng.next_bounded(12) as usize;
                    let mut l = Vec::new();
                    for _ in 0..len {
                        let item = rng.next_bounded(30);
                        if !l.contains(&item) {
                            l.push(item);
                        }
                    }
                    l
                })
                .collect();
            let exclude: HashSet<ItemId> = (0..rng.next_bounded(5))
                .map(|_| rng.next_bounded(30))
                .collect();
            let n = 1 + rng.next_bounded(12) as usize;
            let full = merge_topn(&lists, &exclude, n);
            for k in 0..=n {
                assert_eq!(
                    merge_topn(&lists, &exclude, k),
                    full[..k.min(full.len())],
                    "k={k} n={n} lists={lists:?}"
                );
            }
        });
    }

    // The rank-order proptest for the merge lives with the other query-
    // path properties in rust/tests/integration_cluster.rs.
}
