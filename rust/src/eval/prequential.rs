//! Prequential online evaluation (Algorithm 4): every arriving rating is
//! first used to test (is the item inside the current top-N
//! recommendation for that user?) and then to train. Recall@N per event
//! is 0/1; the paper reports a moving average over 5000-event windows.

use std::time::Instant;

use crate::algorithms::StreamingRecommender;
use crate::data::types::Rating;
use crate::eval::windowed::WindowedRecall;

/// Ring-buffer moving average over the last `window` binary outcomes.
#[derive(Debug, Clone)]
pub struct MovingRecall {
    window: usize,
    buf: Vec<bool>,
    next: usize,
    filled: usize,
    sum: u64,
    hits: u64,
    count: u64,
}

impl MovingRecall {
    /// Empty window of the given size (>= 1).
    pub fn new(window: usize) -> Self {
        assert!(window >= 1);
        Self {
            window,
            buf: vec![false; window],
            next: 0,
            filled: 0,
            sum: 0,
            hits: 0,
            count: 0,
        }
    }

    /// Record one binary prequential outcome.
    pub fn push(&mut self, hit: bool) {
        if self.filled == self.window {
            if self.buf[self.next] {
                self.sum -= 1;
            }
        } else {
            self.filled += 1;
        }
        self.buf[self.next] = hit;
        if hit {
            self.sum += 1;
            self.hits += 1;
        }
        self.next = (self.next + 1) % self.window;
        self.count += 1;
    }

    /// Moving-average recall over the current window.
    pub fn value(&self) -> f64 {
        if self.filled == 0 {
            0.0
        } else {
            self.sum as f64 / self.filled as f64
        }
    }

    /// Lifetime average recall (the paper's "average recall" numbers).
    pub fn lifetime(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.hits as f64 / self.count as f64
        }
    }

    /// Lifetime outcomes recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Lifetime hits recorded.
    pub fn hits(&self) -> u64 {
        self.hits
    }
}

/// One evaluated event: global stream sequence number + hit bit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HitSample {
    /// Global stream sequence number of the evaluated event.
    pub seq: u64,
    /// Was the rated item inside the pre-update top-N?
    pub hit: bool,
}

/// Outcome of one prequential step: the hit bit plus the wall-time split
/// between the recommend (test) and update (train) halves. The split is
/// plumbed into `WorkerReport::{recommend_ns, update_ns}` so the profile
/// shows where a worker's time actually goes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StepOutcome {
    /// Was the rated item inside the pre-update top-N?
    pub hit: bool,
    /// Nanoseconds spent in `recommend()`.
    pub recommend_ns: u64,
    /// Nanoseconds spent in `update()`.
    pub update_ns: u64,
}

/// Prequential evaluator: drives recommend-then-update for one worker.
pub struct Prequential {
    top_n: usize,
    recall: MovingRecall,
    /// Tumbling-window (time-local) recall over this evaluator's own
    /// event order — the drift-response view of the same outcomes the
    /// moving average smooths (same window size).
    windowed: WindowedRecall,
}

impl Prequential {
    /// Evaluator judging hits against top-`top_n` with a moving window
    /// (also the tumbling-window size of [`Prequential::windowed`]).
    pub fn new(top_n: usize, window: usize) -> Self {
        Self {
            top_n,
            recall: MovingRecall::new(window),
            windowed: WindowedRecall::new(window as u64),
        }
    }

    /// Algorithm 4 for one event. The hit is judged against the top-N list
    /// recommended *before* the model update; both halves are timed
    /// separately.
    pub fn step(
        &mut self,
        model: &mut dyn StreamingRecommender,
        event: &Rating,
    ) -> StepOutcome {
        let t0 = Instant::now();
        let recs = model.recommend(event.user, self.top_n);
        let recommend_ns = t0.elapsed().as_nanos() as u64;
        let hit = recs.contains(&event.item);
        self.windowed.push(self.recall.count(), hit);
        self.recall.push(hit);
        let t1 = Instant::now();
        model.update(event);
        let update_ns = t1.elapsed().as_nanos() as u64;
        StepOutcome { hit, recommend_ns, update_ns }
    }

    /// The recall accumulator (moving window + lifetime counters).
    pub fn recall(&self) -> &MovingRecall {
        &self.recall
    }

    /// The tumbling-window recall series over this evaluator's local
    /// event order (window index = local event count / window size).
    pub fn windowed(&self) -> &WindowedRecall {
        &self.windowed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::types::{ItemId, StateSizes, UserId};
    use crate::state::SweepKind;

    /// Scripted model: recommends a fixed list, records updates.
    struct Scripted {
        list: Vec<ItemId>,
        updated: Vec<ItemId>,
        update_changes_list_to: Option<Vec<ItemId>>,
    }

    impl StreamingRecommender for Scripted {
        fn name(&self) -> &'static str {
            "scripted"
        }
        fn recommend(&mut self, _u: UserId, n: usize) -> Vec<ItemId> {
            self.list.iter().copied().take(n).collect()
        }
        fn update(&mut self, e: &Rating) {
            self.updated.push(e.item);
            if let Some(l) = self.update_changes_list_to.take() {
                self.list = l;
            }
        }
        fn state_sizes(&self) -> StateSizes {
            StateSizes::default()
        }
        fn sweep(&mut self, _k: SweepKind) -> u64 {
            0
        }
        fn export_partition(&self, _f: &dyn Fn(UserId) -> bool) -> Vec<u8> {
            Vec::new()
        }
        fn import_partition(&mut self, _bytes: &[u8]) -> anyhow::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn moving_recall_window_math() {
        let mut r = MovingRecall::new(4);
        assert_eq!(r.value(), 0.0);
        r.push(true);
        r.push(false);
        assert!((r.value() - 0.5).abs() < 1e-12);
        r.push(true);
        r.push(true);
        assert!((r.value() - 0.75).abs() < 1e-12);
        // Window slides: first push (true) falls out.
        r.push(false);
        assert!((r.value() - 0.5).abs() < 1e-12);
        assert_eq!(r.count(), 5);
        assert_eq!(r.hits(), 3);
        assert!((r.lifetime() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn recommend_happens_before_update() {
        // The model starts NOT recommending item 7; update() switches the
        // list to include it. Prequential must score the pre-update list.
        let mut model = Scripted {
            list: vec![1, 2, 3],
            updated: vec![],
            update_changes_list_to: Some(vec![7]),
        };
        let mut p = Prequential::new(10, 100);
        let out = p.step(&mut model, &Rating::new(1, 7, 5.0, 0));
        assert!(!out.hit, "item must be tested against the pre-update model");
        assert_eq!(model.updated, vec![7], "update must still happen");
        // Next event: list is now [7].
        let out = p.step(&mut model, &Rating::new(1, 7, 5.0, 1));
        assert!(out.hit);
    }

    #[test]
    fn step_reports_both_timing_halves() {
        let mut model = Scripted {
            list: vec![1, 2, 3],
            updated: vec![],
            update_changes_list_to: None,
        };
        let mut p = Prequential::new(10, 100);
        let mut rec = 0u64;
        let mut upd = 0u64;
        for i in 0..50 {
            let out = p.step(&mut model, &Rating::new(1, 2, 5.0, i));
            rec += out.recommend_ns;
            upd += out.update_ns;
        }
        // Both halves executed; on a coarse clock individual steps may
        // read 0 ns, but 50 steps of real work accumulate something.
        assert!(rec + upd > 0, "timing split must not be dead");
    }

    #[test]
    fn windowed_view_reconciles_with_lifetime() {
        let mut model = Scripted {
            list: vec![1, 2, 3],
            updated: vec![],
            update_changes_list_to: None,
        };
        let mut p = Prequential::new(10, 4);
        for i in 0..10u64 {
            // Alternate hit (item 2) and miss (item 30).
            let item = if i % 2 == 0 { 2 } else { 30 };
            p.step(&mut model, &Rating::new(1, item, 5.0, i));
        }
        let w = p.windowed();
        assert_eq!(w.window(), 4);
        assert_eq!(w.total_events(), p.recall().count());
        assert_eq!(w.total_hits(), p.recall().hits());
        assert_eq!(w.stats().len(), 3, "10 events / window 4");
        assert!((w.stats()[0].recall() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn top_n_truncation_respected() {
        let mut model = Scripted {
            list: (0..50).collect(),
            updated: vec![],
            update_changes_list_to: None,
        };
        let mut p = Prequential::new(10, 100);
        // Item 30 is in the scripted list but outside top-10.
        assert!(!p.step(&mut model, &Rating::new(1, 30, 5.0, 0)).hit);
        assert!(p.step(&mut model, &Rating::new(1, 5, 5.0, 1)).hit);
    }
}
