//! Run metrics: per-worker reports and the aggregated run report the
//! experiment harness serializes. Covers every quantity the paper's
//! evaluation section plots: recall curves (Figs 3/5/6/9/11/12), state
//! size distributions (Figs 4/7/10/13), and throughput (Figs 8/14).

use crate::data::types::StateSizes;
use crate::eval::windowed::WindowStat;
use crate::util::histogram::Histogram;

/// Final report from one worker thread.
#[derive(Debug, Clone)]
pub struct WorkerReport {
    /// Session-unique worker id (ids keep counting across rescale
    /// generations).
    pub worker_id: usize,
    /// Events processed by this worker.
    pub processed: u64,
    /// Prequential hits.
    pub hits: u64,
    /// Serving queries answered by this worker (so a retired
    /// generation's query traffic survives into the aggregates).
    pub queries: u64,
    /// Final state-entry counts (zero for workers retired by a rescale:
    /// their state was exported to the next generation).
    pub state: StateSizes,
    /// Final logical state bytes — the models' deterministic accounting
    /// summed over hosted lanes, resident *and* spilled (zero for
    /// retired workers, like `state`). Placement-independent: the same
    /// stream yields the same total however lanes were placed.
    pub state_bytes: u64,
    /// Per-event processing latency (recommend + update), nanoseconds.
    pub latency: Histogram,
    /// Forgetting sweeps run (clock-driven and memory-pressure-driven).
    pub sweeps: u64,
    /// Entries evicted by forgetting sweeps.
    pub evicted: u64,
    /// Cold-lane spills to the disk tier performed by this worker.
    pub spills: u64,
    /// Spilled-lane fault-ins performed by this worker.
    pub spill_faultins: u64,
    /// Nanoseconds spent inside recommend() (profile split).
    pub recommend_ns: u64,
    /// Nanoseconds spent inside update() (profile split).
    pub update_ns: u64,
    /// Tumbling-window recall over this worker's *local* event order
    /// (window = `recall_window`): a per-worker drift-response
    /// diagnostic. The stream-global windowed curve, bucketed by global
    /// sequence number, is [`RunReport::windowed_recall`].
    pub windows: Vec<WindowStat>,
}

/// Aggregated result of one pipeline run.
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Configuration echo (algorithm, n_i, forgetting, backend, dataset).
    pub label: String,
    /// Worker count of the *final* topology (rescales may have changed it
    /// since spawn; earlier generations are in [`RunReport::retired`]).
    pub n_workers: usize,
    /// Total events ingested.
    pub events: u64,
    /// Total prequential hits.
    pub hits: u64,
    /// Wall-clock seconds for the full stream.
    pub wall_secs: f64,
    /// Events per second end-to-end.
    pub throughput: f64,
    /// Lifetime average online recall (hits / events).
    pub avg_recall: f64,
    /// Moving-average recall curve: (global sequence, recall@N).
    pub recall_curve: Vec<(u64, f64)>,
    /// Tumbling-window online recall over the global stream (window =
    /// `recall_window` events, bucketed by global sequence number) — the
    /// time-local view a drift scenario's dip-and-recovery shows up in,
    /// where the cumulative curve only shows a slow slope change. Sums
    /// reconcile exactly with `hits`/`events` for any window size.
    pub windowed_recall: Vec<WindowStat>,
    /// Per-worker final reports for the final topology (state-size
    /// distributions etc.).
    pub workers: Vec<WorkerReport>,
    /// Final reports of workers retired by [`Cluster::rescale`] cutovers
    /// (their state was exported, so `state` reads zero; `processed`,
    /// `hits`, latency and timing splits are their lifetime totals —
    /// summing `processed` over `workers` + `retired` accounts for every
    /// ingested event exactly once).
    ///
    /// [`Cluster::rescale`]: crate::coordinator::Cluster::rescale
    pub retired: Vec<WorkerReport>,
    /// Router time per event (ns, driver side).
    pub route_ns_per_event: f64,
    /// Total ns senders spent blocked on backpressure.
    pub backpressure_ns: u64,
    /// Total ns worker receivers spent waiting for messages (the other
    /// side of the transport: send-side stalls vs receive-side idling
    /// lets the bench attribute where a win comes from).
    pub recv_blocked_ns: u64,
    /// Mean messages per channel send (1.0 = event-at-a-time; higher =
    /// the `ingest_batch_size` micro-batching is amortizing transport).
    /// Includes query/snapshot probe singletons, so interactive sessions
    /// read lower than pure ingest runs.
    pub mean_send_batch: f64,
    /// Completed rescale cutovers during the session.
    pub rescales: u64,
    /// Total serialized lane bytes moved by rescales.
    pub migrated_bytes: u64,
    /// Total ns spent inside rescale cutovers (ingest/serving paused).
    pub rescale_pause_ns: u64,
    /// Completed crash recoveries (0 unless `fault.checkpoint_interval`
    /// was set and a worker actually died — a recovered session's hits,
    /// recall curve, and answers are identical to a never-crashed run).
    pub recoveries: u64,
    /// Total serialized lane-frame bytes received as checkpoints.
    pub checkpoint_bytes: u64,
    /// Envelopes replayed from the coordinator's log by recoveries.
    pub replayed_events: u64,
    /// Total ns spent inside crash recoveries (respawn + restore +
    /// replay) — the fault-tolerance analog of `rescale_pause_ns`,
    /// measured by `benches/recovery.rs`.
    pub recovery_pause_ns: u64,
    /// Final logical state bytes summed over the final topology's
    /// workers (the paper's memory metric in bytes; retired workers
    /// report zero, so there is no double counting).
    pub state_bytes: u64,
    /// Total cold-lane spills to the disk tier across all workers
    /// (live + retired). `0` unless a `[memory]` budget forced tiering.
    pub spills: u64,
    /// Total spilled-lane fault-ins across all workers (live + retired).
    pub spill_faultins: u64,
}

impl RunReport {
    /// Mean of per-worker user-state sizes (Figs 4/7/10/13 quote these).
    pub fn mean_user_state(&self) -> f64 {
        mean(self.workers.iter().map(|w| w.state.users as f64))
    }

    /// Mean of per-worker item-state sizes.
    pub fn mean_item_state(&self) -> f64 {
        mean(self.workers.iter().map(|w| w.state.items as f64))
    }

    /// Mean of per-worker auxiliary-state sizes (DICS pair entries).
    pub fn mean_aux_state(&self) -> f64 {
        mean(self.workers.iter().map(|w| w.state.aux as f64))
    }

    /// Merged latency histogram across workers.
    pub fn latency(&self) -> Histogram {
        let mut h = Histogram::new();
        for w in &self.workers {
            h.merge(&w.latency);
        }
        h
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "{}: events={} workers={} recall={:.4} thpt={:.0} ev/s \
             user_state(mean)={:.1} item_state(mean)={:.1} aux(mean)={:.1}",
            self.label,
            self.events,
            self.n_workers,
            self.avg_recall,
            self.throughput,
            self.mean_user_state(),
            self.mean_item_state(),
            self.mean_aux_state(),
        )
    }
}

fn mean(xs: impl Iterator<Item = f64>) -> f64 {
    let mut sum = 0.0;
    let mut n = 0u64;
    for x in xs {
        sum += x;
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        sum / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn worker(id: usize, users: u64, items: u64) -> WorkerReport {
        WorkerReport {
            worker_id: id,
            processed: 10,
            hits: 2,
            queries: 0,
            state: StateSizes { users, items, aux: 0 },
            state_bytes: (users + items) * 32,
            latency: Histogram::new(),
            sweeps: 0,
            evicted: 0,
            spills: 0,
            spill_faultins: 0,
            recommend_ns: 0,
            update_ns: 0,
            windows: vec![],
        }
    }

    #[test]
    fn state_means() {
        let r = RunReport {
            label: "t".into(),
            n_workers: 2,
            events: 20,
            hits: 4,
            wall_secs: 1.0,
            throughput: 20.0,
            avg_recall: 0.2,
            recall_curve: vec![],
            windowed_recall: vec![],
            workers: vec![worker(0, 10, 4), worker(1, 20, 6)],
            retired: vec![],
            route_ns_per_event: 1.0,
            backpressure_ns: 0,
            recv_blocked_ns: 0,
            mean_send_batch: 1.0,
            rescales: 0,
            migrated_bytes: 0,
            rescale_pause_ns: 0,
            recoveries: 0,
            checkpoint_bytes: 0,
            replayed_events: 0,
            recovery_pause_ns: 0,
            state_bytes: (10 + 4 + 20 + 6) * 32,
            spills: 0,
            spill_faultins: 0,
        };
        assert!((r.mean_user_state() - 15.0).abs() < 1e-9);
        assert!((r.mean_item_state() - 5.0).abs() < 1e-9);
        assert!(r.summary().contains("recall=0.2000"));
    }
}
