//! Time-local (windowed) online evaluation.
//!
//! The cumulative moving-average recall curve the paper plots answers
//! "how good has the model been so far"; it is dominated by history and
//! barely moves when user interests shift mid-stream. Concept-drift
//! response needs a *time-local* metric: tumbling windows of K events,
//! each scored independently, so a drift point shows up as a dip in the
//! affected window and recovery as the climb back (Chang et al.,
//! *Streaming Recommender Systems*, make the same argument for
//! interest-shift evaluation).
//!
//! [`WindowedRecall`] accumulates per-event prequential outcomes into
//! [`WindowStat`] rows keyed by `seq / window`; because each outcome
//! lands in exactly one window, the windowed view always *reconciles*
//! with the cumulative one (sum of window hits == lifetime hits, for
//! any window size — property-tested in `tests/drift_scenarios.rs`).
//! [`drift_response`] condenses a window series into the
//! pre-drift / dip / recovered triple the drift experiments assert on.

/// Aggregate of one tumbling window of prequential outcomes.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WindowStat {
    /// Window index (`seq / window`).
    pub index: u64,
    /// First sequence number the window covers (`index * window`).
    pub start_seq: u64,
    /// Outcomes recorded in this window (the trailing window of a run
    /// may be partial; all others hold exactly `window` outcomes once
    /// the stream has passed them).
    pub events: u64,
    /// Prequential hits recorded in this window.
    pub hits: u64,
}

impl WindowStat {
    /// Window-local recall@N (== hit-rate for the binary prequential
    /// protocol: each event carries exactly one relevant item).
    pub fn recall(&self) -> f64 {
        if self.events == 0 {
            0.0
        } else {
            self.hits as f64 / self.events as f64
        }
    }
}

/// Accumulator for tumbling-window online recall.
///
/// `push` accepts outcomes in any order (workers see interleaved global
/// sequence numbers; the collector replays in order) — each outcome is
/// bucketed by its sequence number, so the resulting series is
/// order-independent.
#[derive(Debug, Clone)]
pub struct WindowedRecall {
    window: u64,
    stats: Vec<WindowStat>,
}

impl WindowedRecall {
    /// Accumulator with tumbling windows of `window` events (>= 1;
    /// 0 is clamped).
    pub fn new(window: u64) -> Self {
        Self { window: window.max(1), stats: Vec::new() }
    }

    /// The configured window size in events.
    pub fn window(&self) -> u64 {
        self.window
    }

    /// Record one prequential outcome for sequence number `seq`.
    pub fn push(&mut self, seq: u64, hit: bool) {
        let index = seq / self.window;
        let idx = index as usize;
        if idx >= self.stats.len() {
            let window = self.window;
            let from = self.stats.len() as u64;
            self.stats.extend((from..=index).map(|i| WindowStat {
                index: i,
                start_seq: i * window,
                events: 0,
                hits: 0,
            }));
        }
        let w = &mut self.stats[idx];
        w.events += 1;
        w.hits += u64::from(hit);
    }

    /// The window series so far (dense: windows no outcome landed in are
    /// present with `events == 0`).
    pub fn stats(&self) -> &[WindowStat] {
        &self.stats
    }

    /// Consume the accumulator, returning the window series.
    pub fn into_stats(self) -> Vec<WindowStat> {
        self.stats
    }

    /// Total outcomes recorded (reconciles with the cumulative curve).
    pub fn total_events(&self) -> u64 {
        self.stats.iter().map(|w| w.events).sum()
    }

    /// Total hits recorded (reconciles with the cumulative curve).
    pub fn total_hits(&self) -> u64 {
        self.stats.iter().map(|w| w.hits).sum()
    }
}

/// A drift experiment's condensed windowed-recall response: the window
/// just before the drift point, the worst window at/after it, and the
/// final window of the stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DriftResponse {
    /// Index of the window containing the drift point.
    pub drift_window: u64,
    /// Recall of the last full window *before* the drift point.
    pub pre: f64,
    /// Minimum window recall at/after the drift point (the dip).
    pub dip: f64,
    /// Recall of the final window (how far the model climbed back).
    pub recovered: f64,
}

/// Condense a window series around a drift at sequence `drift_seq`.
/// Returns `None` when the series is too short to have at least one
/// window on each side of the drift point.
pub fn drift_response(
    windows: &[WindowStat],
    drift_seq: u64,
) -> Option<DriftResponse> {
    let first = windows.first()?;
    let window = windows.get(1).map_or(
        first.events.max(1),
        |w| w.start_seq - first.start_seq,
    );
    let drift_window = drift_seq / window.max(1);
    if drift_window == 0 || drift_window as usize >= windows.len() {
        return None;
    }
    let pre = windows[drift_window as usize - 1].recall();
    let after = &windows[drift_window as usize..];
    let dip = after
        .iter()
        .filter(|w| w.events > 0)
        .map(|w| w.recall())
        .fold(f64::INFINITY, f64::min);
    let recovered = after.iter().rev().find(|w| w.events > 0)?.recall();
    if !dip.is_finite() {
        return None;
    }
    Some(DriftResponse { drift_window, pre, dip, recovered })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_by_sequence_number() {
        let mut w = WindowedRecall::new(4);
        for seq in 0..10 {
            w.push(seq, seq % 2 == 0);
        }
        let s = w.stats();
        assert_eq!(s.len(), 3);
        assert_eq!(s[0], WindowStat { index: 0, start_seq: 0, events: 4, hits: 2 });
        assert_eq!(s[1], WindowStat { index: 1, start_seq: 4, events: 4, hits: 2 });
        assert_eq!(s[2], WindowStat { index: 2, start_seq: 8, events: 2, hits: 1 });
        assert_eq!(w.total_events(), 10);
        assert_eq!(w.total_hits(), 5);
        assert!((s[0].recall() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn order_independent_and_gap_dense() {
        let mut fwd = WindowedRecall::new(3);
        let mut rev = WindowedRecall::new(3);
        let outcomes = [(0, true), (7, false), (2, true), (8, true)];
        for (s, h) in outcomes {
            fwd.push(s, h);
        }
        for (s, h) in outcomes.iter().rev() {
            rev.push(*s, *h);
        }
        assert_eq!(fwd.stats(), rev.stats());
        // Window 1 (seqs 3..6) saw nothing but is present.
        assert_eq!(fwd.stats()[1].events, 0);
        assert_eq!(fwd.stats()[1].recall(), 0.0);
    }

    #[test]
    fn reconciles_with_cumulative_for_any_window_size() {
        // A fixed pseudo-random outcome sequence; every window size must
        // preserve the lifetime totals.
        let hits: Vec<bool> =
            (0u64..997).map(|i| (i * 2654435761) % 7 < 3).collect();
        let lifetime = hits.iter().filter(|h| **h).count() as u64;
        for window in [1u64, 7, 100, 997, 5000] {
            let mut w = WindowedRecall::new(window);
            for (seq, h) in hits.iter().enumerate() {
                w.push(seq as u64, *h);
            }
            assert_eq!(w.total_events(), 997, "window={window}");
            assert_eq!(w.total_hits(), lifetime, "window={window}");
            let weighted: f64 = w
                .stats()
                .iter()
                .map(|s| s.recall() * s.events as f64)
                .sum::<f64>()
                / 997.0;
            assert!(
                (weighted - lifetime as f64 / 997.0).abs() < 1e-9,
                "window={window}"
            );
        }
    }

    #[test]
    fn drift_response_extracts_dip_and_recovery() {
        // 10 windows of 100; recall 0.4 before, crashes to 0.05 at the
        // drift (window 5), climbs back to 0.3.
        let mk = |i: u64, hits: u64| WindowStat {
            index: i,
            start_seq: i * 100,
            events: 100,
            hits,
        };
        let windows: Vec<WindowStat> = (0..10)
            .map(|i| match i {
                0..=4 => mk(i, 40),
                5 => mk(i, 5),
                6 => mk(i, 10),
                _ => mk(i, 30),
            })
            .collect();
        let r = drift_response(&windows, 500).unwrap();
        assert_eq!(r.drift_window, 5);
        assert!((r.pre - 0.4).abs() < 1e-12);
        assert!((r.dip - 0.05).abs() < 1e-12);
        assert!((r.recovered - 0.3).abs() < 1e-12);
        // Too short for a pre-window: None, not a panic.
        assert!(drift_response(&windows[..1], 500).is_none());
        assert!(drift_response(&windows, 0).is_none());
        assert!(drift_response(&[], 500).is_none());
    }
}
