//! Evaluation: the prequential online protocol (Algorithm 4) and the
//! metrics the experiment harness aggregates.

pub mod merge;
pub mod metrics;
pub mod prequential;
pub mod windowed;

pub use merge::merge_topn;
pub use metrics::{RunReport, WorkerReport};
pub use prequential::{HitSample, MovingRecall, Prequential, StepOutcome};
pub use windowed::{drift_response, DriftResponse, WindowStat, WindowedRecall};
