//! Worker-thread harness: named OS threads with panic propagation — the
//! shared-nothing "task slot" of the engine. Each worker owns its state;
//! the only communication is the inbound event channel and the outbound
//! report/sample channels.

use std::thread::JoinHandle;

/// Handle to a spawned worker.
pub struct WorkerHandle<R> {
    id: usize,
    handle: JoinHandle<R>,
}

impl<R> WorkerHandle<R> {
    /// The id this worker was spawned with.
    pub fn id(&self) -> usize {
        self.id
    }

    /// True once the worker thread has exited — cleanly *or* by panic.
    /// This is the supervisor's cheap liveness probe: it never blocks,
    /// so a whole generation can be scanned between stream events.
    pub fn is_finished(&self) -> bool {
        self.handle.is_finished()
    }

    /// Join, converting a worker panic into an error with the worker id.
    pub fn join(self) -> anyhow::Result<R> {
        self.handle.join().map_err(|p| {
            let msg = p
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| p.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            anyhow::anyhow!("worker {} panicked: {msg}", self.id)
        })
    }
}

/// Spawn a named worker thread. The body runs entirely inside the thread;
/// all worker state (model, backend, PJRT client) is constructed there so
/// non-Send types (the xla crate's Rc-based handles) stay thread-local.
pub fn spawn<R, F>(id: usize, name: &str, body: F) -> WorkerHandle<R>
where
    F: FnOnce() -> R + Send + 'static,
    R: Send + 'static,
{
    let handle = std::thread::Builder::new()
        .name(format!("{name}-{id}"))
        .spawn(body)
        .expect("spawning worker thread");
    WorkerHandle { id, handle }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_returns_value() {
        let h = spawn(3, "t", || 40 + 2);
        assert_eq!(h.id(), 3);
        assert_eq!(h.join().unwrap(), 42);
    }

    #[test]
    fn is_finished_tracks_thread_exit() {
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        let h = spawn(1, "t", move || rx.recv().ok());
        assert!(!h.is_finished(), "worker is parked on the channel");
        tx.send(()).unwrap();
        h.join().unwrap();
    }

    #[test]
    fn worker_panic_is_reported_with_id() {
        let h = spawn(7, "t", || -> i32 { panic!("kaboom") });
        let err = h.join().unwrap_err().to_string();
        assert!(err.contains("worker 7"), "{err}");
        assert!(err.contains("kaboom"), "{err}");
    }
}
