//! The supervised worker actor — the engine-side half of the worker
//! plane.
//!
//! Historically the worker loop lived inside `coordinator/cluster.rs`;
//! this module extracts it into a real runtime layer: a [`WorkerActor`]
//! owns the inbound event FIFO, the control-message protocol
//! ([`WorkerMsg`]), and the per-lane models it hosts, and the
//! coordinator-side [`Supervisor`](crate::coordinator::supervisor) owns
//! spawning, liveness, checkpoints, and crash recovery.
//!
//! # Lanes
//!
//! Model state is partitioned on the fixed virtual
//! [`StateGrid`](crate::coordinator::router::StateGrid) into *lanes* —
//! one independent model per virtual grid cell. The actor hosts the
//! group of lanes the current topology assigns to its worker. Each
//! [`Lane`] carries everything that must be placement-independent:
//!
//! * the model itself (built lazily on first touch, seeded by *lane* id
//!   so its RNG stream is identical wherever it is hosted),
//! * its [`ForgetClock`] — the forgetting *trigger* is per-lane, so a
//!   lane's sweep cadence is a function of its own event stream alone
//!   (this is what makes sweeps survive rescales and recoveries), and
//! * its counters and high-watermark `seq` (the last event applied).
//!
//! # Checkpoints and the lane frame
//!
//! With fault tolerance enabled (`fault.checkpoint_interval > 0`) the
//! actor periodically serializes each lane into a *lane frame* — a
//! fixed-size header (watermark, counters, clock state) followed by the
//! model's [`export_partition`](crate::algorithms::StreamingRecommender)
//! bytes — and hands it to the supervisor over a dedicated channel. The
//! send is non-blocking (`try_send`): a full channel defers the
//! checkpoint to the next event instead of ever stalling the learning
//! loop (or deadlocking against coordinator backpressure). The same
//! frame format is what `Export`/`Import` move during a rescale, so one
//! serialization path serves both migration and recovery.
//!
//! # Chaos
//!
//! [`ChaosPolicy`] injects a deterministic panic — before processing a
//! chosen global sequence number, or during the first checkpoint attempt
//! at/after it — so fault-tolerance tests can kill any worker at any
//! stream position reproducibly. A disarmed policy costs one `Option`
//! compare per event.
//!
//! This is *actor-level* chaos: the worker itself dies, wherever it
//! runs. Transport-level chaos — severed connections, delayed dials,
//! truncated frames — lives in `net::chaos` (`[fault.net]`) and only
//! applies to remote slots. The two compose: both funnel into the same
//! supervisor crash path. Note that transport liveness (answering the
//! coordinator's `Ping` heartbeat) is the host *pump's* job, not the
//! actor's — a remote actor grinding through a slow batch still proves
//! liveness, while a stalled pump (or dead host) is what the
//! coordinator's watchdog converts into a crash within
//! `fault.rpc_timeout_ms`.
//!
//! # Memory
//!
//! Each lane carries a cached `state_bytes` figure — the model's
//! deterministic accounting, refreshed every `memory.check_events`
//! events applied to the lane (the counter travels in lane frames, so
//! the cadence survives migration). With a `[memory]` budget set, two
//! mechanisms keep a worker inside it, both placement-independent:
//!
//! * **Pressure sweeps** (per lane): a lane over its equal slice of the
//!   budget (`budget / state-grid lanes`; the grid is fixed for a
//!   session) fires the configured `[forgetting]` policy's sweep
//!   immediately — same [`SweepKind`], same parameters, the pressure
//!   trigger only changes *when*, never *what*. The lane's `ForgetClock`
//!   is not touched, so the event-cadence sweeps keep their schedule.
//! * **Cold-lane spill** (per worker): if the resident lanes together
//!   still exceed the budget at a window boundary (or right before a
//!   metrics reply — so reported resident bytes respect the budget by
//!   construction), the coldest lanes (smallest applied watermark) are
//!   serialized through the *same lane frame* checkpoints and rescale
//!   use and parked in a [`SpillStore`]. Spilled frames are offered to
//!   the supervisor as checkpoints (they are valid ones), and the lane
//!   faults back in transparently on its next event, query, import, or
//!   export — results are byte-identical to a run that never spilled.

use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::algorithms::{build_model, StreamingRecommender};
use crate::config::{Forgetting, RunConfig};
use crate::coordinator::router::StateGrid;
use crate::data::types::{ItemId, Rating, StateSizes, UserId};
use crate::engine::{Receiver, Sender, WakeSignal};
use crate::eval::{HitSample, Prequential, WorkerReport};
use crate::state::spill::{SpillMeta, SpillStore};
use crate::state::{ForgetClock, SweepKind};
use crate::util::histogram::Histogram;
use crate::util::wire::{WireError, WireReader, WireWriter};

/// Event envelope: global sequence number + the rating.
#[derive(Debug, Clone, Copy)]
pub(crate) struct Envelope {
    /// Global stream sequence number (assigned at ingest).
    pub(crate) seq: u64,
    /// The stream element.
    pub(crate) rating: Rating,
}

/// One serialized lane: the virtual-cell id plus its lane frame
/// (watermark + counters + clock + model partition).
pub(crate) struct LaneSnapshot {
    /// Virtual grid cell id.
    pub(crate) lane: u64,
    /// Encoded lane frame (see the module docs).
    pub(crate) bytes: Vec<u8>,
}

/// A retiring worker's reply to `Export`: every lane it hosted.
pub(crate) struct WorkerExport {
    /// Session-unique id of the worker that answered (the supervisor
    /// maps it back to a slot when collecting a fan-out of exports).
    pub(crate) ord: usize,
    /// One snapshot per hosted lane.
    pub(crate) lanes: Vec<LaneSnapshot>,
}

/// A periodic lane checkpoint, worker → supervisor.
pub(crate) struct CheckpointMsg {
    /// Worker that took the checkpoint (logging only).
    pub(crate) ord: usize,
    /// Virtual grid cell the frame snapshots.
    pub(crate) lane: u64,
    /// Encoded lane frame.
    pub(crate) bytes: Vec<u8>,
}

/// Everything a worker can be asked to do (the control-plane protocol).
/// Queries do *not* travel here — they have their own channel
/// ([`QueryMsg`]) that bypasses this FIFO entirely.
pub(crate) enum WorkerMsg {
    /// One stream event (the learning loop).
    Event(Envelope),
    /// Live counter snapshot over `reply`; never blocks the stream for
    /// longer than one reply-channel send.
    MetricsSnapshot {
        /// Reply channel back to the coordinator.
        reply: Sender<WorkerSnapshot>,
    },
    /// Terminal migration probe: serialize every hosted lane, send the
    /// snapshots over `reply`, then drain out and report. Queued behind
    /// all prior events (FIFO), so the snapshot covers the full accepted
    /// prefix of the stream.
    Export {
        /// Reply channel back to the coordinator.
        reply: Sender<WorkerExport>,
    },
    /// Install a lane frame produced by `Export` (rescale) or by a
    /// checkpoint (crash recovery). Always queued ahead of any
    /// subsequent event on the same FIFO, so the state is in place
    /// before new learning touches the lane.
    Import {
        /// Virtual grid cell to install.
        lane: u64,
        /// Encoded lane frame.
        bytes: Vec<u8>,
        /// `true` on the recovery path: the frame's counters become the
        /// lane's counters (the crashed worker's report is gone, so the
        /// replacement must re-own them). `false` on the rescale path:
        /// the retiring worker keeps its totals in its retired report,
        /// and the importing worker counts from zero.
        restore_counters: bool,
    },
}

/// An online recommendation query on the worker's dedicated serving
/// lane. Queries bypass the event FIFO — a backlog of un-trained events
/// never queues a query behind it — and are answered from the local lane
/// models via the frozen
/// [`serve`](crate::algorithms::StreamingRecommender::serve) read: never
/// trains them and never moves serialized state (bounded-staleness
/// caches are served as-is), so query timing cannot perturb the event
/// timeline that crash recovery replays.
pub(crate) struct QueryMsg {
    /// User to recommend for.
    pub(crate) user: UserId,
    /// Per-lane list length to return.
    pub(crate) n: usize,
    /// Read-your-writes fence: `seq + 1` of the last event the
    /// coordinator routed to this worker before issuing the query (`0` =
    /// none). The actor parks the query until its applied watermark
    /// reaches the fence, so bypassing the FIFO never lets a query
    /// observe *less* than the ingested prefix — only sooner.
    pub(crate) fence: u64,
    /// Reply channel back to the coordinator.
    pub(crate) reply: Sender<ReplicaAnswer>,
}

/// One replica's answer to a query: the ranked local top-N of every lane
/// of the user's grid column hosted here, plus the union of the user's
/// locally-rated items. Reply arrival order is irrelevant:
/// [`merge_topn`](crate::eval::merge_topn)'s key (best rank, votes, item
/// id) is order-independent, as is the union of the rated sets — and the
/// *lists themselves* are per-lane, so the merged result does not depend
/// on how lanes are currently placed on workers (the rescale-equivalence
/// guarantee).
pub(crate) struct ReplicaAnswer {
    /// Ranked local top-N per hosted lane of the user's column (local
    /// rated items already excluded; empty lists elided).
    pub(crate) lists: Vec<Vec<ItemId>>,
    /// Items this user has rated on this replica, for global exclusion.
    pub(crate) rated: Vec<ItemId>,
}

/// Message from workers to the collector.
pub(crate) enum CollectorMsg {
    /// A batch of prequential outcomes.
    Hits(Vec<HitSample>),
    /// Worker finished draining (reports travel via thread join).
    Done {
        /// Session-unique id of the drained worker.
        worker_id: usize,
    },
}

/// Live per-worker counters — a moment-in-time view of what
/// [`WorkerReport`] reports at shutdown.
#[derive(Debug, Clone)]
pub struct WorkerSnapshot {
    /// Session-unique worker id (ids keep counting across rescale
    /// generations and crash recoveries, so retired, crashed, and live
    /// workers never collide).
    pub worker_id: usize,
    /// Events processed so far (summed over hosted lanes; a worker
    /// respawned by crash recovery resumes its lanes' checkpointed
    /// counters, so the aggregate never regresses).
    pub processed: u64,
    /// Prequential hits so far.
    pub hits: u64,
    /// Serving queries answered so far. A serving-traffic diagnostic,
    /// not an exactly-once counter: it is not checkpointed (a crash
    /// loses the dead worker's tally), and a recovery retry re-asks the
    /// surviving replicas of an in-flight fan-out (so it can also count
    /// a little high around a crash).
    pub queries: u64,
    /// Lane models currently hosted, resident *and* spilled (1 per
    /// worker in the default grid-equals-topology configuration).
    pub lanes: u64,
    /// Current state-entry counts (summed over hosted lanes, including
    /// spilled ones — a spilled lane's entries are still this worker's
    /// logical state).
    pub state: StateSizes,
    /// Resident lane bytes (the models' deterministic accounting,
    /// exact as of this reply — lanes are re-measured, and the
    /// `[memory]` budget re-enforced, right before answering). Excludes
    /// spilled lanes; with spill enabled this is `<=` the budget by
    /// construction.
    pub state_bytes: u64,
    /// Lanes currently parked in the spill store.
    pub spilled_lanes: u64,
    /// Logical bytes of the spilled lanes (their `state_bytes` at spill
    /// time).
    pub spilled_bytes: u64,
    /// Cumulative lane spills performed by this worker (monotone).
    pub spills: u64,
    /// Cumulative lane fault-ins performed by this worker (monotone).
    pub spill_faultins: u64,
}

/// Deterministic fault injection: panic a worker at an exact stream
/// position. Exactly one worker processes any given global sequence
/// number, so "kill at seq S" kills exactly one worker, reproducibly,
/// wherever the routing places S.
#[derive(Debug, Clone, Copy)]
pub(crate) struct ChaosPolicy {
    /// Panic before applying the event with this global seq.
    kill_at_seq: Option<u64>,
    /// Defer the panic to the first checkpoint attempt at/after the kill
    /// seq (the "kill during checkpoint" torture: the half-taken
    /// checkpoint must never reach the supervisor).
    in_checkpoint: bool,
}

impl ChaosPolicy {
    /// No injected faults (the production policy, and what respawned
    /// workers get — a fired kill never re-fires on replay).
    pub(crate) fn none() -> Self {
        Self { kill_at_seq: None, in_checkpoint: false }
    }

    /// Policy from the `[fault]` chaos knobs.
    pub(crate) fn from_config(cfg: &RunConfig) -> Self {
        Self {
            kill_at_seq: cfg.fault_chaos_kill_seq,
            in_checkpoint: cfg.fault_chaos_kill_in_checkpoint,
        }
    }

    /// Rebuild a policy from its two knobs — the networked transport
    /// ships the armed policy inside its hello frame so a remote host
    /// arms exactly what an in-proc spawn would have.
    pub(crate) fn from_parts(kill_at_seq: Option<u64>, in_checkpoint: bool) -> Self {
        Self { kill_at_seq, in_checkpoint }
    }

    /// The armed kill position, if any.
    pub(crate) fn kill_at_seq(&self) -> Option<u64> {
        self.kill_at_seq
    }

    /// Whether the kill defers to the next checkpoint attempt.
    pub(crate) fn kill_in_checkpoint(&self) -> bool {
        self.in_checkpoint
    }
}

// ---------------------------------------------------------------------
// The lane frame: watermark + counters + clock + model partition.
// ---------------------------------------------------------------------

/// Lane frame format version.
const LANE_FRAME_VERSION: u8 = 1;

/// Fixed header size: version(1) + has_watermark(1) + watermark(8) +
/// processed/hits/evicted/sweeps (4×8) + clock triple (3×8).
pub(crate) const LANE_FRAME_HEADER: usize = 2 + 8 + 4 * 8 + 3 * 8;

/// Byte range of the four baseline-relative counters inside the header
/// (`processed`, `hits`, `evicted`, `sweeps`) — the supervisor zeroes
/// this range when it converts a rescale export into a checkpoint, so a
/// later recovery restores counters consistent with the importing
/// generation's zero baseline.
const LANE_FRAME_COUNTERS: std::ops::Range<usize> = 10..42;

/// Decoded lane frame header + the nested model partition bytes.
pub(crate) struct LaneFrame<'a> {
    /// Global seq of the last event applied to the lane (`None` only for
    /// a lane that was imported and never touched since).
    pub(crate) watermark: Option<u64>,
    /// Events applied since the lane's counter baseline.
    pub(crate) processed: u64,
    /// Prequential hits since the baseline.
    pub(crate) hits: u64,
    /// Entries evicted by forgetting sweeps since the baseline.
    pub(crate) evicted: u64,
    /// Forgetting sweeps run since the baseline.
    pub(crate) sweeps: u64,
    /// [`ForgetClock::state`] triple (lifetime, travels verbatim).
    pub(crate) clock: (u64, u64, u64),
    /// The model's `export_partition` bytes.
    pub(crate) model: &'a [u8],
}

/// Encode one lane into its wire frame. The model partition is
/// serialized first so the writer can be sized exactly — one allocation
/// per checkpoint for the header+body copy, no growth doublings.
fn encode_lane_frame(lane: &Lane) -> Vec<u8> {
    let model = lane.model.export_partition(&|_| true);
    let mut w = WireWriter::with_capacity(LANE_FRAME_HEADER + model.len());
    w.u8(LANE_FRAME_VERSION);
    w.u8(u8::from(lane.watermark.is_some()));
    w.u64(lane.watermark.unwrap_or(0));
    w.u64(lane.processed);
    w.u64(lane.hits);
    w.u64(lane.evicted);
    w.u64(lane.sweeps);
    let (ev, ts, sw) = lane.clock.state();
    w.u64(ev);
    w.u64(ts);
    w.u64(sw);
    w.bytes(&model);
    w.into_bytes()
}

/// Decode a lane frame (bounds-checked; a truncated or version-skewed
/// frame surfaces as an `Err`, never a panic).
pub(crate) fn decode_lane_frame(bytes: &[u8]) -> Result<LaneFrame<'_>, WireError> {
    let mut r = WireReader::new(bytes);
    let version = r.u8()?;
    if version != LANE_FRAME_VERSION {
        return Err(WireError {
            pos: 0,
            msg: format!(
                "lane frame version {version}, expected {LANE_FRAME_VERSION}"
            ),
        });
    }
    let has_watermark = r.u8()? != 0;
    let watermark_raw = r.u64()?;
    let processed = r.u64()?;
    let hits = r.u64()?;
    let evicted = r.u64()?;
    let sweeps = r.u64()?;
    let clock = (r.u64()?, r.u64()?, r.u64()?);
    Ok(LaneFrame {
        watermark: has_watermark.then_some(watermark_raw),
        processed,
        hits,
        evicted,
        sweeps,
        clock,
        model: r.rest(),
    })
}

/// Peek a frame's watermark without decoding the model payload. `None`
/// for malformed frames too — the caller then replays from scratch,
/// which is safe (just slower).
pub(crate) fn lane_frame_watermark(bytes: &[u8]) -> Option<u64> {
    if bytes.len() < LANE_FRAME_HEADER || bytes[0] != LANE_FRAME_VERSION {
        return None;
    }
    if bytes[1] == 0 {
        return None;
    }
    let mut raw = [0u8; 8];
    raw.copy_from_slice(&bytes[2..10]);
    Some(u64::from_le_bytes(raw))
}

/// Zero the baseline-relative counters of an encoded frame in place (the
/// rescale-export → checkpoint conversion). No-op on malformed frames.
pub(crate) fn zero_lane_frame_counters(bytes: &mut [u8]) {
    if bytes.len() >= LANE_FRAME_HEADER && bytes[0] == LANE_FRAME_VERSION {
        bytes[LANE_FRAME_COUNTERS].fill(0);
    }
}

// ---------------------------------------------------------------------
// The lane and the actor.
// ---------------------------------------------------------------------

/// One hosted lane: the model plus everything placement-independent
/// that must travel with it.
struct Lane {
    model: Box<dyn StreamingRecommender>,
    /// Per-lane forgetting trigger: advances only on this lane's events,
    /// so the sweep cadence is identical wherever the lane is hosted.
    clock: ForgetClock,
    /// Events applied since the counter baseline (zero at lane build and
    /// at a rescale import; restored verbatim by a recovery import).
    processed: u64,
    /// Prequential hits since the baseline.
    hits: u64,
    /// Entries evicted by sweeps since the baseline.
    evicted: u64,
    /// Sweeps run since the baseline.
    sweeps: u64,
    /// Global seq of the last event applied.
    watermark: Option<u64>,
    /// Events applied since the last checkpoint attempt that was either
    /// accepted by the supervisor or deliberately deferred (full
    /// channel); the next periodic checkpoint is due at
    /// `fault.checkpoint_interval`.
    since_ckpt: u64,
    /// Whether any checkpoint (or import, which is one) covers the lane.
    checkpointed: bool,
    /// Cached `state_bytes` of the model — refreshed every
    /// `memory.check_events` events on the lane, after sweeps, after
    /// imports/fault-ins, and exactly before metrics replies. Budget
    /// enforcement sums these, so accounting granularity is the check
    /// cadence, never a per-event full-model walk.
    bytes: u64,
}

impl Lane {
    fn new(cfg: &RunConfig, lane_id: u64) -> Result<Self> {
        let model = build_model(cfg, lane_id as usize)?;
        let bytes = model.state_bytes();
        Ok(Self {
            model,
            clock: ForgetClock::new(cfg.forgetting),
            processed: 0,
            hits: 0,
            evicted: 0,
            sweeps: 0,
            watermark: None,
            since_ckpt: 0,
            checkpointed: false,
            bytes,
        })
    }
}

/// A supervised worker: owns the event FIFO, the control messages, and
/// the per-lane models of one physical worker. Constructed on the
/// coordinator side, consumed by [`WorkerActor::run`] inside the worker
/// thread (models and backends are built in-thread; PJRT handles are
/// `!Send`).
pub(crate) struct WorkerActor {
    ord: usize,
    cfg: RunConfig,
    grid: StateGrid,
    rx: Receiver<WorkerMsg>,
    /// The dedicated serving lane: queries arrive here, never on `rx`.
    query_rx: Receiver<QueryMsg>,
    /// Shared wakeup for both inputs — the loop sleeps on this single
    /// latch instead of blocking inside either channel.
    signal: WakeSignal,
    col_tx: Sender<CollectorMsg>,
    /// `Some` iff fault tolerance is enabled; checkpoints flow here.
    ckpt_tx: Option<Sender<CheckpointMsg>>,
    chaos: ChaosPolicy,
}

impl WorkerActor {
    /// Assemble an actor for one worker slot.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(
        ord: usize,
        cfg: RunConfig,
        grid: StateGrid,
        rx: Receiver<WorkerMsg>,
        query_rx: Receiver<QueryMsg>,
        signal: WakeSignal,
        col_tx: Sender<CollectorMsg>,
        ckpt_tx: Option<Sender<CheckpointMsg>>,
        chaos: ChaosPolicy,
    ) -> Self {
        Self { ord, cfg, grid, rx, query_rx, signal, col_tx, ckpt_tx, chaos }
    }

    /// The worker body: prequential learning loop + serving + snapshots
    /// + checkpoints + migration over the hosted lanes.
    ///
    /// Two inputs, one sleep: each wakeup first drains the serving lane
    /// (`query_rx`) — answering every query whose fence the applied
    /// watermark already covers, parking the rest — then moves
    /// *everything* queued on the event FIFO into a local inbox in one
    /// critical section and works through it in FIFO order. The train
    /// loop stays per-event (prequential accounting is unchanged) but
    /// lock transitions and wakeups are amortized over the window; with
    /// both inputs empty the loop sleeps on the shared [`WakeSignal`]
    /// (never inside one channel, which would starve the other).
    /// Control messages (snapshots, imports, exports) still sit at their
    /// FIFO position among the events, so they observe exactly the
    /// events ingested before them. `Export` is terminal: reply, then
    /// drain out.
    pub(crate) fn run(self) -> Result<WorkerReport> {
        let WorkerActor {
            ord,
            cfg,
            grid,
            rx,
            query_rx,
            signal,
            col_tx,
            ckpt_tx,
            chaos,
        } = self;
        let ckpt_interval = cfg.fault_checkpoint_interval.max(1);
        // [memory] plumbing (module docs §Memory): the per-lane pressure
        // slice is derived from the fixed state grid, so it is identical
        // wherever a lane is hosted. `.max(1)` keeps a sub-lane-sized
        // budget meaning "always under pressure" rather than "disabled".
        let budget = cfg.memory_budget_bytes;
        let lane_budget = if budget > 0 {
            (budget / grid.n_lanes().max(1)).max(1)
        } else {
            0
        };
        let check_events = cfg.memory_check_events.max(1);
        let mut spill_store: Option<SpillStore> = (budget > 0
            && cfg.memory_spill)
            .then(|| SpillStore::new(&cfg.memory_spill_dir, ord));
        // Counters of lanes that left via `Export` while spilled: their
        // frames went to the new owners (counting from zero there), so
        // this retiring worker's report must keep the totals.
        let mut banked = (0u64, 0u64, 0u64, 0u64);
        let mut lanes: BTreeMap<u64, Lane> = BTreeMap::new();
        let mut preq = Prequential::new(cfg.top_n, cfg.recall_window);
        let mut latency = Histogram::new();
        let mut batch: Vec<HitSample> = Vec::with_capacity(256);
        let mut inbox: Vec<WorkerMsg> =
            Vec::with_capacity(cfg.ingest_batch_size.clamp(1, 4096));
        let mut queries = 0u64;
        let mut recommend_ns = 0u64;
        let mut update_ns = 0u64;
        let mut exported = false;
        // Armed once the chaos kill seq passes in `in_checkpoint` mode;
        // the next checkpoint attempt then panics mid-checkpoint.
        let mut chaos_ckpt_armed = false;
        // Read-your-writes watermark: `seq + 1` of the newest event this
        // actor has applied (or deliberately filtered), advanced by
        // imports too. A query whose fence is at or below it is
        // answerable now; otherwise it parks until ingest catches up.
        // Fences are not monotone across coordinator threads, so the
        // parked queue is re-scanned whole after every event window.
        let mut applied = 0u64;
        let mut parked: VecDeque<QueryMsg> = VecDeque::new();
        let mut qbuf: Vec<QueryMsg> = Vec::new();
        const IDLE_WAIT: Duration = Duration::from_millis(10);

        'drain: loop {
            // Epoch read BEFORE draining: anything arriving after it
            // bumps the epoch, so the idle wait below can never sleep
            // through a message (see `WakeSignal`).
            let seen = signal.epoch();
            let mut served = false;
            if query_rx.try_drain(&mut qbuf) > 0 {
                for q in qbuf.drain(..) {
                    if q.fence <= applied {
                        answer_query(
                            &mut lanes,
                            &mut spill_store,
                            &cfg,
                            &grid,
                            &mut queries,
                            q,
                        )?;
                        served = true;
                    } else {
                        parked.push_back(q);
                    }
                }
            }
            if rx.try_drain(&mut inbox) == 0 {
                if served {
                    // Queries may have faulted spilled lanes back in;
                    // re-enforce the budget before sleeping on them.
                    enforce_budget(
                        &mut lanes,
                        &mut spill_store,
                        budget,
                        ord,
                        &ckpt_tx,
                        &col_tx,
                        &mut batch,
                    )?;
                } else {
                    if rx.is_ended() {
                        // End-of-stream: the coordinator dropped its
                        // event sender. Any still-parked query waits on
                        // events that can no longer arrive; dropping it
                        // closes its reply channel, and the serving
                        // fan-out degrades instead of deadlocking.
                        break 'drain;
                    }
                    let t0 = Instant::now();
                    signal.wait_past(seen, IDLE_WAIT);
                    rx.record_wait(t0.elapsed().as_nanos() as u64);
                }
                continue 'drain;
            }
            for msg in inbox.drain(..) {
                match msg {
                    WorkerMsg::Event(env) => {
                        // Advance the fence watermark even for events the
                        // lane filter below skips: a filtered duplicate
                        // was applied before the snapshot that guards it,
                        // so for read-your-writes purposes it *is*
                        // applied.
                        applied = applied.max(env.seq + 1);
                        if chaos.kill_at_seq == Some(env.seq) {
                            // The in-checkpoint variant needs a checkpoint
                            // path to fire in; without fault tolerance
                            // there are no checkpoints, so it degenerates
                            // to the plain event kill instead of silently
                            // never firing.
                            if chaos.in_checkpoint && ckpt_tx.is_some() {
                                chaos_ckpt_armed = true;
                            } else {
                                panic!(
                                    "chaos: injected crash on worker {ord} \
                                     before event seq {}",
                                    env.seq
                                );
                            }
                        }
                        let lane_id =
                            grid.lane(env.rating.user, env.rating.item);
                        // A spilled lane faults back in before learning
                        // touches it (transparent disk tier).
                        fault_in(&mut lanes, &mut spill_store, &cfg, lane_id)?;
                        let lane = lane_entry(&mut lanes, &cfg, lane_id)?;
                        // Watermark filter (exactly-once): an event at or
                        // below the lane's high-water seq was already
                        // applied before the snapshot this lane was
                        // restored from — re-applying it would double-
                        // train. The supervisor already filters its
                        // replay, so this is a defensive second fence.
                        if lane.watermark.is_some_and(|w| env.seq <= w) {
                            continue;
                        }
                        let out = preq.step(lane.model.as_mut(), &env.rating);
                        latency.record(out.recommend_ns + out.update_ns);
                        recommend_ns += out.recommend_ns;
                        update_ns += out.update_ns;
                        lane.processed += 1;
                        if out.hit {
                            lane.hits += 1;
                        }
                        lane.watermark = Some(env.seq);
                        lane.since_ckpt += 1;
                        batch.push(HitSample { seq: env.seq, hit: out.hit });
                        if batch.len() >= 256 {
                            let full = std::mem::replace(
                                &mut batch,
                                Vec::with_capacity(256),
                            );
                            let _ = col_tx.send(CollectorMsg::Hits(full));
                        }
                        if let Some(kind) = lane.clock.on_event(env.rating.ts)
                        {
                            lane.sweeps += 1;
                            lane.evicted += lane.model.sweep(kind);
                            if budget > 0 {
                                lane.bytes = lane.model.state_bytes();
                            }
                        }
                        // Memory pressure (module docs §Memory): at the
                        // check cadence, re-measure the lane; over its
                        // budget slice, fire the configured policy's
                        // sweep now. Cadence keys off `lane.processed`
                        // (travels in lane frames) and the slice off the
                        // fixed grid, so pressure sweeps replay
                        // identically across placements.
                        if lane_budget > 0
                            && lane.processed % check_events == 0
                        {
                            lane.bytes = lane.model.state_bytes();
                            if lane.bytes > lane_budget {
                                if let Some(kind) = pressure_sweep(
                                    cfg.forgetting,
                                    env.rating.ts,
                                ) {
                                    lane.sweeps += 1;
                                    lane.evicted += lane.model.sweep(kind);
                                    lane.bytes = lane.model.state_bytes();
                                }
                            }
                        }
                        // Periodic per-lane checkpoint: eagerly on the
                        // lane's first event (a tiny frame buys replay-
                        // from-checkpoint instead of replay-from-zero),
                        // then every `fault.checkpoint_interval` events.
                        if let Some(tx) = &ckpt_tx {
                            if !lane.checkpointed
                                || lane.since_ckpt >= ckpt_interval
                            {
                                let bytes = encode_lane_frame(lane);
                                if chaos_ckpt_armed {
                                    panic!(
                                        "chaos: injected crash on worker \
                                         {ord} during checkpoint of lane \
                                         {lane_id}"
                                    );
                                }
                                // The frame's watermark covers every
                                // outcome evaluated so far on this worker;
                                // hand the buffered hit samples to the
                                // collector *before* the checkpoint can
                                // land. Otherwise a crash right after the
                                // handoff loses samples at or below the
                                // watermark, which the replay (it starts
                                // past the watermark) can never
                                // regenerate.
                                if !batch.is_empty() {
                                    let full = std::mem::replace(
                                        &mut batch,
                                        Vec::with_capacity(256),
                                    );
                                    let _ =
                                        col_tx.send(CollectorMsg::Hits(full));
                                }
                                // Never block the learning loop on a slow
                                // supervisor: a full channel defers the
                                // checkpoint to the next event.
                                let msg = CheckpointMsg {
                                    ord,
                                    lane: lane_id,
                                    bytes,
                                };
                                if tx.try_send(msg).is_ok() {
                                    lane.since_ckpt = 0;
                                    lane.checkpointed = true;
                                } else if lane.checkpointed {
                                    // Channel full. Re-encoding the whole
                                    // model every event until the
                                    // coordinator drains would be
                                    // pathological; defer a full interval
                                    // instead — the later frame covers
                                    // strictly more anyway. (A lane with
                                    // no checkpoint at all keeps retrying:
                                    // its frame is still tiny and the
                                    // eager first checkpoint is what caps
                                    // replay-from-zero windows.)
                                    lane.since_ckpt = 0;
                                }
                            }
                        }
                    }
                    WorkerMsg::MetricsSnapshot { reply } => {
                        // Exact accounting at probe time: re-measure
                        // every resident lane, then re-enforce the
                        // budget, so the reported resident bytes are
                        // both exact and (with spill on) within budget
                        // by construction.
                        for lane in lanes.values_mut() {
                            lane.bytes = lane.model.state_bytes();
                        }
                        enforce_budget(
                            &mut lanes,
                            &mut spill_store,
                            budget,
                            ord,
                            &ckpt_tx,
                            &col_tx,
                            &mut batch,
                        )?;
                        let mut snap = WorkerSnapshot {
                            worker_id: ord,
                            processed: lanes
                                .values()
                                .map(|l| l.processed)
                                .sum(),
                            hits: lanes.values().map(|l| l.hits).sum(),
                            queries,
                            lanes: lanes.len() as u64,
                            state: sum_state(&lanes),
                            state_bytes: lanes
                                .values()
                                .map(|l| l.bytes)
                                .sum(),
                            spilled_lanes: 0,
                            spilled_bytes: 0,
                            spills: 0,
                            spill_faultins: 0,
                        };
                        if let Some(store) = &spill_store {
                            snap.lanes += store.len() as u64;
                            snap.spilled_lanes = store.len() as u64;
                            snap.spilled_bytes = store.spilled_bytes();
                            snap.spills = store.spills();
                            snap.spill_faultins = store.faultins();
                            for id in store.lanes() {
                                let m = store.meta(id).expect("listed");
                                snap.processed += m.processed;
                                snap.hits += m.hits;
                                snap.state.users += m.sizes.users;
                                snap.state.items += m.sizes.items;
                                snap.state.aux += m.sizes.aux;
                            }
                        }
                        let _ = reply.send(snap);
                    }
                    WorkerMsg::Import { lane, bytes, restore_counters } => {
                        // The incoming frame overwrites the lane
                        // wholesale; a spilled copy is stale — drop it
                        // unread instead of faulting it in first.
                        if let Some(store) = &mut spill_store {
                            store.remove(lane as usize);
                        }
                        let slot = lane_entry(&mut lanes, &cfg, lane)?;
                        let frame = decode_lane_frame(&bytes)?;
                        slot.model.import_partition(frame.model)?;
                        let (ev, ts, sw) = frame.clock;
                        slot.clock.restore(ev, ts, sw);
                        slot.watermark = frame.watermark;
                        // The frame covers the prefix up to its
                        // watermark: queries fenced at or below it are
                        // answerable without replaying those events.
                        if let Some(w) = frame.watermark {
                            applied = applied.max(w + 1);
                        }
                        if restore_counters {
                            slot.processed = frame.processed;
                            slot.hits = frame.hits;
                            slot.evicted = frame.evicted;
                            slot.sweeps = frame.sweeps;
                        }
                        // The imported frame *is* a checkpoint of this
                        // lane (the supervisor stores it), so the next
                        // periodic one is an interval away.
                        slot.since_ckpt = 0;
                        slot.checkpointed = true;
                        slot.bytes = slot.model.state_bytes();
                    }
                    WorkerMsg::Export { reply } => {
                        // Terminal: everything ingested before this probe
                        // has been processed (FIFO), so the snapshots cover
                        // the complete accepted prefix. The coordinator
                        // sends nothing after Export, so breaking out drops
                        // no work. Parked queries the prefix satisfies are
                        // answered first; the rest wait on events that
                        // will never arrive on this generation — dropping
                        // them closes their reply channels and the
                        // serving fan-out degrades/retries against the
                        // next generation instead of deadlocking.
                        for _ in 0..parked.len() {
                            let q = parked.pop_front().expect("len-bounded");
                            if q.fence <= applied {
                                answer_query(
                                    &mut lanes,
                                    &mut spill_store,
                                    &cfg,
                                    &grid,
                                    &mut queries,
                                    q,
                                )?;
                            }
                        }
                        let mut out: Vec<LaneSnapshot> = lanes
                            .iter()
                            .map(|(id, lane)| LaneSnapshot {
                                lane: *id,
                                bytes: encode_lane_frame(lane),
                            })
                            .collect();
                        // Spilled lanes export *verbatim*: nothing has
                        // touched a lane since it was spilled, so its
                        // parked frame — watermark, counters, clock,
                        // model — is exactly the frame encoding it now
                        // would produce. Their counters are banked into
                        // this retiring worker's report (the importing
                        // generation counts from zero).
                        if let Some(store) = &mut spill_store {
                            for id in store.lanes() {
                                let m = store.meta(id).expect("listed");
                                banked.0 += m.processed;
                                banked.1 += m.hits;
                                banked.2 += m.evicted;
                                banked.3 += m.sweeps;
                                if let Some(bytes) = store.take(id)? {
                                    out.push(LaneSnapshot {
                                        lane: id as u64,
                                        bytes,
                                    });
                                }
                            }
                        }
                        exported = true;
                        let _ = reply.send(WorkerExport { ord, lanes: out });
                        break 'drain;
                    }
                }
            }
            // Events applied this window may have released parked
            // queries; one pass over the queue answers the ready ones
            // and keeps the rest in arrival order.
            for _ in 0..parked.len() {
                let q = parked.pop_front().expect("len-bounded");
                if q.fence <= applied {
                    answer_query(
                        &mut lanes,
                        &mut spill_store,
                        &cfg,
                        &grid,
                        &mut queries,
                        q,
                    )?;
                } else {
                    parked.push_back(q);
                }
            }
            // Window boundary: if the resident lanes (per their cached
            // cadence-fresh figures) exceed the worker budget even after
            // pressure sweeps, tier the coldest out to disk.
            enforce_budget(
                &mut lanes,
                &mut spill_store,
                budget,
                ord,
                &ckpt_tx,
                &col_tx,
                &mut batch,
            )?;
        }
        if !batch.is_empty() {
            let _ = col_tx.send(CollectorMsg::Hits(batch));
        }
        // Final rollup: resident lanes + still-spilled lanes (their
        // counters live in the spill metadata) + counters banked when
        // spilled lanes left via Export.
        let mut processed: u64 = lanes.values().map(|l| l.processed).sum();
        let mut hits: u64 = lanes.values().map(|l| l.hits).sum();
        let mut sweeps: u64 = lanes.values().map(|l| l.sweeps).sum();
        let mut evicted: u64 = lanes.values().map(|l| l.evicted).sum();
        // An exported worker handed its state off; reporting it again
        // would double-count entries that now live on the new workers.
        let mut state = if exported {
            StateSizes::default()
        } else {
            sum_state(&lanes)
        };
        // Exact (re-measured) logical bytes, not the cached figures: the
        // final report is the placement-independent accounting record.
        let mut state_bytes: u64 = if exported {
            0
        } else {
            lanes.values().map(|l| l.model.state_bytes()).sum()
        };
        let (mut spills, mut spill_faultins) = (0u64, 0u64);
        if let Some(store) = &spill_store {
            spills = store.spills();
            spill_faultins = store.faultins();
            for id in store.lanes() {
                let m = store.meta(id).expect("listed");
                processed += m.processed;
                hits += m.hits;
                sweeps += m.sweeps;
                evicted += m.evicted;
                state.users += m.sizes.users;
                state.items += m.sizes.items;
                state.aux += m.sizes.aux;
                state_bytes += m.bytes;
            }
        }
        processed += banked.0;
        hits += banked.1;
        evicted += banked.2;
        sweeps += banked.3;
        let report = WorkerReport {
            worker_id: ord,
            processed,
            hits,
            queries,
            state,
            state_bytes,
            latency,
            sweeps,
            evicted,
            spills,
            spill_faultins,
            recommend_ns,
            update_ns,
            windows: preq.windowed().stats().to_vec(),
        };
        let _ = col_tx.send(CollectorMsg::Done { worker_id: ord });
        Ok(report)
    }
}

/// Fetch-or-build the lane hosting cell `id` (one map lookup via the
/// entry API — shared by the event hot path and the import path so lane
/// construction can never diverge between them).
fn lane_entry<'a>(
    lanes: &'a mut BTreeMap<u64, Lane>,
    cfg: &RunConfig,
    id: u64,
) -> Result<&'a mut Lane> {
    Ok(match lanes.entry(id) {
        std::collections::btree_map::Entry::Vacant(v) => {
            v.insert(Lane::new(cfg, id)?)
        }
        std::collections::btree_map::Entry::Occupied(o) => o.into_mut(),
    })
}

/// The sweep a memory-pressure trigger fires: the *same* kinds with the
/// *same* parameters as the clock-driven path derives from the policy —
/// pressure only changes *when* a sweep runs, never *what* it evicts
/// (the determinism the equivalence suite leans on). `Forgetting::None`
/// yields no sweep: with no policy configured, only spill can honor a
/// budget (see `Cluster::metrics`'s warn-once and the scenario driver's
/// rejection).
fn pressure_sweep(policy: Forgetting, now_ts: u64) -> Option<SweepKind> {
    match policy {
        Forgetting::None => None,
        Forgetting::Lru { max_idle_secs, .. } => Some(SweepKind::Lru {
            cutoff_ts: now_ts.saturating_sub(max_idle_secs),
        }),
        Forgetting::Lfu { min_freq, .. } => {
            Some(SweepKind::Lfu { min_freq })
        }
        Forgetting::Decay { factor, .. } => {
            Some(SweepKind::Decay { factor })
        }
    }
}

/// Fault a spilled lane back in: decode its parked frame and rebuild
/// the lane exactly — model (including its RNG stream), clock cadence,
/// watermark, and live counters all travel in the frame, so the lane is
/// byte-identical to one that never spilled. No-op if the lane is not
/// spilled (or spill is off).
fn fault_in(
    lanes: &mut BTreeMap<u64, Lane>,
    spill: &mut Option<SpillStore>,
    cfg: &RunConfig,
    id: u64,
) -> Result<()> {
    let Some(store) = spill else { return Ok(()) };
    let Some(frame_bytes) = store.take(id as usize)? else {
        return Ok(());
    };
    let lane = lane_entry(lanes, cfg, id)?;
    let frame = decode_lane_frame(&frame_bytes)?;
    lane.model.import_partition(frame.model)?;
    let (ev, ts, sw) = frame.clock;
    lane.clock.restore(ev, ts, sw);
    lane.watermark = frame.watermark;
    lane.processed = frame.processed;
    lane.hits = frame.hits;
    lane.evicted = frame.evicted;
    lane.sweeps = frame.sweeps;
    lane.bytes = lane.model.state_bytes();
    // The spill frame was offered to the supervisor as a checkpoint at
    // spill time; either way the lane needs no eager first checkpoint —
    // the periodic cadence resumes from here.
    lane.since_ckpt = 0;
    lane.checkpointed = true;
    Ok(())
}

/// Spill coldest lanes (smallest applied watermark; never-touched lanes
/// first) until the worker's resident lane bytes fit `budget`. Called
/// at window boundaries and right before metrics replies, so any
/// reported resident figure respects the budget by construction. With
/// fault tolerance on, each spilled frame is also offered to the
/// supervisor as a checkpoint — a spilled frame *is* a valid lane
/// checkpoint (buffered hit samples are flushed first, the same
/// ordering rule the periodic checkpoint path follows). No-op without
/// a spill store (budget unset, or `memory.spill = false`).
#[allow(clippy::too_many_arguments)]
fn enforce_budget(
    lanes: &mut BTreeMap<u64, Lane>,
    spill: &mut Option<SpillStore>,
    budget: u64,
    ord: usize,
    ckpt_tx: &Option<Sender<CheckpointMsg>>,
    col_tx: &Sender<CollectorMsg>,
    batch: &mut Vec<HitSample>,
) -> Result<()> {
    let Some(store) = spill else { return Ok(()) };
    let mut resident: u64 = lanes.values().map(|l| l.bytes).sum();
    if resident <= budget {
        return Ok(());
    }
    let mut order: Vec<(u64, u64)> = lanes
        .iter()
        .map(|(id, l)| (l.watermark.map_or(0, |w| w + 1), *id))
        .collect();
    order.sort_unstable();
    for (_, id) in order {
        if resident <= budget {
            break;
        }
        let lane = lanes.get(&id).expect("id listed from lanes");
        let cached = lane.bytes;
        let frame = encode_lane_frame(lane);
        let meta = SpillMeta {
            bytes: lane.model.state_bytes(),
            watermark: lane.watermark.map_or(0, |w| w + 1),
            sizes: lane.model.state_sizes(),
            processed: lane.processed,
            hits: lane.hits,
            evicted: lane.evicted,
            sweeps: lane.sweeps,
        };
        if let Some(tx) = ckpt_tx {
            // Same rule as the periodic path: hand buffered hit samples
            // to the collector before a frame covering them can land.
            if !batch.is_empty() {
                let full = std::mem::replace(batch, Vec::with_capacity(256));
                let _ = col_tx.send(CollectorMsg::Hits(full));
            }
            let _ = tx.try_send(CheckpointMsg {
                ord,
                lane: id,
                bytes: frame.clone(),
            });
        }
        store.put(id as usize, &frame, meta)?;
        resident = resident.saturating_sub(cached);
        lanes.remove(&id);
    }
    Ok(())
}

/// Answer one serving query from the hosted lanes: every lane of the
/// user's grid column contributes its ranked local list, plus the
/// user's locally-rated items for global exclusion. `serve` is the
/// frozen read — answering never trains the models, so query timing
/// cannot perturb the event timeline crash recovery replays. Spilled
/// lanes of the queried column fault back in first: the disk tier is
/// transparent to serving too.
fn answer_query(
    lanes: &mut BTreeMap<u64, Lane>,
    spill: &mut Option<SpillStore>,
    cfg: &RunConfig,
    grid: &StateGrid,
    queries: &mut u64,
    q: QueryMsg,
) -> Result<()> {
    *queries += 1;
    let QueryMsg { user, n, reply, .. } = q;
    let col = grid.user_col(user);
    let spilled: Vec<u64> = match spill {
        Some(store) => store
            .lanes()
            .into_iter()
            .map(|id| id as u64)
            .filter(|id| grid.lane_col(*id) == col)
            .collect(),
        None => Vec::new(),
    };
    for id in spilled {
        fault_in(lanes, spill, cfg, id)?;
    }
    let mut lists = Vec::new();
    let mut rated = Vec::new();
    for (lane_id, lane) in lanes.iter_mut() {
        if grid.lane_col(*lane_id) != col {
            continue;
        }
        let items = lane.model.serve(user, n);
        if !items.is_empty() {
            lists.push(items);
        }
        rated.extend(lane.model.rated_items(user));
    }
    let _ = reply.send(ReplicaAnswer { lists, rated });
    Ok(())
}

/// Sum state-entry counts across a worker's hosted lanes.
fn sum_state(lanes: &BTreeMap<u64, Lane>) -> StateSizes {
    let mut total = StateSizes::default();
    for lane in lanes.values() {
        let s = lane.model.state_sizes();
        total.users += s.users;
        total.items += s.items;
        total.aux += s.aux;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Forgetting;

    fn test_lane() -> Lane {
        let cfg = RunConfig {
            forgetting: Forgetting::Lfu { trigger_events: 10, min_freq: 1 },
            ..RunConfig::default()
        };
        let mut lane = Lane::new(&cfg, 3).unwrap();
        lane.model.update(&Rating::new(1, 2, 5.0, 0));
        lane.model.update(&Rating::new(4, 2, 4.0, 1));
        lane.processed = 2;
        lane.hits = 1;
        lane.evicted = 7;
        lane.sweeps = 2;
        lane.watermark = Some(41);
        lane.clock.restore(5, 100, 2);
        lane
    }

    #[test]
    fn lane_frame_round_trips_header_and_model() {
        let lane = test_lane();
        let bytes = encode_lane_frame(&lane);
        assert!(bytes.len() > LANE_FRAME_HEADER, "model payload present");
        let frame = decode_lane_frame(&bytes).unwrap();
        assert_eq!(frame.watermark, Some(41));
        assert_eq!(frame.processed, 2);
        assert_eq!(frame.hits, 1);
        assert_eq!(frame.evicted, 7);
        assert_eq!(frame.sweeps, 2);
        assert_eq!(frame.clock, (5, 100, 2));
        assert_eq!(frame.model, &bytes[LANE_FRAME_HEADER..]);
        assert_eq!(lane_frame_watermark(&bytes), Some(41));
    }

    #[test]
    fn zero_counters_keeps_watermark_clock_and_model() {
        let lane = test_lane();
        let mut bytes = encode_lane_frame(&lane);
        let model_before = bytes[LANE_FRAME_HEADER..].to_vec();
        zero_lane_frame_counters(&mut bytes);
        let frame = decode_lane_frame(&bytes).unwrap();
        assert_eq!(frame.processed, 0);
        assert_eq!(frame.hits, 0);
        assert_eq!(frame.evicted, 0);
        assert_eq!(frame.sweeps, 0);
        assert_eq!(frame.watermark, Some(41), "watermark untouched");
        assert_eq!(frame.clock, (5, 100, 2), "clock untouched");
        assert_eq!(frame.model, &model_before[..], "model untouched");
    }

    #[test]
    fn malformed_frames_error_cleanly() {
        assert!(decode_lane_frame(&[]).is_err());
        assert!(decode_lane_frame(&[9; 4]).is_err(), "bad version");
        let lane = test_lane();
        let bytes = encode_lane_frame(&lane);
        assert!(decode_lane_frame(&bytes[..LANE_FRAME_HEADER - 1]).is_err());
        assert_eq!(lane_frame_watermark(&bytes[..4]), None);
        // Zeroing a malformed frame is a no-op, not a panic.
        let mut short = bytes[..8].to_vec();
        zero_lane_frame_counters(&mut short);
        assert_eq!(&short[..], &bytes[..8]);
    }

    #[test]
    fn header_constant_matches_encoder() {
        // A lane with an empty model still encodes a full header; the
        // constant is what the in-place patch helpers rely on.
        let lane = test_lane();
        let bytes = encode_lane_frame(&lane);
        let model_len = lane.model.export_partition(&|_| true).len();
        assert_eq!(bytes.len(), LANE_FRAME_HEADER + model_len);
    }

    #[test]
    fn property_lane_frame_header_round_trips_and_rejects_prefixes() {
        // Randomized counters/watermarks/clocks round-trip exactly, and
        // every strict prefix of the header decodes to a loud WireError
        // (never a panic) — the contract the networked transport leans
        // on when lane frames cross a socket.
        crate::util::proptest::forall("lane_frame_header", 32, |rng| {
            let mut lane = test_lane();
            lane.processed = rng.next_u64();
            lane.hits = rng.next_bounded(1 << 40);
            lane.evicted = rng.next_bounded(1 << 40);
            lane.sweeps = rng.next_bounded(1 << 20);
            lane.watermark = if rng.next_bounded(4) == 0 {
                None
            } else {
                Some(rng.next_u64())
            };
            let bytes = encode_lane_frame(&lane);
            let frame = decode_lane_frame(&bytes).unwrap();
            assert_eq!(frame.processed, lane.processed);
            assert_eq!(frame.hits, lane.hits);
            assert_eq!(frame.evicted, lane.evicted);
            assert_eq!(frame.sweeps, lane.sweeps);
            assert_eq!(frame.watermark, lane.watermark);
            assert_eq!(lane_frame_watermark(&bytes), lane.watermark);
            for cut in 0..LANE_FRAME_HEADER {
                assert!(
                    decode_lane_frame(&bytes[..cut]).is_err(),
                    "prefix of {cut} bytes must error"
                );
            }
        });
    }

    #[test]
    fn pressure_sweep_reuses_policy_parameters() {
        // The pressure trigger must fire the *same* sweep the clock
        // path would derive from the policy — only the timing differs.
        assert_eq!(pressure_sweep(Forgetting::None, 100), None);
        assert_eq!(
            pressure_sweep(
                Forgetting::Lru { trigger_secs: 5, max_idle_secs: 30 },
                100
            ),
            Some(SweepKind::Lru { cutoff_ts: 70 })
        );
        assert_eq!(
            pressure_sweep(
                Forgetting::Lru { trigger_secs: 5, max_idle_secs: 500 },
                100
            ),
            Some(SweepKind::Lru { cutoff_ts: 0 }),
            "cutoff saturates at zero like the clock path"
        );
        assert_eq!(
            pressure_sweep(
                Forgetting::Lfu { trigger_events: 9, min_freq: 2 },
                0
            ),
            Some(SweepKind::Lfu { min_freq: 2 })
        );
        assert_eq!(
            pressure_sweep(
                Forgetting::Decay { trigger_events: 9, factor: 0.5 },
                0
            ),
            Some(SweepKind::Decay { factor: 0.5 })
        );
    }

    #[test]
    fn spill_and_fault_in_rebuild_the_lane_exactly() {
        let cfg = RunConfig::default();
        let mut lanes: BTreeMap<u64, Lane> = BTreeMap::new();
        let mut spill = Some(SpillStore::new("", 0));
        let lane = lane_entry(&mut lanes, &cfg, 3).unwrap();
        lane.model.update(&Rating::new(1, 2, 5.0, 0));
        lane.model.update(&Rating::new(4, 7, 4.0, 1));
        lane.processed = 2;
        lane.hits = 1;
        lane.sweeps = 1;
        lane.evicted = 4;
        lane.watermark = Some(9);
        lane.clock.restore(2, 0, 1);
        lane.bytes = lane.model.state_bytes();
        let reference = encode_lane_frame(lane);
        let bytes_before = lane.bytes;
        let meta = SpillMeta {
            bytes: bytes_before,
            watermark: 10,
            sizes: lane.model.state_sizes(),
            processed: 2,
            hits: 1,
            evicted: 4,
            sweeps: 1,
        };
        spill.as_mut().unwrap().put(3, &reference, meta).unwrap();
        lanes.remove(&3);
        fault_in(&mut lanes, &mut spill, &cfg, 3).unwrap();
        assert!(spill.as_ref().unwrap().is_empty());
        let lane = lanes.get(&3).unwrap();
        // Frame-for-frame identical: model bytes (including the RNG
        // stream), watermark, counters, and clock all round-tripped.
        assert_eq!(encode_lane_frame(lane), reference);
        assert_eq!(lane.bytes, bytes_before);
        assert!(lane.checkpointed);
        assert_eq!(lane.since_ckpt, 0);
        // A second fault-in is a no-op (the lane is resident).
        fault_in(&mut lanes, &mut spill, &cfg, 3).unwrap();
        assert_eq!(encode_lane_frame(lanes.get(&3).unwrap()), reference);
    }

    #[test]
    fn chaos_policy_defaults_off() {
        let p = ChaosPolicy::from_config(&RunConfig::default());
        assert_eq!(p.kill_at_seq, None);
        assert!(!p.in_checkpoint);
        let p = ChaosPolicy::none();
        assert_eq!(p.kill_at_seq, None);
    }
}
