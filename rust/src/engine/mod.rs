//! Shared-nothing mini stream engine: bounded channels with backpressure
//! (the "network"), worker-thread harnesses (the "task slots"), and the
//! supervised worker actor that runs inside them (the worker loop, its
//! control protocol, and per-lane checkpointing). This is the substrate
//! the paper gets from Apache Flink 1.8.1, rebuilt from scratch
//! (DESIGN.md §2, S1).

// The actor module is crate-private runtime machinery (its protocol
// types are pub(crate)); only the live-metrics snapshot type is public,
// re-exported here and through `coordinator::cluster`.
pub(crate) mod actor;
pub mod channel;
pub mod worker;

pub use actor::WorkerSnapshot;
pub use channel::{
    bounded, bounded_with_signal, ChannelStats, Receiver, SendError, Sender,
    TrySendError, WakeSignal,
};
pub use worker::{spawn, WorkerHandle};
