//! Shared-nothing mini stream engine: bounded channels with backpressure
//! (the "network") and worker-thread harnesses (the "task slots"). This is
//! the substrate the paper gets from Apache Flink 1.8.1, rebuilt from
//! scratch (DESIGN.md §2, S1).

pub mod channel;
pub mod worker;

pub use channel::{bounded, ChannelStats, Receiver, SendError, Sender};
pub use worker::{spawn, WorkerHandle};
