//! Bounded MPSC channel with backpressure instrumentation — the "network"
//! of the shared-nothing engine (offline build has no crossbeam-channel;
//! DESIGN.md §3). A Mutex<VecDeque> + two Condvars: simple, correct, and
//! fast enough that the router never bottlenecks on it (see
//! rust/benches/pipeline.rs).
//!
//! Semantics:
//! * `send` blocks while the queue is at capacity (backpressure), fails
//!   once the receiver is gone.
//! * `recv` blocks while empty, returns `None` once all senders dropped
//!   and the queue drained (graceful end-of-stream).
//! * Per-channel counters: messages sent, nanoseconds blocked on
//!   backpressure, high-water mark.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

struct Shared<T> {
    queue: Mutex<Inner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
    metrics: ChannelMetrics,
}

struct Inner<T> {
    buf: VecDeque<T>,
    senders: usize,
    receiver_alive: bool,
}

/// Shared, lock-free-readable channel counters.
#[derive(Default)]
pub struct ChannelMetrics {
    pub sent: AtomicU64,
    pub blocked_ns: AtomicU64,
    pub high_water: AtomicU64,
}

/// Sending half (clonable).
pub struct Sender<T> {
    shared: Arc<Shared<T>>,
}

/// Receiving half (single consumer).
pub struct Receiver<T> {
    shared: Arc<Shared<T>>,
}

/// Error returned when the receiver has been dropped.
#[derive(Debug, PartialEq, Eq)]
pub struct SendError<T>(pub T);

impl<T> std::fmt::Display for SendError<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "receiver dropped")
    }
}

/// Create a bounded channel of the given capacity (>= 1).
pub fn bounded<T>(capacity: usize) -> (Sender<T>, Receiver<T>) {
    assert!(capacity >= 1, "channel capacity must be >= 1");
    let shared = Arc::new(Shared {
        queue: Mutex::new(Inner {
            buf: VecDeque::with_capacity(capacity),
            senders: 1,
            receiver_alive: true,
        }),
        not_full: Condvar::new(),
        not_empty: Condvar::new(),
        capacity,
        metrics: ChannelMetrics::default(),
    });
    (Sender { shared: shared.clone() }, Receiver { shared })
}

impl<T> Sender<T> {
    /// Blocking send with backpressure accounting.
    pub fn send(&self, value: T) -> Result<(), SendError<T>> {
        let mut inner = self.shared.queue.lock().unwrap();
        if !inner.receiver_alive {
            return Err(SendError(value));
        }
        if inner.buf.len() >= self.shared.capacity {
            let start = Instant::now();
            while inner.buf.len() >= self.shared.capacity {
                if !inner.receiver_alive {
                    return Err(SendError(value));
                }
                inner = self.shared.not_full.wait(inner).unwrap();
            }
            self.shared
                .metrics
                .blocked_ns
                .fetch_add(start.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
        inner.buf.push_back(value);
        let depth = inner.buf.len() as u64;
        self.shared.metrics.sent.fetch_add(1, Ordering::Relaxed);
        self.shared
            .metrics
            .high_water
            .fetch_max(depth, Ordering::Relaxed);
        drop(inner);
        self.shared.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking send; returns the value back if the queue is full.
    pub fn try_send(&self, value: T) -> Result<(), SendError<T>> {
        let mut inner = self.shared.queue.lock().unwrap();
        if !inner.receiver_alive || inner.buf.len() >= self.shared.capacity {
            return Err(SendError(value));
        }
        inner.buf.push_back(value);
        self.shared.metrics.sent.fetch_add(1, Ordering::Relaxed);
        drop(inner);
        self.shared.not_empty.notify_one();
        Ok(())
    }

    /// Snapshot of this channel's counters.
    pub fn metrics(&self) -> (u64, u64, u64) {
        let m = &self.shared.metrics;
        (
            m.sent.load(Ordering::Relaxed),
            m.blocked_ns.load(Ordering::Relaxed),
            m.high_water.load(Ordering::Relaxed),
        )
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.shared.queue.lock().unwrap().senders += 1;
        Self { shared: self.shared.clone() }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut inner = self.shared.queue.lock().unwrap();
        inner.senders -= 1;
        if inner.senders == 0 {
            drop(inner);
            // Wake the receiver so it can observe end-of-stream.
            self.shared.not_empty.notify_all();
        }
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; `None` = all senders gone and queue drained.
    pub fn recv(&self) -> Option<T> {
        let mut inner = self.shared.queue.lock().unwrap();
        loop {
            if let Some(v) = inner.buf.pop_front() {
                drop(inner);
                self.shared.not_full.notify_one();
                return Some(v);
            }
            if inner.senders == 0 {
                return None;
            }
            inner = self.shared.not_empty.wait(inner).unwrap();
        }
    }

    /// Gather up to `n` messages, blocking as needed; stops early once
    /// every sender is gone. This is the reply-channel primitive of the
    /// online query path: the coordinator fans a cloned [`Sender`] out to
    /// the `k` replicas of a user, drops its own handle, and `recv_n(k)`
    /// collects exactly the answers that can still arrive — a dead
    /// replica's queued message is destroyed with its channel, so the
    /// call degrades to fewer answers instead of deadlocking.
    pub fn recv_n(&self, n: usize) -> Vec<T> {
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            match self.recv() {
                Some(v) => out.push(v),
                None => break,
            }
        }
        out
    }

    /// Drain up to `max` queued messages without blocking (micro-batching
    /// on the worker side — see EXPERIMENTS.md §Perf).
    pub fn recv_batch(&self, out: &mut Vec<T>, max: usize) -> bool {
        let mut inner = self.shared.queue.lock().unwrap();
        loop {
            if !inner.buf.is_empty() {
                while out.len() < max {
                    match inner.buf.pop_front() {
                        Some(v) => out.push(v),
                        None => break,
                    }
                }
                drop(inner);
                self.shared.not_full.notify_all();
                return true;
            }
            if inner.senders == 0 {
                return false;
            }
            inner = self.shared.not_empty.wait(inner).unwrap();
        }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let mut inner = self.shared.queue.lock().unwrap();
        inner.receiver_alive = false;
        inner.buf.clear();
        drop(inner);
        self.shared.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn fifo_order_single_thread() {
        let (tx, rx) = bounded(16);
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        drop(tx);
        let got: Vec<i32> = std::iter::from_fn(|| rx.recv()).collect();
        assert_eq!(got, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn recv_none_after_all_senders_drop() {
        let (tx, rx) = bounded::<i32>(4);
        let tx2 = tx.clone();
        drop(tx);
        tx2.send(1).unwrap();
        drop(tx2);
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn send_fails_after_receiver_drop() {
        let (tx, rx) = bounded(4);
        drop(rx);
        assert_eq!(tx.send(7), Err(SendError(7)));
    }

    #[test]
    fn backpressure_blocks_until_recv() {
        let (tx, rx) = bounded(2);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert!(tx.try_send(3).is_err());
        let h = thread::spawn(move || {
            // This send must block until the receiver drains one slot.
            tx.send(3).unwrap();
            tx.metrics().1 // blocked_ns
        });
        thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(rx.recv(), Some(1));
        let blocked_ns = h.join().unwrap();
        assert!(blocked_ns > 0, "send should have recorded blocking time");
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), Some(3));
    }

    #[test]
    fn mpsc_delivers_everything_exactly_once() {
        let (tx, rx) = bounded(8);
        let producers = 4;
        let per = 1000;
        let mut handles = Vec::new();
        for p in 0..producers {
            let tx = tx.clone();
            handles.push(thread::spawn(move || {
                for i in 0..per {
                    tx.send(p * per + i).unwrap();
                }
            }));
        }
        drop(tx);
        let mut got: Vec<usize> = std::iter::from_fn(|| rx.recv()).collect();
        for h in handles {
            h.join().unwrap();
        }
        got.sort_unstable();
        assert_eq!(got, (0..producers * per).collect::<Vec<_>>());
    }

    #[test]
    fn recv_batch_drains_up_to_max() {
        let (tx, rx) = bounded(64);
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let mut buf = Vec::new();
        assert!(rx.recv_batch(&mut buf, 4));
        assert_eq!(buf, vec![0, 1, 2, 3]);
        buf.clear();
        assert!(rx.recv_batch(&mut buf, 100));
        assert_eq!(buf.len(), 6);
        drop(tx);
        buf.clear();
        assert!(!rx.recv_batch(&mut buf, 4));
    }

    #[test]
    fn recv_n_collects_replies_and_survives_dropped_senders() {
        // Fan-out/fan-in shape of the query path: 3 replicas answer, one
        // "dies" (its sender is dropped without sending).
        let (tx, rx) = bounded::<u32>(4);
        let replicas: Vec<Sender<u32>> = (0..4).map(|_| tx.clone()).collect();
        drop(tx);
        let mut handles = Vec::new();
        for (i, rtx) in replicas.into_iter().enumerate() {
            handles.push(thread::spawn(move || {
                if i != 2 {
                    rtx.send(i as u32).unwrap();
                }
                // replica 2 drops its sender silently
            }));
        }
        let mut got = rx.recv_n(4);
        for h in handles {
            h.join().unwrap();
        }
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 3], "3 answers, no deadlock on the 4th");
    }

    #[test]
    fn high_water_mark_tracks_depth() {
        let (tx, rx) = bounded(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        assert_eq!(tx.metrics().2, 5);
        let _ = rx.recv();
    }
}
