//! Bounded MPSC channel with backpressure instrumentation — the "network"
//! of the shared-nothing engine (offline build has no crossbeam-channel;
//! DESIGN.md §3). A Mutex<VecDeque> + two Condvars: simple, correct, and
//! fast enough that the router never bottlenecks on it (see
//! rust/benches/pipeline.rs).
//!
//! Semantics:
//! * `send` blocks while the queue is at capacity (backpressure), fails
//!   once the receiver is gone.
//! * `send_many` moves a whole batch under one lock acquisition and one
//!   consumer wakeup per capacity window — the micro-batched data plane.
//!   FIFO order and the capacity bound are preserved exactly: a batch
//!   larger than the remaining capacity wakes the consumer and waits for
//!   space, it never overfills the queue.
//! * `recv` blocks while empty, returns `None` once all senders dropped
//!   and the queue drained (graceful end-of-stream).
//! * `recv_many` hands the consumer everything queued (up to `max`) in
//!   one critical section; `try_drain` is its non-blocking sibling.
//! * Per-channel counters ([`ChannelStats`]): messages/batches sent and
//!   received, nanoseconds blocked on send-side backpressure *and* on
//!   receive-side waiting, high-water mark.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

struct Shared<T> {
    queue: Mutex<Inner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
    metrics: ChannelMetrics,
    /// Optional cross-channel wakeup: notified on every enqueue and on
    /// either half's last drop, so one consumer can sleep on a single
    /// [`WakeSignal`] shared by several channels (the actor's event FIFO
    /// plus its query lane) instead of blocking inside one of them.
    signal: Option<WakeSignal>,
}

/// A shared wakeup latch for consumers draining *several* channels.
///
/// The classic blocking `recv_many` parks inside one channel's condvar,
/// which is wrong for a consumer with two inputs: a message on the other
/// channel would not wake it. A `WakeSignal` is a monotonically
/// increasing epoch plus a condvar; every channel built over it via
/// [`bounded_with_signal`] bumps the epoch on enqueue and teardown. The
/// consumer's loop is lost-wakeup-free by construction:
///
/// ```text
/// let seen = signal.epoch();       // BEFORE draining
/// drain channel A; drain channel B;
/// if nothing arrived { signal.wait_past(seen, timeout); }
/// ```
///
/// Any enqueue after `epoch()` was read bumps the epoch, so `wait_past`
/// returns immediately instead of sleeping through it.
pub struct WakeSignal {
    inner: Arc<(Mutex<u64>, Condvar)>,
}

impl Clone for WakeSignal {
    fn clone(&self) -> Self {
        Self { inner: self.inner.clone() }
    }
}

impl Default for WakeSignal {
    fn default() -> Self {
        Self::new()
    }
}

impl WakeSignal {
    /// A fresh signal at epoch 0.
    pub fn new() -> Self {
        Self { inner: Arc::new((Mutex::new(0), Condvar::new())) }
    }

    /// Current epoch. Read it *before* draining the attached channels.
    pub fn epoch(&self) -> u64 {
        *self.inner.0.lock().unwrap()
    }

    /// Bump the epoch and wake every waiter.
    pub fn notify(&self) {
        let mut epoch = self.inner.0.lock().unwrap();
        *epoch += 1;
        self.inner.1.notify_all();
    }

    /// Sleep until the epoch passes `seen` or `timeout` elapses
    /// (whichever first, robust against spurious wakeups); returns the
    /// epoch at exit.
    pub fn wait_past(&self, seen: u64, timeout: Duration) -> u64 {
        let deadline = Instant::now() + timeout;
        let mut epoch = self.inner.0.lock().unwrap();
        while *epoch <= seen {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, _) = self
                .inner
                .1
                .wait_timeout(epoch, deadline - now)
                .unwrap();
            epoch = guard;
        }
        *epoch
    }
}

struct Inner<T> {
    buf: VecDeque<T>,
    senders: usize,
    receiver_alive: bool,
}

/// Shared, lock-free-readable channel counters.
#[derive(Default)]
pub struct ChannelMetrics {
    /// Messages enqueued.
    pub sent: AtomicU64,
    /// Send operations (`send` counts as a batch of 1); `sent /
    /// send_batches` is the mean send batch size.
    pub send_batches: AtomicU64,
    /// Nanoseconds senders spent blocked on backpressure.
    pub blocked_ns: AtomicU64,
    /// Nanoseconds the receiver spent waiting for messages.
    pub recv_blocked_ns: AtomicU64,
    /// Messages dequeued.
    pub received: AtomicU64,
    /// Receive operations (`recv` counts as a batch of 1).
    pub recv_batches: AtomicU64,
    /// Deepest queue observed.
    pub high_water: AtomicU64,
}

/// Moment-in-time snapshot of a channel's counters, readable from either
/// half (the coordinator reads worker-receiver wait time through its
/// retained [`Sender`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ChannelStats {
    /// Messages enqueued.
    pub sent: u64,
    /// Send operations (`send` counts as a batch of 1).
    pub send_batches: u64,
    /// Nanoseconds senders spent blocked on backpressure.
    pub blocked_ns: u64,
    /// Nanoseconds the receiver spent waiting for messages.
    pub recv_blocked_ns: u64,
    /// Messages dequeued.
    pub received: u64,
    /// Receive operations (`recv` counts as a batch of 1).
    pub recv_batches: u64,
    /// Deepest queue observed.
    pub high_water: u64,
}

impl ChannelStats {
    /// Mean messages moved per send operation (1.0 = unbatched).
    pub fn mean_send_batch(&self) -> f64 {
        self.sent as f64 / self.send_batches.max(1) as f64
    }

    /// Mean messages moved per receive operation (drain amortization).
    pub fn mean_recv_batch(&self) -> f64 {
        self.received as f64 / self.recv_batches.max(1) as f64
    }

    /// Accumulate another snapshot into this one — used to aggregate
    /// across a cluster's per-worker channels and, under rescaling,
    /// across worker *generations* (retired channels' counters would
    /// otherwise vanish from the final report).
    pub fn absorb(&mut self, other: &ChannelStats) {
        self.sent += other.sent;
        self.send_batches += other.send_batches;
        self.blocked_ns += other.blocked_ns;
        self.recv_blocked_ns += other.recv_blocked_ns;
        self.received += other.received;
        self.recv_batches += other.recv_batches;
        self.high_water = self.high_water.max(other.high_water);
    }
}

impl ChannelMetrics {
    fn snapshot(&self) -> ChannelStats {
        ChannelStats {
            sent: self.sent.load(Ordering::Relaxed),
            send_batches: self.send_batches.load(Ordering::Relaxed),
            blocked_ns: self.blocked_ns.load(Ordering::Relaxed),
            recv_blocked_ns: self.recv_blocked_ns.load(Ordering::Relaxed),
            received: self.received.load(Ordering::Relaxed),
            recv_batches: self.recv_batches.load(Ordering::Relaxed),
            high_water: self.high_water.load(Ordering::Relaxed),
        }
    }
}

/// Sending half (clonable).
pub struct Sender<T> {
    shared: Arc<Shared<T>>,
}

/// Receiving half (single consumer).
pub struct Receiver<T> {
    shared: Arc<Shared<T>>,
}

/// Error returned when the receiver has been dropped. Carries the value
/// for single sends; bulk sends drop the unsent tail (the consumer is
/// gone, there is nowhere for it to go).
#[derive(Debug, PartialEq, Eq)]
pub struct SendError<T>(pub T);

impl<T> std::fmt::Display for SendError<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "receiver dropped")
    }
}

/// Why a [`Sender::try_send`] was refused. The two cases demand opposite
/// reactions on the serving path: `Full` is transient backpressure (shed
/// the query, count it), `Closed` is a dead worker (heal and retry).
#[derive(Debug, PartialEq, Eq)]
pub enum TrySendError<T> {
    /// The queue is at capacity; the value is handed back.
    Full(T),
    /// The receiver is gone; the value is handed back.
    Closed(T),
}

impl<T> TrySendError<T> {
    /// The refused value, regardless of the reason.
    pub fn into_inner(self) -> T {
        match self {
            TrySendError::Full(v) | TrySendError::Closed(v) => v,
        }
    }
}

/// Create a bounded channel of the given capacity (>= 1).
pub fn bounded<T>(capacity: usize) -> (Sender<T>, Receiver<T>) {
    bounded_inner(capacity, None)
}

/// Like [`bounded`], but every enqueue (and either half's teardown) also
/// notifies `signal` — the primitive that lets one consumer drain
/// several channels while sleeping on a single latch. See [`WakeSignal`].
pub fn bounded_with_signal<T>(
    capacity: usize,
    signal: &WakeSignal,
) -> (Sender<T>, Receiver<T>) {
    bounded_inner(capacity, Some(signal.clone()))
}

fn bounded_inner<T>(
    capacity: usize,
    signal: Option<WakeSignal>,
) -> (Sender<T>, Receiver<T>) {
    assert!(capacity >= 1, "channel capacity must be >= 1");
    let shared = Arc::new(Shared {
        queue: Mutex::new(Inner {
            buf: VecDeque::with_capacity(capacity),
            senders: 1,
            receiver_alive: true,
        }),
        not_full: Condvar::new(),
        not_empty: Condvar::new(),
        capacity,
        metrics: ChannelMetrics::default(),
        signal,
    });
    (Sender { shared: shared.clone() }, Receiver { shared })
}

impl<T> Shared<T> {
    #[inline]
    fn wake(&self) {
        if let Some(signal) = &self.signal {
            signal.notify();
        }
    }
}

impl<T> Sender<T> {
    /// Blocking send with backpressure accounting.
    pub fn send(&self, value: T) -> Result<(), SendError<T>> {
        let mut inner = self.shared.queue.lock().unwrap();
        if !inner.receiver_alive {
            return Err(SendError(value));
        }
        if inner.buf.len() >= self.shared.capacity {
            let start = Instant::now();
            while inner.buf.len() >= self.shared.capacity {
                if !inner.receiver_alive {
                    return Err(SendError(value));
                }
                inner = self.shared.not_full.wait(inner).unwrap();
            }
            self.shared
                .metrics
                .blocked_ns
                .fetch_add(start.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
        inner.buf.push_back(value);
        let depth = inner.buf.len() as u64;
        drop(inner);
        let m = &self.shared.metrics;
        m.sent.fetch_add(1, Ordering::Relaxed);
        m.send_batches.fetch_add(1, Ordering::Relaxed);
        m.high_water.fetch_max(depth, Ordering::Relaxed);
        self.shared.not_empty.notify_one();
        self.shared.wake();
        Ok(())
    }

    /// Bulk send: move every element of `batch` into the queue, draining
    /// the caller's buffer (its capacity is kept for reuse).
    ///
    /// Cost model — the point of the batched data plane: one mutex
    /// acquisition and one consumer wakeup per *capacity window* instead
    /// of per message. The capacity bound still holds exactly: when the
    /// queue fills mid-batch the consumer is woken, the lock is released
    /// (condvar wait), and the remainder goes out once space frees up, so
    /// a batch larger than `capacity` degrades gracefully instead of
    /// deadlocking or overfilling.
    ///
    /// On a dead receiver the unsent tail is dropped and `Err` returned;
    /// FIFO order of everything that was sent is preserved.
    pub fn send_many(&self, batch: &mut Vec<T>) -> Result<(), SendError<()>> {
        if batch.is_empty() {
            return Ok(());
        }
        let mut pushed = 0u64;
        let mut max_depth = 0u64;
        let mut blocked_ns = 0u64;
        let mut iter = batch.drain(..);
        let mut inner = self.shared.queue.lock().unwrap();
        let result = 'outer: loop {
            if !inner.receiver_alive {
                break 'outer Err(SendError(()));
            }
            while inner.buf.len() < self.shared.capacity {
                match iter.next() {
                    Some(v) => {
                        inner.buf.push_back(v);
                        pushed += 1;
                    }
                    None => {
                        max_depth = max_depth.max(inner.buf.len() as u64);
                        break 'outer Ok(());
                    }
                }
            }
            // Queue full with items remaining: hand the window to the
            // consumer (it may be asleep — wake it while we wait). A
            // signal-sleeping consumer must be woken too, or it would
            // doze out its timeout while we hold the window.
            max_depth = max_depth.max(inner.buf.len() as u64);
            let start = Instant::now();
            self.shared.not_empty.notify_one();
            self.shared.wake();
            inner = self.shared.not_full.wait(inner).unwrap();
            blocked_ns += start.elapsed().as_nanos() as u64;
        };
        drop(inner);
        drop(iter);
        let m = &self.shared.metrics;
        if pushed > 0 {
            m.sent.fetch_add(pushed, Ordering::Relaxed);
            m.send_batches.fetch_add(1, Ordering::Relaxed);
            m.high_water.fetch_max(max_depth, Ordering::Relaxed);
            self.shared.not_empty.notify_one();
            self.shared.wake();
        }
        if blocked_ns > 0 {
            m.blocked_ns.fetch_add(blocked_ns, Ordering::Relaxed);
        }
        result
    }

    /// Non-blocking send; hands the value back with the refusal reason —
    /// [`TrySendError::Full`] (transient backpressure) vs
    /// [`TrySendError::Closed`] (the receiver is gone).
    pub fn try_send(&self, value: T) -> Result<(), TrySendError<T>> {
        let mut inner = self.shared.queue.lock().unwrap();
        if !inner.receiver_alive {
            return Err(TrySendError::Closed(value));
        }
        if inner.buf.len() >= self.shared.capacity {
            return Err(TrySendError::Full(value));
        }
        inner.buf.push_back(value);
        drop(inner);
        let m = &self.shared.metrics;
        m.sent.fetch_add(1, Ordering::Relaxed);
        m.send_batches.fetch_add(1, Ordering::Relaxed);
        self.shared.not_empty.notify_one();
        self.shared.wake();
        Ok(())
    }

    /// Snapshot of this channel's counters (both halves).
    pub fn metrics(&self) -> ChannelStats {
        self.shared.metrics.snapshot()
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.shared.queue.lock().unwrap().senders += 1;
        Self { shared: self.shared.clone() }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut inner = self.shared.queue.lock().unwrap();
        inner.senders -= 1;
        if inner.senders == 0 {
            drop(inner);
            // Wake the receiver so it can observe end-of-stream.
            self.shared.not_empty.notify_all();
            self.shared.wake();
        }
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; `None` = all senders gone and queue drained.
    pub fn recv(&self) -> Option<T> {
        let mut inner = self.shared.queue.lock().unwrap();
        loop {
            if let Some(v) = inner.buf.pop_front() {
                drop(inner);
                let m = &self.shared.metrics;
                m.received.fetch_add(1, Ordering::Relaxed);
                m.recv_batches.fetch_add(1, Ordering::Relaxed);
                self.shared.not_full.notify_one();
                return Some(v);
            }
            if inner.senders == 0 {
                return None;
            }
            let start = Instant::now();
            inner = self.shared.not_empty.wait(inner).unwrap();
            self.shared
                .metrics
                .recv_blocked_ns
                .fetch_add(start.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
    }

    /// Gather up to `n` messages, blocking as needed; stops early once
    /// every sender is gone. This is the reply-channel primitive of the
    /// online query path: the coordinator fans a cloned [`Sender`] out to
    /// the `k` replicas of a user, drops its own handle, and `recv_n(k)`
    /// collects exactly the answers that can still arrive — a dead
    /// replica's queued message is destroyed with its channel, so the
    /// call degrades to fewer answers instead of deadlocking.
    pub fn recv_n(&self, n: usize) -> Vec<T> {
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            match self.recv() {
                Some(v) => out.push(v),
                None => break,
            }
        }
        out
    }

    /// Draining receive: block until at least one message is queued, then
    /// move everything queued (up to `max`) into `out` in one critical
    /// section. Returns `false` once all senders are gone and the queue
    /// is empty (end-of-stream). This is the worker side of the
    /// micro-batched data plane: one wakeup, one lock transition, a whole
    /// window of work.
    pub fn recv_many(&self, out: &mut Vec<T>, max: usize) -> bool {
        let mut inner = self.shared.queue.lock().unwrap();
        loop {
            if !inner.buf.is_empty() {
                let mut taken = 0u64;
                while out.len() < max {
                    match inner.buf.pop_front() {
                        Some(v) => {
                            out.push(v);
                            taken += 1;
                        }
                        None => break,
                    }
                }
                drop(inner);
                let m = &self.shared.metrics;
                m.received.fetch_add(taken, Ordering::Relaxed);
                m.recv_batches.fetch_add(1, Ordering::Relaxed);
                self.shared.not_full.notify_all();
                return true;
            }
            if inner.senders == 0 {
                return false;
            }
            let start = Instant::now();
            inner = self.shared.not_empty.wait(inner).unwrap();
            self.shared
                .metrics
                .recv_blocked_ns
                .fetch_add(start.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
    }

    /// Deadline-bounded drain: like [`Receiver::recv_many`], but gives up
    /// waiting at `deadline`. Returns `true` while any sender is still
    /// alive — with `out` left empty if the deadline passed before a
    /// message arrived — and `false` once every sender is gone and the
    /// queue is drained (end-of-stream, exactly like `recv_many`).
    ///
    /// This is the waiting primitive of the remote-worker proxy's
    /// liveness machinery: the proxy's single writer thread must both
    /// consume the coordinator's FIFO *and* wake on a heartbeat cadence
    /// to ping its peer and enforce RPC deadlines, which a pure blocking
    /// `recv_many` cannot do.
    pub fn recv_many_deadline(
        &self,
        out: &mut Vec<T>,
        max: usize,
        deadline: Instant,
    ) -> bool {
        let mut inner = self.shared.queue.lock().unwrap();
        loop {
            if !inner.buf.is_empty() {
                let mut taken = 0u64;
                while out.len() < max {
                    match inner.buf.pop_front() {
                        Some(v) => {
                            out.push(v);
                            taken += 1;
                        }
                        None => break,
                    }
                }
                drop(inner);
                let m = &self.shared.metrics;
                m.received.fetch_add(taken, Ordering::Relaxed);
                m.recv_batches.fetch_add(1, Ordering::Relaxed);
                self.shared.not_full.notify_all();
                return true;
            }
            if inner.senders == 0 {
                return false;
            }
            let now = Instant::now();
            if now >= deadline {
                return true;
            }
            let start = now;
            let (guard, _timeout) = self
                .shared
                .not_empty
                .wait_timeout(inner, deadline - now)
                .unwrap();
            inner = guard;
            self.shared
                .metrics
                .recv_blocked_ns
                .fetch_add(start.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
    }

    /// Non-blocking drain: move everything currently queued into `out`.
    /// Returns how many messages were taken (0 = queue was empty; says
    /// nothing about sender liveness).
    pub fn try_drain(&self, out: &mut Vec<T>) -> usize {
        let mut inner = self.shared.queue.lock().unwrap();
        if inner.buf.is_empty() {
            return 0;
        }
        let taken = inner.buf.len();
        out.extend(inner.buf.drain(..));
        drop(inner);
        let m = &self.shared.metrics;
        m.received.fetch_add(taken as u64, Ordering::Relaxed);
        m.recv_batches.fetch_add(1, Ordering::Relaxed);
        self.shared.not_full.notify_all();
        taken
    }

    /// True once every sender is gone *and* the queue is drained — the
    /// non-blocking end-of-stream probe for signal-driven consumers
    /// (equivalent to `recv_many` returning `false`). Monotonic: once
    /// true it stays true, since no sender can be cloned back into
    /// existence.
    pub fn is_ended(&self) -> bool {
        let inner = self.shared.queue.lock().unwrap();
        inner.senders == 0 && inner.buf.is_empty()
    }

    /// Fold externally measured wait time into this channel's
    /// `recv_blocked_ns`. A signal-driven consumer waits on a
    /// [`WakeSignal`] shared across channels instead of blocking inside
    /// `recv_many`; attributing that wait here keeps the
    /// send-vs-receive timing split live and monotone for such
    /// consumers.
    pub fn record_wait(&self, ns: u64) {
        self.shared
            .metrics
            .recv_blocked_ns
            .fetch_add(ns, Ordering::Relaxed);
    }

    /// Snapshot of this channel's counters (both halves).
    pub fn metrics(&self) -> ChannelStats {
        self.shared.metrics.snapshot()
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let mut inner = self.shared.queue.lock().unwrap();
        inner.receiver_alive = false;
        inner.buf.clear();
        drop(inner);
        self.shared.not_full.notify_all();
        self.shared.wake();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn fifo_order_single_thread() {
        let (tx, rx) = bounded(16);
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        drop(tx);
        let got: Vec<i32> = std::iter::from_fn(|| rx.recv()).collect();
        assert_eq!(got, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn recv_none_after_all_senders_drop() {
        let (tx, rx) = bounded::<i32>(4);
        let tx2 = tx.clone();
        drop(tx);
        tx2.send(1).unwrap();
        drop(tx2);
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn send_fails_after_receiver_drop() {
        let (tx, rx) = bounded(4);
        drop(rx);
        assert_eq!(tx.send(7), Err(SendError(7)));
    }

    #[test]
    fn backpressure_blocks_until_recv() {
        let (tx, rx) = bounded(2);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert!(tx.try_send(3).is_err());
        let h = thread::spawn(move || {
            // This send must block until the receiver drains one slot.
            tx.send(3).unwrap();
            tx.metrics().blocked_ns
        });
        thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(rx.recv(), Some(1));
        let blocked_ns = h.join().unwrap();
        assert!(blocked_ns > 0, "send should have recorded blocking time");
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), Some(3));
    }

    #[test]
    fn mpsc_delivers_everything_exactly_once() {
        let (tx, rx) = bounded(8);
        let producers = 4;
        let per = 1000;
        let mut handles = Vec::new();
        for p in 0..producers {
            let tx = tx.clone();
            handles.push(thread::spawn(move || {
                for i in 0..per {
                    tx.send(p * per + i).unwrap();
                }
            }));
        }
        drop(tx);
        let mut got: Vec<usize> = std::iter::from_fn(|| rx.recv()).collect();
        for h in handles {
            h.join().unwrap();
        }
        got.sort_unstable();
        assert_eq!(got, (0..producers * per).collect::<Vec<_>>());
    }

    #[test]
    fn send_many_preserves_fifo_and_drains_caller() {
        let (tx, rx) = bounded(64);
        let mut batch: Vec<i32> = (0..10).collect();
        tx.send_many(&mut batch).unwrap();
        assert!(batch.is_empty(), "batch must be drained into the queue");
        assert!(batch.capacity() >= 10, "caller buffer capacity kept");
        drop(tx);
        let got: Vec<i32> = std::iter::from_fn(|| rx.recv()).collect();
        assert_eq!(got, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn send_many_empty_batch_is_free() {
        let (tx, _rx) = bounded::<i32>(4);
        tx.send_many(&mut Vec::new()).unwrap();
        let st = tx.metrics();
        assert_eq!(st.sent, 0);
        assert_eq!(st.send_batches, 0);
    }

    #[test]
    fn send_many_larger_than_capacity_backpressures() {
        // A 100-message batch through a 4-slot channel: the consumer must
        // be woken mid-batch, and every message must arrive in order.
        let (tx, rx) = bounded(4);
        let consumer = thread::spawn(move || {
            let mut got = Vec::new();
            let mut buf = Vec::new();
            while rx.recv_many(&mut buf, usize::MAX) {
                got.append(&mut buf);
            }
            (got, rx.metrics())
        });
        let mut batch: Vec<u32> = (0..100).collect();
        tx.send_many(&mut batch).unwrap();
        let blocked = tx.metrics().blocked_ns;
        assert!(blocked > 0, "a 100-msg batch must hit the capacity bound");
        drop(tx);
        let (got, stats) = consumer.join().unwrap();
        assert_eq!(got, (0..100).collect::<Vec<_>>());
        assert_eq!(stats.sent, 100);
        assert_eq!(stats.send_batches, 1, "one bulk op, many windows");
        assert_eq!(stats.received, 100);
        assert!(stats.mean_send_batch() > 99.0);
    }

    #[test]
    fn send_many_fails_after_receiver_drop() {
        let (tx, rx) = bounded(4);
        drop(rx);
        let mut batch = vec![1, 2, 3];
        assert_eq!(tx.send_many(&mut batch), Err(SendError(())));
        assert!(batch.is_empty(), "unsent tail is dropped, not returned");
    }

    #[test]
    fn recv_many_drains_up_to_max() {
        let (tx, rx) = bounded(64);
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let mut buf = Vec::new();
        assert!(rx.recv_many(&mut buf, 4));
        assert_eq!(buf, vec![0, 1, 2, 3]);
        buf.clear();
        assert!(rx.recv_many(&mut buf, 100));
        assert_eq!(buf.len(), 6);
        drop(tx);
        buf.clear();
        assert!(!rx.recv_many(&mut buf, 4));
    }

    #[test]
    fn recv_many_deadline_times_out_alive_and_empty() {
        let (tx, rx) = bounded::<u32>(4);
        let mut buf = Vec::new();
        let t0 = Instant::now();
        let deadline = t0 + std::time::Duration::from_millis(30);
        assert!(
            rx.recv_many_deadline(&mut buf, usize::MAX, deadline),
            "senders alive: a timeout is not end-of-stream"
        );
        assert!(buf.is_empty(), "nothing was sent");
        assert!(t0.elapsed() >= std::time::Duration::from_millis(25));
        // Messages already queued return immediately, before any wait.
        tx.send(5).unwrap();
        let deadline = Instant::now() + std::time::Duration::from_secs(60);
        assert!(rx.recv_many_deadline(&mut buf, usize::MAX, deadline));
        assert_eq!(buf, vec![5]);
        // End-of-stream is still reported as `false`, like recv_many.
        drop(tx);
        buf.clear();
        let deadline = Instant::now() + std::time::Duration::from_secs(60);
        assert!(!rx.recv_many_deadline(&mut buf, usize::MAX, deadline));
        assert!(buf.is_empty());
    }

    #[test]
    fn recv_many_deadline_wakes_on_send() {
        let (tx, rx) = bounded::<u32>(4);
        let h = thread::spawn(move || {
            let mut buf = Vec::new();
            let deadline = Instant::now() + std::time::Duration::from_secs(30);
            let alive = rx.recv_many_deadline(&mut buf, usize::MAX, deadline);
            (alive, buf)
        });
        thread::sleep(std::time::Duration::from_millis(20));
        tx.send(11).unwrap();
        let (alive, buf) = h.join().unwrap();
        assert!(alive);
        assert_eq!(buf, vec![11], "a send interrupts the timed wait");
    }

    #[test]
    fn try_drain_takes_everything_without_blocking() {
        let (tx, rx) = bounded(64);
        let mut buf = Vec::new();
        assert_eq!(rx.try_drain(&mut buf), 0, "empty queue, no block");
        for i in 0..7 {
            tx.send(i).unwrap();
        }
        assert_eq!(rx.try_drain(&mut buf), 7);
        assert_eq!(buf, (0..7).collect::<Vec<_>>());
        assert_eq!(rx.try_drain(&mut buf), 0);
    }

    #[test]
    fn recv_wait_time_is_recorded() {
        let (tx, rx) = bounded::<u32>(4);
        let h = thread::spawn(move || {
            let v = rx.recv();
            (v, rx.metrics().recv_blocked_ns)
        });
        thread::sleep(std::time::Duration::from_millis(20));
        tx.send(9).unwrap();
        let (v, waited_ns) = h.join().unwrap();
        assert_eq!(v, Some(9));
        assert!(waited_ns > 0, "receiver wait must be accounted");
    }

    #[test]
    fn batch_counters_expose_amortization() {
        let (tx, rx) = bounded(256);
        let mut batch: Vec<u32> = (0..64).collect();
        tx.send_many(&mut batch).unwrap();
        tx.send(64).unwrap();
        let mut buf = Vec::new();
        assert!(rx.recv_many(&mut buf, usize::MAX));
        assert_eq!(buf.len(), 65);
        let st = tx.metrics();
        assert_eq!(st.sent, 65);
        assert_eq!(st.send_batches, 2);
        assert!((st.mean_send_batch() - 32.5).abs() < 1e-9);
        assert_eq!(st.received, 65);
        assert_eq!(st.recv_batches, 1);
        assert!((st.mean_recv_batch() - 65.0).abs() < 1e-9);
    }

    #[test]
    fn recv_n_collects_replies_and_survives_dropped_senders() {
        // Fan-out/fan-in shape of the query path: 3 replicas answer, one
        // "dies" (its sender is dropped without sending).
        let (tx, rx) = bounded::<u32>(4);
        let replicas: Vec<Sender<u32>> = (0..4).map(|_| tx.clone()).collect();
        drop(tx);
        let mut handles = Vec::new();
        for (i, rtx) in replicas.into_iter().enumerate() {
            handles.push(thread::spawn(move || {
                if i != 2 {
                    rtx.send(i as u32).unwrap();
                }
                // replica 2 drops its sender silently
            }));
        }
        let mut got = rx.recv_n(4);
        for h in handles {
            h.join().unwrap();
        }
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 3], "3 answers, no deadlock on the 4th");
    }

    #[test]
    fn high_water_mark_tracks_depth() {
        let (tx, rx) = bounded(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        assert_eq!(tx.metrics().high_water, 5);
        let _ = rx.recv();
    }

    #[test]
    fn try_send_distinguishes_full_from_closed() {
        let (tx, rx) = bounded::<u32>(1);
        tx.try_send(1).unwrap();
        assert_eq!(tx.try_send(2), Err(TrySendError::Full(2)));
        assert_eq!(rx.recv(), Some(1));
        drop(rx);
        assert_eq!(tx.try_send(3), Err(TrySendError::Closed(3)));
        assert_eq!(TrySendError::Full(9).into_inner(), 9);
    }

    #[test]
    fn is_ended_is_monotone_end_of_stream() {
        let (tx, rx) = bounded::<u32>(4);
        assert!(!rx.is_ended(), "sender alive");
        tx.send(1).unwrap();
        drop(tx);
        assert!(!rx.is_ended(), "queued message still pending");
        assert_eq!(rx.recv(), Some(1));
        assert!(rx.is_ended());
        assert!(rx.is_ended(), "stays ended");
    }

    #[test]
    fn wake_signal_wakes_on_send_across_two_channels() {
        // The two-input consumer shape: sleep on ONE signal, get woken
        // by a message on EITHER channel.
        let signal = WakeSignal::new();
        let (tx_a, rx_a) = bounded_with_signal::<u32>(4, &signal);
        let (tx_b, rx_b) = bounded_with_signal::<u32>(4, &signal);
        let sig = signal.clone();
        let h = thread::spawn(move || {
            let mut got = Vec::new();
            loop {
                let seen = sig.epoch();
                let mut buf = Vec::new();
                rx_a.try_drain(&mut buf);
                rx_b.try_drain(&mut buf);
                got.extend(buf);
                if got.len() == 2 {
                    return got;
                }
                sig.wait_past(seen, std::time::Duration::from_secs(5));
            }
        });
        thread::sleep(std::time::Duration::from_millis(10));
        tx_a.send(1).unwrap();
        thread::sleep(std::time::Duration::from_millis(10));
        tx_b.send(2).unwrap();
        let mut got = h.join().unwrap();
        got.sort_unstable();
        assert_eq!(got, vec![1, 2]);
    }

    #[test]
    fn wake_signal_missed_wakeup_is_impossible_with_epoch_capture() {
        // Epoch captured BEFORE the drain: a send racing between drain
        // and wait bumps the epoch, so wait_past returns immediately.
        let signal = WakeSignal::new();
        let (tx, rx) = bounded_with_signal::<u32>(4, &signal);
        let seen = signal.epoch();
        tx.send(7).unwrap(); // "races" in after the epoch read
        let mut buf = Vec::new();
        rx.try_drain(&mut buf); // drained it, but epoch already moved
        let t0 = Instant::now();
        signal.wait_past(seen, std::time::Duration::from_secs(5));
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(1),
            "wait_past must not sleep through a post-epoch send"
        );
    }

    #[test]
    fn wake_signal_fires_on_sender_teardown() {
        let signal = WakeSignal::new();
        let (tx, rx) = bounded_with_signal::<u32>(4, &signal);
        let seen = signal.epoch();
        let h = thread::spawn(move || {
            thread::sleep(std::time::Duration::from_millis(10));
            drop(tx);
        });
        signal.wait_past(seen, std::time::Duration::from_secs(5));
        assert!(rx.is_ended(), "teardown woke the waiter into end-of-stream");
        h.join().unwrap();
    }

    #[test]
    fn record_wait_folds_into_recv_blocked() {
        let (_tx, rx) = bounded::<u32>(4);
        let before = rx.metrics().recv_blocked_ns;
        rx.record_wait(1234);
        assert_eq!(rx.metrics().recv_blocked_ns, before + 1234);
    }
}
